"""Batched serving example: wave-batched decode with KV caches.

    PYTHONPATH=src python examples/serve_batched.py --arch qwen2_5_3b
"""
import argparse
import os
import sys
import time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import smoke_experiment
from repro.models import transformer
from repro.serving.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_5_3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    exp = smoke_experiment(args.arch)
    m = exp.model
    print(f"serving {m.name} (reduced config, {m.param_count()/1e3:.0f}K params)")
    params = transformer.init_lm(jax.random.PRNGKey(0), m, exp.e2)
    engine = ServeEngine(exp, params, batch_slots=args.slots, max_len=64)
    rng = np.random.RandomState(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        engine.submit(Request(rid=i,
                              prompt=rng.randint(0, m.vocab_size, size=6),
                              max_new=args.max_new))
    done = engine.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    print(f"{len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s on CPU)")
    for r in done:
        print(f"  rid={r.rid}: {r.out}")


if __name__ == "__main__":
    main()
