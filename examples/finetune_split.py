"""Paper §4.5 proof-of-concept: adapting a pre-trained model.

Pre-train on the first half of the task distribution, then compare two
energy-efficient fine-tuning options on the second half:
  (1) last-layer-only fine-tuning with standard training,
  (2) all-layers fine-tuning with E²-Train.
The paper finds (2) wins on both accuracy and energy; we reproduce the
mechanism on the synthetic task (two Markov chains = two "domains").

    PYTHONPATH=src python examples/finetune_split.py
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import (E2TrainConfig, Experiment, ModelConfig,
                               PSGConfig, SLUConfig, SMDConfig, TrainConfig)
from repro.data.synthetic import MarkovLMTask, make_lm_batch
from repro.training.train_step import init_train_state
from repro.training.trainer import Trainer

MODEL = ModelConfig(name="ft", family="dense", num_layers=4, d_model=64,
                    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                    dtype="float32")
TASK_A = MarkovLMTask(vocab=64, seed=1234)
TASK_B = MarkovLMTask(vocab=64, seed=5678)    # the "second half"


def eval_loss(params, task, n=4):
    from repro.models import transformer as T
    tot = 0.0
    for i in range(n):
        b = make_lm_batch(task, 777, i, 0, 16, 32)
        loss, _ = T.lm_loss(params, b, MODEL, remat="none")
        tot += float(loss)
    return tot / n


def main():
    # --- pre-train on domain A ---
    exp = Experiment(model=MODEL,
                     train=TrainConfig(global_batch=16, seq_len=32, lr=0.1,
                                       total_steps=80, schedule="constant"))
    mkA = lambda s, sh: make_lm_batch(TASK_A, 0, s, sh, 16, 32)
    state = init_train_state(jax.random.PRNGKey(0), exp)
    trA = Trainer(exp, state, mkA)
    trA.run(80)
    # the train step donates its input state; take the *final* params and
    # copy before seeding each fine-tune run (their steps donate too)
    base_params = jax.tree.map(lambda x: jnp.array(x, copy=True),
                               trA.state.params)
    print(f"pre-trained on A; loss on B before FT: "
          f"{eval_loss(base_params, TASK_B):.4f}")

    mkB = lambda s, sh: make_lm_batch(TASK_B, 1, s, sh, 16, 32)

    # --- option 1: last-FC-layer only (paper's baseline), standard SGD ---
    from repro.models import transformer as T
    from repro.optim.api import make_optimizer
    params1 = jax.tree.map(lambda x: jnp.array(x, copy=True), base_params)
    opt1 = make_optimizer(dataclasses.replace(exp.train, total_steps=60))
    opt_state1 = opt1.init(params1)

    @jax.jit
    def head_only_step(params, opt_state, batch, i):
        (l, _), g = jax.value_and_grad(
            lambda p: T.lm_loss(p, batch, MODEL, remat="none"),
            has_aux=True)(params)
        # freeze everything except the LM head (paper: "only the last FC")
        g = {k: (v if k == "head" else jax.tree.map(jnp.zeros_like, v))
             for k, v in g.items()}
        return *opt1.apply(params, g, opt_state, i), l

    for i in range(60):
        params1, opt_state1, _ = head_only_step(
            params1, opt_state1, mkB(i, 0), jnp.int32(i))
    l1 = eval_loss(params1, TASK_B)

    # --- option 2: all layers with E2-Train ---
    e2 = E2TrainConfig(smd=SMDConfig(True), slu=SLUConfig(True, alpha=1e-3),
                       psg=PSGConfig(True, swa=False))
    exp2 = exp.replace(e2=e2, train=dataclasses.replace(
        exp.train, optimizer="psg", lr=0.03, total_steps=240))
    st2 = init_train_state(jax.random.PRNGKey(2), exp2)
    # E2-Train adds the (fresh) SLU gate params; body comes from pre-training
    merged = dict(st2.params)
    for k, v in base_params.items():
        merged[k] = jax.tree.map(lambda x: jnp.array(x, copy=True), v)
    st2 = st2._replace(params=merged)
    tr2 = Trainer(exp2, st2, mkB)
    tr2.run(240)
    l2 = eval_loss(tr2.state.params, TASK_B)

    e1 = 60 * 1.0
    # per-executed-step factor from the run's measured telemetry (PSG
    # fallback tiles -> 45nm factor; SLU execution), via the ledger
    rep = tr2.energy_report(steps=240)
    factor = (rep.psg_factor_measured if rep.psg_factor_measured is not None
              else rep.psg_factor_assumed)
    if rep.slu.resolved() is not None:
        factor *= 1.0 - rep.slu.resolved()
    e2_cost = tr2.executed_steps * factor
    print(f"option 1 (standard FT):  loss on B = {l1:.4f}, "
          f"energy units = {e1:.0f}")
    print(f"option 2 (E2-Train FT):  loss on B = {l2:.4f}, "
          f"energy units = {e2_cost:.0f} "
          f"({1 - e2_cost/e1:.0%} less energy)")
    print("paper §4.5: E2-Train fine-tuning wins on accuracy AND energy"
          f" -> reproduced: {l2 <= l1 and e2_cost < e1}")


if __name__ == "__main__":
    main()
