"""End-to-end driver (deliverable b): train a ~100M-param LM for a few
hundred steps with E²-Train, checkpointing + resume + SMD straggler policy.

    PYTHONPATH=src python examples/train_e2e.py --steps 200
    PYTHONPATH=src python examples/train_e2e.py --steps 300 --resume

By default uses a ~100M-parameter llama-style config; --tiny shrinks it for
fast CI runs.
"""
import argparse
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core.config import (E2TrainConfig, Experiment, ModelConfig,
                               PSGConfig, SLUConfig, SMDConfig, TrainConfig)
from repro.data.synthetic import MarkovLMTask, make_lm_batch
from repro.ft.checkpoint import latest_step, restore_checkpoint
from repro.training.train_step import init_train_state
from repro.training.trainer import Trainer


def model_100m() -> ModelConfig:
    # ~109M params: 12L, d=768, 12H, kv 4, ff 2048, vocab 32k
    return ModelConfig(name="lm-100m", family="dense", num_layers=12,
                       d_model=768, num_heads=12, num_kv_heads=4,
                       d_ff=2048, vocab_size=32000)


def model_tiny() -> ModelConfig:
    return ModelConfig(name="lm-tiny", family="dense", num_layers=4,
                       d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                       vocab_size=512, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/e2train_ckpt")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    model = model_tiny() if args.tiny else model_100m()
    print(f"model {model.name}: {model.param_count()/1e6:.1f}M params")

    exp = Experiment(
        model=model,
        e2=E2TrainConfig(smd=SMDConfig(enabled=True, drop_prob=0.5),
                         slu=SLUConfig(enabled=True, alpha=1e-3),
                         psg=PSGConfig(enabled=True)),
        train=TrainConfig(global_batch=args.batch, seq_len=args.seq,
                          lr=0.03, optimizer="psg", total_steps=args.steps,
                          schedule="step", microbatches=1))
    task = MarkovLMTask(vocab=model.vocab_size)

    def make_batch(step, shard):
        return make_lm_batch(task, 0, step, shard, args.batch, args.seq)

    state = init_train_state(jax.random.PRNGKey(0), exp)
    if args.resume and latest_step(args.ckpt) is not None:
        tree, step = restore_checkpoint(args.ckpt, state)
        state = jax.tree.map(jax.numpy.asarray, tree)
        print(f"resumed from checkpoint at step {step}")

    trainer = Trainer(exp, state, make_batch, checkpoint_dir=args.ckpt,
                      checkpoint_every=50, deadline_s=30.0)
    hist = trainer.run(args.steps, log_every=10)
    if hist:
        print(f"\nfinal loss {np.mean([h['loss'] for h in hist[-5:]]):.4f} "
              f"(bayes floor {task.bayes_xent():.3f}); "
              f"executed {trainer.executed_steps}, "
              f"SMD-dropped {trainer.dropped_steps}; "
              f"checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
