"""End-to-end driver (deliverable b): train with E²-Train for a few hundred
steps, checkpointing + resume + SMD straggler policy, on either registered
task — the ~100M-param LM or the paper's CIFAR ResNet.

    PYTHONPATH=src python examples/train_e2e.py --steps 200
    PYTHONPATH=src python examples/train_e2e.py --steps 300 --resume
    PYTHONPATH=src python examples/train_e2e.py --task cifar_cnn --depth 14
    PYTHONPATH=src python examples/train_e2e.py --tiny --chunk-steps 8
    XLA_FLAGS=--xla_force_host_platform_device_count=2 PYTHONPATH=src \
        python examples/train_e2e.py --tiny --chunk-steps 4 --mesh 2

By default uses a ~100M-parameter llama-style config; --tiny shrinks it for
fast CI runs.  Both tasks run the SAME Trainer/train_step stack — the task
registry (repro.tasks) supplies init/loss.  ``--chunk-steps K`` switches to
the compiled chunked loop (DESIGN.md §Loop: one lax.scan program per K
executed steps, prefetched data, chunk-boundary metric syncs); ``--mesh N``
adds N-way data-parallel execution and fails fast when fewer than N
devices are visible (on CPU, export
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` first — it must be
set before the JAX backend initializes, so the script can't do it for you).
"""
import argparse
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.paper_cnns import cnn_model
from repro.core.config import (E2TrainConfig, Experiment, ModelConfig,
                               PSGConfig, SLUConfig, SMDConfig, TrainConfig)
from repro.data.synthetic import (GaussianImageTask, MarkovLMTask,
                                  make_image_batch, make_lm_batch)
from repro.ft.checkpoint import latest_step, restore_checkpoint
from repro.training.train_step import init_train_state
from repro.training.trainer import Trainer


def model_100m() -> ModelConfig:
    # ~109M params: 12L, d=768, 12H, kv 4, ff 2048, vocab 32k
    return ModelConfig(name="lm-100m", family="dense", num_layers=12,
                       d_model=768, num_heads=12, num_kv_heads=4,
                       d_ff=2048, vocab_size=32000)


def model_tiny() -> ModelConfig:
    return ModelConfig(name="lm-tiny", family="dense", num_layers=4,
                       d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                       vocab_size=512, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", choices=["lm", "cifar_cnn"], default="lm")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint dir (default: /tmp/e2train_ckpt_<task> "
                         "— per task, so --resume never crosses tasks)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--depth", type=int, default=74,
                    help="CIFAR ResNet depth (6n+2) for --task cifar_cnn")
    ap.add_argument("--chunk-steps", type=int, default=1,
                    help="compile K executed steps into one device program "
                         "(1 = per-step reference loop)")
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="N-way data-parallel mesh over the batch axis "
                         "(0 = single device)")
    ap.add_argument("--fused-conv", action="store_true",
                    help="route CNN convs through the fused implicit-GEMM "
                         "kernels (kernels/conv.py) instead of materialized "
                         "im2col (cifar_cnn task; DESIGN.md §Kernels)")
    args = ap.parse_args()
    if args.mesh > 1 and jax.device_count() < args.mesh:
        raise SystemExit(
            f"--mesh {args.mesh} needs {args.mesh} devices but only "
            f"{jax.device_count()} are visible; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={args.mesh} for the "
            "CPU demo")
    if args.ckpt is None:
        args.ckpt = f"/tmp/e2train_ckpt_{args.task}"

    e2 = E2TrainConfig(smd=SMDConfig(enabled=True, drop_prob=0.5),
                       slu=SLUConfig(enabled=True, alpha=1e-3),
                       psg=PSGConfig(enabled=True,
                                     fused_conv=args.fused_conv))
    tcfg = TrainConfig(global_batch=args.batch, seq_len=args.seq,
                       lr=0.03, optimizer="psg", total_steps=args.steps,
                       schedule="step", microbatches=1)

    if args.task == "cifar_cnn":
        depth = 8 if args.tiny else args.depth     # --tiny shrinks both tasks
        model = cnn_model(f"resnet{depth}", depth,
                          width=8 if args.tiny else 16)
        exp = Experiment(model=model, e2=e2, train=tcfg, task="cifar_cnn")
        img_task = GaussianImageTask(num_classes=10, snr=2.0)
        bayes = "n/a"

        def make_batch(step, shard):
            return make_image_batch(img_task, 0, step, shard, args.batch)
        print(f"model {model.name} (CIFAR shapes, width {model.d_model})")
    else:
        model = model_tiny() if args.tiny else model_100m()
        exp = Experiment(model=model, e2=e2, train=tcfg)
        lm_task = MarkovLMTask(vocab=model.vocab_size)
        bayes = f"{lm_task.bayes_xent():.3f}"

        def make_batch(step, shard):
            return make_lm_batch(lm_task, 0, step, shard, args.batch, args.seq)
        print(f"model {model.name}: {model.param_count()/1e6:.1f}M params")

    state = init_train_state(jax.random.PRNGKey(0), exp)
    if args.resume and latest_step(args.ckpt) is not None:
        tree, step = restore_checkpoint(args.ckpt, state)
        state = jax.tree.map(jax.numpy.asarray, tree)
        print(f"resumed from checkpoint at step {step}")

    mesh = None
    if args.mesh > 1:
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((args.mesh, 1), ("data", "model"))
        print(f"mesh: {args.mesh}-way data parallel over {mesh.devices.size} "
              "devices")
    trainer = Trainer(exp, state, make_batch, checkpoint_dir=args.ckpt,
                      checkpoint_every=50, deadline_s=30.0,
                      chunk_steps=args.chunk_steps, mesh=mesh)
    hist = trainer.run(args.steps, log_every=10)
    if hist:
        extras = ""
        fb = trainer.measured_psg_fallback()
        if fb is not None:
            extras = f"; measured PSG fallback {fb:.3f}"
        sps = trainer.steps_per_s()
        loop = (f"chunked K={args.chunk_steps}" if args.chunk_steps > 1
                or mesh is not None else "per-step")
        print(f"\nfinal loss {np.mean([h['loss'] for h in hist[-5:]]):.4f} "
              f"(bayes floor {bayes}); "
              f"executed {trainer.executed_steps}, "
              f"SMD-dropped {trainer.dropped_steps}{extras}; "
              f"checkpoints in {args.ckpt}")
        if sps:
            print(f"throughput: {sps:.2f} executed steps/s ({loop} loop)")
        # the run's energy accounting: this run's telemetry composed with
        # the per-layer cost model, measured next to assumed
        print("\n" + trainer.energy_report(steps=args.steps).summary())


if __name__ == "__main__":
    main()
