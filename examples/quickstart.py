"""Quickstart: train a small LM with full E²-Train (SMD + SLU + PSG).

    PYTHONPATH=src python examples/quickstart.py

Shows the three techniques working together on a learnable synthetic task,
then compares against the plain-SGD baseline and prints the energy
accounting from the paper's 45nm model.
"""
import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core.config import (E2TrainConfig, Experiment, ModelConfig,
                               PSGConfig, SLUConfig, SMDConfig, TrainConfig)
from repro.core.ledger import EnergyLedger
from repro.data.synthetic import MarkovLMTask, make_lm_batch
from repro.training.train_step import init_train_state
from repro.training.trainer import Trainer


def main():
    model = ModelConfig(name="quickstart", family="dense", num_layers=4,
                        d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                        vocab_size=64, dtype="float32")
    task = MarkovLMTask(vocab=64)

    def make_batch(step, shard):
        return make_lm_batch(task, 0, step, shard, 16, 32)

    def train(tag, e2, optimizer, lr, steps):
        exp = Experiment(model=model, e2=e2,
                         train=TrainConfig(global_batch=16, seq_len=32,
                                           lr=lr, optimizer=optimizer,
                                           total_steps=steps,
                                           schedule="constant"))
        state = init_train_state(jax.random.PRNGKey(0), exp)
        tr = Trainer(exp, state, make_batch)
        hist = tr.run(steps, log_every=20)
        final = np.mean([h["loss"] for h in hist[-5:]])
        print(f"[{tag}] final loss {final:.4f} "
              f"(executed {tr.executed_steps}, SMD-dropped {tr.dropped_steps}, "
              f"bayes floor {task.bayes_xent():.3f})")
        return tr

    print("=== baseline: 32-bit SGD ===")
    train("sgd32", E2TrainConfig(), "sgdm", 0.1, 60)

    print("\n=== E2-Train: SMD + SLU + PSG (SignSGD+SWA) ===")
    e2 = E2TrainConfig(smd=SMDConfig(enabled=True, drop_prob=0.5),
                       slu=SLUConfig(enabled=True, alpha=1e-3,
                                     target_skip=0.2),
                       psg=PSGConfig(enabled=True))
    tr = train("e2train", e2, "psg", 0.03, 120)

    # the run's own ledger: this run's telemetry (executed/dropped steps,
    # SLU execution, PSG fallback tiles) composed with the per-layer cost
    # model — measured next to the config's assumed operating point.
    print("\n=== energy accounting: this run, measured vs assumed ===")
    print(tr.energy_report(steps=120).summary())

    # paper Tab. 3 sweep from config-derived inputs alone: each operating
    # point is an E2TrainConfig, and the ledger reproduces the published
    # composition rows — no hand-fed ratios.
    print("\n=== energy accounting (paper Tab. 3 composition) ===")
    for skip, paper in ((0.2, "80.27%"), (0.4, "85.20%"), (0.6, "90.13%")):
        op = E2TrainConfig(smd=SMDConfig(enabled=True, drop_prob=0.5),
                           slu=SLUConfig(enabled=True, target_skip=skip),
                           psg=PSGConfig(enabled=True))
        rep = EnergyLedger(Experiment(model=model, e2=op)).report()
        print(f"  SLU skip {skip:.0%}: computational savings = "
              f"{rep.paper_composition:.2%} (paper: {paper})")


if __name__ == "__main__":
    main()
