"""Serving: prefill/decode step builders + a batched wave scheduler.

``make_prefill_step`` / ``make_decode_step`` return the pure functions the
multi-pod dry-run lowers (``serve_step`` in the assignment's terms): decode
is one new token against a KV/recurrent state of ``max_kv_len``.

``ServeEngine`` batches requests in *waves*: up to ``batch_slots`` prompts
are left-padded to a common length, bulk-prefilled in ONE forward pass
(``transformer.prefill_to_state`` hands the KV ring buffers / recurrent
states to the decode loop), then decoded until every request in the wave
hits its token budget.  The compiled prefill/decode shapes never change,
so two jitted functions serve all traffic — the property that matters for
production serving.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import Experiment
from repro.models import transformer


def make_prefill_step(exp: Experiment):
    cfg = exp.model

    def prefill(params, tokens, frontend=None):
        out = transformer.lm_fwd(params, tokens, cfg, None, None,
                                 frontend_embeds=frontend, train=False,
                                 remat="none")
        return out.logits[:, -1:]

    return prefill


def make_decode_step(exp: Experiment):
    cfg = exp.model

    def decode(params, token, state, memory=None):
        return transformer.decode_step(params, token, state, cfg, memory)

    return decode


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int = 32
    out: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Wave-batched serving (single-host demo of the pjit serving path)."""

    def __init__(self, exp: Experiment, params, batch_slots: int = 4,
                 max_len: int = 512):
        self.exp, self.cfg = exp, exp.model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self._decode = jax.jit(make_decode_step(exp))
        cdt = jnp.float32 if self.cfg.dtype == "float32" else jnp.bfloat16
        self._prefill = jax.jit(lambda p, t: transformer.prefill_to_state(
            p, t, self.cfg, max_len, cache_dtype=cdt))
        self.queue: List[Request] = []
        self.finished: List[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def _run_wave(self, wave: List[Request]):
        B = self.slots
        plen = max(len(r.prompt) for r in wave)
        toks = np.zeros((B, plen), np.int32)
        for s, r in enumerate(wave):           # left-pad with token repeats
            pr = np.asarray(r.prompt, np.int32)
            toks[s, plen - len(pr):] = pr
            toks[s, :plen - len(pr)] = pr[0]
        # bulk prefill -> decode-state handoff (one forward, not plen steps)
        logits, state = self._prefill(self.params, jnp.asarray(toks))
        cur = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        budget = max(r.max_new for r in wave)
        for _ in range(budget):
            for s, r in enumerate(wave):
                if len(r.out) < r.max_new:
                    r.out.append(int(cur[s]))
            if all(len(r.out) >= r.max_new for r in wave):
                break
            logits, state = self._decode(self.params,
                                         jnp.asarray(cur[:, None]), state)
            cur = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        for r in wave:
            r.done = True
            self.finished.append(r)

    def run(self) -> List[Request]:
        while self.queue:
            wave = [self.queue.pop(0) for _ in range(min(self.slots,
                                                         len(self.queue)))]
            while len(wave) < self.slots:      # pad the wave with a clone
                wave.append(Request(rid=-1, prompt=wave[0].prompt,
                                    max_new=wave[0].max_new))
            self._run_wave([r for r in wave])
            self.finished = [r for r in self.finished if r.rid != -1]
        return self.finished
