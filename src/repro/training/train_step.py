"""Train-step builder: loss + grad + E²-Train integration + optimizer.

One function, ``make_train_step(exp)``, returns a pure jittable
``(state, batch, step) -> (state, metrics)`` covering:

* mixed-precision loss (params fp32, activations bf16),
* PSG routing (trace-time ``psg.enable``) and sign-gradient handling,
* microbatch gradient accumulation (``lax.scan``; for PSG the per-micro
  signs sum then re-sign — a majority vote over microbatches),
* majority-vote 1-bit compression marker (sign() after pjit's mean-reduce),
* SLU rng/regularizer plumbing (inside the model),
* optimizer + optional SWA.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import psg as psgmod
from repro.core.config import Experiment
from repro.distributed.sharding import constrain_like_params
from repro.models import transformer
from repro.optim.api import make_optimizer
from repro.optim.majority_vote import majority_vote_tree
from repro.optim.swa import swa_init, swa_params, swa_update


class TrainState(NamedTuple):
    params: Any
    opt: Any
    swa: Any                     # None when disabled (static)
    step: jnp.ndarray


def init_train_state(key, exp: Experiment) -> TrainState:
    params = transformer.init_lm(key, exp.model, exp.e2)
    opt = make_optimizer(exp.train)
    swa = swa_init(params) if (exp.e2.psg.enabled and exp.e2.psg.swa) else None
    return TrainState(params=params, opt=opt.init(params), swa=swa,
                      step=jnp.zeros((), jnp.int32))


def make_train_step(exp: Experiment):
    cfg, e2, tc = exp.model, exp.e2, exp.train
    opt = make_optimizer(tc)
    psg_cfg = e2.psg if e2.psg.enabled else None
    m = max(tc.microbatches, 1)

    def loss_fn(params, probe, batch, rng):
        # probe: zeros((2,)) carrier — its gradient accumulates the tile
        # kernel's [sum fallback_ratio, n_psg_matmuls] across the whole
        # backward pass (core/psg.py), giving the measured per-step
        # psg_fallback_ratio without a side channel.
        with psgmod.enable(psg_cfg, probe=probe):
            return transformer.lm_loss(params, batch, cfg, e2, rng,
                                       remat=tc.remat)

    grad_fn = jax.value_and_grad(loss_fn, argnums=(0, 1), has_aux=True)

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]
                   ) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
        rng = jax.random.fold_in(jax.random.PRNGKey(tc.seed), state.step)
        probe0 = psgmod.zero_probe()
        if m == 1:
            (loss, metrics), (grads, probe_g) = grad_fn(
                state.params, probe0, batch, rng)
            grads = constrain_like_params(grads)
        else:
            def micro(carry, mb):
                g_acc, p_acc, i = carry
                (l, mt), (g, pg) = grad_fn(
                    state.params, probe0, mb, jax.random.fold_in(rng, i))
                g = constrain_like_params(g)
                acc = constrain_like_params(jax.tree.map(jnp.add, g_acc, g))
                return (acc, p_acc + pg, i + 1), (l, mt)

            mbs = jax.tree.map(
                lambda x: x.reshape(m, x.shape[0] // m, *x.shape[1:]), batch)
            g0 = jax.tree.map(jnp.zeros_like, state.params)
            (grads, probe_g, _), (losses, mets) = jax.lax.scan(
                micro, (g0, probe0, 0), mbs)
            grads = jax.tree.map(lambda g: g / m, grads)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, mets)

        if psg_cfg is not None:
            # per-replica signs were mean-reduced by pjit across data/pod;
            # the final sign() completes the distributed majority vote.
            grads = majority_vote_tree(grads)
        if tc.grad_clip > 0 and psg_cfg is None:
            gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                              for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, tc.grad_clip / (gn + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        else:
            gn = jnp.float32(0.0)

        params, opt_state = opt.apply(state.params, grads, state.opt,
                                      state.step)
        swa = state.swa
        if swa is not None:
            swa = swa_update(swa, params, state.step,
                             int(tc.total_steps * e2.psg.swa_start_frac))
        metrics = dict(metrics)
        metrics["total_loss"] = loss
        metrics["grad_norm"] = gn
        if psg_cfg is not None:
            # measured (not assumed) predictor usage: MAC-weighted fraction
            # of backward kernel tiles that ran the full-precision fallback
            # product.  Only emitted when PSG ran — a baseline step has no
            # measurement, not a measurement of zero.
            metrics["psg_fallback_ratio"] = psgmod.probe_fallback_ratio(probe_g)
        return TrainState(params, opt_state, swa, state.step + 1), metrics

    return train_step


def eval_params(state: TrainState, exp: Experiment):
    """Weights to evaluate with — SWA average when PSG+SWA is active."""
    if state.swa is not None:
        return swa_params(state.swa, state.params)
    return state.params
