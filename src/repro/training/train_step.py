"""Train-step builder: loss + grad + E²-Train integration + optimizer.

One function, ``make_train_step(exp)``, returns a pure jittable
``(state, batch) -> (state, metrics)`` covering:

* the experiment's task (``repro.tasks`` registry: LM or CIFAR CNN — the
  step builder is model-agnostic),
* mixed-precision loss (params fp32, activations per model config),
* PSG routing (trace-time ``psg.enable``) and sign-gradient handling,
* microbatch gradient accumulation (``lax.scan``; for PSG the per-micro
  signs sum then re-sign — a majority vote over microbatches),
* majority-vote 1-bit compression marker (sign() after pjit's mean-reduce),
* SLU rng/regularizer plumbing (inside the model),
* non-trainable model state (BatchNorm running stats) threaded past the
  optimizer: the loss returns the updated buffers, the step stores them on
  ``TrainState.model_state`` — they are never touched by the optimizer,
* optimizer + optional SWA.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import psg as psgmod
from repro.core.config import Experiment
from repro.distributed.sharding import constrain_like_params
from repro.optim.api import make_optimizer
from repro.optim.majority_vote import majority_vote_tree
from repro.optim.swa import swa_init, swa_params, swa_update
from repro.tasks import get_task


class TrainState(NamedTuple):
    params: Any
    opt: Any
    swa: Any                     # None when disabled (static)
    step: jnp.ndarray
    model_state: Any = None      # non-trainable buffers (BN running stats)


def init_train_state(key, exp: Experiment) -> TrainState:
    task = get_task(exp.task)
    params, model_state = task.init(key, exp)
    opt = make_optimizer(exp.train)
    swa = swa_init(params) if (exp.e2.psg.enabled and exp.e2.psg.swa) else None
    return TrainState(params=params, opt=opt.init(params), swa=swa,
                      step=jnp.zeros((), jnp.int32), model_state=model_state)


def make_train_step(exp: Experiment):
    e2, tc = exp.e2, exp.train
    task_loss = get_task(exp.task).make_loss(exp)
    opt = make_optimizer(tc)
    psg_cfg = e2.psg if e2.psg.enabled else None
    m = max(tc.microbatches, 1)

    def loss_fn(params, model_state, probe, batch, rng):
        # probe: zeros((2,)) carrier — its gradient accumulates the tile
        # kernel's [sum fallback_ratio, n_psg_matmuls] across the whole
        # backward pass (core/psg.py), giving the measured per-step
        # psg_fallback_ratio without a side channel.
        with psgmod.enable(psg_cfg, probe=probe):
            return task_loss(params, model_state, batch, rng)

    grad_fn = jax.value_and_grad(loss_fn, argnums=(0, 2), has_aux=True)

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]
                   ) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
        rng = jax.random.fold_in(jax.random.PRNGKey(tc.seed), state.step)
        probe0 = psgmod.zero_probe()
        if m == 1:
            (loss, (metrics, mstate)), (grads, probe_g) = grad_fn(
                state.params, state.model_state, probe0, batch, rng)
            grads = constrain_like_params(grads)
        else:
            def micro(carry, mb):
                g_acc, p_acc, ms, i = carry
                (l, (mt, ms2)), (g, pg) = grad_fn(
                    state.params, ms, probe0, mb, jax.random.fold_in(rng, i))
                g = constrain_like_params(g)
                acc = constrain_like_params(jax.tree.map(jnp.add, g_acc, g))
                return (acc, p_acc + pg, ms2, i + 1), (l, mt)

            mbs = jax.tree.map(
                lambda x: x.reshape(m, x.shape[0] // m, *x.shape[1:]), batch)
            g0 = jax.tree.map(jnp.zeros_like, state.params)
            (grads, probe_g, mstate, _), (losses, mets) = jax.lax.scan(
                micro, (g0, probe0, state.model_state, 0), mbs)
            grads = jax.tree.map(lambda g: g / m, grads)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, mets)

        if psg_cfg is not None:
            # per-replica signs were mean-reduced by pjit across data/pod;
            # the final sign() completes the distributed majority vote.
            grads = majority_vote_tree(grads)
        if tc.grad_clip > 0 and psg_cfg is None:
            gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                              for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, tc.grad_clip / (gn + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        else:
            gn = jnp.float32(0.0)

        params, opt_state = opt.apply(state.params, grads, state.opt,
                                      state.step)
        swa = state.swa
        if swa is not None:
            swa = swa_update(swa, params, state.step,
                             int(tc.total_steps * e2.psg.swa_start_frac))
        metrics = dict(metrics)
        metrics["total_loss"] = loss
        metrics["grad_norm"] = gn
        if psg_cfg is not None:
            # measured (not assumed) predictor usage: MAC-weighted fraction
            # of backward kernel tiles that ran the full-precision fallback
            # product.  Only emitted when PSG ran — a baseline step has no
            # measurement, not a measurement of zero.
            metrics["psg_fallback_ratio"] = psgmod.probe_fallback_ratio(probe_g)
        return TrainState(params, opt_state, swa, state.step + 1,
                          mstate), metrics

    return train_step


def eval_params(state: TrainState, exp: Experiment):
    """Weights to evaluate with — SWA average when PSG+SWA is active.

    Caveat for tasks with non-trainable buffers (BN running stats): the
    stats in ``state.model_state`` tracked the *raw* parameter trajectory,
    not the SWA average — evaluate SWA weights with
    :func:`recalibrate_model_state` output, per standard SWA practice.
    """
    if state.swa is not None:
        return swa_params(state.swa, state.params)
    return state.params


def recalibrate_model_state(exp: Experiment, params, model_state, batches,
                            rng=None):
    """Re-estimate non-trainable buffers under ``params`` by running
    train-mode forwards over ``batches`` (SWA BN-recalibration).  A no-op
    for stateless tasks (the LM): the input state passes through."""
    if not jax.tree.leaves(model_state):
        return model_state
    loss = get_task(exp.task).make_loss(exp)
    rng = rng if rng is not None else jax.random.PRNGKey(exp.train.seed)
    for i, batch in enumerate(batches):
        _, (_, model_state) = loss(params, model_state, batch,
                                   jax.random.fold_in(rng, i))
    return model_state
