from repro.training.train_step import make_train_step, TrainState
from repro.training.loop import ChunkPlanner, make_chunk_step, stack_batches
from repro.training.trainer import Trainer
