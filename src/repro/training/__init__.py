from repro.training.train_step import make_train_step, TrainState
from repro.training.trainer import Trainer
