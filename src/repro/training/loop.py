"""Compiled chunked training loop (DESIGN.md §Loop).

The per-step loop dispatches one jitted step per Python iteration and
blocks on a host sync for every metric.  This module compiles K executed
steps into ONE device program (``lax.scan`` over :func:`make_train_step`)
so steady-state training has no per-step Python, no per-step host sync,
and no per-step data fetch for SMD-dropped steps:

* SMD decisions stay **host-side and counter-based** (``smd_schedule``):
  a dropped step never reaches the device, costs no compute and no data
  generation — the paper's §3.1 zero-overhead property.  What the scan
  sees is only the chunk's *executed* steps.
* The step counter still advances **inside** the scan: each executed step
  carries a ``step_increment`` = 1 + the number of drops immediately
  before it, so ``state.step`` (which seeds the per-step RNG fold-in) is
  bit-identical to the per-step loop's.
* Metrics accumulate device-resident and come back stacked ``(K, ...)``;
  the caller syncs them once per chunk boundary.

Trailing drops (after the chunk's last executed step) are NOT part of the
chunk — they belong to the next chunk's first increment, or to
:func:`ChunkPlanner.flush_trailing` at the end of the run.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import Experiment
from repro.training.train_step import TrainState, make_train_step

# The chunk program's contract, verified statically per commit by
# ``analysis/hotloop_lint.py`` (DESIGN.md §Analysis).  Rule names are the
# lint's vocabulary — keep them in sync with its rule table:
#
# * no-host-callback        — nothing inside the scan calls back to the
#                             host (debug prints, io_callback, infeed);
#                             one callback per step is the per-step loop's
#                             sync cost all over again.
# * static-trip-count       — the chunk is a ``lax.scan`` with a static K,
#                             never a ``while`` (unknown trips poison the
#                             HLO cost audit and defeat ahead-of-time
#                             scheduling).
# * shape-stable-body       — the scanned body's primitive mix must not
#                             depend on K (a Python-value-dependent
#                             operand would recompile per chunk length).
# * device-resident-metrics — metrics return stacked ``(K, ...)``; the
#                             sync happens at chunk boundaries, in the
#                             caller.
# * no-donation-default     — callers jit WITHOUT ``donate_argnums`` by
#                             default (see the docstring below;
#                             ``Trainer(donate_chunk_state=True)`` is the
#                             explicit opt-in).
#
# The straggler-deadline instrumentation (``make_chunk_step(...,
# step_timer=...)``) is an EXPLICIT OPT-IN that trades one ordered host
# callback per scanned step for per-step wall-clock visibility — the same
# opt-in convention as donation.  The default program (what the lint
# traces) stays callback-free; ``Trainer(deadline_s=...)`` is the only
# caller that requests the timed variant (DESIGN.md §Fault-tolerance).
CHUNK_CONTRACT = (
    "no-host-callback",
    "static-trip-count",
    "shape-stable-body",
    "device-resident-metrics",
    "no-donation-default",
)


def make_chunk_step(exp: Experiment, K: Optional[int] = None,
                    step_timer=None):
    """Build ``(state, batches, step_increment) -> (state, stacked_metrics)``.

    ``batches`` is the chunk's executed-step batches stacked along a new
    leading axis; ``step_increment`` is an int32 ``(k,)`` vector (see module
    doc).  ``K`` is an optional declared chunk length: when given, calls are
    validated against it (the tail chunk of a run may be shorter — jit
    retraces per shape, so pass ``K=None`` to accept any length).

    ``step_timer`` opts into the straggler-deadline instrumentation: a
    host callable ``step_timer(step)`` invoked via an ORDERED
    ``jax.debug.callback`` at the top of every scanned step, so the host
    observes device-side per-step boundaries (the gap between consecutive
    callbacks is one executed step's device time).  The default
    (``None``) program contains no callback — the ``CHUNK_CONTRACT``
    ``no-host-callback`` rule applies to it; the timed variant is the
    explicit opt-in ``Trainer(deadline_s=...)`` requests at per-step
    straggler granularity (DESIGN.md §Fault-tolerance).

    The returned function is pure and jittable; callers jit it once and let
    shape-driven retracing handle tail chunks.  Do NOT jit it with
    ``donate_argnums``: donating the carried TrainState lets XLA CPU
    rewrite the scanned body in place, which changes fusion and breaks the
    bit-for-bit parity with the per-step loop (tests/test_loop.py pins it;
    DESIGN.md §Loop records the measurement).
    """
    train_step = make_train_step(exp)

    def chunk_step(state: TrainState, batches: Dict[str, jnp.ndarray],
                   step_increment: jnp.ndarray
                   ) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
        k = step_increment.shape[0]
        if K is not None and k != K:
            raise ValueError(f"chunk declared K={K} but got {k} steps")
        lead = {l.shape[0] for l in jax.tree.leaves(batches)}
        if lead != {k}:
            raise ValueError(f"stacked batch leading axes {lead} != k={k}")

        def body(st, xs):
            inc, batch = xs
            # advance over the drops *before* this executed step; train_step
            # itself adds the final +1 — net advance per scan step is `inc`
            st = st._replace(step=st.step + (inc - 1))
            if step_timer is not None:
                # ordered: sequenced with the scan's effects so timestamp
                # arrival order matches device step order
                jax.debug.callback(step_timer, st.step, ordered=True)
            return train_step(st, batch)

        # the named scope marks the contract-bearing scan for the static
        # hot-loop lint (metadata only — fusion and numerics unchanged)
        with jax.named_scope("hotloop:chunk"):
            return jax.lax.scan(body, state,
                                (step_increment.astype(jnp.int32), batches))

    return chunk_step


def stack_batches(batches: Sequence[Dict[str, Any]]):
    """Stack per-step batches into the chunk's leading-K layout.

    Stacks on the HOST (np.stack): the stacked batch then reaches the
    device in ONE transfer — at the trainer's ``device_put`` (sharded
    layout under a mesh) or implicitly at the chunk call.  ``jnp.stack``
    would commit the stack to the default device first and mesh placement
    would pay a second full copy to reshard it.
    """
    return jax.tree.map(lambda *xs: np.stack([np.asarray(x) for x in xs]),
                        *batches)


class ChunkPlanner:
    """Groups a stream of ``(step, batch_or_None)`` items into chunks.

    Feed items in nominal-step order (``DataPipeline`` / ``SMDIterator``
    yield exactly this); ``None`` means the step was SMD-dropped before
    generation.  ``add`` returns a completed ``(steps, batches,
    increments)`` chunk once ``chunk_steps`` executed steps accumulated,
    else ``None``.  ``flush`` returns the final partial chunk;
    ``flush_trailing`` returns drops after the last executed step (the
    caller advances the device step counter by that much once, at the end).
    """

    def __init__(self, chunk_steps: int):
        self.chunk_steps = chunk_steps
        self._steps: List[int] = []
        self._batches: List[Any] = []
        self._incs: List[int] = []
        self._pending_drops = 0
        self.dropped = 0
        self.executed = 0

    def add(self, step: int, batch):
        if batch is None:
            self._pending_drops += 1
            self.dropped += 1
            return None
        self._steps.append(step)
        self._batches.append(batch)
        self._incs.append(self._pending_drops + 1)
        self._pending_drops = 0
        self.executed += 1
        if len(self._steps) == self.chunk_steps:
            return self._emit()
        return None

    def drop(self, step: int, batch) -> None:
        """Force-drop a kept step (straggler policy): the generated batch is
        discarded and the step is accounted exactly like an SMD drop."""
        del step, batch
        self._pending_drops += 1
        self.dropped += 1

    def flush(self):
        """The final partial chunk, or ``None`` if no executed step is
        buffered (trailing drops stay pending for ``flush_trailing``)."""
        if not self._steps:
            return None
        return self._emit()

    def flush_trailing(self) -> int:
        n, self._pending_drops = self._pending_drops, 0
        return n

    def _emit(self):
        steps = tuple(self._steps)
        batches = stack_batches(self._batches)
        incs = np.asarray(self._incs, np.int32)
        self._steps, self._batches, self._incs = [], [], []
        return steps, batches, incs
