"""Training loop orchestration: SMD, checkpoints, straggler policy, metrics.

The loop is deliberately thin — all compute lives in the jitted train_step,
and everything model-specific lives behind the ``repro.tasks`` registry, so
the same loop trains the transformer LM stack and the paper's CIFAR CNNs
(there is no other training loop in the repo) — and deals with the
operational concerns of a long-running multi-pod job:

* SMD-dropped steps advance the step counter without compute or data fetch;
* periodic + final checkpoints via ``repro.ft.checkpoint`` (async save);
* a straggler hook: if a step exceeds ``deadline_s`` (observed on this
  host), the *next* step is pre-declared droppable — the SMD machinery makes
  that sound (DESIGN.md §7).  On real multi-host deployments the deadline
  check runs per-host against the shared counter-based SMD schedule.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core.config import Experiment
from repro.core.smd import smd_keep_host
from repro.training.train_step import TrainState, make_train_step


class Trainer:
    def __init__(self, exp: Experiment, state: TrainState,
                 make_batch: Callable[[int, int], Dict],
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 0,
                 deadline_s: float = 0.0,
                 shard: int = 0):
        self.exp = exp
        self.state = state
        self.make_batch = make_batch
        self.step_fn = jax.jit(make_train_step(exp), donate_argnums=(0,))
        self.ckpt_dir = checkpoint_dir
        self.ckpt_every = checkpoint_every
        self.deadline_s = deadline_s
        self.shard = shard
        self.history: List[Dict[str, float]] = []
        self._straggler_pending = False
        self.executed_steps = 0
        self.dropped_steps = 0

    def run(self, num_steps: int, log_every: int = 0) -> List[Dict[str, float]]:
        e2 = self.exp.e2
        for _ in range(num_steps):
            step = int(self.state.step)
            drop = False
            if e2.smd.enabled and not smd_keep_host(self.exp.train.seed, step,
                                                    e2.smd.drop_prob):
                drop = True
            if self._straggler_pending:       # straggler -> SMD-style drop
                drop = True
                self._straggler_pending = False
            if drop:
                self.state = self.state._replace(step=self.state.step + 1)
                self.dropped_steps += 1
                continue

            batch = self.make_batch(step, self.shard)
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            metrics["step"] = step
            metrics["wall_s"] = dt
            self.history.append(metrics)
            self.executed_steps += 1
            if self.deadline_s and dt > self.deadline_s:
                self._straggler_pending = True
            if self.ckpt_dir and self.ckpt_every and \
                    (step + 1) % self.ckpt_every == 0:
                self._save(step)
            if log_every and step % log_every == 0:
                print(f"step {step}: loss={metrics.get('total_loss', 0):.4f} "
                      f"({dt*1e3:.0f} ms)")
        if self.ckpt_dir:
            self._save(int(self.state.step) - 1)
            # the final save must survive process exit: async writers are
            # daemon threads, and an orphaned write leaves a stale .tmp
            # (and no checkpoint) for the next --resume to trip over
            from repro.ft.checkpoint import wait_for_saves
            wait_for_saves()
        return self.history

    def measured_psg_fallback(self) -> Optional[float]:
        """Mean measured PSG fallback-tile ratio over executed steps — the
        quantity core/energy.py uses in place of its 0.4 design assumption
        (``training_energy_pj(psg_fallback_rate=...)``).  ``None`` when no
        PSG step executed: no measurement is not a measurement of zero."""
        vals = [h["psg_fallback_ratio"] for h in self.history
                if "psg_fallback_ratio" in h]
        return float(np.mean(vals)) if vals else None

    def energy_report(self, steps: Optional[int] = None):
        """The run's :class:`~repro.core.ledger.EnergyReport`: this run's
        telemetry (SMD executed/dropped counts, SLU execution ratios, PSG
        fallback-tile ratios) composed with the experiment's per-layer cost
        model and the 45nm per-op tables — measured next to assumed
        (DESIGN.md §Energy).  ``steps`` defaults to the config's nominal
        ``total_steps`` budget."""
        from repro.core.ledger import EnergyLedger
        return EnergyLedger.from_trainer(self).report(steps=steps)

    def _save(self, step: int):
        from repro.ft.checkpoint import save_checkpoint
        save_checkpoint(self.ckpt_dir, self.state, step, async_save=True)
