"""Training loop orchestration: SMD, checkpoints, straggler policy, metrics.

All compute lives in jitted device programs and everything model-specific
lives behind the ``repro.tasks`` registry, so the same loop trains the
transformer LM stack and the paper's CIFAR CNNs (there is no other
training loop in the repo).  Two execution modes share one Trainer
(DESIGN.md §Loop):

* **per-step** (``chunk_steps=1``, no mesh): one jitted train_step per
  Python iteration, metrics synced every step — the reference loop the
  chunked mode is parity-tested against;
* **chunked** (``chunk_steps=K>1`` or ``mesh=...``): K executed steps
  compile into one ``lax.scan`` program (``training/loop.py``); batches
  come from ``data/pipeline.py``'s background prefetch thread and are
  ``jax.device_put`` while the previous chunk still runs; metrics stay
  device-resident and sync once per chunk boundary.  With ``mesh=...``
  the stacked batch is sharded along its batch axis
  (``distributed/sharding.batch_sharding``) and the TrainState is
  replicated/FSDP-sharded (``state_shardings``) — data-parallel execution
  with counter-based per-shard batch generation, no host data exchange.

Operational concerns of a long-running multi-pod job, in both modes:

* SMD-dropped steps advance the step counter without compute or data fetch
  (decided host-side from the counter-based schedule; in chunked mode the
  drops never even reach the device — they ride along as per-executed-step
  ``step_increment`` values);
* periodic + final checkpoints via ``repro.ft.checkpoint`` (async save);
  in chunked mode the cadence is evaluated at chunk granularity and saves
  land on chunk boundaries (``repro.ft.checkpoint.resume_chunk_start``);
* a straggler hook at PER-STEP granularity in both modes: per-step mode
  times each dispatch directly; chunked mode opts into the timed chunk
  program (``make_chunk_step(step_timer=...)`` — one ordered host
  callback per scanned step, so per-step device-side boundaries are
  observable without breaking the chunk into per-step dispatches).  Every
  step whose wall time exceeds ``deadline_s`` arms one forced drop; armed
  drops are consumed by subsequent kept steps (``ChunkPlanner.drop``) and
  counted in ``straggler_dropped_steps``, which ``energy_report()``
  surfaces — the SMD machinery makes forced drops sound (DESIGN.md
  §Fault-tolerance).  On real multi-host deployments the deadline check
  runs per-host against the shared counter-based SMD schedule.
"""
from __future__ import annotations

import contextlib
import sys
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import Experiment
from repro.core.smd import smd_keep_host
from repro.training.loop import ChunkPlanner, make_chunk_step
from repro.training.train_step import TrainState, make_train_step


class Trainer:
    def __init__(self, exp: Experiment, state: TrainState,
                 make_batch: Callable[[int, int], Dict],
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 0,
                 deadline_s: float = 0.0,
                 shard: int = 0,
                 chunk_steps: int = 1,
                 mesh: Optional[Any] = None,
                 prefetch: int = 2,
                 donate_chunk_state: bool = False):
        self.exp = exp
        self.make_batch = make_batch
        self.step_fn = jax.jit(make_train_step(exp), donate_argnums=(0,))
        self.ckpt_dir = checkpoint_dir
        self.ckpt_every = checkpoint_every
        self.deadline_s = deadline_s
        self.shard = shard
        self.chunk_steps = max(int(chunk_steps), 1)
        self.mesh = mesh
        self.prefetch = prefetch
        self.donate_chunk_state = donate_chunk_state
        self.history: List[Dict[str, float]] = []
        self._straggler_pending = 0     # armed forced drops (a count)
        self._last_sync_t = 0.0
        self.executed_steps = 0
        self.dropped_steps = 0
        self.straggler_dropped_steps = 0   # subset of dropped_steps
        self.save_errors: Dict[str, BaseException] = {}
        self._chunk_fn = None           # built lazily (chunked mode only)
        self._step_times: Dict[int, float] = {}   # timed-chunk timestamps
        if mesh is not None:
            from repro.distributed.sharding import state_shardings
            state = jax.device_put(state, state_shardings(state, mesh))
        self.state = state

    # ------------------------------------------------------------------
    # entry point
    # ------------------------------------------------------------------

    def run(self, num_steps: int, log_every: int = 0) -> List[Dict[str, float]]:
        if self.chunk_steps > 1 or self.mesh is not None:
            return self._run_chunked(num_steps, log_every)
        return self._run_per_step(num_steps, log_every)

    # ------------------------------------------------------------------
    # per-step reference loop (chunk_steps=1): one dispatch + one metrics
    # sync per executed step
    # ------------------------------------------------------------------

    def _run_per_step(self, num_steps: int,
                      log_every: int = 0) -> List[Dict[str, float]]:
        e2 = self.exp.e2
        for _ in range(num_steps):
            step = int(self.state.step)
            drop = False
            if e2.smd.enabled and not smd_keep_host(self.exp.train.seed, step,
                                                    e2.smd.drop_prob):
                drop = True
            forced = False
            if self._straggler_pending:       # straggler -> SMD-style drop
                if not drop:
                    forced = True             # an otherwise-kept step
                drop = True                   # (an SMD drop absorbs the arm)
                self._straggler_pending -= 1
            if drop:
                self.state = self.state._replace(step=self.state.step + 1)
                self.dropped_steps += 1
                self.straggler_dropped_steps += int(forced)
                continue

            batch = self.make_batch(step, self.shard)
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            metrics["step"] = step
            metrics["wall_s"] = dt
            self.history.append(metrics)
            self.executed_steps += 1
            if self.deadline_s and dt > self.deadline_s:
                self._straggler_pending += 1
            if self.ckpt_dir and self.ckpt_every and \
                    (step + 1) % self.ckpt_every == 0:
                self._save(step)
            if log_every and step % log_every == 0:
                print(f"step {step}: loss={metrics.get('total_loss', 0):.4f} "
                      f"({dt*1e3:.0f} ms)")
        self._final_save()
        return self.history

    # ------------------------------------------------------------------
    # chunked loop: K executed steps per device program, prefetched data,
    # chunk-boundary metric syncs, optional mesh data-parallelism
    # ------------------------------------------------------------------

    def _run_chunked(self, num_steps: int,
                     log_every: int = 0) -> List[Dict[str, float]]:
        from repro.data.pipeline import DataPipeline

        if self._chunk_fn is None:
            # donate_chunk_state=False (default): donating the carried
            # TrainState lets XLA CPU rewrite the scanned body in place,
            # which changes fusion and breaks the bit-for-bit parity with
            # the per-step loop that tests/test_loop.py pins (measured:
            # losses drift in the 4th decimal from the second in-chunk step
            # onward; DESIGN.md §Loop).  The cost is one extra TrainState
            # copy per chunk.  Opt in per backend/profile with
            # Trainer(donate_chunk_state=True) — the curve then matches
            # the per-step loop to fp tolerance, not bit-for-bit
            # (tests/test_loop.py::test_donate_chunk_state_parity).
            donate = (0,) if self.donate_chunk_state else ()
            # deadline_s > 0 opts into the TIMED chunk program: one ordered
            # host callback per scanned step records device-side step
            # boundaries, so the straggler deadline applies per step, not
            # per chunk mean (DESIGN.md §Fault-tolerance).  The default
            # program stays callback-free (CHUNK_CONTRACT).  Ordered
            # effects are single-device only in XLA, so mesh runs keep
            # the chunk-mean fallback clock.
            timer = (self._record_step_time
                     if self.deadline_s and self.mesh is None else None)
            self._chunk_fn = jax.jit(
                make_chunk_step(self.exp, step_timer=timer),
                donate_argnums=donate)
        planner = ChunkPlanner(self.chunk_steps)
        self._last_sync_t = 0.0
        start = int(self.state.step)
        pipe = DataPipeline(self.make_batch, self.exp.e2.smd,
                            seed=self.exp.train.seed, shard=self.shard,
                            prefetch=self.prefetch, start_step=start)
        # one-chunk pipeline: while chunk N runs on device, chunk N+1 is
        # assembled from the prefetch queue and device_put (double-buffer);
        # chunk N's metrics sync when N+1 has been dispatched
        in_flight = None                  # (steps, t0, device metrics)
        try:
            for _ in range(num_steps):
                step, batch = next(pipe)
                assert step == start + planner.executed + planner.dropped, \
                    "pipeline out of lockstep with the SMD schedule"
                if self._straggler_pending:
                    # same contract as the per-step loop: each armed drop is
                    # consumed by the NEXT step whatever it is — an SMD
                    # drop absorbs it (one drop, not two); a kept step is
                    # force-dropped (its prefetched batch is discarded)
                    self._straggler_pending -= 1
                    if batch is not None:
                        planner.drop(step, batch)
                        self.straggler_dropped_steps += 1
                        continue
                chunk = planner.add(step, batch)
                if chunk is not None:
                    in_flight = self._dispatch(chunk, in_flight, log_every)
            tail = planner.flush()
            if tail is not None:
                in_flight = self._dispatch(tail, in_flight, log_every)
            if in_flight is not None:
                self._finalize(in_flight, log_every)
        finally:
            pipe.close()
            # keep telemetry consistent even if interrupted mid-run (the
            # per-step loop updates these incrementally): an
            # EnergyLedger.from_trainer after a KeyboardInterrupt must see
            # the counts that produced self.history
            self.executed_steps += planner.executed
            self.dropped_steps += planner.dropped
        trailing = planner.flush_trailing()
        if trailing:
            self.state = self.state._replace(step=self.state.step + trailing)
        self._final_save()
        return self.history

    def _dispatch(self, chunk, in_flight, log_every):
        """device_put + launch one chunk; sync the previous one after."""
        steps, batches, incs = chunk
        batches, incs = self._place(batches, incs)
        with self._mesh_ctx():
            t0 = time.perf_counter()
            self.state, stacked = self._chunk_fn(self.state, batches, incs)
        if in_flight is not None:
            self._finalize(in_flight, log_every)
        if self.ckpt_dir and self.ckpt_every and any(
                (s + 1) % self.ckpt_every == 0 for s in steps):
            # cadence at chunk granularity: the save waits for THIS chunk
            # (np.asarray blocks) and lands on its boundary — its last
            # executed step — which is what resume derives the
            # chunk-aligned restart from (ft/checkpoint.resume_chunk_start)
            self._save(steps[-1])
        return steps, t0, stacked

    def _place(self, batches, incs):
        incs = jnp.asarray(incs)
        if self.mesh is None:
            return batches, incs
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed.sharding import batch_sharding
        shardings = batch_sharding(self.mesh, batches, batch_axis=1)
        batches = jax.device_put(batches, shardings)
        incs = jax.device_put(incs, NamedSharding(self.mesh, P(None)))
        return batches, incs

    def _mesh_ctx(self):
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.distributed.sharding import activation_sharding
        stack = contextlib.ExitStack()
        stack.enter_context(activation_sharding(self.mesh))
        stack.enter_context(self.mesh)
        return stack

    def _finalize(self, in_flight, log_every):
        """Chunk boundary: ONE host sync for the whole chunk's stacked
        metrics, then bookkeeping at chunk granularity."""
        steps, t0, stacked = in_flight
        host = jax.device_get(stacked)            # blocks until chunk done
        sync_t = time.perf_counter()
        # this chunk was dispatched (t0) while the PREVIOUS one was still
        # running — clamp to the previous sync so overlapped time is not
        # double-counted (else summed wall_s overstates wall clock ~2x and
        # the straggler deadline trips on healthy chunks)
        dt = sync_t - max(t0, self._last_sync_t)
        self._last_sync_t = sync_t
        per_step_s = dt / max(len(steps), 1)
        for i, step in enumerate(steps):
            metrics = {k: float(v[i]) for k, v in host.items()}
            metrics["step"] = step
            metrics["wall_s"] = per_step_s
            self.history.append(metrics)
            if log_every and step % log_every == 0:
                print(f"step {step}: "
                      f"loss={metrics.get('total_loss', 0):.4f} "
                      f"({per_step_s*1e3:.0f} ms)")
        if self.deadline_s and not self._arm_stragglers(steps, sync_t):
            # no device-side timestamps arrived (callback not yet flushed or
            # instrumentation unavailable): fall back to the pre-PR 10
            # chunk-mean check so a straggling chunk still arms one drop
            if per_step_s > self.deadline_s:
                self._straggler_pending += 1

    def _record_step_time(self, step) -> None:
        """Ordered-callback target: one timestamp per scanned step, keyed by
        the nominal step counter (runs on JAX's callback thread)."""
        self._step_times[int(step)] = time.perf_counter()

    def _arm_stragglers(self, steps, end_t: float) -> bool:
        """Per-step deadline check over one finished chunk's device-side
        step boundaries.  The gap between consecutive step timestamps is
        one executed step's device time; the chunk's last step is bounded
        by the metrics-sync time (a slight over-estimate — host get
        latency — conservative in the drop direction).  Each straggling
        step arms ONE forced drop.  Returns whether any timestamps were
        available for this chunk."""
        jax.effects_barrier()          # flush this chunk's ordered callbacks
        ts = [self._step_times.pop(s, None) for s in steps]
        if all(t is None for t in ts):
            return False
        for i, t in enumerate(ts):
            if t is None:
                continue
            nxt = next((u for u in ts[i + 1:] if u is not None), end_t)
            if nxt - t > self.deadline_s:
                self._straggler_pending += 1
        return True

    def _final_save(self) -> bool:
        """Final checkpoint; returns whether every pending save landed.

        A failed write (disk full, permission — surfaced by the async
        writer after retries) is REPORTED, never claimed as success: the
        failures land in ``self.save_errors`` and are printed, and the
        caller can decide whether a run without a final checkpoint is
        acceptable.  Training results (history/telemetry) are preserved
        either way."""
        if not self.ckpt_dir:
            return True
        self._save(int(self.state.step) - 1)
        # the final save must survive process exit: async writers are
        # daemon threads, and an orphaned write leaves a stale .tmp
        # (and no checkpoint) for the next --resume to trip over
        from repro.ft.checkpoint import wait_for_saves
        failures = wait_for_saves(raise_on_error=False)
        if failures:
            self.save_errors.update(failures)
            for path, err in failures.items():
                print(f"CHECKPOINT SAVE FAILED (post-retry): {path}: {err!r}",
                      file=sys.stderr)
            return False
        return True

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------

    def steps_per_s(self) -> Optional[float]:
        """Executed-step throughput over the run's measured wall time."""
        wall = sum(h.get("wall_s", 0.0) for h in self.history)
        if not self.history or wall <= 0:
            return None
        return len(self.history) / wall

    def measured_psg_fallback(self) -> Optional[float]:
        """Mean measured PSG fallback-tile ratio over executed steps — the
        quantity core/energy.py uses in place of its 0.4 design assumption
        (``training_energy_pj(psg_fallback_rate=...)``).  ``None`` when no
        PSG step executed: no measurement is not a measurement of zero."""
        vals = [h["psg_fallback_ratio"] for h in self.history
                if "psg_fallback_ratio" in h]
        return float(np.mean(vals)) if vals else None

    def energy_report(self, steps: Optional[int] = None,
                      validate_against_hlo: bool = False):
        """The run's :class:`~repro.core.ledger.EnergyReport`: this run's
        telemetry (SMD executed/dropped counts, SLU execution ratios, PSG
        fallback-tile ratios) composed with the experiment's per-layer cost
        model and the 45nm per-op tables — measured next to assumed
        (DESIGN.md §Energy).  ``steps`` defaults to the config's nominal
        ``total_steps`` budget; ``validate_against_hlo`` additionally runs
        the static cost audit (``analysis/audit.py``, cached per config)
        and stamps its verdict into ``validated_against_hlo``."""
        from repro.core.ledger import EnergyLedger
        return EnergyLedger.from_trainer(self).report(
            steps=steps, validate_against_hlo=validate_against_hlo)

    def _save(self, step: int):
        from repro.ft.checkpoint import save_checkpoint
        save_checkpoint(self.ckpt_dir, self.state, step, async_save=True)
