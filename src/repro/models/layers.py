"""Core neural-net layers, pure-functional JAX.

Conventions
-----------
* Every layer is a pair of functions ``init_<layer>(key, cfg, ...) -> params``
  and ``<layer>(params, x, ...) -> y`` where ``params`` is a (nested) dict of
  ``jnp.ndarray``.
* Parameters are stored in ``cfg.param_dtype`` (fp32 by default) and cast to
  ``cfg.dtype`` (bf16) inside apply — standard mixed-precision training.
* Weight matrices are laid out ``(in_features, ..., out_features)`` so that
  ``x @ w`` contracts the trailing input axis; this keeps TP sharding rules
  uniform (shard the *output* axis of up-projections, the *input* axis of
  down-projections).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import psg
from repro.core.config import ModelConfig

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: float = 1.0, mode: str = "fan_in"):
    """LeCun/He-style truncated-normal init."""
    fan_in = shape[0] if mode == "fan_in" else shape[-1]
    std = scale / math.sqrt(max(fan_in, 1))
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.dtype(cfg.param_dtype))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.dtype(cfg.param_dtype))
    return p


def apply_norm(p: Params, x: jnp.ndarray, cfg: ModelConfig, eps: float = 1e-6):
    """Statistics accumulate in fp32 via ``preferred_element_type`` reductions
    so the (possibly scan-stacked) bf16 input is never upcast wholesale —
    XLA hoists such converts out of while loops, materializing a full fp32
    copy of the saved-residual stack."""
    d = x.shape[-1]
    if cfg.norm == "layernorm":
        one = jnp.ones((d,), x.dtype)
        mu = jnp.einsum("...d,d->...", x, one,
                        preferred_element_type=jnp.float32)[..., None] / d
        # var = E[x^2] - mu^2 (fp32 accumulation)
        ms = jnp.einsum("...d,...d->...", x, x,
                        preferred_element_type=jnp.float32)[..., None] / d
        var = ms - mu * mu
        inv = lax.rsqrt(var + eps)
        w = (inv * p["scale"].astype(jnp.float32))
        b = (p["bias"].astype(jnp.float32) - mu[..., 0:1] * w)
        y = x * w.astype(x.dtype) + b.astype(x.dtype)
    else:  # rmsnorm
        ms = jnp.einsum("...d,...d->...", x, x,
                        preferred_element_type=jnp.float32)[..., None] / d
        inv = lax.rsqrt(ms + eps)
        y = x * (inv * p["scale"].astype(jnp.float32)).astype(x.dtype)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, optional sliding window, causal, KV-cache decode)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nh, nkv = cfg.num_heads, cfg.num_kv_heads
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, nh, hd), pd),
        "wk": dense_init(ks[1], (d, nkv, hd), pd),
        "wv": dense_init(ks[2], (d, nkv, hd), pd),
        "wo": dense_init(ks[3], (nh, hd, d), pd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nh, hd), pd)
        p["bk"] = jnp.zeros((nkv, hd), pd)
        p["bv"] = jnp.zeros((nkv, hd), pd)
    return p


def _qkv(p: Params, x: jnp.ndarray, cfg: ModelConfig):
    dt = x.dtype
    q = psg.einsum("bsd,dnh->bsnh", x, p["wq"].astype(dt))
    k = psg.einsum("bsd,dnh->bsnh", x, p["wk"].astype(dt))
    v = psg.einsum("bsd,dnh->bsnh", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return q, k, v


@jax.custom_vjp
def _softmax_lowp(scores: jnp.ndarray) -> jnp.ndarray:
    """Row softmax with fp32 statistics but *bf16 probabilities* — the
    probability tensor is the largest attention buffer (fwd residual AND
    its gradient in bwd); storing it in bf16 halves the attention share of
    the memory roofline term, with fp32-stable max/sum reductions."""
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    return (e / jnp.sum(e, axis=-1, keepdims=True)).astype(jnp.bfloat16)


def _softmax_lowp_fwd(scores):
    w = _softmax_lowp(scores)
    return w, w


def _softmax_lowp_bwd(w, g):
    # ds = w * (g - sum(g * w)); the inner product accumulates fp32
    gw = jnp.einsum("...t,...t->...", g, w,
                    preferred_element_type=jnp.float32)[..., None]
    ds = w.astype(jnp.float32) * (g.astype(jnp.float32) - gw)
    return (ds,)


_softmax_lowp.defvjp(_softmax_lowp_fwd, _softmax_lowp_bwd)


def _sdpa(q, k, v, mask, cfg: ModelConfig):
    """q:(B,S,nh,hd) k/v:(B,T,nkv,hd) mask:(B,1,S,T) bool -> (B,S,nh,hd).

    GQA: query heads are grouped over kv heads via reshape (no repeat).
    """
    B, S, nh, hd = q.shape
    T, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    qf = q.reshape(B, S, nkv, g, hd)
    # bf16 x bf16 -> fp32 accumulation on the MXU; upcasting k wholesale
    # would materialize an fp32 copy of the (stacked) KV cache per decode
    # step (XLA hoists the convert out of the unit loop).
    scores = jnp.einsum("bsngh,btnh->bnsgt", qf, k,
                        preferred_element_type=jnp.float32) / math.sqrt(hd)
    scores = jnp.where(mask[:, :, :, None, :] if mask.ndim == 4 else mask,
                       scores, -1e30) if mask is not None else scores
    w = _softmax_lowp(scores)
    out = jnp.einsum("bnsgt,btnh->bsngh", w.astype(v.dtype), v)
    return out.reshape(B, S, nh, hd)


def causal_mask(S: int, T: int, offset: int = 0, window: int = 0) -> jnp.ndarray:
    """(S,T) bool; query i attends key j iff j <= i+offset (and within window)."""
    qi = jnp.arange(S)[:, None] + offset
    kj = jnp.arange(T)[None, :]
    m = kj <= qi
    if window > 0:
        m = m & (kj > qi - window)
    return m


ATTN_Q_CHUNK = 512          # query-chunked attention above this seq length
ATTN_CHUNK_THRESHOLD = 8192


def attention_fwd(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                  positions: Optional[jnp.ndarray] = None,
                  causal: bool = True,
                  prefer_chunked: bool = False,
                  return_kv: bool = False):
    """Full-sequence attention (training / prefill).

    For long sequences (prefill_32k+) the S x S score tensor does not fit
    HBM even sharded, so we stream query chunks against the full KV with a
    ``lax.scan`` — O(S * chunk) live memory (flash-attention's memory
    shape, adapted to TPU: the per-chunk matmuls stay MXU-sized and XLA
    double-buffers the scan).
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _qkv(p, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    chunk = S > ATTN_CHUNK_THRESHOLD or (prefer_chunked and S >= 2 * ATTN_Q_CHUNK)
    if causal and cfg.sliding_window == 0 and \
            psg.fused_attention_active(psg.active_config()):
        # flash Pallas kernels with the PSG dk/dv backward: no (S, T)
        # probability tensor in HBM in either direction, fallback stats on
        # the shared probe (core/psg.attention).  Sliding-window masks and
        # the decode ring buffer (attention_decode's wrap-aware masks need
        # a per-batch dynamic key length the kernel's static-length guard
        # does not express) stay on the materialized/chunked paths.
        out = psg.attention(q, k, v, causal=True)
    elif causal and chunk:
        out = _sdpa_qchunked(q, k, v, cfg)
    else:
        mask = causal_mask(S, S, 0, cfg.sliding_window)[None, None] if causal else None
        out = _sdpa(q, k, v, mask, cfg)
    y = psg.einsum("bsnh,nhd->bsd", out, p["wo"].astype(x.dtype))
    if return_kv:
        return y, (k, v)
    return y


def _sdpa_qchunked(q, k, v, cfg: ModelConfig):
    """Causal attention, scanning over query chunks vs full KV.

    Queries are padded up to a chunk multiple (VLM prefills prepend patch
    tokens, e.g. 32768+576); padded rows attend causally past the end and
    are sliced off."""
    B, S, nh, hd = q.shape
    L = ATTN_Q_CHUNK
    pad = (-S) % L
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nch = Sp // L
    qc = jnp.moveaxis(q.reshape(B, nch, L, nh, hd), 1, 0)     # (nch,B,L,nh,hd)

    @jax.checkpoint   # bwd recomputes each chunk's scores (O(L*S) live)
    def one_chunk(_, inp):
        qi, ci = inp
        offset = ci * L
        mask = (jnp.arange(S)[None, :] <= (jnp.arange(L)[:, None] + offset))
        if cfg.sliding_window > 0:
            mask = mask & (jnp.arange(S)[None, :] >
                           (jnp.arange(L)[:, None] + offset - cfg.sliding_window))
        yi = _sdpa(qi, k, v, mask[None, None], cfg)
        return None, yi

    _, yc = lax.scan(one_chunk, None, (qc, jnp.arange(nch)))
    out = jnp.moveaxis(yc, 0, 1).reshape(B, Sp, nh, hd)
    return out[:, :S] if pad else out


def attention_decode(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                     kv_cache: Tuple[jnp.ndarray, jnp.ndarray],
                     cache_len: jnp.ndarray) -> Tuple[jnp.ndarray, Tuple]:
    """One-token decode. x:(B,1,d); kv_cache k/v:(B,T,nkv,hd); cache_len:(B,).

    With sliding-window attention the cache is a ring buffer of size
    ``min(T, window)`` — positions wrap; masking handles validity.
    """
    B = x.shape[0]
    kc, vc = kv_cache
    T = kc.shape[1]
    pos = cache_len[:, None]                     # (B,1) absolute position
    q, k, v = _qkv(p, x, cfg)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    slot = (cache_len % T)
    kc = jax.vmap(lambda c, u, s: lax.dynamic_update_slice(c, u, (s, 0, 0)))(
        kc, k.astype(kc.dtype), slot)
    vc = jax.vmap(lambda c, u, s: lax.dynamic_update_slice(c, u, (s, 0, 0)))(
        vc, v.astype(vc.dtype), slot)
    # key j (ring index) valid iff its absolute position within [pos-window, pos]
    idx = jnp.arange(T)[None, :]                  # ring indices
    n_valid = jnp.minimum(cache_len + 1, T)[:, None]
    # absolute position of ring slot j:
    wraps = (cache_len[:, None] + 1) > T
    abs_pos = jnp.where(wraps, cache_len[:, None] - ((slot[:, None] - idx) % T), idx)
    valid = idx < n_valid
    if cfg.sliding_window > 0:
        valid = valid & (abs_pos > cache_len[:, None] - cfg.sliding_window)
    mask = valid[:, None, None, :]                # (B,1,1,T)
    out = _sdpa(q, kc, vc, mask, cfg).astype(x.dtype)
    y = jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(x.dtype))
    return y, (kc, vc)


def fill_kv_cache(cfg: ModelConfig, k: jnp.ndarray, v: jnp.ndarray,
                  max_len: int, dtype=jnp.bfloat16):
    """Build decode ring buffers from prefill K/V (B, S, nkv, hd).

    With sliding-window attention the cache holds the last ``window``
    positions at slots ``abs % T`` — the layout ``attention_decode``'s
    wrap-aware masking expects."""
    B, S = k.shape[0], k.shape[1]
    T = min(max_len, cfg.sliding_window) if cfg.sliding_window > 0 else max_len
    kc, vc = init_kv_cache(cfg, B, max_len, dtype)
    n = min(S, T)
    idx_abs = jnp.arange(S - n, S)
    slots = idx_abs % T
    kc = kc.at[:, slots].set(k[:, idx_abs].astype(dtype))
    vc = vc.at[:, slots].set(v[:, idx_abs].astype(dtype))
    return kc, vc


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, jnp.ndarray]:
    T = min(max_len, cfg.sliding_window) if cfg.sliding_window > 0 else max_len
    shape = (batch, T, cfg.num_kv_heads, cfg.resolved_head_dim)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


# ---------------------------------------------------------------------------
# cross attention (whisper decoder)
# ---------------------------------------------------------------------------


def cross_attention_fwd(p: Params, x: jnp.ndarray, memory: jnp.ndarray,
                        cfg: ModelConfig) -> jnp.ndarray:
    dt = x.dtype
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,dnh->btnh", memory, p["wk"].astype(dt))
    v = jnp.einsum("btd,dnh->btnh", memory, p["wv"].astype(dt))
    out = _sdpa(q, k, v, None, cfg)
    return jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(dt))


# ---------------------------------------------------------------------------
# MLP (optionally gated / GLU)
# ---------------------------------------------------------------------------

_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None,
             d_model: Optional[int] = None) -> Params:
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d, f), pd),
         "w_down": dense_init(ks[1], (f, d), pd)}
    if cfg.glu:
        p["w_gate"] = dense_init(ks[2], (d, f), pd)
    if cfg.mlp_bias:
        p["b_up"] = jnp.zeros((f,), pd)
        p["b_down"] = jnp.zeros((d,), pd)
    return p


def mlp_fwd(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    dt = x.dtype
    act = _ACTS[cfg.act]
    up = psg.matmul(x, p["w_up"].astype(dt))
    if cfg.mlp_bias:
        up = up + p["b_up"].astype(dt)
    h = act(up) * psg.matmul(x, p["w_gate"].astype(dt)) if cfg.glu else act(up)
    y = psg.matmul(h, p["w_down"].astype(dt))
    if cfg.mlp_bias:
        y = y + p["b_down"].astype(dt)
    return y
