"""Model zoo: assigned LM-family architectures + the paper's CNN backbones."""
