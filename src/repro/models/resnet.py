"""Paper-faithful CIFAR backbones: ResNet-74 / ResNet-110 / MobileNetV2.

These are the models the paper actually trains (§4.1): CIFAR-style ResNets
(6n+2 layers; n=12 -> 74, n=18 -> 110, [He et al. 2016]) and MobileNetV2
scaled for 32x32 inputs.  E²-Train hooks are identical to the transformer
path: SLU gates every residual block (the paper's granularity), PSG routes
the conv-as-matmul weight gradients, SMD lives in the data pipeline.

Convs are implemented as im2col + ``psg.matmul`` so the PSG custom-vjp (and
later the Pallas kernel) applies to the conv backward exactly as the paper's
Eq. (4) describes (``g_w`` as a sum of input x output-grad inner products).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import psg, slu
from repro.core.config import E2TrainConfig
from repro.models.layers import dense_init

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# conv via im2col (PSG-routable)
# ---------------------------------------------------------------------------


def init_conv(key, cin: int, cout: int, k: int = 3) -> Params:
    return {"w": dense_init(key, (k * k * cin, cout), jnp.float32, scale=1.41)}


def conv2d(p: Params, x: jnp.ndarray, k: int = 3, stride: int = 1) -> jnp.ndarray:
    """x: (B, H, W, C) -> (B, H', W', cout) via im2col + matmul."""
    B, H, W, C = x.shape
    pad = k // 2
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    patches = lax.conv_general_dilated_patches(
        xp, (k, k), (stride, stride), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    Ho, Wo = patches.shape[1], patches.shape[2]
    y = psg.matmul(patches.reshape(B * Ho * Wo, k * k * C), p["w"])
    return y.reshape(B, Ho, Wo, -1)


def init_bn(c: int) -> Params:
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,)),
            "mean": jnp.zeros((c,)), "var": jnp.ones((c,))}


def batchnorm(p: Params, x: jnp.ndarray, train: bool = True):
    if train:
        mu = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
    else:
        mu, var = p["mean"], p["var"]
    y = (x - mu) * lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    return y


# ---------------------------------------------------------------------------
# CIFAR ResNet (6n+2)
# ---------------------------------------------------------------------------


def resnet_depth_to_n(depth: int) -> int:
    assert (depth - 2) % 6 == 0, "CIFAR ResNet depth must be 6n+2"
    return (depth - 2) // 6


def init_resnet(key, depth: int, num_classes: int = 10,
                e2: Optional[E2TrainConfig] = None,
                width: int = 16) -> Params:
    n = resnet_depth_to_n(depth)
    e2 = e2 or E2TrainConfig()
    keys = jax.random.split(key, 3 * n * 2 + 5)
    ki = iter(range(len(keys)))
    p: Params = {"stem": init_conv(keys[next(ki)], 3, width),
                 "stem_bn": init_bn(width), "blocks": [], "downs": []}
    cin = width
    for stage, cout in enumerate((width, 2 * width, 4 * width)):
        for b in range(n):
            blk = {"conv1": init_conv(keys[next(ki)], cin if b == 0 else cout, cout),
                   "bn1": init_bn(cout),
                   "conv2": init_conv(keys[next(ki)], cout, cout),
                   "bn2": init_bn(cout)}
            p["blocks"].append(blk)
            if b == 0 and cin != cout:
                p["downs"].append({"conv": init_conv(keys[next(ki)], cin, cout, k=1)})
            elif b == 0:
                p["downs"].append(None)
            cin = cout
    p["fc_w"] = dense_init(keys[next(ki)], (4 * width, num_classes), jnp.float32)
    p["fc_b"] = jnp.zeros((num_classes,))
    if e2.slu.enabled:
        # gate operates on channel-pooled features; proj from max width
        p["slu_gate"] = _init_cnn_gate(keys[next(ki)], 4 * width, e2.slu)
    return p


def _init_cnn_gate(key, cmax: int, slu_cfg) -> Params:
    ks = jax.random.split(key, 4)
    h, pj = slu_cfg.gate_hidden, slu_cfg.gate_proj
    return {"proj": dense_init(ks[0], (cmax, pj), jnp.float32),
            "lstm_wx": dense_init(ks[1], (pj, 4 * h), jnp.float32),
            "lstm_wh": dense_init(ks[2], (h, 4 * h), jnp.float32),
            "lstm_b": jnp.zeros((4 * h,), jnp.float32),
            "head_w": dense_init(ks[3], (h, 1), jnp.float32),
            "head_b": jnp.zeros((1,), jnp.float32)}


def _cnn_gate_apply(gp: Params, x: jnp.ndarray, state, slu_cfg):
    """Gate input = global-average-pooled features (paper Fig. 7)."""
    pooled = jnp.mean(x.astype(jnp.float32), axis=(0, 1, 2))
    cmax = gp["proj"].shape[0]
    pooled = jnp.pad(pooled, (0, cmax - pooled.shape[0]))
    z = pooled @ gp["proj"]
    h_prev, c_prev = state
    g = z @ gp["lstm_wx"] + h_prev @ gp["lstm_wh"] + gp["lstm_b"]
    i_t, f_t, o_t, u_t = jnp.split(g, 4)
    c = jax.nn.sigmoid(f_t + 1.0) * c_prev + jax.nn.sigmoid(i_t) * jnp.tanh(u_t)
    h = jax.nn.sigmoid(o_t) * jnp.tanh(c)
    logit = (h @ gp["head_w"] + gp["head_b"])[0]
    pkeep = jnp.clip(jax.nn.sigmoid(logit), slu_cfg.min_keep_prob, 1.0)
    return pkeep, (h, c)


def resnet_fwd(p: Params, x: jnp.ndarray, depth: int,
               e2: Optional[E2TrainConfig] = None,
               rng: Optional[jnp.ndarray] = None,
               train: bool = True) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B, 32, 32, 3) -> (logits, aux{slu_cost, executed})."""
    n = resnet_depth_to_n(depth)
    e2 = e2 or E2TrainConfig()
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    slu_on = e2.slu.enabled and train and "slu_gate" in p

    h = jax.nn.relu(batchnorm(p["stem_bn"], conv2d(p["stem"], x), train))
    gst = (jnp.zeros((e2.slu.gate_hidden,)), jnp.zeros((e2.slu.gate_hidden,)))
    kps, exs = [], []
    bi = 0
    n_blocks = 3 * n
    for stage in range(3):
        for b in range(n):
            blk = p["blocks"][bi]
            stride = 2 if (stage > 0 and b == 0) else 1
            down = p["downs"][stage] if b == 0 else None

            def block_fn(h, blk=blk, stride=stride, down=down):
                y = jax.nn.relu(batchnorm(blk["bn1"],
                                          conv2d(blk["conv1"], h, stride=stride),
                                          train))
                y = batchnorm(blk["bn2"], conv2d(blk["conv2"], y), train)
                return y

            shortcut = h
            if down is not None:
                shortcut = conv2d(down["conv"], h, k=1, stride=2 if stage > 0 else 1)
            if slu_on and stride == 1 and down is None:
                pkeep, gst = _cnn_gate_apply(p["slu_gate"], h, gst, e2.slu)
                brng = jax.random.fold_in(rng, bi)
                force = jnp.bool_(bi == 0 or bi == n_blocks - 1) \
                    if e2.slu.never_skip_first_last else jnp.bool_(False)
                keep = jax.random.bernoulli(brng, pkeep) | force
                g_st = 1.0 + pkeep - lax.stop_gradient(pkeep)
                h = lax.cond(keep,
                             lambda h: h + g_st * block_fn(h),
                             lambda h: h, h)
                h = jax.nn.relu(h)
                kps.append(pkeep); exs.append(keep.astype(jnp.float32))
            else:
                h = jax.nn.relu(shortcut + block_fn(h))
                kps.append(jnp.float32(1.0)); exs.append(jnp.float32(1.0))
            bi += 1
    pooled = jnp.mean(h, axis=(1, 2))
    logits = pooled @ p["fc_w"] + p["fc_b"]
    kps_a = jnp.stack(kps)
    aux = {"slu_cost": jnp.mean(kps_a) if slu_on else jnp.float32(1.0),
           "slu_executed": jnp.stack(exs), "slu_keep_probs": kps_a}
    return logits, aux


def resnet_loss(p: Params, batch, depth: int, e2=None, rng=None):
    e2 = e2 or E2TrainConfig()
    logits, aux = resnet_fwd(p, batch["image"], depth, e2, rng)
    labels = batch["label"]
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))
    total = nll + (e2.slu.alpha * aux["slu_cost"] if e2.slu.enabled else 0.0)
    return total, {"loss": nll, "slu_cost": aux["slu_cost"],
                   "slu_exec_ratio": jnp.mean(aux["slu_executed"])}


# ---------------------------------------------------------------------------
# MobileNetV2 (CIFAR variant)
# ---------------------------------------------------------------------------

MBV2_CFG = [  # (expansion, cout, blocks, stride)
    (1, 16, 1, 1), (6, 24, 2, 1), (6, 32, 3, 2),
    (6, 64, 4, 2), (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]


def init_mobilenetv2(key, num_classes: int = 10) -> Params:
    keys = jax.random.split(key, 64)
    ki = iter(range(64))
    p: Params = {"stem": init_conv(keys[next(ki)], 3, 32), "stem_bn": init_bn(32),
                 "blocks": []}
    cin = 32
    for t, c, nblk, s in MBV2_CFG:
        for b in range(nblk):
            stride = s if b == 0 else 1
            hidden = cin * t
            blk = {"expand": init_conv(keys[next(ki)], cin, hidden, k=1),
                   "bn1": init_bn(hidden),
                   "dw": dense_init(keys[next(ki)], (3 * 3, hidden), jnp.float32),
                   "bn2": init_bn(hidden),
                   "project": init_conv(keys[next(ki)], hidden, c, k=1),
                   "bn3": init_bn(c),
                   "stride": stride, "residual": stride == 1 and cin == c}
            p["blocks"].append(blk)
            cin = c
    p["head"] = init_conv(keys[next(ki)], cin, 1280, k=1)
    p["head_bn"] = init_bn(1280)
    p["fc_w"] = dense_init(keys[next(ki)], (1280, num_classes), jnp.float32)
    p["fc_b"] = jnp.zeros((num_classes,))
    return p


def _depthwise(w: jnp.ndarray, x: jnp.ndarray, stride: int) -> jnp.ndarray:
    B, H, W, C = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    cols = []
    for i in range(3):
        for j in range(3):
            cols.append(xp[:, i:i + H:1, j:j + W:1, :])
    stack = jnp.stack(cols, axis=-2)                       # (B,H,W,9,C)
    y = jnp.sum(stack * w[None, None, None], axis=-2)
    if stride > 1:
        y = y[:, ::stride, ::stride]
    return y


def mobilenetv2_fwd(p: Params, x: jnp.ndarray, train: bool = True):
    h = jax.nn.relu6(batchnorm(p["stem_bn"], conv2d(p["stem"], x), train))
    for blk in p["blocks"]:
        inp = h
        y = jax.nn.relu6(batchnorm(blk["bn1"], conv2d(blk["expand"], h, k=1), train))
        y = jax.nn.relu6(batchnorm(blk["bn2"],
                                   _depthwise(blk["dw"], y, blk["stride"]), train))
        y = batchnorm(blk["bn3"], conv2d(blk["project"], y, k=1), train)
        h = inp + y if blk["residual"] else y
    h = jax.nn.relu6(batchnorm(p["head_bn"], conv2d(p["head"], h, k=1), train))
    pooled = jnp.mean(h, axis=(1, 2))
    return pooled @ p["fc_w"] + p["fc_b"]
