"""Paper-faithful CIFAR backbones: ResNet-74 / ResNet-110 / MobileNetV2.

These are the models the paper actually trains (§4.1): CIFAR-style ResNets
(6n+2 layers; n=12 -> 74, n=18 -> 110, [He et al. 2016]) and MobileNetV2
scaled for 32x32 inputs.  E²-Train hooks are identical to the transformer
path: SLU gates every residual block (the paper's granularity), PSG routes
the conv-as-matmul weight gradients, SMD lives in the data pipeline.

Convs are implemented as im2col + ``psg.matmul`` so the PSG custom-vjp (and
later the Pallas kernel) applies to the conv backward exactly as the paper's
Eq. (4) describes (``g_w`` as a sum of input x output-grad inner products).

Structure (mirrors the transformer stack, DESIGN.md §Tasks):

* **Scanned stages.**  Each of the three CIFAR stages holds one unrolled
  *transition* block (``trans`` — owns the stride-2 spatial reduction and
  the 1x1 projection shortcut ``down`` when the channel count changes) plus
  the remaining ``n-1`` identical blocks with parameters stacked on a
  leading axis (``rest``), executed with ``jax.lax.scan``.  ResNet-110
  traces as 3 transition blocks + 3 scans of 17 instead of 54 unrolled
  blocks, so ``jax.jit`` of a full train step completes in seconds.  The
  SLU gate's LSTM state and the ``lax.cond`` hard skip are carried through
  the scan exactly like the LM path.
* **BatchNorm running statistics** live in a *state* tree parallel to the
  params (same ``stages``/``trans``/``rest`` shape): the forward threads
  them through the scan and returns the EMA-updated tree, so ``train=False``
  evaluation normalizes with learned statistics — and the optimizer never
  touches them (they are not parameters).
* ``resnet_fwd_ref`` keeps the per-block unrolled execution over the same
  parameter layout as the scan's semantics anchor (tests/test_resnet_scan).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import psg, slu
from repro.core.config import E2TrainConfig
from repro.models.layers import dense_init

Params = Dict[str, Any]

BN_MOMENTUM = 0.9           # running-stat EMA decay per executed train step


# ---------------------------------------------------------------------------
# conv via im2col (PSG-routable)
# ---------------------------------------------------------------------------


def init_conv(key, cin: int, cout: int, k: int = 3) -> Params:
    return {"w": dense_init(key, (k * k * cin, cout), jnp.float32, scale=1.41)}


def conv2d(p: Params, x: jnp.ndarray, k: int = 3, stride: int = 1) -> jnp.ndarray:
    """x: (B, H, W, C) -> (B, H', W', cout).

    With an active PSG context whose ``fused_conv`` resolves on (the
    default on the reference/interpret backends — see
    ``psg.fused_conv_active``), the conv runs through ``psg.conv2d`` — the
    fused implicit-GEMM Pallas kernels (``kernels/conv.py``) that gather
    the k x k patches inside the kernel in BOTH directions (forward, PSG
    weight gradient, and the implicit transposed-conv input gradient) and
    never write a ``(B*H'*W', k*k*C)`` patch tensor to HBM (DESIGN.md
    §Kernels).  Otherwise: materialized im2col + ``psg.matmul`` (the
    original PSG-routable formulation, kept as the correctness anchor and
    the Mosaic default pending real-TPU profiling).  Both model families
    share this entry point (the MobileNetV2 1x1 expand/project/head convs
    included).
    """
    cfg = psg.active_config()
    if psg.fused_conv_active(cfg):
        return psg.conv2d(x, p["w"], k=k, stride=stride)
    B, H, W, C = x.shape
    pad = k // 2
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    patches = lax.conv_general_dilated_patches(
        xp, (k, k), (stride, stride), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    Ho, Wo = patches.shape[1], patches.shape[2]
    y = psg.matmul(patches.reshape(B * Ho * Wo, k * k * C), p["w"])
    return y.reshape(B, Ho, Wo, -1)


def init_bn(c: int) -> Params:
    """Trainable affine only — running stats live in the state tree."""
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def init_bn_state(c: int) -> Params:
    return {"mean": jnp.zeros((c,)), "var": jnp.ones((c,))}


def batchnorm(p: Params, s: Params, x: jnp.ndarray, train: bool = True
              ) -> Tuple[jnp.ndarray, Params]:
    """Returns (normalized x, new running-stat state).

    Train mode normalizes with batch statistics and moves the EMA toward
    them; eval mode normalizes with the stored statistics and leaves the
    state untouched.
    """
    if train:
        mu = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
        new_s = {"mean": BN_MOMENTUM * s["mean"] + (1.0 - BN_MOMENTUM) * mu,
                 "var": BN_MOMENTUM * s["var"] + (1.0 - BN_MOMENTUM) * var}
    else:
        mu, var = s["mean"], s["var"]
        new_s = s
    y = (x - mu) * lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    return y, new_s


def _stack(trees: List[Params]) -> Params:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# ---------------------------------------------------------------------------
# CIFAR ResNet (6n+2)
# ---------------------------------------------------------------------------


def resnet_depth_to_n(depth: int) -> int:
    assert (depth - 2) % 6 == 0, "CIFAR ResNet depth must be 6n+2"
    return (depth - 2) // 6


def _init_block(key, cin: int, cout: int) -> Params:
    k1, k2 = jax.random.split(key)
    return {"conv1": init_conv(k1, cin, cout), "bn1": init_bn(cout),
            "conv2": init_conv(k2, cout, cout), "bn2": init_bn(cout)}


def _init_block_state(cout: int) -> Params:
    return {"bn1": init_bn_state(cout), "bn2": init_bn_state(cout)}


def init_resnet(key, depth: int, num_classes: int = 10,
                e2: Optional[E2TrainConfig] = None,
                width: int = 16) -> Tuple[Params, Params]:
    """Returns (params, state): state is the BatchNorm running-stat tree."""
    n = resnet_depth_to_n(depth)
    e2 = e2 or E2TrainConfig()
    # fixed budget: stem + 3 x (trans, down, rest-fold base) + fc + gate
    keys = jax.random.split(key, 12)
    ki = iter(range(len(keys)))
    p: Params = {"stem": init_conv(keys[next(ki)], 3, width),
                 "stem_bn": init_bn(width), "stages": []}
    s: Params = {"stem_bn": init_bn_state(width), "stages": []}
    cin = width
    for stage, cout in enumerate((width, 2 * width, 4 * width)):
        trans = _init_block(keys[next(ki)], cin, cout)
        if cin != cout:
            trans["down"] = {"conv": init_conv(keys[next(ki)], cin, cout, k=1)}
        else:
            next(ki)
        sp: Params = {"trans": trans}
        ss: Params = {"trans": _init_block_state(cout)}
        if n > 1:
            rest_base = keys[next(ki)]
            sp["rest"] = _stack([_init_block(jax.random.fold_in(rest_base, b),
                                             cout, cout) for b in range(n - 1)])
            ss["rest"] = _stack([_init_block_state(cout) for _ in range(n - 1)])
        else:
            next(ki)
        p["stages"].append(sp)
        s["stages"].append(ss)
        cin = cout
    p["fc_w"] = dense_init(keys[next(ki)], (4 * width, num_classes), jnp.float32)
    p["fc_b"] = jnp.zeros((num_classes,))
    if e2.slu.enabled:
        # weight-shared gate on channel-pooled features, padded to max width
        p["slu_gate"] = slu.init_gate(keys[next(ki)], 4 * width, e2.slu)
    return p, s


def _block_branch(blk: Params, bst: Params, h: jnp.ndarray, stride: int,
                  train: bool) -> Tuple[jnp.ndarray, Params]:
    """conv-BN-relu-conv-BN residual branch; returns (branch, new bn state)."""
    y, ns1 = batchnorm(blk["bn1"], bst["bn1"],
                       conv2d(blk["conv1"], h, stride=stride), train)
    y = jax.nn.relu(y)
    y, ns2 = batchnorm(blk["bn2"], bst["bn2"], conv2d(blk["conv2"], y), train)
    return y, {"bn1": ns1, "bn2": ns2}


def _gated_block(blk, bst, h, gate_params, gst, glob, n_blocks, e2, rng,
                 train: bool, slu_on: bool):
    """Stride-1 identity-shortcut block, SLU-gated when ``slu_on``.

    ``glob`` may be a traced scalar (the scan's block-index input); returns
    (h, new_bn_state, new_gate_state, keep_prob, executed).
    """
    if not slu_on:
        y, nbst = _block_branch(blk, bst, h, 1, train)
        return (jax.nn.relu(h + y), nbst, gst,
                jnp.float32(1.0), jnp.float32(1.0))
    pkeep, gst = slu.gate_apply(gate_params, h, gst, e2.slu)
    brng = jax.random.fold_in(rng, glob)
    force = ((glob == 0) | (glob == n_blocks - 1)) \
        if e2.slu.never_skip_first_last else jnp.bool_(False)
    keep = jax.random.bernoulli(brng, pkeep) | force
    g_st = 1.0 + pkeep - lax.stop_gradient(pkeep)   # straight-through factor

    def run(op):
        h, bst = op
        y, nbst = _block_branch(blk, bst, h, 1, train)
        return h + g_st * y, nbst

    h, nbst = lax.cond(keep, run, lambda op: op, (h, bst))
    return jax.nn.relu(h), nbst, gst, pkeep, keep.astype(jnp.float32)


def _transition_block(sp, ss, h, stage, gate_params, gst, glob, n_blocks,
                      e2, rng, train: bool, slu_on: bool):
    """First block of a stage.  With a projection shortcut it is never gated
    (the paper gates only identity-shortcut blocks); stage 0's transition is
    an ordinary stride-1 identity block and gates like the rest."""
    blk, bst = sp["trans"], ss["trans"]
    stride = 2 if stage > 0 else 1
    if "down" in blk:
        shortcut = conv2d(blk["down"]["conv"], h, k=1, stride=stride)
        y, nbst = _block_branch(blk, bst, h, stride, train)
        return (jax.nn.relu(shortcut + y), nbst, gst,
                jnp.float32(1.0), jnp.float32(1.0))
    return _gated_block(blk, bst, h, gate_params, gst, glob, n_blocks, e2,
                        rng, train, slu_on)


def resnet_fwd(p: Params, state: Params, x: jnp.ndarray, depth: int,
               e2: Optional[E2TrainConfig] = None,
               rng: Optional[jnp.ndarray] = None,
               train: bool = True
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray], Params]:
    """x: (B, 32, 32, 3) -> (logits, aux{slu_*}, new running-stat state).

    Per-stage ``lax.scan`` over the stacked ``rest`` blocks; the SLU gate
    state, the activations, and the BN statistics thread through the scan.
    """
    n = resnet_depth_to_n(depth)
    e2 = e2 or E2TrainConfig()
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    slu_on = e2.slu.enabled and train and "slu_gate" in p
    gate_params = p.get("slu_gate")
    n_blocks = 3 * n

    # "cost:<group>" scopes are the attribution anchors the static audit
    # reads back out of the traced jaxpr (analysis/jaxpr_cost.py); group
    # names follow core/cost.py's layer prefixes (s{i}b0 -> s{i}.trans,
    # s{i}b{1..} -> s{i}.rest).
    with jax.named_scope("cost:stem"):
        h, ns_stem = batchnorm(p["stem_bn"], state["stem_bn"],
                               conv2d(p["stem"], x), train)
        h = jax.nn.relu(h)
    gst = slu.init_gate_state(e2.slu)
    new_state: Params = {"stem_bn": ns_stem, "stages": []}
    kps, exs = [], []
    for stage in range(3):
        sp, ss = p["stages"][stage], state["stages"][stage]
        glob = stage * n
        with jax.named_scope(f"cost:s{stage}.trans"):
            h, nbst, gst, kp, ex = _transition_block(
                sp, ss, h, stage, gate_params, gst, glob, n_blocks, e2, rng,
                train, slu_on)
        nss: Params = {"trans": nbst}
        kps.append(kp[None]); exs.append(ex[None])
        if n > 1:
            globs = jnp.arange(glob + 1, glob + n)

            def body(carry, xs, n_blocks=n_blocks, stage=stage):
                h, gst = carry
                bp, bs, g = xs
                with jax.named_scope(f"cost:s{stage}.rest"):
                    h, nbst, gst, kp, ex = _gated_block(
                        bp, bs, h, gate_params, gst, g, n_blocks, e2, rng,
                        train, slu_on)
                return (h, gst), (nbst, kp, ex)

            (h, gst), (rest_ns, rest_kp, rest_ex) = lax.scan(
                body, (h, gst), (sp["rest"], ss["rest"], globs))
            nss["rest"] = rest_ns
            kps.append(rest_kp); exs.append(rest_ex)
        new_state["stages"].append(nss)

    with jax.named_scope("cost:fc"):
        pooled = jnp.mean(h, axis=(1, 2))
        logits = pooled @ p["fc_w"] + p["fc_b"]
    kps_a = jnp.concatenate(kps)
    aux = {"slu_cost": jnp.mean(kps_a) if slu_on else jnp.float32(1.0),
           "slu_executed": jnp.concatenate(exs), "slu_keep_probs": kps_a}
    return logits, aux, new_state


def resnet_fwd_ref(p: Params, state: Params, x: jnp.ndarray, depth: int,
                   e2: Optional[E2TrainConfig] = None,
                   rng: Optional[jnp.ndarray] = None,
                   train: bool = True
                   ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray], Params]:
    """Per-block unrolled reference over the same parameter layout.

    Semantics anchor for the scanned forward (identical block math, RNG
    folding, gate-state order, and BN-state threading — only the iteration
    strategy differs).  Kept for parity tests; ResNet-110 through this path
    unrolls 54 blocks and traces accordingly slowly.
    """
    n = resnet_depth_to_n(depth)
    e2 = e2 or E2TrainConfig()
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    slu_on = e2.slu.enabled and train and "slu_gate" in p
    gate_params = p.get("slu_gate")
    n_blocks = 3 * n

    h, ns_stem = batchnorm(p["stem_bn"], state["stem_bn"],
                           conv2d(p["stem"], x), train)
    h = jax.nn.relu(h)
    gst = slu.init_gate_state(e2.slu)
    new_state: Params = {"stem_bn": ns_stem, "stages": []}
    kps, exs = [], []
    for stage in range(3):
        sp, ss = p["stages"][stage], state["stages"][stage]
        glob = stage * n
        h, nbst, gst, kp, ex = _transition_block(
            sp, ss, h, stage, gate_params, gst, glob, n_blocks, e2, rng,
            train, slu_on)
        nss: Params = {"trans": nbst}
        kps.append(kp); exs.append(ex)
        if n > 1:
            rest_ns = []
            for b in range(n - 1):
                bp = jax.tree.map(lambda a, b=b: a[b], sp["rest"])
                bs = jax.tree.map(lambda a, b=b: a[b], ss["rest"])
                h, nbst, gst, kp, ex = _gated_block(
                    bp, bs, h, gate_params, gst, jnp.int32(glob + 1 + b),
                    n_blocks, e2, rng, train, slu_on)
                rest_ns.append(nbst)
                kps.append(kp); exs.append(ex)
            nss["rest"] = _stack(rest_ns)
        new_state["stages"].append(nss)

    pooled = jnp.mean(h, axis=(1, 2))
    logits = pooled @ p["fc_w"] + p["fc_b"]
    kps_a = jnp.stack(kps)
    aux = {"slu_cost": jnp.mean(kps_a) if slu_on else jnp.float32(1.0),
           "slu_executed": jnp.stack(exs), "slu_keep_probs": kps_a}
    return logits, aux, new_state


def resnet_loss(p: Params, state: Params, batch, depth: int, e2=None,
                rng=None, train: bool = True, fwd=resnet_fwd):
    """Cross-entropy + SLU FLOPs regularizer (Eq. 1).

    Returns ``(total, (metrics, new_state))`` — the task-registry loss
    contract (``repro.tasks``); ``new_state`` is the updated BN-stat tree.
    """
    e2 = e2 or E2TrainConfig()
    logits, aux, new_state = fwd(p, state, batch["image"], depth, e2, rng,
                                 train=train)
    labels = batch["label"]
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))
    total = nll + (e2.slu.alpha * aux["slu_cost"] if e2.slu.enabled else 0.0)
    metrics = {"loss": nll, "slu_cost": aux["slu_cost"],
               "slu_exec_ratio": jnp.mean(aux["slu_executed"])}
    return total, (metrics, new_state)


# ---------------------------------------------------------------------------
# MobileNetV2 (CIFAR variant)
# ---------------------------------------------------------------------------

MBV2_CFG = [  # (expansion, cout, blocks, stride)
    (1, 16, 1, 1), (6, 24, 2, 1), (6, 32, 3, 2),
    (6, 64, 4, 2), (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]


def _mbv2_layout() -> List[Tuple[int, int, int, int, bool]]:
    """Static per-block (cin, hidden, cout, stride, residual) — architecture
    facts stay out of the param pytree so optimizers only see arrays."""
    cin, out = 32, []
    for t, c, nblk, s in MBV2_CFG:
        for b in range(nblk):
            stride = s if b == 0 else 1
            out.append((cin, cin * t, c, stride, stride == 1 and cin == c))
            cin = c
    return out


def init_mobilenetv2(key, num_classes: int = 10) -> Tuple[Params, Params]:
    """Returns (params, state): state is the BatchNorm running-stat tree."""
    keys = jax.random.split(key, 64)
    ki = iter(range(64))
    p: Params = {"stem": init_conv(keys[next(ki)], 3, 32), "stem_bn": init_bn(32),
                 "blocks": []}
    s: Params = {"stem_bn": init_bn_state(32), "blocks": []}
    for cin, hidden, c, _stride, _res in _mbv2_layout():
        blk = {"expand": init_conv(keys[next(ki)], cin, hidden, k=1),
               "bn1": init_bn(hidden),
               "dw": dense_init(keys[next(ki)], (3 * 3, hidden), jnp.float32),
               "bn2": init_bn(hidden),
               "project": init_conv(keys[next(ki)], hidden, c, k=1),
               "bn3": init_bn(c)}
        p["blocks"].append(blk)
        s["blocks"].append({"bn1": init_bn_state(hidden),
                            "bn2": init_bn_state(hidden),
                            "bn3": init_bn_state(c)})
    last_cout = _mbv2_layout()[-1][2]
    p["head"] = init_conv(keys[next(ki)], last_cout, 1280, k=1)
    p["head_bn"] = init_bn(1280)
    s["head_bn"] = init_bn_state(1280)
    p["fc_w"] = dense_init(keys[next(ki)], (1280, num_classes), jnp.float32)
    p["fc_b"] = jnp.zeros((num_classes,))
    return p, s


def _depthwise(w: jnp.ndarray, x: jnp.ndarray, stride: int) -> jnp.ndarray:
    """3x3 depthwise conv: stride applied to the patch stack *before* the
    multiply-sum, so a stride-2 block computes a quarter of the products
    instead of computing full resolution and slicing the result."""
    B, H, W, C = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    cols = []
    for i in range(3):
        for j in range(3):
            cols.append(xp[:, i:i + H:stride, j:j + W:stride, :])
    stack = jnp.stack(cols, axis=-2)                 # (B,H',W',9,C)
    return jnp.sum(stack * w[None, None, None], axis=-2)


def mobilenetv2_fwd(p: Params, state: Params, x: jnp.ndarray,
                    train: bool = True) -> Tuple[jnp.ndarray, Params]:
    """Returns (logits, new running-stat state)."""
    with jax.named_scope("cost:stem"):
        h, ns_stem = batchnorm(p["stem_bn"], state["stem_bn"],
                               conv2d(p["stem"], x), train)
        h = jax.nn.relu6(h)
    new_state: Params = {"stem_bn": ns_stem, "blocks": []}
    for i, (blk, bst, (_cin, _hid, _c, stride, residual)) in enumerate(zip(
            p["blocks"], state["blocks"], _mbv2_layout())):
        # nested scopes: the dw tag is innermost, so the audit walker
        # attributes the depthwise multiply-sum separately from the
        # block's 1x1 expand/project convs (their MAC models differ).
        with jax.named_scope(f"cost:b{i}"):
            inp = h
            y, ns1 = batchnorm(blk["bn1"], bst["bn1"],
                               conv2d(blk["expand"], h, k=1), train)
            y = jax.nn.relu6(y)
            with jax.named_scope(f"cost:b{i}.dw"):
                y = _depthwise(blk["dw"], y, stride)
            y, ns2 = batchnorm(blk["bn2"], bst["bn2"], y, train)
            y = jax.nn.relu6(y)
            y, ns3 = batchnorm(blk["bn3"], bst["bn3"],
                               conv2d(blk["project"], y, k=1), train)
            h = inp + y if residual else y
        new_state["blocks"].append({"bn1": ns1, "bn2": ns2, "bn3": ns3})
    with jax.named_scope("cost:head"):
        h, ns_head = batchnorm(p["head_bn"], state["head_bn"],
                               conv2d(p["head"], h, k=1), train)
        h = jax.nn.relu6(h)
    new_state["head_bn"] = ns_head
    with jax.named_scope("cost:fc"):
        pooled = jnp.mean(h, axis=(1, 2))
        logits = pooled @ p["fc_w"] + p["fc_b"]
    return logits, new_state


def mobilenetv2_loss(p: Params, state: Params, batch, rng=None,
                     train: bool = True):
    """Task-registry loss contract; MobileNetV2 carries no SLU gate, so the
    SLU metrics report full execution."""
    logits, new_state = mobilenetv2_fwd(p, state, batch["image"], train=train)
    labels = batch["label"]
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))
    metrics = {"loss": nll, "slu_cost": jnp.float32(1.0),
               "slu_exec_ratio": jnp.float32(1.0)}
    return nll, (metrics, new_state)
