"""Mixture-of-Experts FFN (GShard-style grouped dispatch/combine einsums).

Design notes (TPU / SPMD):

* **Groups.**  Tokens are processed in groups of ``GROUP_SIZE``; capacity is
  per-group (``C = cf * k * n_g / E``), so the dispatch one-hot is
  (G, n_g, E, C) — linear in tokens.  An un-grouped formulation has
  ``C ∝ N`` and the one-hot grows quadratically (hundreds of GiB/device at
  1M tokens); grouping is what makes the einsum MoE scale.
* **Sharding.**  G follows the batch axis; when ``num_experts`` divides the
  model axis the E axis is expert-parallel (XLA inserts the dispatch/combine
  all-to-alls), otherwise the capacity axis shards over model (grok: 8
  experts on a 16-way axis) with TP inside each expert.
* Expert weights are stacked ``(E, d, f)``; shared experts (DeepSeekMoE)
  are always-on dense MLPs; the router runs fp32 and never sees PSG (sign
  updates break load-balance dynamics — DESIGN.md §5).
* Tokens above capacity drop (combine weight 0) — standard GShard; with
  ``capacity_factor >= 1`` and balanced routing nothing drops in tests.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import psg
from repro.core.config import ModelConfig
from repro.distributed.sharding import ctx_mesh_axis_size, hint
from repro.models import layers

Params = Dict[str, Any]

GROUP_SIZE = 1024


def init_moe(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    E = cfg.num_experts
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": layers.dense_init(ks[0], (d, E), jnp.float32),
        "w_up": layers.dense_init(ks[1], (E, d, f), pd),
        "w_gate": layers.dense_init(ks[2], (E, d, f), pd),
        "w_down": layers.dense_init(ks[3], (E, f, d), pd),
    }
    if cfg.num_shared_experts:
        sk = jax.random.split(ks[4], cfg.num_shared_experts)
        p["shared"] = [layers.init_mlp(k, cfg, d_ff=f) for k in sk]
    return p


def moe_fwd(p: Params, x: jnp.ndarray, cfg: ModelConfig
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    dt = x.dtype
    N = B * S
    n_g = min(GROUP_SIZE, N)
    G = N // n_g
    assert G * n_g == N, f"tokens {N} not divisible by group {n_g}"
    cap = max(int(cfg.capacity_factor * k * n_g / E), 1)

    # EP when experts divide the model axis; otherwise leave E and C
    # unsharded and let the weights' d_ff TP-sharding drive the expert
    # matmuls (sharding C over model conflicts with the f axis and makes
    # the partitioner all-gather the full expert weights — observed 12 GiB
    # on grok prefill).
    ep = E % max(ctx_mesh_axis_size("model"), 1) == 0
    e_ax, c_ax = ("mlp", None) if ep else (None, None)

    # groups shard over all of (pod, data, model): the flattened token axis
    # absorbs both the batch sharding and (under SP) the sequence sharding.
    xg = hint(x.reshape(G, n_g, d), "batch", None, None)
    logits = (xg.astype(jnp.float32) @ p["router"])            # (G, n, E)
    probs = jax.nn.softmax(logits, axis=-1)

    # --- top-k selection (renormalized weights) ---
    topv, topi = jax.lax.top_k(probs, k)                       # (G, n, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)

    # --- per-group capacity positions ---
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.int32)          # (G, n, k, E)
    flat = onehot.reshape(G, n_g * k, E)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(G, n_g, k, E)
    pos = jnp.sum(pos * onehot, axis=-1)                       # (G, n, k)
    keep = pos < cap

    # --- dispatch/combine ---
    disp = (jax.nn.one_hot(topi, E, dtype=dt)[..., None]
            * jax.nn.one_hot(pos, cap, dtype=dt)[..., None, :]
            * keep[..., None, None].astype(dt))                # (G, n, k, E, C)
    comb = disp * topv[..., None, None].astype(dt)
    disp_ec = hint(jnp.sum(disp, axis=2), "batch", None, e_ax, c_ax)
    comb_ec = hint(jnp.sum(comb, axis=2), "batch", None, e_ax, c_ax)

    # --- expert computation ---
    ex_in = hint(jnp.einsum("gnec,gnd->gecd", disp_ec, xg),
                 "batch", e_ax, c_ax, None)                    # (G, E, C, d)
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = act(psg.einsum("gecd,edf->gecf", ex_in, p["w_up"].astype(dt)))
    h = h * psg.einsum("gecd,edf->gecf", ex_in, p["w_gate"].astype(dt))
    ex_out = hint(psg.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt)),
                  "batch", e_ax, c_ax, None)
    y = jnp.einsum("gnec,gecd->gnd", comb_ec, ex_out)          # (G, n, d)
    y = y.reshape(N, d)

    # --- shared experts ---
    if cfg.num_shared_experts:
        xt = x.reshape(N, d)
        for sp in p["shared"]:
            y = y + layers.mlp_fwd(sp, xt, cfg)

    # --- load-balance aux loss (Switch-style, over all tokens) ---
    frac_tokens = jnp.mean(
        jax.nn.one_hot(topi[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)

    return y.reshape(B, S, d), aux.astype(jnp.float32)
