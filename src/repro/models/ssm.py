"""State-space / recurrent mixers: Mamba2 (SSD), mLSTM and sLSTM (xLSTM).

TPU adaptation notes (DESIGN.md §3): the GPU reference implementations use
fused CUDA selective-scan kernels.  On TPU we use the *chunked SSD* ("state
space duality") formulation for Mamba2 — per-chunk quadratic matmuls (MXU
friendly) plus a short ``lax.scan`` over chunks for the state carry — and the
parallel quadratic form for mLSTM training.  Decode uses O(1) recurrent
updates, which is the sub-quadratic long-context path for these families.

Every mixer exposes:
  init_<kind>(key, cfg) -> params
  <kind>_fwd(params, x, cfg) -> y                      # full sequence
  <kind>_step(params, x, state, cfg) -> (y, state)     # single-token decode
  init_<kind>_state(cfg, batch, dtype) -> state
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.config import ModelConfig
from repro.distributed.sharding import hint_batch
from repro.models.layers import dense_init

Params = Dict[str, Any]

MAMBA_HEAD_DIM = 64
SSD_CHUNK = 256


def _mamba_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    di = cfg.ssm_expand * cfg.d_model
    nh = max(di // MAMBA_HEAD_DIM, 1)
    hd = di // nh
    return di, nh, hd


# ---------------------------------------------------------------------------
# Mamba2 (chunked SSD)
# ---------------------------------------------------------------------------


def init_mamba(key, cfg: ModelConfig) -> Params:
    d, st = cfg.d_model, cfg.ssm_state
    di, nh, hd = _mamba_dims(cfg)
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], (d, 2 * di), pd),          # -> (x, z)
        "conv": dense_init(ks[1], (cfg.ssm_conv_width, di), pd, scale=1.0),
        "w_bc": dense_init(ks[2], (d, 2 * st), pd),          # -> (B, C)
        "w_dt": dense_init(ks[3], (d, nh), pd),
        "dt_bias": jnp.zeros((nh,), pd),
        "A_log": jnp.log(jnp.linspace(1.0, float(nh), nh)).astype(pd),
        "D": jnp.ones((nh,), pd),
        "w_out": dense_init(ks[4], (di, d), pd),
    }


def _mamba_proj(p: Params, u: jnp.ndarray, cfg: ModelConfig):
    """Shared projections. u:(B,S,d) -> x:(B,S,nh,hd), z, B, C, dt, A."""
    dt_ = u.dtype
    di, nh, hd = _mamba_dims(cfg)
    xz = u @ p["w_in"].astype(dt_)
    x, z = jnp.split(xz, 2, axis=-1)
    bc = u @ p["w_bc"].astype(dt_)
    Bm, Cm = jnp.split(bc, 2, axis=-1)                        # (B,S,st)
    dt = jax.nn.softplus(u.astype(jnp.float32) @ p["w_dt"].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,S,nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (nh,) negative
    return x, z, Bm, Cm, dt, A


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, buf: jnp.ndarray = None):
    """Depthwise causal conv. x:(B,S,di), w:(W,di)."""
    W = w.shape[0]
    pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype) if buf is None else buf
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(W))
    return jax.nn.silu(out), xp[:, -(W - 1):]


def mamba_fwd(p: Params, u: jnp.ndarray, cfg: ModelConfig,
              return_state: bool = False):
    """Chunked SSD: per-chunk quadratic matmuls + scan over chunks.
    With ``return_state`` also returns the end-of-sequence recurrent state
    (prefill -> decode handoff)."""
    Bsz, S, d = u.shape
    di, nh, hd = _mamba_dims(cfg)
    st = cfg.ssm_state
    L = min(SSD_CHUNK, S)
    assert S % L == 0, f"seq {S} must be divisible by chunk {L}"
    nchunk = S // L

    x, z, Bm, Cm, dt, A = _mamba_proj(p, u, cfg)
    x, conv_buf = _causal_conv(x, p["conv"])
    xh = x.reshape(Bsz, S, nh, hd)

    # per-step log decay: a_t = exp(A * dt_t); work in log space
    loga = dt * A[None, None, :]                              # (B,S,nh) <= 0
    # chunked views (scan over chunks — materializing all chunks' quadratic
    # intermediates at once costs O(S*L*nh) fp32 per tensor, tens of GiB at
    # 4k seq x 64 heads; the scan keeps one chunk's working set live)
    xc = jnp.moveaxis(xh.reshape(Bsz, nchunk, L, nh, hd), 1, 0)
    Bc = jnp.moveaxis(Bm.reshape(Bsz, nchunk, L, st), 1, 0).astype(jnp.float32)
    Cc = jnp.moveaxis(Cm.reshape(Bsz, nchunk, L, st), 1, 0).astype(jnp.float32)
    dtc = jnp.moveaxis(dt.reshape(Bsz, nchunk, L, nh), 1, 0)
    lac = jnp.moveaxis(loga.reshape(Bsz, nchunk, L, nh), 1, 0)
    tri = jnp.tril(jnp.ones((L, L), bool))
    adt = xh.dtype

    @jax.checkpoint   # bwd recomputes the O(L^2) chunk tensors instead of
    # saving them per chunk (x chunks x layers: tens of GiB otherwise)
    def one_chunk(H, inp):
        xn, Bn, Cn, dtn, lan = inp                # (B,L,...) one chunk
        H = hint_batch(H)
        s = jnp.cumsum(lan, axis=1)               # (B,L,nh) inclusive
        # intra-chunk: M[i,j] = (C_i . B_j) exp(s_i - s_j) dt_j, j <= i
        cb = jnp.einsum("bis,bjs->bij", Cn, Bn)   # (B,L,L)
        gap = s[:, :, None, :] - s[:, None, :, :]           # (B,L,L,nh)
        gap = jnp.where(tri[None, :, :, None], gap, -jnp.inf)
        M = (cb[..., None] * jnp.exp(gap) * dtn[:, None, :, :]).astype(adt)
        y = jnp.einsum("bijh,bjhd->bihd", M, xn)            # (B,L,nh,hd)
        # inter-chunk: y_i += exp(s_i) * C_i @ H
        y = y + jnp.einsum("bis,bhsd->bihd", Cn.astype(adt),
                           H.astype(adt)) * jnp.exp(s)[..., None].astype(adt)
        # state update: H' = exp(s_L) H + sum_j exp(s_L - s_j) dt_j B_j x_j
        w_j = (jnp.exp(s[:, -1:, :] - s) * dtn).astype(adt)
        Hc = jnp.einsum("bjh,bjs,bjhd->bhsd", w_j, Bn.astype(adt), xn)
        H = H * jnp.exp(s[:, -1, :])[..., None, None].astype(H.dtype) \
            + Hc.astype(H.dtype)
        return H, y

    H0 = jnp.zeros((Bsz, nh, st, hd), jnp.float32)
    H_end, ys = lax.scan(one_chunk, H0, (xc, Bc, Cc, dtc, lac))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S, nh, hd)
    y = y + xh * p["D"].astype(xh.dtype)[None, None, :, None]
    y = y.reshape(Bsz, S, di) * jax.nn.silu(z)
    out = y @ p["w_out"].astype(u.dtype)
    if return_state:
        return out, {"H": H_end.astype(jnp.float32),
                     "conv_buf": conv_buf.astype(jnp.float32)}
    return out


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    di, nh, hd = _mamba_dims(cfg)
    return {
        "H": jnp.zeros((batch, nh, cfg.ssm_state, hd), dtype),
        "conv_buf": jnp.zeros((batch, cfg.ssm_conv_width - 1, di), dtype),
    }


def mamba_step(p: Params, u: jnp.ndarray, state: Params, cfg: ModelConfig):
    """u:(B,1,d) single-token recurrent update."""
    Bsz = u.shape[0]
    di, nh, hd = _mamba_dims(cfg)
    x, z, Bm, Cm, dt, A = _mamba_proj(p, u, cfg)
    x, buf = _causal_conv(x, p["conv"], state["conv_buf"].astype(x.dtype))
    xh = x.reshape(Bsz, nh, hd)
    a = jnp.exp(dt[:, 0] * A[None, :])                        # (B,nh)
    H = state["H"]
    upd = jnp.einsum("bh,bs,bhd->bhsd", dt[:, 0].astype(H.dtype),
                     Bm[:, 0].astype(H.dtype), xh.astype(H.dtype))
    H = H * a[..., None, None].astype(H.dtype) + upd
    y = jnp.einsum("bs,bhsd->bhd", Cm[:, 0].astype(H.dtype), H)
    y = y + xh.astype(H.dtype) * p["D"].astype(H.dtype)[None, :, None]
    y = (y.reshape(Bsz, 1, di).astype(u.dtype)) * jax.nn.silu(z)
    return y @ p["w_out"].astype(u.dtype), {"H": H, "conv_buf": buf.astype(state["conv_buf"].dtype)}


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory block) — parallel quadratic train form
# ---------------------------------------------------------------------------


def _mlstm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    di = cfg.ssm_expand * cfg.d_model
    nh = cfg.num_heads
    hd = di // nh
    return di, nh, hd


def init_mlstm(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    di, nh, hd = _mlstm_dims(cfg)
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    return {
        "w_in": dense_init(ks[0], (d, 2 * di), pd),           # (x, z)
        "wq": dense_init(ks[1], (di, nh, hd), pd),
        "wk": dense_init(ks[2], (di, nh, hd), pd),
        "wv": dense_init(ks[3], (di, nh, hd), pd),
        "w_if": dense_init(ks[4], (di, 2 * nh), pd),          # input/forget gate logits
        "b_if": jnp.concatenate([jnp.zeros((nh,)), 3.0 * jnp.ones((nh,))]).astype(pd),
        "w_out": dense_init(ks[5], (di, d), pd),
    }


def _mlstm_gates(p, xi):
    g = xi.astype(jnp.float32) @ p["w_if"].astype(jnp.float32) + p["b_if"].astype(jnp.float32)
    i_t, f_t = jnp.split(g, 2, axis=-1)
    logf = jax.nn.log_sigmoid(f_t)
    return i_t, logf


MLSTM_CHUNK = 256


def mlstm_fwd(p: Params, u: jnp.ndarray, cfg: ModelConfig,
              return_state: bool = False):
    """Chunkwise-parallel mLSTM (xLSTM): per-chunk quadratic matmuls +
    a scan carrying the stabilized matrix memory (C, n, m) across chunks —
    O(S * chunk) memory instead of the O(S^2) fully-parallel form.
    ``return_state`` also returns the end state for decode handoff.

    NOTE state convention: the chunk scan stores C as (k-dim, v-dim) which
    matches ``mlstm_step``'s layout."""
    Bsz, S, d = u.shape
    di, nh, hd = _mlstm_dims(cfg)
    dt_ = u.dtype
    L = min(MLSTM_CHUNK, S)
    assert S % L == 0, f"seq {S} must be divisible by chunk {L}"
    nch = S // L

    xz = u @ p["w_in"].astype(dt_)
    xi, z = jnp.split(xz, 2, axis=-1)
    q = jnp.einsum("bsd,dnh->bsnh", xi, p["wq"].astype(dt_)) / math.sqrt(hd)
    k = jnp.einsum("bsd,dnh->bsnh", xi, p["wk"].astype(dt_))
    v = jnp.einsum("bsd,dnh->bsnh", xi, p["wv"].astype(dt_))
    i_t, logf = _mlstm_gates(p, xi)                           # (B,S,nh) fp32

    def chunkify(t):
        return jnp.moveaxis(t.reshape(Bsz, nch, L, *t.shape[2:]), 1, 0)

    qc, kc, vc = chunkify(q), chunkify(k), chunkify(v)        # (nch,B,L,nh,*)
    ic, fc = chunkify(i_t), chunkify(logf)

    tri = jnp.tril(jnp.ones((L, L), bool))

    @jax.checkpoint   # same O(L^2)-residual argument as the Mamba2 scan
    def one_chunk(carry, inp):
        C_in, n_in, m_in = carry                              # (B,nh,hd,hd),(B,nh,hd),(B,nh)
        C_in = hint_batch(C_in)
        qi, ki, vi, ii, fi = inp
        qi, ki, vi = hint_batch(qi), hint_batch(ki), hint_batch(vi)
        F = jnp.cumsum(fi, axis=1)                            # (B,L,nh)
        # log-weights: inter = F_i + m_in ; intra[i,j] = F_i - F_j + i_j
        inter_lw = F + m_in[:, None, :]
        intra_lw = (F[:, :, None, :] - F[:, None, :, :] + ii[:, None, :, :])
        intra_lw = jnp.where(tri[None, :, :, None], intra_lw, -jnp.inf)
        m_i = jnp.maximum(inter_lw, jnp.max(intra_lw, axis=2))  # (B,L,nh)
        Dm = jnp.exp(intra_lw - m_i[:, :, None, :])           # (B,L,L,nh)
        wq_inter = jnp.exp(inter_lw - m_i)                    # (B,L,nh)

        qf = qi.astype(jnp.float32)
        kf = ki.astype(jnp.float32)
        vf = vi.astype(jnp.float32)
        qk = jnp.einsum("blnh,bjnh->bljn", qf, kf)
        Wm = qk * Dm                                          # (B,L,L,nh)
        # y_num[i] = sum_j Wm[i,j] v_j + (q_i . C_in) * w_inter[i]
        y_num = jnp.einsum("bljn,bjnh->blnh", Wm, vf) \
            + jnp.einsum("blng,bngh->blnh", qf, C_in) * wq_inter[..., None]
        # normalizer vector: n_vec[i] = sum_j Dm[i,j] k_j + n_in * w_inter[i]
        n_vec = jnp.einsum("bljn,bjng->blng", Dm, kf) \
            + n_in[:, None, :, :] * wq_inter[..., None]
        den = jnp.abs(jnp.einsum("blng,blng->bln", qf, n_vec))
        den = jnp.maximum(den, jnp.exp(-m_i))
        y = (y_num / den[..., None]).astype(dt_)              # (B,L,nh,hd)

        # ---- state update at chunk end ----
        F_L = F[:, -1, :]                                     # (B,nh)
        st_lw = F_L[:, None, :] - F + ii                      # (B,L,nh) weight of token j
        m_out = jnp.maximum(m_in + F_L, jnp.max(st_lw, axis=1))
        w_st = jnp.exp(st_lw - m_out[:, None, :])             # (B,L,nh)
        decay = jnp.exp(m_in + F_L - m_out)                   # (B,nh)
        C_out = C_in * decay[..., None, None] + jnp.einsum(
            "blng,blnh,bln->bngh", ki.astype(jnp.float32),
            vi.astype(jnp.float32), w_st)
        n_out = n_in * decay[..., None] + jnp.einsum(
            "blng,bln->bng", ki.astype(jnp.float32), w_st)
        return (C_out, n_out, m_out), y

    C0 = jnp.zeros((Bsz, nh, hd, hd), jnp.float32)
    n0 = jnp.zeros((Bsz, nh, hd), jnp.float32)
    m0 = jnp.full((Bsz, nh), -1e30, jnp.float32)
    (C_end, n_end, m_end), ys = lax.scan(one_chunk, (C0, n0, m0),
                                         (qc, kc, vc, ic, fc))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S, di)
    y = y * jax.nn.silu(z)
    out = y @ p["w_out"].astype(dt_)
    if return_state:
        return out, {"C": C_end, "n": n_end, "m": m_end}
    return out


def init_mlstm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    di, nh, hd = _mlstm_dims(cfg)
    return {"C": jnp.zeros((batch, nh, hd, hd), dtype),
            "n": jnp.zeros((batch, nh, hd), dtype),
            "m": jnp.full((batch, nh), -1e30, dtype)}


def mlstm_step(p: Params, u: jnp.ndarray, state: Params, cfg: ModelConfig):
    Bsz = u.shape[0]
    di, nh, hd = _mlstm_dims(cfg)
    dt_ = u.dtype
    xz = u @ p["w_in"].astype(dt_)
    xi, z = jnp.split(xz, 2, axis=-1)
    q = jnp.einsum("bd,dnh->bnh", xi[:, 0], p["wq"].astype(dt_)) / math.sqrt(hd)
    k = jnp.einsum("bd,dnh->bnh", xi[:, 0], p["wk"].astype(dt_))
    v = jnp.einsum("bd,dnh->bnh", xi[:, 0], p["wv"].astype(dt_))
    i_t, logf = _mlstm_gates(p, xi[:, 0])                     # (B,nh)
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(logf + m, i_t)
    fp = jnp.exp(logf + m - m_new)
    ip = jnp.exp(i_t - m_new)
    # C is (B, nh, hd_k, hd_v): C += i' * k (outer) v
    C = C * fp[..., None, None] + ip[..., None, None] * jnp.einsum(
        "bng,bnh->bngh", k.astype(C.dtype), v.astype(C.dtype))
    n = n * fp[..., None] + ip[..., None] * k.astype(n.dtype)
    num = jnp.einsum("bngh,bng->bnh", C, q.astype(C.dtype))
    den = jnp.maximum(jnp.abs(jnp.einsum("bng,bng->bn", n, q.astype(n.dtype))),
                      jnp.exp(-m_new))[..., None]
    y = (num / den).reshape(Bsz, 1, di).astype(dt_) * jax.nn.silu(z)
    return y @ p["w_out"].astype(dt_), {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory block) — sequential scan
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    nh = cfg.num_heads
    hd = d // nh
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    return {
        "w_g": dense_init(ks[0], (d, 4 * d), pd),             # z,i,f,o pre-acts
        "r_g": dense_init(ks[1], (nh, hd, 4 * hd), pd),       # block-diag recurrent
        "b_g": jnp.zeros((4 * d,), pd),
        "w_out": dense_init(ks[2], (d, d), pd),
    }


def _slstm_cell(p, cfg, x_g, carry):
    """x_g: (B, 4d) input pre-activation; carry: (c, n, h, m) each (B,nh,hd)."""
    nh = cfg.num_heads
    hd = cfg.d_model // nh
    c, n, h, m = carry
    rec = jnp.einsum("bnh,nhg->bng", h, p["r_g"].astype(h.dtype))  # (B,nh,4hd)
    g = x_g.reshape(x_g.shape[0], nh, 4 * hd) + rec
    z, i_t, f_t, o = jnp.split(g.astype(jnp.float32), 4, axis=-1)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o)
    logf = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(logf + m, i_t)
    ip = jnp.exp(i_t - m_new)
    fp = jnp.exp(logf + m - m_new)
    c = fp * c + ip * z
    n = fp * n + ip
    h = o * c / jnp.maximum(n, 1.0)
    return (c, n, h.astype(x_g.dtype), m_new)


def slstm_fwd(p: Params, u: jnp.ndarray, cfg: ModelConfig,
              return_state: bool = False):
    Bsz, S, d = u.shape
    nh = cfg.num_heads
    hd = d // nh
    x_g = u @ p["w_g"].astype(u.dtype) + p["b_g"].astype(u.dtype)  # (B,S,4d)

    def step(carry, xg):
        carry = tuple(hint_batch(c) for c in carry)
        carry = _slstm_cell(p, cfg, xg, carry)
        return carry, carry[2]

    f32 = jnp.float32
    init = (jnp.zeros((Bsz, nh, hd), f32), jnp.zeros((Bsz, nh, hd), f32),
            jnp.zeros((Bsz, nh, hd), u.dtype), jnp.full((Bsz, nh, hd), -1e30, f32))
    (c_e, n_e, h_e, m_e), hs = lax.scan(step, init, jnp.moveaxis(x_g, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(Bsz, S, d)
    out = y @ p["w_out"].astype(u.dtype)
    if return_state:
        return out, {"c": c_e, "n": n_e, "h": h_e, "m": m_e}
    return out


def init_slstm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    nh = cfg.num_heads
    hd = cfg.d_model // nh
    return {"c": jnp.zeros((batch, nh, hd), dtype),
            "n": jnp.zeros((batch, nh, hd), dtype),
            "h": jnp.zeros((batch, nh, hd), dtype),
            "m": jnp.full((batch, nh, hd), -1e30, dtype)}


def slstm_step(p: Params, u: jnp.ndarray, state, cfg: ModelConfig):
    x_g = u[:, 0] @ p["w_g"].astype(u.dtype) + p["b_g"].astype(u.dtype)
    carry = (state["c"], state["n"], state["h"].astype(u.dtype), state["m"])
    c, n, h, m = _slstm_cell(p, cfg, x_g, carry)
    y = h.reshape(u.shape[0], 1, cfg.d_model) @ p["w_out"].astype(u.dtype)
    return y, {"c": c, "n": n, "h": h, "m": m}
