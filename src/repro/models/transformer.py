"""Generic LM stack: every assigned architecture is an instance of this.

Key structural choices (DESIGN.md §4/§7):

* **Scan over repeating units.**  ``cfg.blocks`` tiles a ``block_unit`` of
  heterogeneous block kinds; parameters for each unit are stacked on a
  leading axis and the stack is executed with ``jax.lax.scan`` — this keeps
  the HLO size O(unit) instead of O(layers) (compile time at 512 devices)
  and is what makes per-layer FSDP all-gather prefetching schedulable.
* **SLU hooks.**  When ``e2.slu.enabled``, every residual sub-block is
  wrapped in ``slu.gated_residual`` with the weight-shared RNN gate carried
  through the scan; the regularizer inputs (keep-probs, analytic block
  FLOPs) are returned in ``aux``.
* **Decode.**  ``decode_step`` runs one token against per-layer state
  (KV cache ring buffers for attention kinds, recurrent states for
  SSM/xLSTM kinds) — the state pytree is stacked along units like params.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import psg, slu
from repro.core.config import (BLOCK_ATTN, BLOCK_MAMBA, BLOCK_MLSTM,
                               BLOCK_MOE, BLOCK_SHARED_ATTN, BLOCK_SLSTM,
                               E2TrainConfig, ModelConfig, SLUConfig)
from repro.core.energy import block_fwd_flops
from repro.distributed.sharding import hint, hint_batch
from repro.models import layers, moe, ssm
from repro.models.layers import (apply_norm, attention_decode, attention_fwd,
                                 cross_attention_fwd, embed_init, init_attention,
                                 init_kv_cache, init_mlp, init_norm, mlp_fwd)

Params = Dict[str, Any]


# lax.optimization_barrier has no differentiation rule, so wrap it in a
# custom_vjp identity that applies the barrier on BOTH passes: the forward
# barrier keeps XLA from hoisting saved-residual upcasts out of the unit
# scan, and the backward barrier does the same for the cotangent stream
# (the bwd loop is where the +14 GiB fp32 copy was observed).
@jax.custom_vjp
def _grad_safe_barrier(x):
    return lax.optimization_barrier(x)


def _grad_safe_barrier_fwd(x):
    return lax.optimization_barrier(x), None


def _grad_safe_barrier_bwd(_, g):
    return (lax.optimization_barrier(g),)


_grad_safe_barrier.defvjp(_grad_safe_barrier_fwd, _grad_safe_barrier_bwd)


class LMOutput(NamedTuple):
    logits: jnp.ndarray
    aux_loss: jnp.ndarray          # MoE load-balance loss
    slu_cost: jnp.ndarray          # expected executed-FLOPs fraction (C in Eq.1)
    slu_executed: jnp.ndarray      # per-(unit, sub-block) executed flags
    slu_keep_probs: jnp.ndarray


# ---------------------------------------------------------------------------
# per-block init / apply
# ---------------------------------------------------------------------------


def init_block(key, kind: str, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    if kind == BLOCK_ATTN:
        return {"ln1": init_norm(cfg), "attn": init_attention(ks[0], cfg),
                "ln2": init_norm(cfg), "mlp": init_mlp(ks[1], cfg)}
    if kind == BLOCK_MOE:
        return {"ln1": init_norm(cfg), "attn": init_attention(ks[0], cfg),
                "ln2": init_norm(cfg), "moe": moe.init_moe(ks[1], cfg)}
    if kind == BLOCK_MAMBA:
        return {"ln1": init_norm(cfg), "mamba": ssm.init_mamba(ks[0], cfg)}
    if kind == BLOCK_MLSTM:
        return {"ln1": init_norm(cfg), "mlstm": ssm.init_mlstm(ks[0], cfg)}
    if kind == BLOCK_SLSTM:
        return {"ln1": init_norm(cfg), "slstm": ssm.init_slstm(ks[0], cfg)}
    if kind == BLOCK_SHARED_ATTN:
        return {}            # weight-shared params live at top level
    raise ValueError(kind)


def _sub_blocks(kind: str):
    """Residual sub-blocks per kind — the SLU gating granularity."""
    if kind in (BLOCK_ATTN, BLOCK_MOE, BLOCK_SHARED_ATTN):
        return ("mixer", "ffn") if kind != BLOCK_SHARED_ATTN else ("mixer",)
    return ("mixer",)


def block_apply(bp: Params, shared: Params, kind: str, x: jnp.ndarray,
                cfg: ModelConfig, e2: E2TrainConfig, gate_ctx,
                rng, force_keep,
                prefer_chunked_attn: bool = False
                ) -> Tuple[jnp.ndarray, Dict[str, Any], Any]:
    """One block (train / prefill).  gate_ctx = (gate_params, lstm_state) or None."""
    aux = jnp.zeros((), jnp.float32)
    kps, execs = [], []

    def gated(fn, x, sub_rng):
        nonlocal gate_ctx
        if gate_ctx is None:
            return x + fn(x), jnp.float32(1.0), jnp.float32(1.0)
        gp, gst = gate_ctx
        p_keep, gst = slu.gate_apply(gp, x, gst, e2.slu)
        gate_ctx = (gp, gst)
        out, ex = slu.gated_residual(fn, x, p_keep, sub_rng, force_keep)
        return out, p_keep, ex

    r1, r2 = jax.random.split(rng)
    if kind in (BLOCK_ATTN, BLOCK_MOE):
        x, kp, ex = gated(lambda h: attention_fwd(
            bp["attn"], apply_norm(bp["ln1"], h, cfg), cfg,
            prefer_chunked=prefer_chunked_attn), x, r1)
        kps.append(kp); execs.append(ex)
        if kind == BLOCK_ATTN:
            x, kp, ex = gated(lambda h: mlp_fwd(bp["mlp"],
                                                apply_norm(bp["ln2"], h, cfg),
                                                cfg), x, r2)
        else:
            # aux loss must flow even under lax.cond: compute the MoE branch's
            # aux inside the cond via a (delta, aux) pair.
            def moe_block(h):
                y, a = moe.moe_fwd(bp["moe"], apply_norm(bp["ln2"], h, cfg), cfg)
                return y, a

            if gate_ctx is None:
                y, a = moe_block(x)
                x = x + y
                aux = aux + a
                kp, ex = jnp.float32(1.0), jnp.float32(1.0)
            else:
                gp, gst = gate_ctx
                p_keep, gst = slu.gate_apply(gp, x, gst, e2.slu)
                gate_ctx = (gp, gst)
                keep = jax.random.bernoulli(r2, p_keep) | force_keep
                g_st = 1.0 + p_keep - lax.stop_gradient(p_keep)

                def run(h):
                    y, a = moe_block(h)
                    return h + g_st.astype(h.dtype) * y, a

                x, a = lax.cond(keep, run,
                                lambda h: (h, jnp.zeros((), jnp.float32)), x)
                aux = aux + a
                kp, ex = p_keep, keep.astype(jnp.float32)
        kps.append(kp); execs.append(ex)
    elif kind == BLOCK_MAMBA:
        x, kp, ex = gated(lambda h: ssm.mamba_fwd(bp["mamba"],
                                                  apply_norm(bp["ln1"], h, cfg),
                                                  cfg), x, r1)
        kps.append(kp); execs.append(ex)
    elif kind == BLOCK_MLSTM:
        x, kp, ex = gated(lambda h: ssm.mlstm_fwd(bp["mlstm"],
                                                  apply_norm(bp["ln1"], h, cfg),
                                                  cfg), x, r1)
        kps.append(kp); execs.append(ex)
    elif kind == BLOCK_SLSTM:
        x, kp, ex = gated(lambda h: ssm.slstm_fwd(bp["slstm"],
                                                  apply_norm(bp["ln1"], h, cfg),
                                                  cfg), x, r1)
        kps.append(kp); execs.append(ex)
    elif kind == BLOCK_SHARED_ATTN:
        # zamba2 weight-shared attention: never SLU-gated (DESIGN.md §5)
        x = x + attention_fwd(shared["attn"],
                              apply_norm(shared["ln"], x, cfg), cfg,
                              prefer_chunked=prefer_chunked_attn)
        kps.append(jnp.float32(1.0)); execs.append(jnp.float32(1.0))
    else:
        raise ValueError(kind)
    return x, {"aux": aux, "kp": jnp.stack(kps), "ex": jnp.stack(execs)}, gate_ctx


# ---------------------------------------------------------------------------
# whole-model init
# ---------------------------------------------------------------------------


def init_lm(key, cfg: ModelConfig, e2: Optional[E2TrainConfig] = None) -> Params:
    e2 = e2 or E2TrainConfig()
    unit = cfg.blocks[: len(cfg.block_unit) or 1]
    if not cfg.block_unit:
        unit = (cfg.blocks[0],)
    n_units = cfg.num_layers // len(unit)
    assert n_units * len(unit) == cfg.num_layers, \
        f"{cfg.name}: num_layers {cfg.num_layers} not divisible by unit {unit}"

    keys = jax.random.split(key, n_units + 5)
    p: Params = {
        "embed": embed_init(keys[-1], (cfg.padded_vocab, cfg.d_model),
                            jnp.dtype(cfg.param_dtype)),
        "final_norm": init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        p["head"] = layers.dense_init(keys[-2], (cfg.d_model, cfg.padded_vocab),
                                      jnp.dtype(cfg.param_dtype))

    def one_unit(k):
        uks = jax.random.split(k, len(unit))
        return {f"b{i}_{kind}": init_block(uk, kind, cfg)
                for i, (kind, uk) in enumerate(zip(unit, uks))}

    units = [one_unit(keys[i]) for i in range(n_units)]
    p["units"] = jax.tree.map(lambda *xs: jnp.stack(xs), *units)

    if BLOCK_SHARED_ATTN in unit or cfg.shared_attn_every:
        p["shared"] = {"ln": init_norm(cfg),
                       "attn": init_attention(keys[-3], cfg)}
    if cfg.encoder_layers:
        eks = jax.random.split(keys[-4], cfg.encoder_layers + 1)
        enc = [init_block(eks[i], BLOCK_ATTN, cfg)
               for i in range(cfg.encoder_layers)]
        p["encoder"] = jax.tree.map(lambda *xs: jnp.stack(xs), *enc)
        p["enc_norm"] = init_norm(cfg)
        xks = jax.random.split(eks[-1], n_units * len(unit))
        xattn = [{"ln": init_norm(cfg), "attn": init_attention(xk, cfg)}
                 for xk in xks[: n_units]]
        p["cross"] = jax.tree.map(lambda *xs: jnp.stack(xs), *xattn)
    if e2.slu.enabled:
        p["slu_gate"] = slu.init_gate(keys[-5], cfg.d_model, e2.slu)
    return p


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _unit_kinds(cfg: ModelConfig) -> Tuple[str, ...]:
    return cfg.block_unit or (cfg.blocks[0],)


def unit_flops(cfg: ModelConfig, seq: int) -> jnp.ndarray:
    """Analytic fwd FLOPs per gated sub-block of one unit (for Eq. 1's C)."""
    vals = []
    for kind in _unit_kinds(cfg):
        f = block_fwd_flops(cfg, kind, seq)
        subs = _sub_blocks(kind)
        vals.extend([f / len(subs)] * len(subs))
    return jnp.asarray(vals, jnp.float32)


def encoder_fwd(p: Params, embeds: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Whisper-style bidirectional encoder over frontend embeddings."""

    @jax.checkpoint   # without remat the scan saves per-layer O(F^2) scores
    def body(x, bp):
        x = hint_batch(x)
        x = x + attention_fwd(bp["attn"], apply_norm(bp["ln1"], x, cfg), cfg,
                              causal=False)
        x = x + mlp_fwd(bp["mlp"], apply_norm(bp["ln2"], x, cfg), cfg)
        return x, None

    x, _ = lax.scan(body, embeds, p["encoder"])
    return apply_norm(p["enc_norm"], x, cfg)


def lm_fwd(p: Params, tokens: jnp.ndarray, cfg: ModelConfig,
           e2: Optional[E2TrainConfig] = None,
           rng: Optional[jnp.ndarray] = None,
           frontend_embeds: Optional[jnp.ndarray] = None,
           train: bool = True,
           remat: str = "block") -> LMOutput:
    """tokens: (B, S) int32.  frontend_embeds: (B, F, d) for audio/vlm."""
    e2 = e2 or E2TrainConfig()
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    dt = cfg.act_dtype
    # "cost:<group>" scopes anchor the static audit's per-layer attribution
    # (analysis/jaxpr_cost.py); groups: embed / unit (scan body) / head.
    with jax.named_scope("cost:embed"):
        x = p["embed"][tokens].astype(dt)

    memory = None
    if cfg.encoder_layers:
        assert frontend_embeds is not None, "enc-dec arch needs frontend embeds"
        memory = encoder_fwd(p, frontend_embeds.astype(dt), cfg)
    elif frontend_embeds is not None:
        # VLM: prepend patch embeddings to the token stream
        x = jnp.concatenate([frontend_embeds.astype(dt), x], axis=1)

    unit = _unit_kinds(cfg)
    n_units = cfg.num_layers // len(unit)
    S = x.shape[1]
    uflops = unit_flops(cfg, S)

    slu_on = e2.slu.enabled and train and "slu_gate" in p
    gate_params = p.get("slu_gate")
    shared = p.get("shared", {})
    has_cross = cfg.encoder_layers > 0

    # Sequence parallelism (training path): shard the residual stream's S
    # axis over the model mesh axis between blocks.  Valid for attention/MoE
    # units (their token-pointwise projections run S-sharded; attention
    # all-gathers KV, standard SP) but not for SSM/xLSTM units, whose
    # sequential chunk scans iterate the S axis.  This divides the
    # saved-residual stack — the training memory peak — by the model size.
    sp = train and all(k in (BLOCK_ATTN, BLOCK_MOE) for k in unit)
    stream_axes = ("batch", "seq", None) if sp else ("batch", None, None)
    x = hint(x, *stream_axes)

    def unit_body(carry, scanned):
        x, gst, base_rng = carry
        # barrier: stops XLA from hoisting the bwd loop's bf16->f32 upcast of
        # the saved-residual stack out of the loop (a full-size fp32 copy of
        # all saved activations — observed +14 GiB on deepseek train_4k).
        x = _grad_safe_barrier(x)
        x = hint(x, *stream_axes)  # re-pin stream sharding inside the body
        up = scanned["unit"]
        idx = scanned["idx"]
        urng = jax.random.fold_in(base_rng, idx)
        aux = jnp.zeros((), jnp.float32)
        kps, exs = [], []
        gate_ctx = (gate_params, gst) if slu_on else None
        with jax.named_scope("cost:unit"):
            for i, kind in enumerate(unit):
                brng = jax.random.fold_in(urng, i)
                glob = idx * len(unit) + i
                force = jnp.logical_or(glob == 0, glob == cfg.num_layers - 1) \
                    if e2.slu.never_skip_first_last else jnp.bool_(False)
                x, info, gate_ctx = block_apply(up[f"b{i}_{kind}"], shared,
                                                kind, x, cfg, e2, gate_ctx,
                                                brng, force,
                                                prefer_chunked_attn=not sp)
                if has_cross and kind == BLOCK_ATTN:
                    cp = scanned["cross"]
                    x = x + cross_attention_fwd(cp["attn"],
                                                apply_norm(cp["ln"], x, cfg),
                                                memory, cfg)
                aux = aux + info["aux"]
                kps.append(info["kp"]); exs.append(info["ex"])
        gst = gate_ctx[1] if gate_ctx is not None else gst
        return (x, gst, base_rng), (aux, jnp.concatenate(kps),
                                    jnp.concatenate(exs))

    if remat == "block":
        # prevent_cse=True (default) matters: with CSE allowed, XLA hoists
        # dtype converts of the saved-residual stack out of the backward
        # loop, materializing a second full-size fp32 copy (observed +14 GiB
        # on deepseek-moe train_4k).
        unit_body = jax.checkpoint(unit_body)

    gst0 = slu.init_gate_state(e2.slu)
    scanned = {"unit": p["units"], "idx": jnp.arange(n_units)}
    if has_cross:
        scanned["cross"] = p["cross"]
    (x, _, _), (auxs, kps, exs) = lax.scan(
        unit_body, (x, gst0, rng), scanned)

    with jax.named_scope("cost:head"):
        x = apply_norm(p["final_norm"], x, cfg)
        head = p["embed"].T if cfg.tie_embeddings else p["head"]
        # At the LM head, switch the stream from seq-sharded (SP) back to
        # batch-sharded and shard the *vocab* axis instead: with seq-sharded
        # logits the head/embed gradients become full (d, V) fp32 partials
        # per device (all-reduce); vocab-sharded logits keep them
        # (d, V/model), reduce-scattered — multi-GiB at 128k vocabs.
        x = hint(x, "batch", None, None)
        logits = hint((x @ head.astype(dt)).astype(jnp.float32),
                      "batch", None, "vocab")
    if cfg.padded_vocab != cfg.vocab_size:   # mask pad ids (never predicted)
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask[None, None, :], logits, -1e30)

    slu_cost = slu.flops_regularizer(kps.reshape(-1),
                                     jnp.tile(uflops, n_units), e2.slu) \
        if slu_on else jnp.float32(1.0)
    return LMOutput(logits=logits, aux_loss=jnp.sum(auxs),
                    slu_cost=slu_cost, slu_executed=exs, slu_keep_probs=kps)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def lm_loss(p: Params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            e2: Optional[E2TrainConfig] = None,
            rng: Optional[jnp.ndarray] = None,
            remat: str = "block") -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    e2 = e2 or E2TrainConfig()
    out = lm_fwd(p, batch["tokens"], cfg, e2, rng,
                 frontend_embeds=batch.get("frontend"), remat=remat)
    logits = out.logits
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:        # VLM prepended patches
        logits = logits[:, -labels.shape[1]:]
    mask = (labels >= 0).astype(jnp.float32)
    lab = jnp.maximum(labels, 0)
    # SPMD-partitionable cross-entropy: a gather (take_along_axis) over the
    # vocab-sharded axis would force the partitioner to replicate the full
    # (B, S, V) logits per device; logsumexp + one-hot contraction keep every
    # op sharded over (batch, -, vocab) with only tiny all-reduces.
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = (lab[..., None] == jnp.arange(logits.shape[-1])[None, None, :])
    ll = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = lse - ll
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + cfg.router_aux_coef * out.aux_loss
    if e2.slu.enabled:
        total = total + e2.slu.alpha * out.slu_cost       # Eq. (1)
    metrics = {"loss": loss, "aux_loss": out.aux_loss,
               "slu_cost": out.slu_cost,
               "slu_exec_ratio": jnp.mean(out.slu_executed)}
    return total, metrics


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> Dict[str, Any]:
    unit = _unit_kinds(cfg)
    n_units = cfg.num_layers // len(unit)

    def one_unit():
        st = {}
        for i, kind in enumerate(unit):
            if kind in (BLOCK_ATTN, BLOCK_MOE):
                st[f"b{i}"] = {"kv": init_kv_cache(cfg, batch, max_len, dtype)}
            elif kind == BLOCK_MAMBA:
                st[f"b{i}"] = ssm.init_mamba_state(cfg, batch)
            elif kind == BLOCK_MLSTM:
                st[f"b{i}"] = ssm.init_mlstm_state(cfg, batch)
            elif kind == BLOCK_SLSTM:
                st[f"b{i}"] = ssm.init_slstm_state(cfg, batch)
            elif kind == BLOCK_SHARED_ATTN:
                st[f"b{i}"] = {"kv": init_kv_cache(cfg, batch, max_len, dtype)}
        return st

    units = [one_unit() for _ in range(n_units)]
    state = {"units": jax.tree.map(lambda *xs: jnp.stack(xs), *units),
             "pos": jnp.zeros((batch,), jnp.int32)}
    return state


def prefill_to_state(p: Params, tokens: jnp.ndarray, cfg: ModelConfig,
                     max_kv_len: int,
                     memory: Optional[jnp.ndarray] = None,
                     frontend_embeds: Optional[jnp.ndarray] = None,
                     cache_dtype=jnp.bfloat16
                     ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Bulk prefill: full-sequence forward that RETURNS the decode state
    (KV ring buffers / recurrent states) — the production prefill->decode
    handoff.  tokens: (B, S) -> (last-position logits (B, 1, V), state)."""
    from repro.models.layers import fill_kv_cache
    dt = cfg.act_dtype
    B, S = tokens.shape
    x = p["embed"][tokens].astype(dt)
    if cfg.encoder_layers:
        assert memory is not None or frontend_embeds is not None
        if memory is None:
            memory = encoder_fwd(p, frontend_embeds.astype(dt), cfg)
    elif frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(dt), x], axis=1)
    unit = _unit_kinds(cfg)
    shared = p.get("shared", {})
    has_cross = cfg.encoder_layers > 0

    def unit_body(x, scanned):
        up = scanned["unit"]
        nst = {}
        for i, kind in enumerate(unit):
            bp = up.get(f"b{i}_{kind}")
            if kind in (BLOCK_ATTN, BLOCK_MOE):
                h = apply_norm(bp["ln1"], x, cfg)
                y, (k, v) = attention_fwd(bp["attn"], h, cfg, return_kv=True)
                x = x + y
                nst[f"b{i}"] = {"kv": fill_kv_cache(cfg, k, v, max_kv_len,
                                                    cache_dtype)}
                if has_cross and kind == BLOCK_ATTN:
                    cp = scanned["cross"]
                    x = x + cross_attention_fwd(
                        cp["attn"], apply_norm(cp["ln"], x, cfg), memory, cfg)
                h2 = apply_norm(bp["ln2"], x, cfg)
                if kind == BLOCK_ATTN:
                    x = x + mlp_fwd(bp["mlp"], h2, cfg)
                else:
                    y2, _ = moe.moe_fwd(bp["moe"], h2, cfg)
                    x = x + y2
            elif kind == BLOCK_MAMBA:
                y, st = ssm.mamba_fwd(bp["mamba"], apply_norm(bp["ln1"], x, cfg),
                                      cfg, return_state=True)
                x = x + y
                nst[f"b{i}"] = st
            elif kind == BLOCK_MLSTM:
                y, st = ssm.mlstm_fwd(bp["mlstm"], apply_norm(bp["ln1"], x, cfg),
                                      cfg, return_state=True)
                x = x + y
                nst[f"b{i}"] = st
            elif kind == BLOCK_SLSTM:
                y, st = ssm.slstm_fwd(bp["slstm"], apply_norm(bp["ln1"], x, cfg),
                                      cfg, return_state=True)
                x = x + y
                nst[f"b{i}"] = st
            elif kind == BLOCK_SHARED_ATTN:
                h = apply_norm(shared["ln"], x, cfg)
                y, (k, v) = attention_fwd(shared["attn"], h, cfg,
                                          return_kv=True)
                x = x + y
                nst[f"b{i}"] = {"kv": fill_kv_cache(cfg, k, v, max_kv_len,
                                                    cache_dtype)}
        return x, nst

    scanned = {"unit": p["units"]}
    if has_cross:
        scanned["cross"] = p["cross"]
    x, units_state = lax.scan(unit_body, x, scanned)
    x = apply_norm(p["final_norm"], x, cfg)
    head = p["embed"].T if cfg.tie_embeddings else p["head"]
    logits = (x[:, -1:] @ head.astype(dt)).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask[None, None, :], logits, -1e30)
    total = x.shape[1]                      # S (+ frontend tokens for VLM)
    state = {"units": units_state,
             "pos": jnp.full((B,), total, jnp.int32)}
    return logits, state


def decode_step(p: Params, token: jnp.ndarray, state: Dict[str, Any],
                cfg: ModelConfig,
                memory: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, Dict]:
    """token: (B, 1) int32 -> (logits (B, 1, V), new state).  No SLU at serve."""
    dt = cfg.act_dtype
    x = p["embed"][token].astype(dt)
    unit = _unit_kinds(cfg)
    pos = state["pos"]
    shared = p.get("shared", {})
    has_cross = cfg.encoder_layers > 0

    def unit_body(x, scanned):
        x = hint_batch(x)
        up, ust = scanned["unit"], scanned["state"]
        nst = {}
        for i, kind in enumerate(unit):
            bp = up.get(f"b{i}_{kind}")
            st = ust[f"b{i}"]
            if kind in (BLOCK_ATTN, BLOCK_MOE):
                h = apply_norm(bp["ln1"], x, cfg)
                y, kv = attention_decode(bp["attn"], h, cfg, st["kv"], pos)
                x = x + y
                if has_cross and kind == BLOCK_ATTN:
                    cp = scanned["cross"]
                    x = x + cross_attention_fwd(
                        cp["attn"], apply_norm(cp["ln"], x, cfg), memory, cfg)
                h2 = apply_norm(bp["ln2"], x, cfg)
                if kind == BLOCK_ATTN:
                    x = x + mlp_fwd(bp["mlp"], h2, cfg)
                else:
                    y2, _ = moe.moe_fwd(bp["moe"], h2, cfg)
                    x = x + y2
                nst[f"b{i}"] = {"kv": kv}
            elif kind == BLOCK_MAMBA:
                y, s2 = ssm.mamba_step(bp["mamba"],
                                       apply_norm(bp["ln1"], x, cfg), st, cfg)
                x = x + y
                nst[f"b{i}"] = s2
            elif kind == BLOCK_MLSTM:
                y, s2 = ssm.mlstm_step(bp["mlstm"],
                                       apply_norm(bp["ln1"], x, cfg), st, cfg)
                x = x + y
                nst[f"b{i}"] = s2
            elif kind == BLOCK_SLSTM:
                y, s2 = ssm.slstm_step(bp["slstm"],
                                       apply_norm(bp["ln1"], x, cfg), st, cfg)
                x = x + y
                nst[f"b{i}"] = s2
            elif kind == BLOCK_SHARED_ATTN:
                h = apply_norm(shared["ln"], x, cfg)
                y, kv = attention_decode(shared["attn"], h, cfg, st["kv"], pos)
                x = x + y
                nst[f"b{i}"] = {"kv": kv}
        return x, nst

    scanned = {"unit": p["units"], "state": state["units"]}
    if has_cross:
        scanned["cross"] = p["cross"]
    x, new_units = lax.scan(unit_body, x, scanned)
    x = apply_norm(p["final_norm"], x, cfg)
    head = p["embed"].T if cfg.tie_embeddings else p["head"]
    logits = hint((x @ head.astype(dt)).astype(jnp.float32),
                  "batch", None, "vocab")
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask[None, None, :], logits, -1e30)
    return logits, {"units": new_units, "pos": pos + 1}
