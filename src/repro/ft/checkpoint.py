"""Checkpoint save/restore — host-local npz shards + a JSON manifest.

Design for 1000+ nodes (DESIGN.md §7):

* each host writes only the *addressable* shards of its arrays (here: the
  whole array on the single-host container; the addressing logic goes
  through ``addressable_shards`` so the multi-host path is the same code);
* saves are atomic (tmp file + rename) and optionally async (a daemon
  thread snapshots to host RAM first — device-to-host copy is the only
  part on the critical path, matching async-checkpointing practice);
* the manifest records the step, the flattened tree structure and per-leaf
  dtypes/shapes, so restore can (a) validate, (b) feed ``elastic.py`` which
  reshards onto a different mesh.
"""
from __future__ import annotations

import json
import os
import re
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SEP = "::"
_pending: Dict[str, threading.Thread] = {}


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(ckpt_dir: str, state: Any, step: int,
                    async_save: bool = False) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(state)            # device->host copy happens here
    treedef = jax.tree_util.tree_structure(state)
    manifest = {
        "step": step,
        "time": time.time(),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
        "treedef": str(treedef),
    }
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    if path in _pending:           # same step already being written
        return path

    def _write():
        tmp = f"{path}.{os.getpid()}.{threading.get_ident()}.tmp.npz"
        np.savez(tmp, **flat)
        os.replace(tmp, path)
        mtmp = path + ".manifest.tmp"
        with open(mtmp, "w") as f:
            json.dump(manifest, f)
        os.replace(mtmp, path + ".manifest.json")

    if async_save:
        th = threading.Thread(target=_write, daemon=True)
        th.start()
        _pending[path] = th
    else:
        _write()
    return path


def wait_for_saves():
    for th in list(_pending.values()):
        th.join()
    _pending.clear()


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    # strict match: in-flight async writes park as
    # step_XXXXXXXX.npz.<pid>.<tid>.tmp.npz (np.savez forces the .npz
    # suffix), which a loose endswith(".npz") filter would parse as a step
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             for m in [re.fullmatch(r"step_(\d+)\.npz", f)] if m]
    return max(steps) if steps else None


def resume_chunk_start(ckpt_dir: str,
                       step: Optional[int] = None) -> Optional[int]:
    """First nominal step a resumed run executes — the chunk boundary
    derived from the saved step.

    The chunked loop (training/loop.py) saves only at chunk boundaries and
    plans chunks *relative to the start step*, so the boundary after a save
    at nominal step ``s`` is exactly ``s + 1``: a resumed chunked run and
    an uninterrupted one see identical chunk layouts from that point (the
    parity property tests/test_loop.py pins).  Returns ``None`` when the
    directory holds no checkpoint, so callers can distinguish "fresh run"
    from "resume at step 0"."""
    s = step if step is not None else latest_step(ckpt_dir)
    return None if s is None else s + 1


def restore_checkpoint(ckpt_dir: str, like: Any,
                       step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (validates shapes/dtypes)."""
    wait_for_saves()
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    data = np.load(path)
    flat_like = _flatten(like)
    missing = set(flat_like) - set(data.files)
    if missing:
        raise ValueError(f"checkpoint missing leaves: {sorted(missing)[:5]}")
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_k, leaf in leaves_like:
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path_k)
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"{key}: shape {arr.shape} != {np.shape(leaf)}")
        out.append(arr.astype(np.asarray(leaf).dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)
    return tree, step
