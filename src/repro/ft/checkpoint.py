"""Checkpoint save/restore — host-local npz shards + a JSON manifest.

Design for 1000+ nodes (DESIGN.md §Fault-tolerance):

* each host writes only the *addressable* shards of its arrays (here: the
  whole array on the single-host container; the addressing logic goes
  through ``addressable_shards`` so the multi-host path is the same code);
* saves are atomic (tmp file + rename) and optionally async (a daemon
  thread snapshots to host RAM first — device-to-host copy is the only
  part on the critical path, matching async-checkpointing practice);
* the **manifest is the commit record**: it is written atomically AFTER
  the npz landed and carries a per-leaf CRC32 next to dtypes/shapes, so a
  checkpoint is *intact* only when (a) the manifest exists, (b) every
  manifest leaf is present in the npz, and (c) every checksum matches.
  A crash between the npz rename and the manifest rename leaves a
  detectable partial save, never a silently-loadable half-checkpoint;
* restore verifies integrity and **falls back to the previous intact
  step** instead of crashing on (or worse, loading) a truncated or
  corrupted save — node loss during a save must not take out the run's
  whole checkpoint history;
* the async writer retries with backoff (transient NFS/object-store
  hiccups) and surfaces terminal failures: ``wait_for_saves`` raises
  :class:`CheckpointWriteError` instead of letting a daemon thread die
  silently with the data.

The manifest also records the flattened tree structure, so restore can
(a) validate, (b) feed ``elastic.py`` which reshards onto a different
mesh.
"""
from __future__ import annotations

import json
import os
import re
import threading
import time
import zipfile
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_SEP = "::"
_pending: Dict[str, threading.Thread] = {}
# path -> terminal exception of a failed (post-retry) async write.  Never
# dropped silently: wait_for_saves() turns these into CheckpointWriteError.
_errors: Dict[str, BaseException] = {}
_errors_lock = threading.Lock()

# indirection so ft/faults.py can deterministically inject write failures
# (disk full, flaky storage) without monkeypatching numpy globally
_savez = np.savez

MANIFEST_SUFFIX = ".manifest.json"
WRITE_RETRIES = 3          # attempts per save (1 + 2 retries)
WRITE_BACKOFF_S = 0.05     # doubles per retry


class CheckpointWriteError(RuntimeError):
    """One or more checkpoint writes failed terminally (post-retry)."""

    def __init__(self, failures: Dict[str, BaseException]):
        self.failures = dict(failures)
        detail = "; ".join(f"{os.path.basename(p)}: {e!r}"
                           for p, e in sorted(self.failures.items()))
        super().__init__(f"{len(self.failures)} checkpoint write(s) failed: "
                         f"{detail}")


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _leaf_crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _ckpt_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}.npz")


def save_checkpoint(ckpt_dir: str, state: Any, step: int,
                    async_save: bool = False) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(state)            # device->host copy happens here
    treedef = jax.tree_util.tree_structure(state)
    manifest = {
        "step": step,
        "time": time.time(),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                       "crc32": _leaf_crc(v)}
                   for k, v in flat.items()},
        "treedef": str(treedef),
    }
    path = _ckpt_path(ckpt_dir, step)
    if path in _pending:           # same step already being written
        return path

    def _write_once():
        tmp = f"{path}.{os.getpid()}.{threading.get_ident()}.tmp.npz"
        try:
            _savez(tmp, **flat)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)
        # the manifest rename COMMITS the checkpoint: readers treat a
        # manifest-less npz as an in-flight/partial save
        mtmp = path + ".manifest.tmp"
        with open(mtmp, "w") as f:
            json.dump(manifest, f)
        os.replace(mtmp, path + MANIFEST_SUFFIX)

    def _write():
        delay = WRITE_BACKOFF_S
        for attempt in range(WRITE_RETRIES):
            try:
                _write_once()
                return
            except OSError as e:
                if attempt == WRITE_RETRIES - 1:
                    with _errors_lock:
                        _errors[path] = e
                    return
                time.sleep(delay)
                delay *= 2

    if async_save:
        th = threading.Thread(target=_write, daemon=True)
        th.start()
        _pending[path] = th
    else:
        _write()
        with _errors_lock:
            err = _errors.pop(path, None)
        if err is not None:
            raise CheckpointWriteError({path: err})
    return path


def _join_pending() -> None:
    for th in list(_pending.values()):
        th.join()
    _pending.clear()


def wait_for_saves(raise_on_error: bool = True) -> Dict[str, BaseException]:
    """Join all in-flight async writes.

    A failed write (post-retry) is a *surfaced* error, never a silently
    dead daemon thread: by default this raises :class:`CheckpointWriteError`
    aggregating every failure since the last call; with
    ``raise_on_error=False`` it returns-and-consumes the failure dict
    instead (the trainer's final-save path uses this to report rather
    than crash).
    """
    _join_pending()
    with _errors_lock:
        failures = dict(_errors)
        _errors.clear()
    if failures and raise_on_error:
        raise CheckpointWriteError(failures)
    return failures


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    # strict match: in-flight async writes park as
    # step_XXXXXXXX.npz.<pid>.<tid>.tmp.npz (np.savez forces the .npz
    # suffix), which a loose endswith(".npz") filter would parse as a step
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             for m in [re.fullmatch(r"step_(\d+)\.npz", f)] if m]
    return max(steps) if steps else None


# ---------------------------------------------------------------------------
# integrity
# ---------------------------------------------------------------------------


def verify_checkpoint(ckpt_dir: str, step: int) -> Tuple[bool, str]:
    """``(intact, reason)`` for one saved step.

    Checks, in order: npz present, manifest present (the commit record —
    a manifest-less npz is a partial save), npz readable (truncation shows
    up here), every manifest leaf present with matching shape/dtype, every
    per-leaf CRC32 matching.  ``reason`` names the first failure.
    """
    path = _ckpt_path(ckpt_dir, step)
    if not os.path.exists(path):
        return False, "missing npz"
    mpath = path + MANIFEST_SUFFIX
    if not os.path.exists(mpath):
        return False, "missing manifest (uncommitted/partial save)"
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        return False, f"unreadable manifest: {e!r}"
    leaves = manifest.get("leaves", {})
    try:
        with np.load(path) as data:
            files = set(data.files)
            missing = set(leaves) - files
            if missing:
                return False, f"missing leaves: {sorted(missing)[:5]}"
            for key, meta in leaves.items():
                arr = data[key]
                if list(arr.shape) != list(meta["shape"]) or \
                        str(arr.dtype) != meta["dtype"]:
                    return False, f"leaf {key}: shape/dtype mismatch"
                if "crc32" in meta and _leaf_crc(arr) != meta["crc32"]:
                    return False, f"leaf {key}: checksum mismatch"
    except (OSError, ValueError, zlib.error, zipfile.BadZipFile,
            EOFError, KeyError) as e:
        return False, f"unreadable npz (truncated/corrupt): {e!r}"
    return True, "ok"


def intact_steps(ckpt_dir: str) -> List[int]:
    """All verified-intact steps in ``ckpt_dir``, ascending."""
    if not os.path.isdir(ckpt_dir):
        return []
    steps = sorted(int(m.group(1)) for f in os.listdir(ckpt_dir)
                   for m in [re.fullmatch(r"step_(\d+)\.npz", f)] if m)
    return [s for s in steps if verify_checkpoint(ckpt_dir, s)[0]]


def latest_intact_step(ckpt_dir: str) -> Optional[int]:
    """Newest step that passes integrity verification — the step an
    elastic restart resumes from (``ft/supervisor.py``)."""
    steps = intact_steps(ckpt_dir)
    return steps[-1] if steps else None


def resume_chunk_start(ckpt_dir: str,
                       step: Optional[int] = None) -> Optional[int]:
    """First nominal step a resumed run executes — the chunk boundary
    derived from the saved step.

    The chunked loop (training/loop.py) saves only at chunk boundaries and
    plans chunks *relative to the start step*, so the boundary after a save
    at nominal step ``s`` is exactly ``s + 1``: a resumed chunked run and
    an uninterrupted one see identical chunk layouts from that point (the
    parity property tests/test_loop.py pins).  Returns ``None`` when the
    directory holds no checkpoint, so callers can distinguish "fresh run"
    from "resume at step 0"."""
    s = step if step is not None else latest_step(ckpt_dir)
    return None if s is None else s + 1


def restore_checkpoint(ckpt_dir: str, like: Any,
                       step: Optional[int] = None,
                       verify: bool = True) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (validates shapes/dtypes).

    With ``verify=True`` (default) a truncated/corrupt/partial save is
    detected by the manifest checksums and restore **falls back to the
    newest earlier intact step** rather than crashing or loading garbage;
    ``FileNotFoundError`` is raised only when no intact checkpoint exists
    at all.  The returned step tells the caller which save was actually
    loaded.  ``verify=False`` restores the raw requested/latest step
    (legacy behavior; shape validation still applies).
    """
    # join in-flight writes but do NOT consume failure records: a failed
    # save simply isn't an intact candidate here, and the failure must
    # still reach the next wait_for_saves() caller
    _join_pending()
    if verify:
        candidates = intact_steps(ckpt_dir)
        if step is not None:
            candidates = [s for s in candidates if s <= step]
        if not candidates:
            raise FileNotFoundError(
                f"no intact checkpoint in {ckpt_dir}"
                + (f" at or before step {step}" if step is not None else ""))
        step = candidates[-1]
    else:
        step = step if step is not None else latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = _ckpt_path(ckpt_dir, step)
    data = np.load(path)
    flat_like = _flatten(like)
    missing = set(flat_like) - set(data.files)
    if missing:
        raise ValueError(f"checkpoint missing leaves: {sorted(missing)[:5]}")
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_k, leaf in leaves_like:
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path_k)
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"{key}: shape {arr.shape} != {np.shape(leaf)}")
        out.append(arr.astype(np.asarray(leaf).dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)
    return tree, step
