"""Elastic restart: reshard a checkpointed state onto a different mesh.

Node loss at scale means restarting on a smaller (or differently shaped)
mesh.  Because checkpoints are stored as full logical arrays + a manifest
(ft/checkpoint.py) and shardings are *derived* from the rule table
(distributed/sharding.py) rather than stored, resharding is just
``jax.device_put`` with the new mesh's shardings — the rule engine's
divisibility fallback guarantees a valid placement exists for any mesh.

The batch contract also survives: the synthetic/counter-based data pipeline
keys on (seed, step, shard), so a restart with a different number of data
shards replays distinct, non-overlapping shards by construction.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.distributed.sharding import param_shardings, state_shardings


def reshard_state(state: Any, new_mesh, fsdp: bool = True) -> Any:
    """Place a (host-resident) TrainState onto a new mesh."""
    shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype)
        if hasattr(x, "shape") else x, state)
    sh = state_shardings(shapes, new_mesh, fsdp=fsdp)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s) if hasattr(x, "shape") else x,
        state, sh)
