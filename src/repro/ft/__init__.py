from repro.ft.checkpoint import save_checkpoint, restore_checkpoint, latest_step
from repro.ft.elastic import reshard_state
