from repro.ft.checkpoint import (CheckpointWriteError, intact_steps,
                                 latest_intact_step, latest_step,
                                 restore_checkpoint, save_checkpoint,
                                 verify_checkpoint, wait_for_saves)
from repro.ft.elastic import reshard_state
from repro.ft.supervisor import Attempt, RestartPolicy, Supervisor
