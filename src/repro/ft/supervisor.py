"""Elastic worker supervision: detect death, shrink the world, resume.

The restart-policy owner for multi-host training (DESIGN.md
§Fault-tolerance).  A :class:`Supervisor` launches a *world* of worker
processes (one per host/rank — on the test container, subprocesses of
``launch/train.py``), monitors them, and on a worker death applies the
elastic kill-and-restart policy:

1. **detect** — a worker exiting nonzero (or on a signal) marks the whole
   attempt failed; surviving workers are terminated (a smaller SPMD world
   cannot absorb a missing rank mid-program);
2. **shrink** — the next attempt's world is the survivor count
   (``world - deaths``), bounded below by ``RestartPolicy.min_world``;
3. **resume** — the restart resumes from the **last intact checkpoint**
   (``ft/checkpoint.latest_intact_step`` — integrity-verified, so a save
   torn by the kill is skipped, never loaded), resharding onto the
   smaller mesh via ``ft/elastic.reshard_state`` inside the relaunched
   worker;
4. **give up** — after ``max_restarts`` restarts or when the world would
   fall below ``min_world``.

The data/SMD path needs no special casing across restarts: batches and
drop decisions are counter-based functions of ``(seed, step, shard)``, so
the resumed counter stream is bit-consistent with an uninterrupted run by
construction — the property the kill-and-restart test pins.

The supervisor is deliberately ignorant of JAX: workers are opaque
commands built by a ``make_cmd(world, rank, resume_step)`` template, so
the same loop supervises single-process elastic-mesh workers (CPU test
harness: ``--devices W --mesh-data W``) and real ``jax.distributed``
multi-process worlds (``--coordinator … --num-processes W --process-id
r``).
"""
from __future__ import annotations

import signal
import subprocess
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from repro.ft.checkpoint import latest_intact_step


@dataclass(frozen=True)
class RestartPolicy:
    """When and how to restart after a worker death."""

    max_restarts: int = 2       # restart attempts after the first launch
    min_world: int = 1          # smallest mesh worth re-forming
    backoff_s: float = 0.0      # pause before a relaunch (storm damping)


@dataclass
class Attempt:
    """One launch of the full world (for reporting / BENCH_ft.json)."""

    world: int
    resume_step: Optional[int]          # intact step resumed from (None=fresh)
    exit_codes: List[Optional[int]] = field(default_factory=list)
    outcome: str = "running"            # "ok" | "worker-died" | "aborted"

    def to_dict(self) -> dict:
        return {"world": self.world, "resume_step": self.resume_step,
                "exit_codes": list(self.exit_codes), "outcome": self.outcome}


class SupervisorError(RuntimeError):
    """The run could not be completed under the restart policy."""

    def __init__(self, message: str, attempts: List[Attempt]):
        super().__init__(message)
        self.attempts = attempts


class Supervisor:
    """Launch, monitor and elastically restart a world of workers.

    ``make_cmd(world, rank, resume_step)`` returns the argv for one
    worker.  ``resume_step`` is ``None`` on the first attempt and the
    last *intact* checkpoint step on restarts — the command template
    decides how to translate that into flags (``--resume``) and how the
    world size shapes the worker's mesh.
    """

    def __init__(self, make_cmd: Callable[[int, int, Optional[int]],
                                          Sequence[str]],
                 world: int, ckpt_dir: str,
                 policy: RestartPolicy = RestartPolicy(),
                 env: Optional[Dict[str, str]] = None,
                 poll_s: float = 0.05,
                 worker_timeout_s: float = 600.0):
        self.make_cmd = make_cmd
        self.world = world
        self.ckpt_dir = ckpt_dir
        self.policy = policy
        self.env = env
        self.poll_s = poll_s
        self.worker_timeout_s = worker_timeout_s
        self.attempts: List[Attempt] = []

    # -- one attempt ------------------------------------------------------

    def _launch(self, world: int, resume_step: Optional[int]
                ) -> List[subprocess.Popen]:
        procs = []
        for rank in range(world):
            cmd = list(self.make_cmd(world, rank, resume_step))
            procs.append(subprocess.Popen(cmd, env=self.env))
        return procs

    def _reap(self, procs: List[subprocess.Popen]) -> List[Optional[int]]:
        """Wait until every worker exits or any worker dies (then the
        survivors are killed — a torn SPMD world cannot continue)."""
        deadline = time.monotonic() + self.worker_timeout_s
        while True:
            codes = [p.poll() for p in procs]
            if all(c is not None for c in codes):
                return codes
            if any(c is not None and c != 0 for c in codes):
                # one dead rank tears the attempt: terminate survivors
                for p in procs:
                    if p.poll() is None:
                        p.terminate()
                for p in procs:
                    try:
                        p.wait(timeout=10)
                    except subprocess.TimeoutExpired:
                        p.kill()
                        p.wait()
                return [p.poll() for p in procs]
            if time.monotonic() > deadline:
                for p in procs:
                    if p.poll() is None:
                        p.kill()
                        p.wait()
                raise SupervisorError(
                    f"worker timeout after {self.worker_timeout_s}s",
                    self.attempts)
            time.sleep(self.poll_s)

    # -- the policy loop --------------------------------------------------

    def run(self) -> List[Attempt]:
        """Drive the world to completion under the restart policy.

        Returns the attempt history (last outcome ``"ok"``); raises
        :class:`SupervisorError` when the policy gives up.
        """
        world = self.world
        resume: Optional[int] = None
        restarts = 0
        while True:
            att = Attempt(world=world, resume_step=resume)
            self.attempts.append(att)
            procs = self._launch(world, resume)
            att.exit_codes = self._reap(procs)
            if all(c == 0 for c in att.exit_codes):
                att.outcome = "ok"
                return self.attempts
            att.outcome = "worker-died"
            deaths = sum(1 for c in att.exit_codes
                         if c not in (0, -signal.SIGTERM))
            new_world = world - max(deaths, 1)
            if restarts >= self.policy.max_restarts:
                att.outcome = "aborted"
                raise SupervisorError(
                    f"gave up after {restarts} restart(s): "
                    f"exit codes {att.exit_codes}", self.attempts)
            if new_world < self.policy.min_world:
                att.outcome = "aborted"
                raise SupervisorError(
                    f"world {new_world} below min_world="
                    f"{self.policy.min_world}", self.attempts)
            # resume from the last INTACT checkpoint: a save torn by the
            # kill fails checksum verification and is skipped here
            resume = latest_intact_step(self.ckpt_dir)
            restarts += 1
            world = new_world
            if self.policy.backoff_s:
                time.sleep(self.policy.backoff_s)

    def summary(self) -> dict:
        return {"attempts": [a.to_dict() for a in self.attempts],
                "final_world": self.attempts[-1].world if self.attempts
                else self.world,
                "restarts": max(len(self.attempts) - 1, 0)}


def free_tcp_port() -> int:
    """A free localhost port for a ``jax.distributed`` coordinator."""
    import socket
    with socket.socket() as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]
