"""Deterministic fault injection — every recovery path gets a real fault.

Long-running edge/multi-host training (the paper's operating regime) sees
preemption, node loss, silent storage corruption and flaky I/O as routine
events.  This module is the test harness's fault source: each injector is
a *deterministic* function of the nominal step counter or an explicit
call, so a recovery test reproduces the same fault at the same point on
every run (DESIGN.md §Fault-tolerance).

Injectors:

* :func:`kill_at_step` — hard process death (``os._exit``) the moment the
  data path asks for a given nominal step: simulates preemption/node loss
  mid-run.  Exits with :data:`KILL_EXIT_CODE` so a supervisor can tell an
  injected kill from a clean exit or a Python crash.
* :func:`raising_at_step` — ``make_batch`` raises at a given step: the
  producer-thread death the pipeline must propagate, not swallow.
* :func:`slow_at_step` — a configured delay on given steps: a straggling
  data source / device feeding the per-step deadline machinery.
* :func:`corrupt_checkpoint` — truncation, byte-flip, silent value
  tampering, or a missing-manifest partial save, applied to an on-disk
  checkpoint: everything ``ft/checkpoint.verify_checkpoint`` must catch.
* :func:`failing_writer` — a context manager that makes the checkpoint
  writer's ``savez`` raise ``OSError(ENOSPC)`` for the first N calls:
  disk-full/flaky-storage simulation for the retry-with-backoff and
  error-surfacing paths.
"""
from __future__ import annotations

import contextlib
import errno
import os
import time
from typing import Callable, Dict, Iterable

import numpy as np

from repro.ft import checkpoint as _ckpt

# distinct from any Python/pytest exit code, so the supervisor's restart
# policy can classify worker deaths
KILL_EXIT_CODE = 43

CORRUPT_MODES = ("truncate", "flip", "tamper", "partial")


def kill_at_step(make_batch: Callable[[int, int], Dict], step: int,
                 exit_code: int = KILL_EXIT_CODE
                 ) -> Callable[[int, int], Dict]:
    """Wrap ``make_batch`` to hard-kill the process at nominal ``step``.

    ``os._exit`` — no atexit handlers, no finally blocks, no flushing of
    in-flight async checkpoint writers: the closest a single process gets
    to losing its node.  Triggers on the first *generated* step ``>=
    step`` (an SMD drop never calls ``make_batch``, and a kill scheduled
    on a dropped step must still fire).
    """
    def wrapped(s: int, shard: int) -> Dict:
        if s >= step:
            os._exit(exit_code)
        return make_batch(s, shard)
    return wrapped


def raising_at_step(make_batch: Callable[[int, int], Dict], step: int,
                    exc: Callable[[], BaseException] = None
                    ) -> Callable[[int, int], Dict]:
    """Wrap ``make_batch`` to raise at the first generated step ``>= step``
    — the producer-thread fault ``DataPipeline`` must propagate."""
    def wrapped(s: int, shard: int) -> Dict:
        if s >= step:
            raise (exc() if exc is not None else
                   RuntimeError(f"injected data fault at step {s}"))
        return make_batch(s, shard)
    return wrapped


def slow_at_step(make_batch: Callable[[int, int], Dict],
                 steps: Iterable[int], delay_s: float
                 ) -> Callable[[int, int], Dict]:
    """Wrap ``make_batch`` to sleep ``delay_s`` on the given nominal steps
    (a deterministic straggler)."""
    slow = frozenset(int(s) for s in steps)

    def wrapped(s: int, shard: int) -> Dict:
        if s in slow:
            time.sleep(delay_s)
        return make_batch(s, shard)
    return wrapped


def corrupt_checkpoint(ckpt_dir: str, step: int, mode: str = "truncate"
                       ) -> str:
    """Damage one saved checkpoint in a specific, reproducible way.

    * ``truncate`` — cut the npz to half its size (crash mid-write /
      torn page): ``np.load`` fails, integrity says *unreadable*.
    * ``flip`` — flip one payload byte in place: zip-level CRC breakage.
    * ``tamper`` — rewrite the npz **legitimately** with one leaf's values
      altered (silent bit-rot / wrong-object-version storage): the zip
      container is self-consistent, so ONLY the manifest's per-leaf CRC32
      catches it — the failure mode that justifies checkpoint-level
      checksums over trusting the container format.
    * ``partial`` — delete the manifest: a crash between the npz rename
      and the manifest commit (the save never committed).

    Returns the damaged path.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    if mode == "truncate":
        size = os.path.getsize(path)
        with open(path, "rb+") as f:
            f.truncate(max(size // 2, 1))
    elif mode == "flip":
        size = os.path.getsize(path)
        with open(path, "rb+") as f:
            f.seek(size // 2)
            b = f.read(1)
            f.seek(size // 2)
            f.write(bytes([b[0] ^ 0xFF]))
    elif mode == "tamper":
        with np.load(path) as data:
            arrs = {k: np.array(data[k]) for k in data.files}
        # alter the first leaf's bytes without changing shape/dtype
        key = sorted(arrs)[0]
        flat = arrs[key].reshape(-1).view(np.uint8)
        flat[0] ^= 0xFF
        np.savez(path, **arrs)
    elif mode == "partial":
        os.remove(path + _ckpt.MANIFEST_SUFFIX)
    else:
        raise ValueError(f"unknown corruption mode {mode!r}; "
                         f"one of {CORRUPT_MODES}")
    return path


@contextlib.contextmanager
def failing_writer(fails: int = 10**9, exc: OSError = None):
    """Make the checkpoint writer's ``savez`` raise for the first ``fails``
    calls (then recover) — disk-full / flaky-storage simulation.

    ``fails`` smaller than the writer's retry budget exercises
    retry-with-backoff success; ``fails`` larger exercises terminal
    failure surfacing (``wait_for_saves`` → ``CheckpointWriteError``).
    """
    err = exc if exc is not None else \
        OSError(errno.ENOSPC, "injected: no space left on device")
    count = {"n": 0}
    real = _ckpt._savez

    def flaky(path, **arrs):
        if count["n"] < fails:
            count["n"] += 1
            raise err
        return real(path, **arrs)

    _ckpt._savez = flaky
    try:
        yield count
    finally:
        _ckpt._savez = real
