"""jit'd wrappers over the Pallas kernels — the raw kernel entry points.

``psg_grad_w(x, gy, cfg)`` is the drop-in tile-level replacement for the
element-level ``repro.kernels.ref.psg_grad_w_ref`` oracle; outputs are
value-identical (the tile granularity only changes the *energy accounting*,
reported via the returned fallback-tile ratio).

Backend selection (reference vs. Pallas-interpret vs. Mosaic-compiled) is
owned by ``repro.kernels.dispatch`` — model and training code should call
the dispatch layer, not this module (DESIGN.md §Dispatch).  The ``interpret``
flag here is a plain argument: on this CPU container the dispatch layer
passes ``True`` (kernel body executed by the Pallas interpreter); on a real
TPU it resolves to ``False`` and the kernels lower through Mosaic.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.config import PSGConfig
from repro.core.quant import qscale
from repro.kernels import conv as _cv
from repro.kernels import psg_matmul as _pm
from repro.kernels import quant as _q


def _codes(x: jnp.ndarray, bits: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Integer codes on the ``bits``-bit grid + the grid scale."""
    s = qscale(x, bits)
    lim = 2.0 ** (bits - 1) - 1.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -lim, lim)
    dt = jnp.int8 if bits <= 8 else jnp.int16
    return q.astype(dt), s


@partial(jax.jit, static_argnames=("cfg", "interpret"))
def psg_grad_w(x2: jnp.ndarray, gy2: jnp.ndarray, cfg: PSGConfig,
               interpret: bool = True
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Tile-level PSG weight gradient.

    Returns (sign (din,dout) float32 in {-1,0,+1}, fallback_tile_ratio
    scalar — the fraction of output tiles that needed the full product; the
    energy model charges full-precision MACs only for those).
    """
    xm_c, xm_s = _codes(x2, cfg.bits_x_msb)
    gm_c, gm_s = _codes(gy2, cfg.bits_g_msb)
    xq_c, xq_s = _codes(x2, cfg.bits_x)
    gq_c, gq_s = _codes(gy2, cfg.bits_g)
    # threshold in *code units* of the predictor product:
    # tau_real = beta * max|g_msb_real|; g_msb_real = codes * (xm_s * gm_s)
    # -> tau_codes = beta * max|codes-product|
    # we need max|g_msb| first: cheap jnp matmul on the narrow codes would
    # defeat the kernel, so compute it from the kernel's own pass-1 product.
    g_msb_codes = _pm.predictor_matmul_pallas(xm_c, gm_c, interpret=interpret)
    tau_codes = cfg.beta * jnp.max(jnp.abs(g_msb_codes))
    # rescale full-product codes so both accumulators share tau units:
    # sign(g_full) is scale-invariant, so no rescale needed for the sign.
    sign_i8, stats = _pm.psg_grad_w_pallas(
        xm_c, gm_c, xq_c, gq_c, tau_codes, interpret=interpret)
    fallback_ratio = jnp.mean(stats.astype(jnp.float32))
    return sign_i8.astype(jnp.float32), fallback_ratio


@partial(jax.jit, static_argnames=("bits", "interpret"))
def quantize(x: jnp.ndarray, bits: int, interpret: bool = True
             ) -> jnp.ndarray:
    return _q.quantize_pallas(x, bits, interpret=interpret)


@partial(jax.jit, static_argnames=("k", "stride", "interpret"))
def conv_fwd(xq: jnp.ndarray, wq: jnp.ndarray, k: int, stride: int,
             interpret: bool = True) -> jnp.ndarray:
    """Implicit-GEMM conv forward on pre-quantized operands.

    ``xq``: pre-padded NHWC ``(B, Hp, Wp, C)``; ``wq``: patch-major
    ``(k*k*C, dout)``.  Value-equal to the materialized
    ``kernels/ref.conv_fwd_ref`` up to fp32 tap-summation order — the
    patch tensor is never written to HBM.
    """
    return _cv.conv_fwd_pallas(xq, wq, k=k, stride=stride,
                               interpret=interpret)


@partial(jax.jit, static_argnames=("k", "stride", "hp", "wp", "interpret"))
def conv_grad_x(gq: jnp.ndarray, wq: jnp.ndarray, k: int, stride: int,
                hp: int, wp: int, interpret: bool = True) -> jnp.ndarray:
    """Implicit transposed-conv input gradient on pre-quantized operands.

    ``gq``: quantized output-grad ``(B, Ho, Wo, dout)``; ``wq``:
    patch-major quantized weight; ``hp``/``wp``: the pre-padded input
    extent.  Returns ``dx (B, hp, wp, C)`` float32 — value-equal to the
    col2im reference (``kernels/ref.conv_grad_x_ref``) up to fp32
    tap-summation order; no dpatches tensor, no k^2 scatter passes.
    """
    return _cv.conv_grad_x_pallas(gq, wq, k=k, stride=stride, hp=hp, wp=wp,
                                  interpret=interpret)


@partial(jax.jit, static_argnames=("cfg", "k", "stride", "interpret"))
def conv_grad_w(xp: jnp.ndarray, gy: jnp.ndarray, cfg: PSGConfig,
                k: int, stride: int, interpret: bool = True
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Tile-level PSG conv weight gradient, implicit im2col gather.

    ``xp``: pre-padded NHWC input (raw values; codes are built here the
    same way :func:`psg_grad_w` builds them — element-wise on the padded
    input, which carries the identical quantization grid as the patch
    tensor since gathering commutes with the per-tensor code map).
    Returns ``(sign (k*k*C, dout) float32 patch-major, fallback_tile_ratio
    scalar)`` — the same contract as :func:`psg_grad_w` on the
    materialized operand.
    """
    xm_c, _ = _codes(xp, cfg.bits_x_msb)
    gm_c, _ = _codes(gy, cfg.bits_g_msb)
    xq_c, _ = _codes(xp, cfg.bits_x)
    gq_c, _ = _codes(gy, cfg.bits_g)
    # pass 1: predictor product for the adaptive threshold (code units —
    # sign(g) is scale-invariant, exactly as in psg_grad_w above)
    g_msb = _cv.conv_grad_w_predictor_pallas(xm_c, gm_c, k=k, stride=stride,
                                             interpret=interpret)
    tau_codes = cfg.beta * jnp.max(jnp.abs(g_msb))
    sign_i8, stats = _cv.conv_grad_w_pallas(
        xm_c, gm_c, xq_c, gq_c, tau_codes, k=k, stride=stride,
        interpret=interpret)
    return sign_i8.astype(jnp.float32), jnp.mean(stats.astype(jnp.float32))
