"""jit'd wrappers over the Pallas kernels — the raw kernel entry points.

``psg_grad_w(x, gy, cfg)`` is the drop-in tile-level replacement for the
element-level ``repro.kernels.ref.psg_grad_w_ref`` oracle; outputs are
value-identical (the tile granularity only changes the *energy accounting*,
reported via the returned fallback-tile ratio).

Backend selection (reference vs. Pallas-interpret vs. Mosaic-compiled) is
owned by ``repro.kernels.dispatch`` — model and training code should call
the dispatch layer, not this module (DESIGN.md §Dispatch).  The ``interpret``
flag here is a plain argument: on this CPU container the dispatch layer
passes ``True`` (kernel body executed by the Pallas interpreter); on a real
TPU it resolves to ``False`` and the kernels lower through Mosaic.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.config import PSGConfig
from repro.core.quant import qscale
from repro.kernels import conv as _cv
from repro.kernels import flash_attn as _fa
from repro.kernels import psg_matmul as _pm
from repro.kernels import quant as _q


def _codes(x: jnp.ndarray, bits: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Integer codes on the ``bits``-bit grid + the grid scale."""
    s = qscale(x, bits)
    lim = 2.0 ** (bits - 1) - 1.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -lim, lim)
    dt = jnp.int8 if bits <= 8 else jnp.int16
    return q.astype(dt), s


@partial(jax.jit, static_argnames=("cfg", "interpret"))
def psg_grad_w(x2: jnp.ndarray, gy2: jnp.ndarray, cfg: PSGConfig,
               interpret: bool = True
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Tile-level PSG weight gradient.

    Returns (sign (din,dout) float32 in {-1,0,+1}, fallback_tile_ratio
    scalar — the fraction of output tiles that needed the full product; the
    energy model charges full-precision MACs only for those).
    """
    xm_c, xm_s = _codes(x2, cfg.bits_x_msb)
    gm_c, gm_s = _codes(gy2, cfg.bits_g_msb)
    xq_c, xq_s = _codes(x2, cfg.bits_x)
    gq_c, gq_s = _codes(gy2, cfg.bits_g)
    # threshold in *code units* of the predictor product:
    # tau_real = beta * max|g_msb_real|; g_msb_real = codes * (xm_s * gm_s)
    # -> tau_codes = beta * max|codes-product|
    # we need max|g_msb| first: cheap jnp matmul on the narrow codes would
    # defeat the kernel, so compute it from the kernel's own pass-1 product.
    g_msb_codes = _pm.predictor_matmul_pallas(xm_c, gm_c, interpret=interpret)
    tau_codes = cfg.beta * jnp.max(jnp.abs(g_msb_codes))
    # rescale full-product codes so both accumulators share tau units:
    # sign(g_full) is scale-invariant, so no rescale needed for the sign.
    sign_i8, stats = _pm.psg_grad_w_pallas(
        xm_c, gm_c, xq_c, gq_c, tau_codes, interpret=interpret)
    fallback_ratio = jnp.mean(stats.astype(jnp.float32))
    return sign_i8.astype(jnp.float32), fallback_ratio


@partial(jax.jit, static_argnames=("bits", "interpret"))
def quantize(x: jnp.ndarray, bits: int, interpret: bool = True
             ) -> jnp.ndarray:
    return _q.quantize_pallas(x, bits, interpret=interpret)


@partial(jax.jit, static_argnames=("k", "stride", "interpret"))
def conv_fwd(xq: jnp.ndarray, wq: jnp.ndarray, k: int, stride: int,
             interpret: bool = True) -> jnp.ndarray:
    """Implicit-GEMM conv forward on pre-quantized operands.

    ``xq``: pre-padded NHWC ``(B, Hp, Wp, C)``; ``wq``: patch-major
    ``(k*k*C, dout)``.  Value-equal to the materialized
    ``kernels/ref.conv_fwd_ref`` up to fp32 tap-summation order — the
    patch tensor is never written to HBM.
    """
    return _cv.conv_fwd_pallas(xq, wq, k=k, stride=stride,
                               interpret=interpret)


@partial(jax.jit, static_argnames=("k", "stride", "hp", "wp", "interpret"))
def conv_grad_x(gq: jnp.ndarray, wq: jnp.ndarray, k: int, stride: int,
                hp: int, wp: int, interpret: bool = True) -> jnp.ndarray:
    """Implicit transposed-conv input gradient on pre-quantized operands.

    ``gq``: quantized output-grad ``(B, Ho, Wo, dout)``; ``wq``:
    patch-major quantized weight; ``hp``/``wp``: the pre-padded input
    extent.  Returns ``dx (B, hp, wp, C)`` float32 — value-equal to the
    col2im reference (``kernels/ref.conv_grad_x_ref``) up to fp32
    tap-summation order; no dpatches tensor, no k^2 scatter passes.
    """
    return _cv.conv_grad_x_pallas(gq, wq, k=k, stride=stride, hp=hp, wp=wp,
                                  interpret=interpret)


@partial(jax.jit, static_argnames=("cfg", "k", "stride", "interpret"))
def conv_grad_w(xp: jnp.ndarray, gy: jnp.ndarray, cfg: PSGConfig,
                k: int, stride: int, interpret: bool = True
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Tile-level PSG conv weight gradient, implicit im2col gather.

    ``xp``: pre-padded NHWC input (raw values; codes are built here the
    same way :func:`psg_grad_w` builds them — element-wise on the padded
    input, which carries the identical quantization grid as the patch
    tensor since gathering commutes with the per-tensor code map).
    Returns ``(sign (k*k*C, dout) float32 patch-major, fallback_tile_ratio
    scalar)`` — the same contract as :func:`psg_grad_w` on the
    materialized operand.
    """
    xm_c, _ = _codes(xp, cfg.bits_x_msb)
    gm_c, _ = _codes(gy, cfg.bits_g_msb)
    xq_c, _ = _codes(xp, cfg.bits_x)
    gq_c, _ = _codes(gy, cfg.bits_g)
    # pass 1: predictor product for the adaptive threshold (code units —
    # sign(g) is scale-invariant, exactly as in psg_grad_w above)
    g_msb = _cv.conv_grad_w_predictor_pallas(xm_c, gm_c, k=k, stride=stride,
                                             interpret=interpret)
    tau_codes = cfg.beta * jnp.max(jnp.abs(g_msb))
    sign_i8, stats = _cv.conv_grad_w_pallas(
        xm_c, gm_c, xq_c, gq_c, tau_codes, k=k, stride=stride,
        interpret=interpret)
    return sign_i8.astype(jnp.float32), jnp.mean(stats.astype(jnp.float32))


@partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True, interpret: bool = True
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Flash attention forward + the logsumexp residual.

    q: (B, S, nh, hd); k/v: (B, T, nkv, hd).  Returns (o, lse) with
    lse (B, nh, S) fp32 — the only extra residual the recomputed-tile
    backward needs; no (S, T) tensor touches HBM.
    """
    return _fa.flash_attention(q, k, v, causal=causal, interpret=interpret,
                               return_lse=True)


@partial(jax.jit, static_argnames=("cfg", "causal", "interpret"))
def flash_attention_bwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        o: jnp.ndarray, lse: jnp.ndarray, do: jnp.ndarray,
                        cfg: PSGConfig, causal: bool = True,
                        interpret: bool = True):
    """PSG flash-attention backward: (dq, dk, dv, fallback_tile_ratio).

    dq comes from the plain fp32 recompute kernel.  dk/dv come from the
    dual-accumulator PSG kernel: per-query-head MSB and full code
    products, group-summed here to kv heads, then the Eq. (2) select
    (predictor value where ``|g_msb| >= beta*max|g_msb|``, dequantized
    full product elsewhere) — the finish stage hoisted out of the kernel
    because a Pallas grid step cannot reduce across query heads (see
    flash_attn.py's GQA note).  The fallback ratio counts (bk x hd)
    kv-tiles of the dk/dv outputs that contain any fallback element —
    the tile granularity the energy model charges full-precision MACs at.
    """
    B, S, nh, hd = q.shape
    T, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    do32 = do.astype(jnp.float32)
    delta = jnp.einsum("bsnh,bsnh->bns", do32, o.astype(jnp.float32))
    dq = _fa.flash_bwd_dq_pallas(q, k, v, do, lse, delta, causal=causal,
                                 interpret=interpret)
    scales = _fa.attention_psg_scales(
        q, v, do, delta, bits_x=cfg.bits_x, bits_x_msb=cfg.bits_x_msb,
        bits_g=cfg.bits_g, bits_g_msb=cfg.bits_g_msb)
    lims = (_fa.qlim(cfg.bits_x), _fa.qlim(cfg.bits_x_msb),
            _fa.qlim(cfg.bits_g), _fa.qlim(cfg.bits_g_msb))
    parts = _fa.flash_bwd_dkv_pallas(q, k, v, do, lse, delta, scales,
                                     lims=lims, causal=causal,
                                     interpret=interpret)
    # group-sum the per-query-head code products to kv heads (identical
    # jnp.sum in the oracle keeps the products bit-aligned)
    dv_m, dv_f, dk_m, dk_f = (
        p.reshape(B, T, nkv, g, hd).sum(axis=3) for p in parts)
    s_q, s_qm, s_do, s_dom, s_ds, s_dsm = scales
    lim_x, lim_xm = lims[0], lims[1]
    dv, r_dv = _fa.psg_attention_select(dv_m, dv_f, (1.0 / lim_xm) * s_dom,
                                        (1.0 / lim_x) * s_do, cfg.beta)
    dk, r_dk = _fa.psg_attention_select(dk_m, dk_f, s_dsm * s_qm,
                                        s_ds * s_q, cfg.beta)
    return dq, dk, dv, 0.5 * (r_dv + r_dk)
