"""Pallas TPU kernel: tile-level Predictive Sign Gradient weight-gradient.

This is the kernel the training backward pass actually executes: the
``custom_vjp`` in ``core/psg.py`` routes every PSG weight gradient here
through ``kernels/dispatch.py`` (backend selection rules in DESIGN.md
§Dispatch), and the per-tile fallback stats it emits drive the measured
energy accounting (``core/energy.py``).

Computes ``sign_psg(x^T g_y)`` for a weight matmul's backward pass with the
paper's Eq. (2) semantics, adapted to the TPU memory/compute hierarchy
(DESIGN.md §3.2):

* the MSB *predictor* product runs over narrow operands (4-bit / 10-bit
  codes carried in int8/int16 containers) — on real TPUs this is the int8
  MXU path at ~2x bf16 throughput and ~1/10 the per-MAC energy;
* the *fallback* full product is computed **per output tile**, only when the
  tile contains at least one entry below the confidence threshold
  ``tau = beta * max|g_msb|`` — the MXU is dense, so element-level fallback
  (the paper's bit-serial formulation) is replaced by tile-level
  ``pl.when`` gating.  Output values are identical to the element-level
  oracle; only the *energy accounting* is tile-granular.

Grid/BlockSpec layout: grid = (din/BM, dout/BN, N/BK) with the reduction
axis innermost; a VMEM scratch accumulator carries partial sums across the
k-steps; outputs are written on the last k-step.  Tile sizes default to
(128, 128, 512) — MXU-aligned (multiples of 128) and a VMEM working set of
BK*(BM+BN)*2B + BM*BN*8B ≈ 0.6 MB, far under the ~16 MB/core budget, which
leaves room for double-buffered pipelining of the HBM->VMEM streams.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _psg_kernel(xm_ref, gm_ref, xq_ref, gq_ref, tau_ref,
                out_ref, stats_ref, acc_msb, acc_full, *, n_k: int):
    """One (i, j) output tile; k-loop accumulates in VMEM scratch.

    xm/xq: (BK, BM) MSB / full codes of x;  gm/gq: (BK, BN) of g_y.
    out: (BM, BN) sign in {-1, 0, +1} (int8);  stats: (1, 1) int32 — 1 if
    this tile needed the full-product fallback (energy accounting).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_msb[...] = jnp.zeros_like(acc_msb)
        acc_full[...] = jnp.zeros_like(acc_full)

    # predictor product: narrow codes (int8 containers) — int MXU path
    xm = xm_ref[...].astype(jnp.float32)
    gm = gm_ref[...].astype(jnp.float32)
    acc_msb[...] += jnp.dot(xm.T, gm, preferred_element_type=jnp.float32)

    # full-precision-grid product (8b x 16b codes) — accumulated every step;
    # on real hardware this stream is elided for confident tiles via the
    # two-pass variant (ops.py `two_pass=True`); the fused single-pass
    # version computes it but only *uses* it on fallback tiles.
    xq = xq_ref[...].astype(jnp.float32)
    gq = gq_ref[...].astype(jnp.float32)
    acc_full[...] += jnp.dot(xq.T, gq, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        g_msb = acc_msb[...]
        tau = tau_ref[0, 0]
        conf = jnp.abs(g_msb) >= tau
        need_full = jnp.logical_not(jnp.all(conf))
        g_full = acc_full[...]
        sign = jnp.where(conf, jnp.sign(g_msb), jnp.sign(g_full))
        out_ref[...] = sign.astype(jnp.int8)
        stats_ref[0, 0] = need_full.astype(jnp.int32)


def _pred_kernel(xm_ref, gm_ref, out_ref, acc, *, n_k: int):
    """Predictor-only matmul (pass 1 of the two-pass variant)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    xm = xm_ref[...].astype(jnp.float32)
    gm = gm_ref[...].astype(jnp.float32)
    acc[...] += jnp.dot(xm.T, gm, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        out_ref[...] = acc[...]


def _pad_to(x: jnp.ndarray, m0: int, m1: int) -> jnp.ndarray:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def psg_grad_w_pallas(x_msb: jnp.ndarray, g_msb: jnp.ndarray,
                      x_q: jnp.ndarray, g_q: jnp.ndarray,
                      tau: jnp.ndarray,
                      *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                      bk: int = DEFAULT_BK,
                      interpret: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Tile-level PSG sign gradient.

    Args: code tensors (N, din) / (N, dout) (int8/int16 containers, values on
    the quantization grids), ``tau`` scalar fp32 threshold **in code units**
    (i.e. already divided by the product of scales).
    Returns: (sign (din, dout) int8, tile_fallback (din/bm, dout/bn) int32).
    """
    N, din = x_q.shape
    dout = g_q.shape[1]
    bm_, bn_, bk_ = min(bm, din), min(bn, dout), min(bk, N)
    xm = _pad_to(x_msb, bk_, bm_)
    gm = _pad_to(g_msb, bk_, bn_)
    xq = _pad_to(x_q, bk_, bm_)
    gq = _pad_to(g_q, bk_, bn_)
    Np, dinp = xq.shape
    doutp = gq.shape[1]
    n_i, n_j, n_k = dinp // bm_, doutp // bn_, Np // bk_

    grid = (n_i, n_j, n_k)
    out, stats = pl.pallas_call(
        functools.partial(_psg_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk_, bm_), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk_, bm_), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),   # tau scalar
        ],
        out_specs=[
            pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((dinp, doutp), jnp.int8),
            jax.ShapeDtypeStruct((n_i, n_j), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm_, bn_), jnp.float32),
            pltpu.VMEM((bm_, bn_), jnp.float32),
        ],
        interpret=interpret,
    )(xm, gm, xq, gq, tau.reshape(1, 1).astype(jnp.float32))
    return out[:din, :dout], stats


def predictor_matmul_pallas(x_msb: jnp.ndarray, g_msb: jnp.ndarray,
                            *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                            bk: int = DEFAULT_BK,
                            interpret: bool = True) -> jnp.ndarray:
    """g_msb = x_msb^T @ g_msb codes product (fp32), tiled."""
    N, din = x_msb.shape
    dout = g_msb.shape[1]
    bm_, bn_, bk_ = min(bm, din), min(bn, dout), min(bk, N)
    xm = _pad_to(x_msb, bk_, bm_)
    gm = _pad_to(g_msb, bk_, bn_)
    Np, dinp = xm.shape
    doutp = gm.shape[1]
    n_k = Np // bk_
    out = pl.pallas_call(
        functools.partial(_pred_kernel, n_k=n_k),
        grid=(dinp // bm_, doutp // bn_, n_k),
        in_specs=[
            pl.BlockSpec((bk_, bm_), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dinp, doutp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=interpret,
    )(xm, gm)
    return out[:din, :dout]
