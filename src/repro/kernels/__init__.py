"""Pallas TPU kernels for the compute hot-spots the paper optimizes.

Layout (DESIGN.md §Dispatch):

* ``dispatch.py`` — backend selection (reference / interpret / mosaic);
  the only entry point model/training code should use.
* ``ops.py``      — jit'd wrappers over the raw kernels.
* ``psg_matmul.py`` / ``quant.py`` / ``flash_attn.py`` — kernel bodies.
* ``ref.py``      — pure-jnp oracles (test-only semantics anchors).
"""
