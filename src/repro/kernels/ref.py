"""Pure-jnp oracles for the Pallas kernels (the ``ref.py`` contract).

Every kernel in this package is validated against these references in
``tests/test_kernels.py`` over a shape/dtype sweep.  Since the dispatch
layer landed (DESIGN.md §Dispatch) the element-level PSG weight-gradient
lives HERE, as a test-only reference: the training hot path runs the
tile-level kernel (``kernels/ops.psg_grad_w``), and these oracles are what
it is held accountable to.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import PSGConfig
from repro.core.quant import msb_of, quantize, quantize_int


def quantize_ref(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Fake-quantization oracle (matches kernels/quant.py)."""
    return quantize(x, bits)


def predictor_confidence_ref(x2: jnp.ndarray, gy2: jnp.ndarray,
                             cfg: PSGConfig
                             ) -> tuple:
    """Eq. (2)'s predictor state, computed once: (g_msb, confident_mask).

    The single definition of the MSB product + adaptive threshold
    ``tau = beta * max|g_msb|`` — the sign oracle, the fallback-ratio
    reference and ``core/psg.psg_predictor_usage`` all derive from this so
    a threshold-rule change cannot desynchronize them.
    """
    xm = msb_of(x2, cfg.bits_x, cfg.bits_x_msb)
    gm = msb_of(gy2, cfg.bits_g, cfg.bits_g_msb)
    g_msb = xm.astype(jnp.float32).T @ gm.astype(jnp.float32)
    tau = cfg.beta * jnp.max(jnp.abs(g_msb))
    return g_msb, jnp.abs(g_msb) >= tau


def psg_grad_w_ref(x2: jnp.ndarray, gy2: jnp.ndarray, cfg: PSGConfig
                   ) -> jnp.ndarray:
    """Element-level Eq. (2).  x2: (N, din), gy2: (N, dout) -> (din, dout).

    Returns the sign-valued weight gradient in {-1, 0, +1} (float32).
    The paper's rule: use sign(g_msb) where the MSB predictor's magnitude
    clears the adaptive threshold; fall back to the sign of the full
    fixed-point product elsewhere.
    """
    xq = quantize(x2, cfg.bits_x)
    gq = quantize(gy2, cfg.bits_g)
    g_full = xq.astype(jnp.float32).T @ gq.astype(jnp.float32)
    g_msb, pred_ok = predictor_confidence_ref(x2, gy2, cfg)
    return jnp.where(pred_ok, jnp.sign(g_msb), jnp.sign(g_full))


def psg_fallback_ratio_ref(x2: jnp.ndarray, gy2: jnp.ndarray, cfg: PSGConfig
                           ) -> jnp.ndarray:
    """Element-level fallback fraction: entries the predictor could NOT
    decide (the complement of the paper's §4.4 predictor-usage figure).
    The tile-level kernel reports the analogous *tile* ratio."""
    _, pred_ok = predictor_confidence_ref(x2, gy2, cfg)
    return jnp.mean(jnp.logical_not(pred_ok).astype(jnp.float32))


# ---------------------------------------------------------------------------
# conv oracles: materialized im2col — what the implicit-GEMM kernels
# (kernels/conv.py) eliminate and are held accountable to
# ---------------------------------------------------------------------------


def conv_patches_ref(xp: jnp.ndarray, k: int, stride: int) -> jnp.ndarray:
    """Materialized im2col of a pre-padded NHWC input: ``(B*Ho*Wo, k*k*C)``
    in the patch-major (channel-major) layout the model weights use."""
    p = jax.lax.conv_general_dilated_patches(
        xp, (k, k), (stride, stride), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return p.reshape(-1, p.shape[-1])


def conv_fwd_ref(xp: jnp.ndarray, w: jnp.ndarray, k: int, stride: int
                 ) -> jnp.ndarray:
    """im2col + single-GEMM conv forward (the materialized reference)."""
    B, Hp, Wp, _ = xp.shape
    ho = (Hp - k) // stride + 1
    wo = (Wp - k) // stride + 1
    y = conv_patches_ref(xp, k, stride) @ w.astype(xp.dtype)
    return y.reshape(B, ho, wo, -1)


def conv_grad_x_ref(gq: jnp.ndarray, wq: jnp.ndarray, k: int, stride: int,
                    hp: int, wp: int) -> jnp.ndarray:
    """Per-tap col2im scatter-add input gradient — the demoted reference
    the implicit transposed-conv kernel (``kernels/conv.py``) is held to.

    Each tap's ``(B*Ho*Wo, C)`` contribution is computed and scattered
    into a strided window of the full-size accumulator: k^2 strided
    read-modify-write passes, the traffic pattern the kernel eliminates.
    Accumulation is forced to float32 regardless of the operand dtype
    (accumulating ``k^2`` taps in a narrow gradient dtype loses low-order
    contributions; ``tests/test_conv.py`` pins the regression).
    """
    from repro.kernels.conv import to_tap_major
    B, ho, wo, dout = gq.shape
    C = wq.shape[0] // (k * k)
    wt = to_tap_major(wq.astype(jnp.float32), k, C)
    g2 = gq.astype(jnp.float32).reshape(-1, dout)
    dx = jnp.zeros((B, hp, wp, C), jnp.float32)
    for t in range(k * k):
        ki, kj = t // k, t % k
        g_t = (g2 @ wt[t * C:(t + 1) * C, :].T).reshape(B, ho, wo, C)
        dx = dx.at[:, ki:ki + (ho - 1) * stride + 1:stride,
                   kj:kj + (wo - 1) * stride + 1:stride, :].add(g_t)
    return dx


def conv_grad_w_ref(xp: jnp.ndarray, gy: jnp.ndarray, cfg: PSGConfig,
                    k: int, stride: int) -> jnp.ndarray:
    """Element-level PSG conv weight gradient: materialize the im2col
    operand, then apply the Eq. (2) oracle — ``(k*k*C, dout)`` signs."""
    p2 = conv_patches_ref(xp, k, stride)
    return psg_grad_w_ref(p2, gy.reshape(-1, gy.shape[-1]), cfg)


def conv_fallback_ratio_ref(xp: jnp.ndarray, gy: jnp.ndarray, cfg: PSGConfig,
                            k: int, stride: int) -> jnp.ndarray:
    """Element-level fallback fraction over the im2col operand."""
    p2 = conv_patches_ref(xp, k, stride)
    return psg_fallback_ratio_ref(p2, gy.reshape(-1, gy.shape[-1]), cfg)


def psg_grad_w_oracle(x2: jnp.ndarray, gy2: jnp.ndarray, cfg: PSGConfig
                      ) -> jnp.ndarray:
    """Element-level Eq. (2) — identical semantics to the tile-level kernel:
    a tile that is fully predictor-confident emits sign(g_msb) (== the
    element-level choice for those entries); any other tile computes the full
    product and uses it exactly where the element-level rule would."""
    return psg_grad_w_ref(x2, gy2, cfg)


def predictor_matmul_oracle(x2: jnp.ndarray, gy2: jnp.ndarray,
                            cfg: PSGConfig) -> jnp.ndarray:
    """The MSB predictor product g_msb = (x_msb)^T (gy_msb), fp32."""
    xm = msb_of(x2, cfg.bits_x, cfg.bits_x_msb)
    gm = msb_of(gy2, cfg.bits_g, cfg.bits_g_msb)
    return xm.astype(jnp.float32).T @ gm.astype(jnp.float32)


def flash_attention_oracle(q, gk, gv, causal: bool = True):
    """Pure-jnp softmax attention (GQA), fp32 — oracle for flash_attn.py."""
    import math
    B, S, nh, hd = q.shape
    T, nkv = gk.shape[1], gk.shape[2]
    g = nh // nkv
    qf = q.reshape(B, S, nkv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bsngh,btnh->bnsgt", qf,
                   gk.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        m = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        s = jnp.where(m[None, None, :, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bnsgt,btnh->bsngh", w, gv.astype(jnp.float32))
    return o.reshape(B, S, nh, hd)


# ---------------------------------------------------------------------------
# attention backward oracles (flash_attn.py's recomputed-tile kernels)
# ---------------------------------------------------------------------------


def _attn_scores_ref(q, gk, causal: bool):
    """Masked fp32 scores per query head, (B, nkv, S, g, T)."""
    import math
    B, S, nh, hd = q.shape
    T, nkv = gk.shape[1], gk.shape[2]
    g = nh // nkv
    qf = q.reshape(B, S, nkv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bsngh,btnh->bnsgt", qf,
                   gk.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        m = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        s = jnp.where(m[None, None, :, None, :], s, -1e30)
    return s


def attention_lse_ref(q, gk, causal: bool = True) -> jnp.ndarray:
    """Per-row logsumexp of the masked scores, (B, nh, S) fp32 — the
    residual the flash forward emits with ``return_lse=True``."""
    s = _attn_scores_ref(q, gk, causal)            # (B, nkv, S, g, T)
    lse = jax.nn.logsumexp(s, axis=-1)             # (B, nkv, S, g)
    B, nkv, S, g = lse.shape
    return jnp.moveaxis(lse, 2, 3).reshape(B, nkv * g, S)


def flash_attention_vjp_oracle(q, gk, gv, do, causal: bool = True):
    """fp32 (dq, dk, dv) — plain autodiff of the materialized oracle."""
    f = lambda a, b, c: flash_attention_oracle(a, b, c, causal)  # noqa: E731
    _, vjp = jax.vjp(f, q.astype(jnp.float32), gk.astype(jnp.float32),
                     gv.astype(jnp.float32))
    return vjp(do.astype(jnp.float32))


def psg_attention_bwd_ref(q, gk, gv, do, cfg: PSGConfig,
                          causal: bool = True):
    """Element-level PSG attention backward — the reference-backend path.

    dq is the exact fp32 cotangent (no PSG there, matching the kernel
    path).  dk/dv apply Eq. (2) at element level on the materialized
    probability/dS tensors: quantize each operand onto the same grids the
    kernel uses (``flash_attn.attention_psg_scales``), form the MSB and
    full code products per *query* head, sum each GQA group, then the
    shared select picks predictor values where confident.  Returns
    ``(dq, dk, dv, fallback_ratio)``.
    """
    import math

    from repro.kernels import flash_attn as fa
    B, S, nh, hd = q.shape
    T, nkv = gk.shape[1], gk.shape[2]
    g = nh // nkv
    scale = 1.0 / math.sqrt(hd)
    dq, _, _ = flash_attention_vjp_oracle(q, gk, gv, do, causal)

    s = _attn_scores_ref(q, gk, causal)
    p = jax.nn.softmax(s, axis=-1)                 # (B, nkv, S, g, T)
    do_r = do.reshape(B, S, nkv, g, hd).astype(jnp.float32)
    dp = jnp.einsum("bsngh,btnh->bnsgt", do_r, gv.astype(jnp.float32))
    o = jnp.einsum("bnsgt,btnh->bsngh", p, gv.astype(jnp.float32))
    delta = jnp.sum(do_r * o, axis=-1)             # (B, S, nkv, g)
    ds = p * (dp - jnp.moveaxis(delta, 1, 2)[..., None]) * scale

    dlt_rows = jnp.moveaxis(delta.reshape(B, S, nh), 1, 2)  # (B, nh, S)
    scales = fa.attention_psg_scales(
        q, gv, do, dlt_rows, bits_x=cfg.bits_x, bits_x_msb=cfg.bits_x_msb,
        bits_g=cfg.bits_g, bits_g_msb=cfg.bits_g_msb)
    s_q, s_qm, s_do, s_dom, s_ds, s_dsm = scales
    lim_x, lim_xm = fa.qlim(cfg.bits_x), fa.qlim(cfg.bits_x_msb)
    lim_g, lim_gm = fa.qlim(cfg.bits_g), fa.qlim(cfg.bits_g_msb)
    q_r = q.reshape(B, S, nkv, g, hd).astype(jnp.float32)

    # code products summed over (s, group) jointly == group-summed
    # per-query-head products; the select then operates on kv-head tensors
    dv_m = jnp.einsum("bnsgt,bsngd->btnd",
                      fa.codes_tile(p, 1.0 / lim_xm, lim_xm),
                      fa.codes_tile(do_r, s_dom, lim_gm))
    dv_f = jnp.einsum("bnsgt,bsngd->btnd",
                      fa.codes_tile(p, 1.0 / lim_x, lim_x),
                      fa.codes_tile(do_r, s_do, lim_g))
    dk_m = jnp.einsum("bnsgt,bsngd->btnd",
                      fa.codes_tile(ds, s_dsm, lim_gm),
                      fa.codes_tile(q_r, s_qm, lim_xm))
    dk_f = jnp.einsum("bnsgt,bsngd->btnd",
                      fa.codes_tile(ds, s_ds, lim_g),
                      fa.codes_tile(q_r, s_q, lim_x))
    dv, r_dv = fa.psg_attention_select(dv_m, dv_f, (1.0 / lim_xm) * s_dom,
                                       (1.0 / lim_x) * s_do, cfg.beta)
    dk, r_dk = fa.psg_attention_select(dk_m, dk_f, s_dsm * s_qm,
                                       s_ds * s_q, cfg.beta)
    return dq, dk, dv, 0.5 * (r_dv + r_dk)


def attention_dkv_products_oracle(q, gk, gv, do, lse, delta, scales, *,
                                  lims, causal: bool = True,
                                  bq: int | None = None,
                                  bk: int | None = None):
    """Tile-replay oracle of ``flash_bwd_dkv_pallas``'s code products.

    Recomputes the four per-query-head code-product accumulators with a
    plain Python loop over the SAME tile schedule — identical block
    shapes, identical ``lax.dot_general`` calls (the shared tile helpers
    in flash_attn.py), identical accumulation order — so the fp32 results
    are bit-identical to the kernel's, which is what pins the dv/dk sign
    agreement.  Returns ``(dv_msb, dv_full, dk_msb, dk_full)``, each
    (B, T, nh, hd) fp32 in code units.
    """
    import math

    from repro.kernels import flash_attn as fa
    B, S, nh, hd = q.shape
    T, nkv = gk.shape[1], gk.shape[2]
    g = nh // nkv
    scale = 1.0 / math.sqrt(hd)
    bq_ = min(fa.DEFAULT_BQ if bq is None else bq, S)
    bk_ = min(fa.DEFAULT_BK if bk is None else bk, T)
    pq, pk = (-S) % bq_, (-T) % bk_
    qh = fa._heads_major(fa._pad_seq(q, pq))
    doh = fa._heads_major(fa._pad_seq(do, pq))
    kh = fa._heads_major(fa._pad_seq(gk, pk))
    vh = fa._heads_major(fa._pad_seq(gv, pk))
    Sp, Tp = S + pq, T + pk
    rows = jnp.pad(jnp.stack([lse, delta]), ((0, 0),) * 3 + ((0, pq),)) \
        if pq else jnp.stack([lse, delta])
    lseh = rows[0].reshape(B * nh, Sp).astype(jnp.float32)
    dlth = rows[1].reshape(B * nh, Sp).astype(jnp.float32)
    s_q, s_qm, s_do, s_dom, s_ds, s_dsm = scales.astype(jnp.float32)
    lim_x, lim_xm, lim_g, lim_gm = lims
    n_q, n_kv = Sp // bq_, Tp // bk_

    outs = [jnp.zeros((B * nh, Tp, hd), jnp.float32) for _ in range(4)]
    for bh in range(B * nh):
        for ikv in range(n_kv):
            accs = [jnp.zeros((bk_, hd), jnp.float32) for _ in range(4)]
            kt = kh[bh // g, ikv * bk_:(ikv + 1) * bk_].astype(jnp.float32)
            vt = vh[bh // g, ikv * bk_:(ikv + 1) * bk_].astype(jnp.float32)
            for iq in range(n_q):
                if causal and not (iq * bq_ + bq_ - 1 >= ikv * bk_):
                    continue
                qt = qh[bh, iq * bq_:(iq + 1) * bq_].astype(jnp.float32)
                dot = doh[bh, iq * bq_:(iq + 1) * bq_].astype(jnp.float32)
                lse_t = lseh[bh, iq * bq_:(iq + 1) * bq_][:, None]
                dlt_t = dlth[bh, iq * bq_:(iq + 1) * bq_][:, None]
                qi = iq * bq_ + jax.lax.broadcasted_iota(
                    jnp.int32, (bq_, bk_), 0)
                kj = ikv * bk_ + jax.lax.broadcasted_iota(
                    jnp.int32, (bq_, bk_), 1)
                valid = jnp.logical_and(kj < T, qi < S)
                if causal:
                    valid = jnp.logical_and(valid, kj <= qi)
                p = fa.p_tile(qt, kt, lse_t, valid, scale)
                ds = fa.ds_tile(p, fa._dot_nt(dot, vt), dlt_t, scale)
                accs[0] += fa._dot_tn(fa.codes_tile(p, 1.0 / lim_xm, lim_xm),
                                      fa.codes_tile(dot, s_dom, lim_gm))
                accs[1] += fa._dot_tn(fa.codes_tile(p, 1.0 / lim_x, lim_x),
                                      fa.codes_tile(dot, s_do, lim_g))
                accs[2] += fa._dot_tn(fa.codes_tile(ds, s_dsm, lim_gm),
                                      fa.codes_tile(qt, s_qm, lim_xm))
                accs[3] += fa._dot_tn(fa.codes_tile(ds, s_ds, lim_g),
                                      fa.codes_tile(qt, s_q, lim_x))
            for i in range(4):
                outs[i] = outs[i].at[bh, ikv * bk_:(ikv + 1) * bk_].set(
                    accs[i])
    return tuple(jnp.moveaxis(o.reshape(B, nh, Tp, hd)[:, :, :T], 1, 2)
                 for o in outs)
