"""Pure-jnp oracles for the Pallas kernels (the ``ref.py`` contract).

Every kernel in this package is validated against these references in
``tests/test_kernels.py`` over a shape/dtype sweep.  Since the dispatch
layer landed (DESIGN.md §Dispatch) the element-level PSG weight-gradient
lives HERE, as a test-only reference: the training hot path runs the
tile-level kernel (``kernels/ops.psg_grad_w``), and these oracles are what
it is held accountable to.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import PSGConfig
from repro.core.quant import msb_of, quantize, quantize_int


def quantize_ref(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Fake-quantization oracle (matches kernels/quant.py)."""
    return quantize(x, bits)


def predictor_confidence_ref(x2: jnp.ndarray, gy2: jnp.ndarray,
                             cfg: PSGConfig
                             ) -> tuple:
    """Eq. (2)'s predictor state, computed once: (g_msb, confident_mask).

    The single definition of the MSB product + adaptive threshold
    ``tau = beta * max|g_msb|`` — the sign oracle, the fallback-ratio
    reference and ``core/psg.psg_predictor_usage`` all derive from this so
    a threshold-rule change cannot desynchronize them.
    """
    xm = msb_of(x2, cfg.bits_x, cfg.bits_x_msb)
    gm = msb_of(gy2, cfg.bits_g, cfg.bits_g_msb)
    g_msb = xm.astype(jnp.float32).T @ gm.astype(jnp.float32)
    tau = cfg.beta * jnp.max(jnp.abs(g_msb))
    return g_msb, jnp.abs(g_msb) >= tau


def psg_grad_w_ref(x2: jnp.ndarray, gy2: jnp.ndarray, cfg: PSGConfig
                   ) -> jnp.ndarray:
    """Element-level Eq. (2).  x2: (N, din), gy2: (N, dout) -> (din, dout).

    Returns the sign-valued weight gradient in {-1, 0, +1} (float32).
    The paper's rule: use sign(g_msb) where the MSB predictor's magnitude
    clears the adaptive threshold; fall back to the sign of the full
    fixed-point product elsewhere.
    """
    xq = quantize(x2, cfg.bits_x)
    gq = quantize(gy2, cfg.bits_g)
    g_full = xq.astype(jnp.float32).T @ gq.astype(jnp.float32)
    g_msb, pred_ok = predictor_confidence_ref(x2, gy2, cfg)
    return jnp.where(pred_ok, jnp.sign(g_msb), jnp.sign(g_full))


def psg_fallback_ratio_ref(x2: jnp.ndarray, gy2: jnp.ndarray, cfg: PSGConfig
                           ) -> jnp.ndarray:
    """Element-level fallback fraction: entries the predictor could NOT
    decide (the complement of the paper's §4.4 predictor-usage figure).
    The tile-level kernel reports the analogous *tile* ratio."""
    _, pred_ok = predictor_confidence_ref(x2, gy2, cfg)
    return jnp.mean(jnp.logical_not(pred_ok).astype(jnp.float32))


# ---------------------------------------------------------------------------
# conv oracles: materialized im2col — what the implicit-GEMM kernels
# (kernels/conv.py) eliminate and are held accountable to
# ---------------------------------------------------------------------------


def conv_patches_ref(xp: jnp.ndarray, k: int, stride: int) -> jnp.ndarray:
    """Materialized im2col of a pre-padded NHWC input: ``(B*Ho*Wo, k*k*C)``
    in the patch-major (channel-major) layout the model weights use."""
    p = jax.lax.conv_general_dilated_patches(
        xp, (k, k), (stride, stride), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return p.reshape(-1, p.shape[-1])


def conv_fwd_ref(xp: jnp.ndarray, w: jnp.ndarray, k: int, stride: int
                 ) -> jnp.ndarray:
    """im2col + single-GEMM conv forward (the materialized reference)."""
    B, Hp, Wp, _ = xp.shape
    ho = (Hp - k) // stride + 1
    wo = (Wp - k) // stride + 1
    y = conv_patches_ref(xp, k, stride) @ w.astype(xp.dtype)
    return y.reshape(B, ho, wo, -1)


def conv_grad_x_ref(gq: jnp.ndarray, wq: jnp.ndarray, k: int, stride: int,
                    hp: int, wp: int) -> jnp.ndarray:
    """Per-tap col2im scatter-add input gradient — the demoted reference
    the implicit transposed-conv kernel (``kernels/conv.py``) is held to.

    Each tap's ``(B*Ho*Wo, C)`` contribution is computed and scattered
    into a strided window of the full-size accumulator: k^2 strided
    read-modify-write passes, the traffic pattern the kernel eliminates.
    Accumulation is forced to float32 regardless of the operand dtype
    (accumulating ``k^2`` taps in a narrow gradient dtype loses low-order
    contributions; ``tests/test_conv.py`` pins the regression).
    """
    from repro.kernels.conv import to_tap_major
    B, ho, wo, dout = gq.shape
    C = wq.shape[0] // (k * k)
    wt = to_tap_major(wq.astype(jnp.float32), k, C)
    g2 = gq.astype(jnp.float32).reshape(-1, dout)
    dx = jnp.zeros((B, hp, wp, C), jnp.float32)
    for t in range(k * k):
        ki, kj = t // k, t % k
        g_t = (g2 @ wt[t * C:(t + 1) * C, :].T).reshape(B, ho, wo, C)
        dx = dx.at[:, ki:ki + (ho - 1) * stride + 1:stride,
                   kj:kj + (wo - 1) * stride + 1:stride, :].add(g_t)
    return dx


def conv_grad_w_ref(xp: jnp.ndarray, gy: jnp.ndarray, cfg: PSGConfig,
                    k: int, stride: int) -> jnp.ndarray:
    """Element-level PSG conv weight gradient: materialize the im2col
    operand, then apply the Eq. (2) oracle — ``(k*k*C, dout)`` signs."""
    p2 = conv_patches_ref(xp, k, stride)
    return psg_grad_w_ref(p2, gy.reshape(-1, gy.shape[-1]), cfg)


def conv_fallback_ratio_ref(xp: jnp.ndarray, gy: jnp.ndarray, cfg: PSGConfig,
                            k: int, stride: int) -> jnp.ndarray:
    """Element-level fallback fraction over the im2col operand."""
    p2 = conv_patches_ref(xp, k, stride)
    return psg_fallback_ratio_ref(p2, gy.reshape(-1, gy.shape[-1]), cfg)


def psg_grad_w_oracle(x2: jnp.ndarray, gy2: jnp.ndarray, cfg: PSGConfig
                      ) -> jnp.ndarray:
    """Element-level Eq. (2) — identical semantics to the tile-level kernel:
    a tile that is fully predictor-confident emits sign(g_msb) (== the
    element-level choice for those entries); any other tile computes the full
    product and uses it exactly where the element-level rule would."""
    return psg_grad_w_ref(x2, gy2, cfg)


def predictor_matmul_oracle(x2: jnp.ndarray, gy2: jnp.ndarray,
                            cfg: PSGConfig) -> jnp.ndarray:
    """The MSB predictor product g_msb = (x_msb)^T (gy_msb), fp32."""
    xm = msb_of(x2, cfg.bits_x, cfg.bits_x_msb)
    gm = msb_of(gy2, cfg.bits_g, cfg.bits_g_msb)
    return xm.astype(jnp.float32).T @ gm.astype(jnp.float32)


def flash_attention_oracle(q, gk, gv, causal: bool = True):
    """Pure-jnp softmax attention (GQA), fp32 — oracle for flash_attn.py."""
    import math
    B, S, nh, hd = q.shape
    T, nkv = gk.shape[1], gk.shape[2]
    g = nh // nkv
    qf = q.reshape(B, S, nkv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bsngh,btnh->bnsgt", qf,
                   gk.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        m = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        s = jnp.where(m[None, None, :, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bnsgt,btnh->bsngh", w, gv.astype(jnp.float32))
    return o.reshape(B, S, nh, hd)
