"""Pure-jnp oracles for the Pallas kernels (the ``ref.py`` contract).

Every kernel in this package is validated against these references in
``tests/test_kernels.py`` over a shape/dtype sweep.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import PSGConfig
from repro.core.psg import msb_of, psg_grad_w_ref, quantize, quantize_int


def quantize_ref(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Fake-quantization oracle (matches kernels/quant.py)."""
    return quantize(x, bits)


def psg_grad_w_oracle(x2: jnp.ndarray, gy2: jnp.ndarray, cfg: PSGConfig
                      ) -> jnp.ndarray:
    """Element-level Eq. (2) — identical semantics to the tile-level kernel:
    a tile that is fully predictor-confident emits sign(g_msb) (== the
    element-level choice for those entries); any other tile computes the full
    product and uses it exactly where the element-level rule would."""
    return psg_grad_w_ref(x2, gy2, cfg)


def predictor_matmul_oracle(x2: jnp.ndarray, gy2: jnp.ndarray,
                            cfg: PSGConfig) -> jnp.ndarray:
    """The MSB predictor product g_msb = (x_msb)^T (gy_msb), fp32."""
    xm = msb_of(x2, cfg.bits_x, cfg.bits_x_msb)
    gm = msb_of(gy2, cfg.bits_g, cfg.bits_g_msb)
    return xm.astype(jnp.float32).T @ gm.astype(jnp.float32)


def flash_attention_oracle(q, gk, gv, causal: bool = True):
    """Pure-jnp softmax attention (GQA), fp32 — oracle for flash_attn.py."""
    import math
    B, S, nh, hd = q.shape
    T, nkv = gk.shape[1], gk.shape[2]
    g = nh // nkv
    qf = q.reshape(B, S, nkv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bsngh,btnh->bnsgt", qf,
                   gk.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        m = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        s = jnp.where(m[None, None, :, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bnsgt,btnh->bsngh", w, gv.astype(jnp.float32))
    return o.reshape(B, S, nh, hd)
