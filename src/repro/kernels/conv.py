"""Pallas TPU kernels: fused implicit-GEMM convolution + PSG weight grad.

The CIFAR backbones (``models/resnet.py``) historically ran every conv as
*materialized* im2col: ``conv_general_dilated_patches`` writes a
``(B*Ho*Wo, k*k*C)`` patch tensor to HBM — a 9x copy of the input for 3x3
convs — before the GEMM ever runs, and the PSG backward re-reads that copy
twice more to build its quantization codes.  The paper's energy story is
dominated by exactly this kind of data movement (PAPERS.md, Yang et al.),
so these kernels do the *implicit* GEMM instead: the k x k patch gather
happens **inside the kernel**, tile by tile, on VMEM-resident input blocks
— the im2col operand never exists in HBM (DESIGN.md §Kernels).

Layout contract (external API): operands use the model's layouts —
NHWC activations and ``(k*k*C, Cout)`` weights in the **patch-major**
(channel-major: row index = ``c*k*k + ki*k + kj``) order that
``conv_general_dilated_patches`` produces and the checkpoints store.
Kernels internally work **tap-major** (row = ``(ki*k + kj)*C + c``): each
filter tap ``t`` gathers one strided window of the input block and
contracts it against one contiguous ``C``-row slice of the weight.  The
wrappers convert (pure transposes, fused by XLA).

Forward (``conv_fwd_pallas``): grid ``(B, dout/BN)``; each step holds one
padded image ``(Hp, Wp, C)`` and a ``(k*k*C, BN)`` weight block in VMEM and
accumulates ``sum_t gather_t(x) @ w_t`` over the unrolled tap loop — the
implicit-GEMM k-loop.  HBM traffic is the input read (once per dout tile)
plus the output write; no patch tensor.

PSG weight gradient (``conv_grad_w_pallas``): mirrors
``psg_matmul.py``'s MSB-predictor / tile-fallback structure — grid
``(dout/BN, B)`` with the batch (reduction) axis innermost, VMEM scratch
accumulators for the narrow-code predictor product and the full
fixed-point product carried across images, ``pl.when``-gated init/finish,
and the adaptive threshold ``tau = beta * max|g_msb|`` applied per output
tile on the last step.  A *tile* here is one ``(C, BN)`` block of ``dw``
(one filter tap x one dout block): the emitted per-tile fallback flags are
the measured energy-accounting stats that flow through the probe cotangent
into ``psg_fallback_ratio`` (DESIGN.md §Dispatch), exactly like the matmul
kernel's.

Input gradient (``conv_grad_x_pallas``): the implicit *transposed* conv —
the exact transpose of the forward's unrolled tap loop.  Grid ``(B,
dout/BN)`` with the dout (reduction) axis innermost; each step gathers the
contributing ``gy`` windows per filter tap from the VMEM-resident
output-grad block and contracts them against the tap's ``(C, BN)`` weight
slice.  Stride-2 is handled by *dilated-window indexing*: dx is
decomposed into its ``stride x stride`` spatial phases, each phase a
stride-1 window-gather conv over the (in-VMEM zero-padded) ``gy`` block —
no dilated gy tensor, no col2im scatter.  The phase results interleave
back via a pure stack+reshape, accumulate in an f32 VMEM tile across dout
tiles, and each dx block is written exactly once on the last reduction
step — versus the demoted col2im reference (``ref.conv_grad_x_ref``)
whose k^2 strided ``.at[].add`` passes read-modify-write a full-size HBM
accumulator once per tap.

VMEM budget: one image block ``Hp*Wp*C`` + two ``(k*k*C, BN)``
accumulators.  For every CIFAR ResNet / MobileNetV2 shape this is well
under 1 MB (worst: stage-0 ResNet ``34*34*16`` input + ``144x128`` accs);
the MobileNetV2 1x1 head (``C=320``) peaks at ~0.5 MB of accumulator.
The dx kernel carries one ``(Hp*Wp, C)`` f32 accumulator (74 KB at the
stage-0 worst case) next to its ``(Ho, Wo, BN)`` gy block.
Non-128-multiple ``dout`` is padded to the clamped ``BN`` tile and cropped
on return; padded columns accumulate zeros and (like ``psg_matmul``'s
padding caveat) count as fallback work in the stats — the ratio reports
*executed* tiles, which is what hardware pays for.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BN = 128


def conv_out_hw(hp: int, wp: int, k: int, stride: int) -> Tuple[int, int]:
    """VALID output extent of a pre-padded ``(Hp, Wp)`` input."""
    return (hp - k) // stride + 1, (wp - k) // stride + 1


def to_tap_major(w: jnp.ndarray, k: int, cin: int) -> jnp.ndarray:
    """(k*k*cin, dout) patch-major (channel-major rows) -> tap-major."""
    dout = w.shape[-1]
    return w.reshape(cin, k, k, dout).transpose(1, 2, 0, 3) \
            .reshape(k * k * cin, dout)


def to_patch_major(wt: jnp.ndarray, k: int, cin: int) -> jnp.ndarray:
    """Inverse of :func:`to_tap_major` (exact for sign tensors)."""
    dout = wt.shape[-1]
    return wt.reshape(k, k, cin, dout).transpose(2, 0, 1, 3) \
             .reshape(k * k * cin, dout)


def _tap_window(x: jnp.ndarray, t: int, k: int, stride: int,
                ho: int, wo: int) -> jnp.ndarray:
    """Strided gather of filter tap ``t`` from an ``(Hp, Wp, C)`` block:
    the (ho*wo, C) column slice of the implicit im2col matrix."""
    ki, kj = t // k, t % k
    c = x.shape[-1]
    win = lax.slice(x, (ki, kj, 0),
                    (ki + (ho - 1) * stride + 1,
                     kj + (wo - 1) * stride + 1, c),
                    (stride, stride, 1))
    return win.reshape(ho * wo, c)


def _conv_fwd_kernel(x_ref, w_ref, o_ref, *, k: int, stride: int,
                     ho: int, wo: int):
    """One (image, dout-tile): unrolled implicit-GEMM tap loop."""
    x = x_ref[0].astype(jnp.float32)
    c = x.shape[-1]
    acc = jnp.zeros((ho * wo, o_ref.shape[-1]), jnp.float32)
    for t in range(k * k):
        acc = acc + jnp.dot(_tap_window(x, t, k, stride, ho, wo),
                            w_ref[t * c:(t + 1) * c, :].astype(jnp.float32),
                            preferred_element_type=jnp.float32)
    o_ref[0] = acc.reshape(ho, wo, -1).astype(o_ref.dtype)


def _conv_grad_x_kernel(g_ref, w_ref, o_ref, acc, *, k: int, stride: int,
                        hp: int, wp: int, ho: int, wo: int, n_j: int):
    """One (image, dout-tile) step of the implicit transposed conv.

    Transpose of the forward tap loop: ``dx[p, q] = sum_t gy[(p-ki)/s,
    (q-kj)/s] @ w_t^T`` over taps where the division is exact.  dx is
    decomposed into ``s x s`` spatial phases ``(pi, pj)``; within a phase
    only taps with ``ki = pi (mod s)`` contribute and the gather becomes a
    *stride-1* shifted window of the zero-padded gy block — dilated-window
    indexing instead of the col2im scatter.  The dout axis is the
    reduction axis: partials accumulate in the f32 ``acc`` tile and the dx
    block is written exactly once, on the last dout tile.
    """
    s = stride
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    g = g_ref[0].astype(jnp.float32)                    # (ho, wo, bn)
    bn = g.shape[-1]
    c = acc.shape[-1]
    nu, nv = -(-hp // s), -(-wp // s)                   # phase lattice extent
    a_max = (k - 1) // s                                # max tap phase offset
    # pad so every shifted (nu, nv) window gather is in range: rows u - a
    # for u in [0, nu), a in [0, a_max] span [-a_max, nu - 1]
    gp = jnp.pad(g, ((a_max, nu - ho), (a_max, nv - wo), (0, 0)))
    phase_rows = []
    for pi in range(s):
        prow = []
        for pj in range(s):
            part = jnp.zeros((nu * nv, c), jnp.float32)
            for a in range(-(-(k - pi) // s)):          # ki = pi + s*a < k
                for b in range(-(-(k - pj) // s)):
                    t = (pi + s * a) * k + (pj + s * b)
                    win = lax.slice(gp, (a_max - a, a_max - b, 0),
                                    (a_max - a + nu, a_max - b + nv, bn))
                    part = part + jnp.dot(
                        win.reshape(nu * nv, bn),
                        w_ref[t * c:(t + 1) * c, :].astype(jnp.float32).T,
                        preferred_element_type=jnp.float32)
            prow.append(part.reshape(nu, nv, c))
        phase_rows.append(jnp.stack(prow, axis=2))      # (nu, nv, s, c)
    full = jnp.stack(phase_rows, axis=1)                # (nu, s, nv, s, c)
    full = full.reshape(nu * s, nv * s, c)[:hp, :wp, :]
    acc[...] += full.reshape(hp * wp, c)

    @pl.when(j == n_j - 1)
    def _finish():
        o_ref[0] = acc[...].reshape(hp, wp, c).astype(o_ref.dtype)


def _conv_pred_kernel(xm_ref, gm_ref, out_ref, acc, *, k: int, stride: int,
                      ho: int, wo: int, n_b: int):
    """Predictor-only implicit weight-grad (pass 1: the tau source)."""
    b = pl.program_id(1)

    @pl.when(b == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    xm = xm_ref[0].astype(jnp.float32)
    gm = gm_ref[0].astype(jnp.float32).reshape(ho * wo, -1)
    c = xm.shape[-1]
    for t in range(k * k):
        acc[t * c:(t + 1) * c, :] += jnp.dot(
            _tap_window(xm, t, k, stride, ho, wo).T, gm,
            preferred_element_type=jnp.float32)

    @pl.when(b == n_b - 1)
    def _finish():
        out_ref[...] = acc[...]


def _conv_grad_w_kernel(xm_ref, gm_ref, xq_ref, gq_ref, tau_ref,
                        out_ref, stats_ref, acc_msb, acc_full,
                        *, k: int, stride: int, ho: int, wo: int, n_b: int):
    """Fused PSG weight grad: both accumulators carried across images,
    tau-gated per (tap, dout-tile) on the last reduction step."""
    b = pl.program_id(1)

    @pl.when(b == 0)
    def _init():
        acc_msb[...] = jnp.zeros_like(acc_msb)
        acc_full[...] = jnp.zeros_like(acc_full)

    xm = xm_ref[0].astype(jnp.float32)
    xq = xq_ref[0].astype(jnp.float32)
    gm = gm_ref[0].astype(jnp.float32).reshape(ho * wo, -1)
    gq = gq_ref[0].astype(jnp.float32).reshape(ho * wo, -1)
    c = xm.shape[-1]
    for t in range(k * k):
        acc_msb[t * c:(t + 1) * c, :] += jnp.dot(
            _tap_window(xm, t, k, stride, ho, wo).T, gm,
            preferred_element_type=jnp.float32)
        acc_full[t * c:(t + 1) * c, :] += jnp.dot(
            _tap_window(xq, t, k, stride, ho, wo).T, gq,
            preferred_element_type=jnp.float32)

    @pl.when(b == n_b - 1)
    def _finish():
        tau = tau_ref[0, 0]
        for t in range(k * k):
            g_msb = acc_msb[t * c:(t + 1) * c, :]
            g_full = acc_full[t * c:(t + 1) * c, :]
            conf = jnp.abs(g_msb) >= tau
            out_ref[t * c:(t + 1) * c, :] = jnp.where(
                conf, jnp.sign(g_msb), jnp.sign(g_full)).astype(jnp.int8)
            stats_ref[t, 0] = jnp.logical_not(jnp.all(conf)).astype(jnp.int32)


def _pad_dout(a: jnp.ndarray, bn: int) -> jnp.ndarray:
    p = (-a.shape[-1]) % bn
    if p:
        pad = [(0, 0)] * (a.ndim - 1) + [(0, p)]
        a = jnp.pad(a, pad)
    return a


def conv_fwd_pallas(xp: jnp.ndarray, w: jnp.ndarray, *, k: int, stride: int,
                    bn: int = DEFAULT_BN, interpret: bool = True
                    ) -> jnp.ndarray:
    """Implicit-GEMM conv forward.

    ``xp``: pre-padded NHWC input ``(B, Hp, Wp, C)``; ``w``: patch-major
    ``(k*k*C, dout)``.  Returns ``(B, Ho, Wo, dout)`` in ``xp.dtype``.
    """
    B, Hp, Wp, C = xp.shape
    dout = w.shape[-1]
    ho, wo = conv_out_hw(Hp, Wp, k, stride)
    bn_ = min(bn, dout)
    wt = _pad_dout(to_tap_major(w, k, C), bn_)
    doutp = wt.shape[-1]
    n_j = doutp // bn_
    y = pl.pallas_call(
        functools.partial(_conv_fwd_kernel, k=k, stride=stride, ho=ho, wo=wo),
        grid=(B, n_j),
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, C), lambda b, j: (b, 0, 0, 0)),
            pl.BlockSpec((k * k * C, bn_), lambda b, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo, bn_), lambda b, j: (b, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((B, ho, wo, doutp), xp.dtype),
        interpret=interpret,
    )(xp, wt)
    return y[..., :dout]


def conv_grad_x_pallas(gq: jnp.ndarray, wq: jnp.ndarray, *, k: int,
                       stride: int, hp: int, wp: int, bn: int = DEFAULT_BN,
                       interpret: bool = True) -> jnp.ndarray:
    """Implicit transposed-conv input gradient.

    ``gq``: quantized output-gradient ``(B, Ho, Wo, dout)``; ``wq``:
    patch-major ``(k*k*C, dout)`` quantized weight; ``hp``/``wp``: the
    pre-padded input extent the forward consumed.  Returns ``dx (B, hp,
    wp, C)`` accumulated in float32 — value-equal to the col2im reference
    (``ref.conv_grad_x_ref``) up to fp32 tap-summation order, with no
    dpatches tensor and no k^2 HBM read-modify-write scatter passes: gy is
    read once, dx is written once.
    """
    B, ho, wo, dout = gq.shape
    C = wq.shape[0] // (k * k)
    bn_ = min(bn, dout)
    wt = _pad_dout(to_tap_major(wq, k, C), bn_)
    gp = _pad_dout(gq, bn_)
    n_j = gp.shape[-1] // bn_
    return pl.pallas_call(
        functools.partial(_conv_grad_x_kernel, k=k, stride=stride,
                          hp=hp, wp=wp, ho=ho, wo=wo, n_j=n_j),
        grid=(B, n_j),
        in_specs=[
            pl.BlockSpec((1, ho, wo, bn_), lambda b, j: (b, 0, 0, j)),
            pl.BlockSpec((k * k * C, bn_), lambda b, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, hp, wp, C), lambda b, j: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, hp, wp, C), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hp * wp, C), jnp.float32)],
        interpret=interpret,
    )(gp, wt)


def conv_grad_w_predictor_pallas(xm: jnp.ndarray, gm: jnp.ndarray,
                                 *, k: int, stride: int,
                                 bn: int = DEFAULT_BN,
                                 interpret: bool = True) -> jnp.ndarray:
    """Predictor product ``gather(x_msb)^T @ g_msb`` (fp32, patch-major) —
    pass 1 of the two-pass PSG conv grad; its global max sets ``tau``."""
    B, Hp, Wp, C = xm.shape
    dout = gm.shape[-1]
    ho, wo = conv_out_hw(Hp, Wp, k, stride)
    bn_ = min(bn, dout)
    gmp = _pad_dout(gm, bn_)
    doutp = gmp.shape[-1]
    n_j = doutp // bn_
    out = pl.pallas_call(
        functools.partial(_conv_pred_kernel, k=k, stride=stride, ho=ho,
                          wo=wo, n_b=B),
        grid=(n_j, B),
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, C), lambda j, b: (b, 0, 0, 0)),
            pl.BlockSpec((1, ho, wo, bn_), lambda j, b: (b, 0, 0, j)),
        ],
        out_specs=pl.BlockSpec((k * k * C, bn_), lambda j, b: (0, j)),
        out_shape=jax.ShapeDtypeStruct((k * k * C, doutp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((k * k * C, bn_), jnp.float32)],
        interpret=interpret,
    )(xm, gmp)
    return to_patch_major(out[:, :dout], k, C)


def conv_grad_w_pallas(xm: jnp.ndarray, gm: jnp.ndarray,
                       xq: jnp.ndarray, gq: jnp.ndarray, tau: jnp.ndarray,
                       *, k: int, stride: int, bn: int = DEFAULT_BN,
                       interpret: bool = True
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Tile-level PSG conv weight gradient (implicit im2col gather).

    Args: code tensors — ``xm``/``xq``: padded-input MSB / full codes
    ``(B, Hp, Wp, C)``; ``gm``/``gq``: output-grad codes ``(B, Ho, Wo,
    dout)``; ``tau`` scalar threshold in predictor code units.
    Returns ``(sign (k*k*C, dout) int8 patch-major, tile_fallback
    (k*k, ceil(dout/BN)) int32)``.
    """
    B, Hp, Wp, C = xm.shape
    dout = gm.shape[-1]
    ho, wo = conv_out_hw(Hp, Wp, k, stride)
    bn_ = min(bn, dout)
    gmp, gqp = _pad_dout(gm, bn_), _pad_dout(gq, bn_)
    doutp = gmp.shape[-1]
    n_j = doutp // bn_
    out, stats = pl.pallas_call(
        functools.partial(_conv_grad_w_kernel, k=k, stride=stride, ho=ho,
                          wo=wo, n_b=B),
        grid=(n_j, B),
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, C), lambda j, b: (b, 0, 0, 0)),
            pl.BlockSpec((1, ho, wo, bn_), lambda j, b: (b, 0, 0, j)),
            pl.BlockSpec((1, Hp, Wp, C), lambda j, b: (b, 0, 0, 0)),
            pl.BlockSpec((1, ho, wo, bn_), lambda j, b: (b, 0, 0, j)),
            pl.BlockSpec((1, 1), lambda j, b: (0, 0)),      # tau scalar
        ],
        out_specs=[
            pl.BlockSpec((k * k * C, bn_), lambda j, b: (0, j)),
            pl.BlockSpec((k * k, 1), lambda j, b: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k * k * C, doutp), jnp.int8),
            jax.ShapeDtypeStruct((k * k, n_j), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((k * k * C, bn_), jnp.float32),
            pltpu.VMEM((k * k * C, bn_), jnp.float32),
        ],
        interpret=interpret,
    )(xm, gmp, xq, gqp, tau.reshape(1, 1).astype(jnp.float32))
    sign = to_patch_major(out[:, :dout], k, C)
    return sign, stats
