"""Pallas TPU kernel: causal flash attention (online softmax).

The §Perf analysis (EXPERIMENTS.md) shows training/prefill attention is
memory-bound in the unfused form: the (S, T) score/probability tensors are
materialized in HBM.  This kernel computes one (q-block x head) output tile
with running row-max / row-sum accumulators, streaming KV blocks through
VMEM — O(S·d) HBM traffic instead of O(S·T).

Layout: grid = (batch*heads, S/BQ, T/BK), KV innermost; BlockSpecs give
(BQ, hd) query tiles and (BK, hd) KV tiles in VMEM; fp32 accumulators in
VMEM scratch.  Causal masking per (q-block, kv-block) index pair; fully
masked-out blocks are skipped with ``pl.when`` (upper-triangle blocks cost
nothing).  Default tiles (128, 128): working set ~= (2·BQ·hd + 2·BK·hd +
BQ·BK)·4B ≈ 0.3 MB — deep double-buffering headroom in 16 MB VMEM.

GQA is handled by the wrapper (kv head broadcast by index mapping, no
repeat materialized).  Validated against ``ref.flash_attention_oracle``
(pure-jnp softmax attention) across shape sweeps in interpret mode.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, n_kv: int, bq: int, bk: int, causal: bool,
                  scale: float, t_real: int):
    """One (bh, iq, ik) step: fold KV block ik into the (iq) accumulators."""
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # skip fully-masked (future) KV blocks: first kv row > last q row
    run = jnp.logical_or(not causal, ik * bk <= iq * bq + bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)              # (BQ, hd)
        k = k_ref[0].astype(jnp.float32)              # (BK, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qi = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kj = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = kj < t_real                 # mask padded keys
        if causal:
            valid = jnp.logical_and(valid, kj <= qi)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    *, causal: bool = True,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = True) -> jnp.ndarray:
    """q: (B, S, nh, hd); k/v: (B, T, nkv, hd) with nh % nkv == 0.

    Returns (B, S, nh, hd).  S and T are padded to the block sizes
    internally (padded queries produce garbage rows that are sliced off;
    padded keys are masked by the running-max/causal logic via -inf
    scores... handled by length masking below).
    """
    B, S, nh, hd = q.shape
    T, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    scale = 1.0 / math.sqrt(hd)

    bq_, bk_ = min(bq, S), min(bk, T)
    pq, pk = (-S) % bq_, (-T) % bk_
    # pad keys with zeros and mask them via an explicit length guard fold
    # into the causal iota comparison: padded kj > real positions iff we
    # extend the causal mask to also require kj < T.
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    Sp, Tp = S + pq, T + pk

    # (B, S, nh, hd) -> (B*nh, S, hd); kv head index = head // g
    qh = jnp.moveaxis(qp, 2, 1).reshape(B * nh, Sp, hd)
    kh = jnp.moveaxis(kp, 2, 1).reshape(B * nkv, Tp, hd)
    vh = jnp.moveaxis(vp, 2, 1).reshape(B * nkv, Tp, hd)

    n_q, n_kv = Sp // bq_, Tp // bk_
    grid = (B * nh, n_q, n_kv)

    def qmap(bh, iq, ik):
        return (bh, iq, 0)

    def kvmap(bh, iq, ik):
        return (bh // g, ik, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, n_kv=n_kv, bq=bq_, bk=bk_,
                          causal=causal, scale=scale, t_real=T),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, hd), qmap),
            pl.BlockSpec((1, bk_, hd), kvmap),
            pl.BlockSpec((1, bk_, hd), kvmap),
        ],
        out_specs=pl.BlockSpec((1, bq_, hd), qmap),
        out_shape=jax.ShapeDtypeStruct((B * nh, Sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    out = out.reshape(B, nh, Sp, hd)[:, :, :S]
    return jnp.moveaxis(out, 1, 2)
