"""Pallas TPU kernels: causal flash attention, forward AND backward.

The §Perf analysis (EXPERIMENTS.md) shows training/prefill attention is
memory-bound in the unfused form: the (S, T) score/probability tensors are
materialized in HBM — in BOTH directions (the bf16 probability residual on
the forward, its cotangent plus the dS tensor on the backward).  These
kernels keep every (S, T)-shaped quantity in VMEM tiles:

* **forward** (``flash_attention``): one (q-block x head) output tile with
  running row-max / row-sum accumulators, streaming KV blocks through
  VMEM — O(S·d) HBM traffic instead of O(S·T).  With ``return_lse=True``
  it also emits the per-row logsumexp, the only residual the backward
  needs beyond the operands themselves.
* **backward dq** (``flash_bwd_dq_pallas``): recomputes each probability
  tile from (q, k, lse), forms ``dS = P * (dP - delta) * scale`` in-tile
  and accumulates ``dq += dS @ k`` in VMEM scratch — same grid shape as
  the forward, KV innermost.
* **backward dk/dv** (``flash_bwd_dkv_pallas``): the PSG kernel.  Grid is
  transposed (Q innermost); each kv-block tile carries FOUR fp32 VMEM
  accumulators — the MSB *predictor* products and the full-precision-grid
  code products of the ``dv = P^T dO`` and ``dk = dS^T q`` contractions —
  exactly the dual-accumulator structure of ``psg_matmul._psg_kernel``.
  Operands are quantized **in-tile** onto per-tensor grids whose scalar
  scales come in as kernel operands (probabilities live on the fixed
  [0, 1] grid, so their codes need no data-dependent scale), which keeps
  the code products integer-exact and therefore reproducible by the tiled
  oracle in ``kernels/ref.py`` — the bit-identical sign contract.

GQA note — predictor placement: dk/dv belong to *kv* heads, summed over
the ``g = nh / nkv`` query heads of each group.  A Pallas grid step may
not revisit another step's output block, so the kernel emits per-query-
head partial code products and the Eq. (2) select (predictor-confident →
MSB product, else full product) plus the adaptive threshold
``tau = beta * max|g_msb|`` and the per-tile fallback stats are applied
OUTSIDE the kernel, on the group-summed (T, hd)-shaped products — O(T·d)
work on tensors that never had an (S, T) extent.  This mirrors the
two-pass ``ops.psg_grad_w`` recipe (tau in code units; sign is
scale-invariant) with the finish stage hoisted one level up; see
``psg_attention_select`` and DESIGN.md §Kernels.

Layout: grid = (batch*heads, S/BQ, T/BK) (transposed for dkv), KV/Q
innermost respectively; fp32 accumulators in VMEM scratch; fully masked
causal blocks are skipped with ``pl.when``.  Default tiles (128, 128):
the dkv working set ~= 4 input tiles + 4 output tiles + 4 scratch
accumulators at (128, 128) fp32 ≈ 0.8 MB — deep double-buffering headroom
in 16 MB VMEM.  Validated against ``ref.flash_attention_oracle`` (+ its
vjp) and the tiled PSG product oracle across shape sweeps in interpret
mode (tests/test_flash_bwd.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# shared tile math — the kernels AND the ref.py oracle call these with
# identically-shaped operands, so the fp32 results (and therefore the
# integer code products) agree bit-for-bit between the two paths
# ---------------------------------------------------------------------------


def _dot_nt(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(m, d) x (n, d) -> (m, n), contracting the trailing axes, fp32."""
    return jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _dot_tn(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(m, n) x (m, d) -> (n, d), contracting the leading axes, fp32."""
    return jax.lax.dot_general(a, b, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _dot_nn(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(m, n) x (n, d) -> (m, d), plain row-major contraction, fp32."""
    return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


def p_tile(q: jnp.ndarray, k: jnp.ndarray, lse: jnp.ndarray,
           valid: jnp.ndarray, scale: float) -> jnp.ndarray:
    """One recomputed probability tile: ``exp(q k^T * scale - lse)`` with
    invalid (future/padded) entries exactly zero.  ``lse``: (bq, 1)."""
    s = _dot_nt(q, k) * scale
    return jnp.where(valid, jnp.exp(s - lse), 0.0)


def ds_tile(p: jnp.ndarray, dp: jnp.ndarray, delta: jnp.ndarray,
            scale: float) -> jnp.ndarray:
    """One dS tile: ``P * (dP - delta) * scale``.  ``delta``: (bq, 1)."""
    return p * (dp - delta) * scale


def codes_tile(x: jnp.ndarray, s, lim: float) -> jnp.ndarray:
    """Integer codes of ``x`` on the grid with scale ``s`` (fp32 values)."""
    return jnp.clip(jnp.round(x / s), -lim, lim)


def qlim(bits: int) -> float:
    return 2.0 ** (bits - 1) - 1.0


def attention_psg_scales(q: jnp.ndarray, v: jnp.ndarray, do: jnp.ndarray,
                         delta: jnp.ndarray, *, bits_x: int, bits_x_msb: int,
                         bits_g: int, bits_g_msb: int) -> jnp.ndarray:
    """The six data-dependent quantization-grid scales the dkv kernel needs,
    packed ``[s_q, s_q_msb, s_do, s_do_msb, s_ds, s_ds_msb]`` (fp32).

    q and dO use the standard per-tensor ``qscale`` grids.  dS is never
    materialized, so its grid comes from the analytic bound
    ``|dS| <= P * (|dP| + |delta|) * scale <= (max_s ||dO_s|| * max_t ||v_t||
    + max|delta|) / sqrt(hd)`` — conservative (typical |dS| is far below
    the bound, so dS predictor codes are small and dk tiles fall back more
    often than a measured-max grid would allow; the fallback ratio honestly
    *measures* that).  The oracle shares these exact scales.
    """
    from repro.core.quant import qscale
    hd = q.shape[-1]
    scale = 1.0 / math.sqrt(hd)
    do32 = do.astype(jnp.float32)
    v32 = v.astype(jnp.float32)
    rn_do = jnp.sqrt(jnp.max(jnp.sum(do32 * do32, axis=-1)))
    rn_v = jnp.sqrt(jnp.max(jnp.sum(v32 * v32, axis=-1)))
    bound = jnp.maximum(scale * (rn_do * rn_v + jnp.max(jnp.abs(delta))),
                        1e-12)
    return jnp.stack([
        qscale(q, bits_x), qscale(q, bits_x_msb),
        qscale(do, bits_g), qscale(do, bits_g_msb),
        bound / qlim(bits_g), bound / qlim(bits_g_msb),
    ]).astype(jnp.float32)


def psg_attention_select(msb: jnp.ndarray, full: jnp.ndarray,
                         deq_msb, deq_full, beta: float,
                         tile_t: int = DEFAULT_BK):
    """Eq. (2) finish stage on a group-summed code-product pair.

    Element-level select: predictor-confident entries (``|g_msb| >= tau``,
    ``tau = beta * max|g_msb|`` in code units) take the dequantized MSB
    product, the rest the dequantized full product — identical to the
    element-level oracle by construction.  Tile-level accounting: the
    fraction of (tile_t x hd) kv-tiles containing any fallback entry is
    what the energy model charges full-precision MACs for (the same tile
    granularity the kernel accumulates at).

    Returns ``(values, tile_fallback_ratio)``.
    """
    tau = beta * jnp.max(jnp.abs(msb))
    conf = jnp.abs(msb) >= tau
    vals = jnp.where(conf, msb * deq_msb, full * deq_full)
    B, T, nkv, hd = conf.shape
    pad = (-T) % tile_t
    cpad = jnp.pad(conf, ((0, 0), (0, pad), (0, 0), (0, 0)),
                   constant_values=True) if pad else conf
    tiles = cpad.reshape(B, (T + pad) // tile_t, tile_t, nkv, hd)
    need_full = jnp.any(jnp.logical_not(tiles), axis=(2, 4))
    return vals, jnp.mean(need_full.astype(jnp.float32))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _flash_kernel(q_ref, k_ref, v_ref, *refs, n_kv: int, bq: int, bk: int,
                  causal: bool, scale: float, t_real: int, with_lse: bool):
    """One (bh, iq, ik) step: fold KV block ik into the (iq) accumulators."""
    if with_lse:
        o_ref, lse_ref, m_scr, l_scr, acc_scr = refs
    else:
        o_ref, m_scr, l_scr, acc_scr = refs
        lse_ref = None
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # skip fully-masked (future) KV blocks: first kv row > last q row
    run = jnp.logical_or(not causal, ik * bk <= iq * bq + bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)              # (BQ, hd)
        k = k_ref[0].astype(jnp.float32)              # (BK, hd)
        v = v_ref[0].astype(jnp.float32)
        s = _dot_nt(q, k) * scale
        qi = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kj = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = kj < t_real                 # mask padded keys
        if causal:
            valid = jnp.logical_and(valid, kj <= qi)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + _dot_nn(p, v)
        m_scr[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)
        if with_lse:
            lse_ref[0, 0] = (m_scr[..., 0] +
                             jnp.log(jnp.maximum(l_scr[..., 0], 1e-30)))


def _pad_seq(x: jnp.ndarray, pad: int) -> jnp.ndarray:
    return jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else x


def _heads_major(x: jnp.ndarray) -> jnp.ndarray:
    """(B, L, n, hd) -> (B*n, L, hd)."""
    B, L, n, hd = x.shape
    return jnp.moveaxis(x, 2, 1).reshape(B * n, L, hd)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    *, causal: bool = True,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = True, return_lse: bool = False):
    """q: (B, S, nh, hd); k/v: (B, T, nkv, hd) with nh % nkv == 0.

    Returns (B, S, nh, hd), plus the per-row logsumexp (B, nh, S) fp32
    when ``return_lse`` (the backward's only extra residual).  S and T are
    padded to the block sizes internally (padded queries produce garbage
    rows that are sliced off; padded keys are masked via the length guard
    folded into the causal iota comparison).
    """
    B, S, nh, hd = q.shape
    T, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    scale = 1.0 / math.sqrt(hd)

    bq_, bk_ = min(bq, S), min(bk, T)
    pq, pk = (-S) % bq_, (-T) % bk_
    qp, kp, vp = _pad_seq(q, pq), _pad_seq(k, pk), _pad_seq(v, pk)
    Sp, Tp = S + pq, T + pk

    qh = _heads_major(qp)                  # (B*nh, Sp, hd)
    kh = _heads_major(kp)                  # (B*nkv, Tp, hd)
    vh = _heads_major(vp)

    n_q, n_kv = Sp // bq_, Tp // bk_
    grid = (B * nh, n_q, n_kv)

    def qmap(bh, iq, ik):
        return (bh, iq, 0)

    def kvmap(bh, iq, ik):
        return (bh // g, ik, 0)

    out_specs = [pl.BlockSpec((1, bq_, hd), qmap)]
    out_shape = [jax.ShapeDtypeStruct((B * nh, Sp, hd), q.dtype)]
    if return_lse:
        out_specs.append(pl.BlockSpec((1, 1, bq_), lambda bh, iq, ik: (bh, 0, iq)))
        out_shape.append(jax.ShapeDtypeStruct((B * nh, 1, Sp), jnp.float32))

    res = pl.pallas_call(
        functools.partial(_flash_kernel, n_kv=n_kv, bq=bq_, bk=bk_,
                          causal=causal, scale=scale, t_real=T,
                          with_lse=return_lse),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, hd), qmap),
            pl.BlockSpec((1, bk_, hd), kvmap),
            pl.BlockSpec((1, bk_, hd), kvmap),
        ],
        out_specs=out_specs if return_lse else out_specs[0],
        out_shape=out_shape if return_lse else out_shape[0],
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    out = res[0] if return_lse else res
    out = out.reshape(B, nh, Sp, hd)[:, :, :S]
    out = jnp.moveaxis(out, 1, 2)
    if return_lse:
        lse = res[1].reshape(B, nh, Sp)[:, :, :S]
        return out, lse
    return out


# ---------------------------------------------------------------------------
# backward: dq (plain fp32 recompute)
# ---------------------------------------------------------------------------


def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dlt_ref,
                         dq_ref, acc, *, n_kv: int, bq: int, bk: int,
                         causal: bool, scale: float, s_real: int,
                         t_real: int):
    """One (bh, iq, ik) step: fold KV block ik into the dq accumulator."""
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    run = jnp.logical_or(not causal, ik * bk <= iq * bq + bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0, 0][:, None]                  # (bq, 1)
        dlt = dlt_ref[0, 0][:, None]
        qi = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kj = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = jnp.logical_and(kj < t_real, qi < s_real)
        if causal:
            valid = jnp.logical_and(valid, kj <= qi)
        p = p_tile(q, k, lse, valid, scale)
        ds = ds_tile(p, _dot_nt(do, v), dlt, scale)
        acc[...] += _dot_nn(ds, k)

    @pl.when(ik == n_kv - 1)
    def _finish():
        dq_ref[0] = acc[...].astype(dq_ref.dtype)


def flash_bwd_dq_pallas(q, k, v, do, lse, delta, *, causal: bool = True,
                        bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                        interpret: bool = True) -> jnp.ndarray:
    """dq of flash attention, recomputed tile-by-tile — no (S, T) in HBM.

    ``lse``/``delta``: (B, nh, S) fp32 (forward logsumexp; rowsum(dO*O)).
    Returns dq (B, S, nh, hd) fp32.
    """
    B, S, nh, hd = q.shape
    T, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    scale = 1.0 / math.sqrt(hd)
    bq_, bk_ = min(bq, S), min(bk, T)
    pq, pk = (-S) % bq_, (-T) % bk_
    qh = _heads_major(_pad_seq(q, pq))
    doh = _heads_major(_pad_seq(do, pq))
    kh = _heads_major(_pad_seq(k, pk))
    vh = _heads_major(_pad_seq(v, pk))
    Sp, Tp = S + pq, T + pk
    rows = jnp.pad(jnp.stack([lse, delta]), ((0, 0),) * 3 + ((0, pq),)) \
        if pq else jnp.stack([lse, delta])
    lseh = rows[0].reshape(B * nh, 1, Sp).astype(jnp.float32)
    dlth = rows[1].reshape(B * nh, 1, Sp).astype(jnp.float32)

    n_q, n_kv = Sp // bq_, Tp // bk_

    def qmap(bh, iq, ik):
        return (bh, iq, 0)

    def kvmap(bh, iq, ik):
        return (bh // g, ik, 0)

    def rowmap(bh, iq, ik):
        return (bh, 0, iq)

    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, n_kv=n_kv, bq=bq_, bk=bk_,
                          causal=causal, scale=scale, s_real=S, t_real=T),
        grid=(B * nh, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq_, hd), qmap),
            pl.BlockSpec((1, bk_, hd), kvmap),
            pl.BlockSpec((1, bk_, hd), kvmap),
            pl.BlockSpec((1, bq_, hd), qmap),
            pl.BlockSpec((1, 1, bq_), rowmap),
            pl.BlockSpec((1, 1, bq_), rowmap),
        ],
        out_specs=pl.BlockSpec((1, bq_, hd), qmap),
        out_shape=jax.ShapeDtypeStruct((B * nh, Sp, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq_, hd), jnp.float32)],
        interpret=interpret,
    )(qh, kh, vh, doh, lseh, dlth)
    return jnp.moveaxis(dq.reshape(B, nh, Sp, hd)[:, :, :S], 1, 2)


# ---------------------------------------------------------------------------
# backward: dk/dv (PSG dual accumulators — predictor + full code products)
# ---------------------------------------------------------------------------


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dlt_ref,
                          sc_ref, dvm_ref, dvf_ref, dkm_ref, dkf_ref,
                          avm, avf, akm, akf, *, n_q: int, bq: int, bk: int,
                          causal: bool, scale: float, s_real: int,
                          t_real: int, lims):
    """One (bh, ikv, iq) step: fold query block iq into the four per-kv-tile
    code-product accumulators (dv/dk x predictor/full)."""
    lim_x, lim_xm, lim_g, lim_gm = lims
    ikv = pl.program_id(1)
    iq = pl.program_id(2)

    @pl.when(iq == 0)
    def _init():
        avm[...] = jnp.zeros_like(avm)
        avf[...] = jnp.zeros_like(avf)
        akm[...] = jnp.zeros_like(akm)
        akf[...] = jnp.zeros_like(akf)

    # skip fully-future query blocks: last q row < first kv row
    run = jnp.logical_or(not causal, iq * bq + bq - 1 >= ikv * bk)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0, 0][:, None]
        dlt = dlt_ref[0, 0][:, None]
        s_q, s_qm = sc_ref[0, 0], sc_ref[0, 1]
        s_do, s_dom = sc_ref[0, 2], sc_ref[0, 3]
        s_ds, s_dsm = sc_ref[0, 4], sc_ref[0, 5]
        qi = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kj = ikv * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = jnp.logical_and(kj < t_real, qi < s_real)
        if causal:
            valid = jnp.logical_and(valid, kj <= qi)
        p = p_tile(q, k, lse, valid, scale)
        ds = ds_tile(p, _dot_nt(do, v), dlt, scale)
        # in-tile quantization: probabilities on the fixed [0, 1] grid, the
        # rest on the per-tensor scalar grids — codes are small integers,
        # so the fp32 accumulations below are the exact code products the
        # tiled oracle recomputes (bit-identical signs).
        avm[...] += _dot_tn(codes_tile(p, 1.0 / lim_xm, lim_xm),
                            codes_tile(do, s_dom, lim_gm))
        avf[...] += _dot_tn(codes_tile(p, 1.0 / lim_x, lim_x),
                            codes_tile(do, s_do, lim_g))
        akm[...] += _dot_tn(codes_tile(ds, s_dsm, lim_gm),
                            codes_tile(q, s_qm, lim_xm))
        akf[...] += _dot_tn(codes_tile(ds, s_ds, lim_g),
                            codes_tile(q, s_q, lim_x))

    @pl.when(iq == n_q - 1)
    def _finish():
        dvm_ref[0] = avm[...]
        dvf_ref[0] = avf[...]
        dkm_ref[0] = akm[...]
        dkf_ref[0] = akf[...]


def flash_bwd_dkv_pallas(q, k, v, do, lse, delta, scales, *, lims,
                         causal: bool = True, bq: int = DEFAULT_BQ,
                         bk: int = DEFAULT_BK, interpret: bool = True):
    """Per-query-head PSG code products of the dv/dk contractions.

    ``scales``: the (6,) vector from :func:`attention_psg_scales`;
    ``lims``: static ``(lim_x, lim_x_msb, lim_g, lim_g_msb)`` code limits.
    Returns ``(dv_msb, dv_full, dk_msb, dk_full)``, each (B, T, nh, hd)
    fp32 **in code units** and per *query* head — the caller group-sums
    over each GQA group and applies :func:`psg_attention_select`.
    """
    B, S, nh, hd = q.shape
    T, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    scale = 1.0 / math.sqrt(hd)
    bq_, bk_ = min(bq, S), min(bk, T)
    pq, pk = (-S) % bq_, (-T) % bk_
    qh = _heads_major(_pad_seq(q, pq))
    doh = _heads_major(_pad_seq(do, pq))
    kh = _heads_major(_pad_seq(k, pk))
    vh = _heads_major(_pad_seq(v, pk))
    Sp, Tp = S + pq, T + pk
    rows = jnp.pad(jnp.stack([lse, delta]), ((0, 0),) * 3 + ((0, pq),)) \
        if pq else jnp.stack([lse, delta])
    lseh = rows[0].reshape(B * nh, 1, Sp).astype(jnp.float32)
    dlth = rows[1].reshape(B * nh, 1, Sp).astype(jnp.float32)
    sc = scales.reshape(1, 6).astype(jnp.float32)

    n_q, n_kv = Sp // bq_, Tp // bk_

    def qmap(bh, ikv, iq):
        return (bh, iq, 0)

    def kvmap(bh, ikv, iq):
        return (bh // g, ikv, 0)

    def rowmap(bh, ikv, iq):
        return (bh, 0, iq)

    def outmap(bh, ikv, iq):
        return (bh, ikv, 0)

    out_spec = pl.BlockSpec((1, bk_, hd), outmap)
    out_sh = jax.ShapeDtypeStruct((B * nh, Tp, hd), jnp.float32)
    outs = pl.pallas_call(
        functools.partial(_flash_bwd_dkv_kernel, n_q=n_q, bq=bq_, bk=bk_,
                          causal=causal, scale=scale, s_real=S, t_real=T,
                          lims=lims),
        grid=(B * nh, n_kv, n_q),
        in_specs=[
            pl.BlockSpec((1, bq_, hd), qmap),
            pl.BlockSpec((1, bk_, hd), kvmap),
            pl.BlockSpec((1, bk_, hd), kvmap),
            pl.BlockSpec((1, bq_, hd), qmap),
            pl.BlockSpec((1, 1, bq_), rowmap),
            pl.BlockSpec((1, 1, bq_), rowmap),
            pl.BlockSpec((1, 6), lambda bh, ikv, iq: (0, 0)),
        ],
        out_specs=[out_spec] * 4,
        out_shape=[out_sh] * 4,
        scratch_shapes=[pltpu.VMEM((bk_, hd), jnp.float32)] * 4,
        interpret=interpret,
    )(qh, kh, vh, doh, lseh, dlth, sc)
    return tuple(jnp.moveaxis(o.reshape(B, nh, Tp, hd)[:, :, :T], 1, 2)
                 for o in outs)
