"""Pallas TPU kernel: fused symmetric fixed-point quantization.

Rounds a tensor onto a ``bits``-bit symmetric grid given a precomputed
per-tensor scale (the amax reduction is a cheap jnp op fused by XLA; the
round/clip/scale is the bandwidth-bound part worth a kernel: one HBM read +
one write, no intermediate materialization).

Block layout: rows x full-width lanes, (256, 512) by default — the second
dimension is the TPU lane dimension (multiple of 128), the first the
sublane dimension (multiple of 8 for fp32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 256
BLOCK_C = 512


def _quant_kernel(x_ref, scale_ref, out_ref, *, bits: int):
    x = x_ref[...].astype(jnp.float32)
    s = scale_ref[0, 0]
    lim = 2.0 ** (bits - 1) - 1.0
    q = jnp.clip(jnp.round(x / s), -lim, lim)
    out_ref[...] = (q * s).astype(out_ref.dtype)


def quantize_pallas(x: jnp.ndarray, bits: int, *, interpret: bool = True
                    ) -> jnp.ndarray:
    """Fake-quantize ``x`` (any 2D+ shape; flattened to 2D tiles)."""
    orig_shape = x.shape
    x2 = x.reshape(-1, orig_shape[-1]) if x.ndim > 1 else x.reshape(1, -1)
    amax = jnp.maximum(jnp.max(jnp.abs(x2.astype(jnp.float32))), 1e-12)
    scale = amax / (2.0 ** (bits - 1) - 1.0)

    R, C = x2.shape
    br, bc = min(BLOCK_R, R), min(BLOCK_C, C)
    pr, pc = (-R) % br, (-C) % bc
    xp = jnp.pad(x2, ((0, pr), (0, pc)))
    Rp, Cp = xp.shape
    out = pl.pallas_call(
        functools.partial(_quant_kernel, bits=bits),
        grid=(Rp // br, Cp // bc),
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Rp, Cp), x.dtype),
        interpret=interpret,
    )(xp, scale.reshape(1, 1))
    return out[:R, :C].reshape(orig_shape)
