"""Kernel backend dispatch — the ONE place that picks how a kernel runs.

Every PSG/quantization op can execute on one of three backends
(DESIGN.md §Dispatch):

* ``"reference"`` — the element-level pure-jnp oracle (``kernels/ref.py``).
  Test-only semantics anchor; also the safety hatch for platforms where the
  Pallas interpreter misbehaves.
* ``"interpret"`` — the tile-level Pallas kernel executed by the Pallas
  interpreter (CPU containers, debugging).  Same tile semantics and the same
  fallback-tile statistics as the compiled path.
* ``"mosaic"`` — the tile-level kernel lowered through Mosaic on a real TPU.

Selection order, strongest first:

1. an active :func:`override_backend` context (tests, benchmarks);
2. ``PSGConfig.backend`` when it is not ``"auto"`` (per-experiment pin);
3. the process default: ``REPRO_KERNEL_BACKEND`` if set — read ONCE at
   import, never at trace time — else a platform probe
   (``jax.default_backend() == "tpu"`` -> mosaic, else interpret).

This retires the scattered environment reads the seed repo had
(``REPRO_PALLAS_COMPILE`` at ``kernels/ops.py`` import, and
``REPRO_PSG_INT8_GATHER`` *inside the traced forward* of
``core/psg.psg_matmul`` — an env read baked into whichever jit cache entry
traced first).  No environment variable is consulted inside jitted code.
"""
from __future__ import annotations

import contextlib
import functools
import os
import threading
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import PSGConfig
from repro.kernels import ops, ref

BACKEND_REFERENCE = "reference"
BACKEND_INTERPRET = "interpret"
BACKEND_MOSAIC = "mosaic"
BACKENDS = (BACKEND_REFERENCE, BACKEND_INTERPRET, BACKEND_MOSAIC)

# retired trace-time env vars; kept as names only so DESIGN.md and the
# migration error message below can point at them.
RETIRED_ENV_VARS = ("REPRO_PALLAS_COMPILE", "REPRO_PSG_INT8_GATHER")

_ENV_DEFAULT = os.environ.get("REPRO_KERNEL_BACKEND", "").strip().lower()

_state = threading.local()
_process_default: Optional[str] = None


def _validate(name: str) -> str:
    if name not in BACKENDS:
        raise ValueError(
            f"unknown kernel backend {name!r}; expected one of {BACKENDS} "
            f"(note: {', '.join(RETIRED_ENV_VARS)} are retired — use "
            f"PSGConfig.backend or repro.kernels.dispatch)")
    return name


def platform_default() -> str:
    """Probe the platform: compiled kernels on TPU, interpreter elsewhere."""
    return BACKEND_MOSAIC if jax.default_backend() == "tpu" else BACKEND_INTERPRET


def default_backend() -> str:
    """Process-wide default (env pin at import time, else platform probe)."""
    global _process_default
    if _process_default is None:
        _process_default = _validate(_ENV_DEFAULT) if _ENV_DEFAULT \
            else platform_default()
    return _process_default


def set_default_backend(name: Optional[str]) -> None:
    """Pin (or with ``None`` re-probe) the process-wide default."""
    global _process_default
    _process_default = _validate(name) if name is not None else None


@contextlib.contextmanager
def override_backend(name: str):
    """Force a backend for ops *traced* under this context (tests/benches).

    Trace-time only: like every non-argument selection path, it cannot be
    part of a jit cache key.  A function traced inside the context keeps the
    overridden backend for the lifetime of its cache entry, and a function
    already traced outside ignores the override entirely.  Use it around
    fresh traces (``jax.jit(f).lower(...)``, first call of a new function);
    to pin the backend of long-lived jitted train steps, set
    ``PSGConfig.backend`` — the config is a static jit argument, so the
    cache does the right thing.
    """
    _validate(name)
    prev = getattr(_state, "override", None)
    _state.override = name
    try:
        yield
    finally:
        _state.override = prev


def resolve_backend(cfg: Optional[PSGConfig] = None) -> str:
    """The backend an op traced right now should use."""
    override = getattr(_state, "override", None)
    if override is not None:
        return override
    if cfg is not None and cfg.backend != "auto":
        return _validate(cfg.backend)
    return default_backend()


# ---------------------------------------------------------------------------
# dispatched ops — call these, not kernels.ops / kernels.ref directly
# ---------------------------------------------------------------------------


def psg_grad_w(x2: jnp.ndarray, gy2: jnp.ndarray, cfg: PSGConfig
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """PSG weight-gradient sign + measured fallback ratio.

    Tile-level Pallas kernel on the interpret/mosaic backends (fallback
    ratio = fraction of output tiles that ran the full product); element
    level on the reference backend (fallback ratio = fraction of entries
    below the confidence threshold).  Both are in [0, 1] and feed the same
    energy model (``core/energy.py``).
    """
    backend = resolve_backend(cfg)
    xf = x2.astype(jnp.float32)
    gf = gy2.astype(jnp.float32)
    if backend == BACKEND_REFERENCE:
        return (ref.psg_grad_w_ref(xf, gf, cfg),
                ref.psg_fallback_ratio_ref(xf, gf, cfg))
    return ops.psg_grad_w(xf, gf, cfg,
                          interpret=backend != BACKEND_MOSAIC)


def quantize(x: jnp.ndarray, bits: int,
             cfg: Optional[PSGConfig] = None) -> jnp.ndarray:
    """Fake-quantize through the backend the context resolves to."""
    backend = resolve_backend(cfg)
    if backend == BACKEND_REFERENCE:
        return ref.quantize_ref(x, bits)
    return ops.quantize(x, bits, interpret=backend != BACKEND_MOSAIC)


def conv_fwd(xq: jnp.ndarray, wq: jnp.ndarray, cfg: Optional[PSGConfig],
             *, k: int, stride: int) -> jnp.ndarray:
    """Conv forward on pre-quantized operands (pre-padded NHWC input,
    patch-major weight).

    Implicit-GEMM Pallas kernel (``kernels/conv.py``) on the
    interpret/mosaic backends — the im2col operand is gathered inside the
    kernel, never materialized in HBM; materialized im2col + single GEMM
    on the reference backend (the semantics anchor, value-equal up to fp32
    tap-summation order).
    """
    backend = resolve_backend(cfg)
    if backend == BACKEND_REFERENCE:
        return ref.conv_fwd_ref(xq, wq, k, stride)
    return ops.conv_fwd(xq, wq, k, stride,
                        interpret=backend != BACKEND_MOSAIC)


def conv_grad_x(gq: jnp.ndarray, wq: jnp.ndarray,
                cfg: Optional[PSGConfig], *, k: int, stride: int,
                hp: int, wp: int) -> jnp.ndarray:
    """Conv input gradient on pre-quantized operands (``dx``).

    Implicit transposed-conv Pallas kernel (``kernels/conv.py``) on the
    interpret/mosaic backends — gy windows and tap-major weight slices are
    gathered inside the kernel, dx accumulates in an f32 VMEM tile and is
    written once; per-tap col2im scatter-add loop (f32 accumulation) on
    the reference backend, the demoted semantics anchor.  Value-equal up
    to fp32 tap-summation order.
    """
    backend = resolve_backend(cfg)
    gf = gq.astype(jnp.float32)
    wf = wq.astype(jnp.float32)
    if backend == BACKEND_REFERENCE:
        return ref.conv_grad_x_ref(gf, wf, k, stride, hp, wp)
    return ops.conv_grad_x(gf, wf, k, stride, hp, wp,
                           interpret=backend != BACKEND_MOSAIC)


def conv_grad_w(xp: jnp.ndarray, gy: jnp.ndarray, cfg: PSGConfig,
                *, k: int, stride: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """PSG conv weight-gradient sign + measured fallback ratio.

    Same contract as :func:`psg_grad_w` with the im2col operand implicit:
    tile-level kernel on interpret/mosaic (fallback ratio = fraction of
    ``(C, BN)``-per-tap output tiles that ran the full product); element
    level on the reference backend.  Both feed the same probe channel
    (``core/psg.py``) and the same energy model.
    """
    backend = resolve_backend(cfg)
    xf = xp.astype(jnp.float32)
    gf = gy.astype(jnp.float32)
    if backend == BACKEND_REFERENCE:
        return (ref.conv_grad_w_ref(xf, gf, cfg, k, stride),
                ref.conv_fallback_ratio_ref(xf, gf, cfg, k, stride))
    return ops.conv_grad_w(xf, gf, cfg, k, stride,
                           interpret=backend != BACKEND_MOSAIC)


def attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  cfg: Optional[PSGConfig], *, causal: bool = True
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused attention forward: ``(o, lse)`` with lse (B, nh, S) fp32.

    Flash Pallas kernel on the interpret/mosaic backends (O(S·d) HBM
    traffic, lse emitted from the same pass); materialized softmax oracle
    + direct logsumexp on the reference backend.  Either way the lse is
    the only residual the backward needs beyond the operands.
    """
    backend = resolve_backend(cfg)
    if backend == BACKEND_REFERENCE:
        o = ref.flash_attention_oracle(q, k, v, causal).astype(q.dtype)
        return o, ref.attention_lse_ref(q, k, causal)
    return ops.flash_attention_fwd(q, k, v, causal=causal,
                                   interpret=backend != BACKEND_MOSAIC)


def attention_bwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  o: jnp.ndarray, lse: jnp.ndarray, do: jnp.ndarray,
                  cfg: PSGConfig, *, causal: bool = True):
    """PSG attention backward: ``(dq, dk, dv, fallback_ratio)``.

    Recomputed-tile Pallas kernels on the interpret/mosaic backends
    (fp32 dq; dual MSB/full code-product accumulators for dk/dv with the
    Eq. (2) select applied on the group-summed kv-head products — fallback
    ratio = fraction of (bk x hd) kv-tiles that needed the full product);
    element level on the reference backend (materialized probabilities,
    same select, element-granularity tiles).  Both ratios are in [0, 1]
    and feed the same probe -> energy channel as the matmul/conv PSG ops.
    """
    backend = resolve_backend(cfg)
    if backend == BACKEND_REFERENCE:
        return ref.psg_attention_bwd_ref(q, k, v, do, cfg, causal)
    return ops.flash_attention_bwd(q, k, v, o, lse, do, cfg, causal=causal,
                                   interpret=backend != BACKEND_MOSAIC)


# ---------------------------------------------------------------------------
# shipped-kernel registry — the kernel linter's worklist
# ---------------------------------------------------------------------------


def conv_lint_geometries() -> Dict[str, Tuple[int, int, int, int, int]]:
    """Kernel-facing conv geometries the linter must cover, one per conv
    *kind* that actually ships: ``kind -> (k, stride, hw, cin, cout)``.

    Derived from ``configs/paper_cnns.resnet_conv_shapes`` (deepest-stage
    representative of each kind, ``psg.conv2d``'s ``k < stride``
    pre-subsample normalization applied — the kernels never see
    ``k < stride``), plus the MobileNetV2-style ``point`` 1x1 with a
    non-128-multiple ``dout`` so the padded dout tile is linted too.
    ``cout`` is widened to 256 so the dout axis tiles (grid > 1) — a
    coverage or accumulator bug cannot hide behind a degenerate grid.
    """
    from repro.configs.paper_cnns import resnet_conv_shapes

    by_kind = {}
    for c in resnet_conv_shapes(depth=14, width=16, batch=4):
        by_kind[c.kind] = c                 # last occurrence: deepest stage
    geoms: Dict[str, Tuple[int, int, int, int, int]] = {}
    for kind, c in sorted(by_kind.items()):
        k, s, hw = c.k, c.stride, c.hw
        if k < s:                           # 1x1 downsample: pre-subsampled
            hw, s = -(-hw // s), 1
        geoms[kind] = (k, s, hw, c.cin, 256)
    geoms["point"] = (1, 1, 4, 40, 200)     # padded dout tile (n_j = 2)
    return geoms


def shipped_kernels() -> Dict[str, Tuple[Callable, tuple]]:
    """Every Pallas kernel this repo ships, with representative abstract
    instantiations: ``name -> (fn, args)`` where ``args`` are
    :class:`jax.ShapeDtypeStruct` trees suitable for ``jax.make_jaxpr(fn)``.

    The static kernel linter (``analysis/kernel_lint.py``) traces each entry
    and checks VMEM budgets, MXU tile alignment, BlockSpec index-map
    coverage, and accumulator init/finish discipline.  The conv kernels are
    registered once per :func:`conv_lint_geometries` kind (``name[kind]``)
    — a hardcoded single geometry would let a geometry-dependent violation
    in the 1x1/strided cases that actually ship slip past the linter.
    Shapes are chosen so every grid has more than one step along each axis
    the kernel tiles.
    """
    from repro.kernels import conv, flash_attn, psg_matmul, quant

    f32 = jnp.float32
    i8 = jnp.int8
    i16 = jnp.int16
    S = jax.ShapeDtypeStruct
    # PSG matmul operands: N=1024 tokens, din=256 -> dout=256 (grid 2x2x2)
    xm, gm = S((1024, 256), i8), S((1024, 256), i8)
    xq, gq = S((1024, 256), i8), S((1024, 256), i16)
    tau = S((), f32)
    # attention operands: S=256 (2 q-blocks, 2 kv-blocks), GQA 4->2 heads.
    # Registered at BOTH fp32 and the model's real bf16 activation dtype —
    # the bf16 rows make precision_lint's narrowed probe exercise the
    # attention kernels with narrow operands instead of skipping them
    # (lse/delta stay fp32, matching the forward's residual contract).
    bf16 = jnp.bfloat16
    q = S((2, 256, 4, 128), f32)
    kv = S((2, 256, 2, 128), f32)
    qb = S((2, 256, 4, 128), bf16)
    kvb = S((2, 256, 2, 128), bf16)
    rows = S((2, 4, 256), f32)              # lse / delta residual rows
    scales6 = S((6,), f32)
    lims = (127.0, 7.0, 32767.0, 511.0)     # default PSGConfig code limits
    entries: Dict[str, Tuple[Callable, tuple]] = {
        "psg_grad_w_pallas": (
            lambda a, b, c, d, t: psg_matmul.psg_grad_w_pallas(
                a, b, c, d, t, interpret=True),
            (xm, gm, xq, gq, tau)),
        "predictor_matmul_pallas": (
            lambda a, b: psg_matmul.predictor_matmul_pallas(
                a, b, interpret=True),
            (xm, gm)),
        "quantize_pallas": (
            functools.partial(quant.quantize_pallas, bits=8, interpret=True),
            (S((512, 1024), f32,),)),
        "flash_attention": (
            functools.partial(flash_attn.flash_attention, causal=True,
                              interpret=True),
            (q, kv, kv)),
        "flash_attention[lse]": (
            functools.partial(flash_attn.flash_attention, causal=True,
                              interpret=True, return_lse=True),
            (q, kv, kv)),
        "flash_attention[bf16]": (
            functools.partial(flash_attn.flash_attention, causal=True,
                              interpret=True, return_lse=True),
            (qb, kvb, kvb)),
        "flash_bwd_dq_pallas": (
            functools.partial(flash_attn.flash_bwd_dq_pallas, causal=True,
                              interpret=True),
            (q, kv, kv, q, rows, rows)),
        "flash_bwd_dq_pallas[bf16]": (
            functools.partial(flash_attn.flash_bwd_dq_pallas, causal=True,
                              interpret=True),
            (qb, kvb, kvb, qb, rows, rows)),
        "flash_bwd_dkv_pallas": (
            functools.partial(flash_attn.flash_bwd_dkv_pallas, lims=lims,
                              causal=True, interpret=True),
            (q, kv, kv, q, rows, rows, scales6)),
        "flash_bwd_dkv_pallas[bf16]": (
            functools.partial(flash_attn.flash_bwd_dkv_pallas, lims=lims,
                              causal=True, interpret=True),
            (qb, kvb, kvb, qb, rows, rows, scales6)),
    }
    B = 4
    for kind, (k, s, hw, cin, cout) in conv_lint_geometries().items():
        pad = k // 2
        hp = hw + 2 * pad
        ho = (hp - k) // s + 1
        cx = S((B, hp, hp, cin), f32)       # pre-padded NHWC input
        cw = S((k * k * cin, cout), f32)    # patch-major weight
        cg = S((B, ho, ho, cout), f32)
        entries[f"conv_fwd_pallas[{kind}]"] = (
            functools.partial(conv.conv_fwd_pallas, k=k, stride=s,
                              interpret=True),
            (cx, cw))
        entries[f"conv_grad_w_predictor_pallas[{kind}]"] = (
            functools.partial(conv.conv_grad_w_predictor_pallas, k=k,
                              stride=s, interpret=True),
            (cx, cg))
        entries[f"conv_grad_w_pallas[{kind}]"] = (
            (lambda a, b, c, d, t, _k=k, _s=s: conv.conv_grad_w_pallas(
                a, b, c, d, t, k=_k, stride=_s, interpret=True)),
            (cx, cg, cx, cg, tau))
        entries[f"conv_grad_x_pallas[{kind}]"] = (
            functools.partial(conv.conv_grad_x_pallas, k=k, stride=s,
                              hp=hp, wp=hp, interpret=True),
            (cg, cw))
    return entries


def kernel_acc_dtypes() -> Dict[str, str]:
    """Declared accumulator-dtype intent per shipped kernel (base name,
    without the ``[geometry]`` suffix of :func:`shipped_kernels` keys).

    This is the contract the precision lint
    (``analysis/precision_lint.py``) holds the kernels to: every
    *float-dtype* ref accumulator the dataflow engine finds in a kernel's
    trace must match the intent declared here, and every shipped kernel
    must declare one.  Integer side-channels (fallback counters, sign
    votes) are exempt — they saturate, they don't lose low-order partial
    sums.  All kernels accumulate in float32: narrow operands are a
    bandwidth story, never an accumulation story (the PR 7 lesson).
    """
    return {
        "psg_grad_w_pallas": "float32",
        "predictor_matmul_pallas": "float32",
        "quantize_pallas": "float32",
        "flash_attention": "float32",
        "flash_bwd_dq_pallas": "float32",
        "flash_bwd_dkv_pallas": "float32",
        "conv_fwd_pallas": "float32",
        "conv_grad_w_predictor_pallas": "float32",
        "conv_grad_w_pallas": "float32",
        "conv_grad_x_pallas": "float32",
    }
