"""ShapeDtypeStruct stand-ins for every model input (dry-run step 2).

Weak-type-correct, shardable, zero allocation — the shapes the production
job would feed, for each of the four assigned shape cells.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import Experiment, ModelConfig, SHAPES
from repro.models import transformer
from repro.training import train_step as ts


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def train_batch_specs(exp: Experiment) -> Dict[str, Any]:
    m, t = exp.model, exp.train
    specs = {"tokens": sds((t.global_batch, t.seq_len), jnp.int32),
             "labels": sds((t.global_batch, t.seq_len), jnp.int32)}
    if m.frontend:
        specs["frontend"] = sds((t.global_batch, m.frontend_tokens, m.d_model),
                                m.act_dtype)
    return specs


def prefill_specs(exp: Experiment) -> Dict[str, Any]:
    m, s = exp.model, exp.serve
    specs = {"tokens": sds((s.batch, s.prefill_len), jnp.int32)}
    if m.frontend:
        specs["frontend"] = sds((s.batch, m.frontend_tokens, m.d_model),
                                m.act_dtype)
    return specs


def decode_specs(exp: Experiment) -> Dict[str, Any]:
    m, s = exp.model, exp.serve
    state = jax.eval_shape(
        lambda: transformer.init_decode_state(m, s.batch, s.max_kv_len))
    specs = {"token": sds((s.batch, 1), jnp.int32), "state": state}
    if m.encoder_layers:
        specs["memory"] = sds((s.batch, m.frontend_tokens, m.d_model),
                              m.act_dtype)
    return specs


def train_state_specs(exp: Experiment):
    return jax.eval_shape(
        lambda: ts.init_train_state(jax.random.PRNGKey(0), exp))


def input_specs(exp: Experiment, shape: str) -> Dict[str, Any]:
    """All inputs for a (arch x shape) dry-run cell."""
    exp = exp.with_shape(shape)
    kind = SHAPES[shape]["kind"]
    if kind == "train":
        return {"state": train_state_specs(exp),
                "batch": train_batch_specs(exp)}
    if kind == "prefill":
        return {"params": train_state_specs(exp).params,
                **prefill_specs(exp)}
    return {"params": train_state_specs(exp).params, **decode_specs(exp)}
