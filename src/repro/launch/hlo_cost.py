"""Loop-aware HLO cost analysis.

``compiled.cost_analysis()`` counts each while-loop *body once* — but the
framework's train/serve steps are scan-based (units x microbatches x
chunks), so FLOPs, bytes and collective payloads would be undercounted by
two to three orders of magnitude.  This module re-derives the three
roofline inputs directly from the optimized HLO text:

* per-computation symbol tables (result name -> type) so dot operands,
  which are referenced by name, can be shape-resolved;
* ``while`` trip counts recovered from the loop condition's comparison
  constant (our scans lower to counted loops);
* recursive accumulation: cost(entry) = direct cost + trip * cost(body),
  conditional branches counted at their max;
* dot FLOPs = 2 * numel(result) * prod(lhs contracting dims);
* bytes accessed = per-instruction result + operand bytes at the
  post-fusion level (fusion internals stay in registers/VMEM, fusion I/O
  is counted from the fusion call's operands/result);
* collective bytes = result payloads of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute.

Validated against analytic FLOPs in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_TYPE_TOKEN = r"(?:f64|f32|bf16|f16|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[[0-9,]*\]"
_TYPE = re.compile(r"(f64|f32|bf16|f16|s64|s32|s16|s8|u64|u32|u16|u8|pred)"
                   r"\[([0-9,]*)\]")
_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
          "pred": 1}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_ELEMWISE = re.compile(
    r"\s(add|multiply|subtract|divide|exponential|tanh|rsqrt|sqrt|power|"
    r"maximum|minimum|compare|select|and|or|negate|abs|floor|sign|"
    r"logistic|log|cosine|sine|clamp)\(")


def _numel(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _first_type(s: str) -> Optional[Tuple[str, str]]:
    m = _TYPE.search(s)
    return (m.group(1), m.group(2)) if m else None


def _type_bytes(t: Optional[Tuple[str, str]]) -> float:
    if t is None:
        return 0.0
    return float(_numel(t[1]) * _BYTES[t[0]])


class Computation:
    def __init__(self, name: str, header: str):
        self.name = name
        self.header = header
        self.lines: List[str] = []
        self._symtab: Optional[Dict[str, Tuple[str, str]]] = None

    def symtab(self) -> Dict[str, Tuple[str, str]]:
        if self._symtab is None:
            tab: Dict[str, Tuple[str, str]] = {}
            # header params: "name: TYPE"
            for m in re.finditer(r"%?([\w.\-]+):\s*(" + _TYPE_TOKEN + ")",
                                 self.header):
                t = _first_type(m.group(2))
                if t:
                    tab[m.group(1)] = t
            # instruction results: "%name = TYPE op(...)"
            for l in self.lines:
                m = re.match(r"(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*("
                             + _TYPE_TOKEN + ")", l.strip())
                if m:
                    t = _first_type(m.group(2))
                    if t:
                        tab[m.group(1)] = t
            self._symtab = tab
        return self._symtab

    def operand_names(self, line: str) -> List[str]:
        m = re.search(r"\s[\w\-\$]+\(([^)]*)\)", line)
        if not m:
            return []
        body = m.group(1)
        # modern HLO writes typed operands — "dot(f32[32,64]{1,0} %lhs, ...)"
        # — so %-prefixed names are authoritative when present
        names = re.findall(r"%([\w.\-]+)", body)
        if names:
            return names
        # legacy/untyped form: bare names separated by commas
        for tok in body.split(","):
            tok = tok.strip()
            mm = re.match(r"%?([\w.\-]+)$", tok)
            if mm:
                names.append(mm.group(1))
        return names


def split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        st = line.strip()
        if st.endswith("{") and "->" in st and "=" not in st.split("(")[0]:
            name = st.split("(")[0].replace("ENTRY", "").strip().lstrip("%")
            if name:
                cur = Computation(name, st)
                comps[name] = cur
                continue
        if st == "}":
            cur = None
            continue
        if cur is not None and st:
            cur.lines.append(st)
    return comps


def _trip_count(cond: Computation) -> Tuple[int, bool]:
    """Counted loops compare the induction variable against a bound; read
    the bound from the constant feeding the compare (not any constant in
    the condition — shapes/limits would inflate the count).

    Returns ``(trips, known)``.  ``known`` is True only when the bound was
    actually recovered from the compare; the heuristic fallbacks (max
    plausible constant, or 1 when the condition holds no constant at all)
    are *guesses* and must be flagged, not silently folded into the totals
    — an unknown-trip loop counted once understates a 4096-step scan by
    three orders of magnitude.
    """
    consts: Dict[str, int] = {}
    for l in cond.lines:
        m = re.match(r"(?:ROOT\s+)?%?([\w.\-]+)\s*=.*?\bconstant\((\d+)\)",
                     l.strip())
        if m:
            consts[m.group(1)] = int(m.group(2))
    best = 0
    for l in cond.lines:
        if " compare(" in l:
            for name in Computation("", "").operand_names(l):
                if name in consts and 1 < consts[name] <= 10_000_000:
                    best = max(best, consts[name])
    if best:
        return best, True
    # fallback: max plausible constant — a guess, surfaced as unknown
    vals = [v for v in consts.values() if 1 < v <= 10_000_000]
    return (max(vals) if vals else 1), False


class HloCost:
    def __init__(self, hlo: str):
        self.comps = split_computations(hlo)
        self._memo: Dict[str, Tuple[float, float, float]] = {}
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
        self.entry = m.group(1) if m else next(iter(self.comps), "")
        # while-loops whose trip count had to be guessed: totals() surfaces
        # the tally so consumers (analysis/audit.py) warn instead of
        # trusting a potentially orders-of-magnitude undercount
        self.unknown_trip_loops = 0

    # ------------------------------------------------------------------
    def _dot_flops(self, comp: Computation, line: str) -> float:
        res = _first_type(line)
        if res is None:
            return 0.0
        ops = comp.operand_names(line)
        k = 1
        mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
        if mc and ops:
            lhs_t = comp.symtab().get(ops[0])
            if lhs_t:
                lhs_dims = [int(d) for d in lhs_t[1].split(",") if d]
                for idx in mc.group(1).split(","):
                    if idx and int(idx) < len(lhs_dims):
                        k *= lhs_dims[int(idx)]
        return 2.0 * _numel(res[1]) * k

    _FREE = re.compile(r"\s(bitcast|get-tuple-element|tuple|parameter|"
                       r"constant|iota|after-all|partition-id|replica-id)\(")

    def _io_bytes(self, comp: Computation, line: str) -> float:
        """HBM traffic of one instruction.  In-place/slicing ops move only
        the slice, not the whole operand buffer (dynamic-update-slice of the
        multi-GiB residual stack inside the unit scan would otherwise be
        charged the full stack every iteration); metadata ops are free."""
        if self._FREE.search(line):
            return 0.0
        res = _type_bytes(_first_type(line))
        tab = comp.symtab()
        ops = comp.operand_names(line)
        if re.search(r"\s(dynamic-slice|slice|gather|broadcast|reshape|"
                     r"reduce-window)\(", line):
            return 2.0 * res                       # read slice + write result
        if " dynamic-update-slice(" in line:
            upd = _type_bytes(tab.get(ops[1])) if len(ops) > 1 else 0.0
            return 2.0 * upd                       # read update + write slice
        if " scatter(" in line:
            upd = _type_bytes(tab.get(ops[-1])) if ops else 0.0
            return res + upd
        total = res
        for name in ops:
            total += _type_bytes(tab.get(name))
        return total

    def _fusion_io(self, comp: Computation, line: str,
                   callee: Optional[str]) -> float:
        """Fusion I/O: result + operand bytes, but an operand whose only use
        inside the fusion is a (dynamic-)slice/gather is charged at the
        slice size — loop bodies that slice one step out of a stacked buffer
        would otherwise be charged the whole stack every iteration."""
        total = _type_bytes(_first_type(line))
        tab = comp.symtab()
        ops = comp.operand_names(line)
        callee_c = self.comps.get(callee) if callee else None
        sliced_params: Dict[int, float] = {}
        if callee_c is not None:
            # param name -> positional index (param_N naming convention)
            names: Dict[str, int] = {}
            for l2 in callee_c.lines:
                mm = re.match(r"(?:ROOT\s+)?%?(param_(\d+)[\w.\-]*)\s*=",
                              l2.strip())
                if mm:
                    names[mm.group(1)] = int(mm.group(2))
            for pname, idx in names.items():
                consumers = [l2 for l2 in callee_c.lines
                             if re.search(r"[(,]\s*%?" + re.escape(pname)
                                          + r"\b", l2)]
                if consumers and all(
                        re.search(r"\s(dynamic-slice|slice|gather)\(", l2)
                        for l2 in consumers):
                    sliced_params[idx] = sum(
                        _type_bytes(_first_type(l2)) for l2 in consumers)
        for i, name in enumerate(ops):
            if i in sliced_params:
                total += sliced_params[i]
            else:
                total += _type_bytes(tab.get(name))
        return total

    VMEM_RESIDENT_LIMIT = 8 * 2**20     # per-buffer cap for VMEM residency

    def _resident_bytes(self, body_name: str) -> float:
        """Bytes of distinct loop-body operands small enough (< 8 MiB) to
        stay VMEM-resident across iterations: recurrent weight blocks, gate
        matrices, norm scales.  The TPU reads them from HBM once; charging
        them per trip makes sequential scans (sLSTM: 4096 steps x 4 MiB of
        recurrent weights) look two orders of magnitude more memory-bound
        than they are."""
        comp = self.comps.get(body_name)
        if comp is None:
            return 0.0
        tab = comp.symtab()
        seen = set()
        total = 0.0
        for l in comp.lines:
            if self._FREE.search(l) or " while(" in l:
                continue
            for name in comp.operand_names(l):
                if name in seen:
                    continue
                b = _type_bytes(tab.get(name))
                if 0 < b <= self.VMEM_RESIDENT_LIMIT:
                    seen.add(name)
                    total += b
        return total

    def _comp_cost(self, name: str) -> Tuple[float, float, float]:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        if comp is None:
            return 0.0, 0.0, 0.0
        self._memo[name] = (0.0, 0.0, 0.0)   # cycle guard
        fl = io = co = 0.0
        for line in comp.lines:
            if " while(" in line:
                mb = re.search(r"body=%?([\w.\-]+)", line)
                mc = re.search(r"condition=%?([\w.\-]+)", line)
                # post-optimization HLO annotates counted loops directly:
                # backend_config={"known_trip_count":{"n":"8"}} — trust it
                # over re-deriving the bound from the condition.
                mkt = re.search(r"known_trip_count[^0-9]*?(\d+)", line)
                if mkt:
                    trips = int(mkt.group(1))
                elif mc and mc.group(1) in self.comps:
                    trips, known = _trip_count(self.comps[mc.group(1)])
                    if not known:
                        self.unknown_trip_loops += 1
                else:
                    trips = 1
                    self.unknown_trip_loops += 1
                bf, bb, bc = self._comp_cost(mb.group(1)) if mb else (0, 0, 0)
                # VMEM residency: loop-invariant small operands (recurrent
                # weights etc.) stay in VMEM across iterations on TPU —
                # charge them once per loop, not once per trip.
                resident = self._resident_bytes(mb.group(1)) if mb else 0.0
                fl += trips * bf
                io += trips * max(bb - resident, 0.0) + resident
                co += trips * bc
                continue
            if " conditional(" in line:
                mbr = re.search(r"branch_computations=\{([^}]*)\}", line)
                branches = [b.strip().lstrip("%")
                            for b in mbr.group(1).split(",")] if mbr else []
                for attr in ("true_computation", "false_computation"):
                    ma = re.search(attr + r"=%?([\w.\-]+)", line)
                    if ma:
                        branches.append(ma.group(1))
                costs = [self._comp_cost(b) for b in branches if b in self.comps]
                if costs:
                    fl += max(c[0] for c in costs)
                    io += max(c[1] for c in costs)
                    co += max(c[2] for c in costs)
                continue
            if " fusion(" in line or re.search(r"\scall\(", line):
                mto = re.search(r"(?:to_apply|calls)=%?([\w.\-]+)", line)
                callee = mto.group(1) if mto else None
                if callee in self.comps:
                    cf, _, cc = self._comp_cost(callee)
                    fl += cf              # fusion compute counts
                    co += cc
                io += self._fusion_io(comp, line, callee)
                continue
            if " dot(" in line:
                fl += self._dot_flops(comp, line)
                io += self._io_bytes(comp, line)
                continue
            mcol = re.search(r"\s(" + "|".join(COLLECTIVES)
                             + r")(?:-start)?\(", line)
            if mcol:
                co += _type_bytes(_first_type(line))
                io += self._io_bytes(comp, line)
                continue
            if _ELEMWISE.search(line):
                res = _first_type(line)
                fl += float(_numel(res[1])) if res else 0.0
            io += self._io_bytes(comp, line)
        self._memo[name] = (fl, io, co)
        return self._memo[name]

    def totals(self) -> Dict[str, float]:
        fl, io, co = self._comp_cost(self.entry)
        return {"flops": fl, "bytes": io, "collective_bytes": co,
                "unknown_trip_count": float(self.unknown_trip_loops)}


def analyze(hlo: str) -> Dict[str, float]:
    return HloCost(hlo).totals()
