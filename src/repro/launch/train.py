"""Training launcher.

Single-host demo / multi-host production entry point:

  PYTHONPATH=src python -m repro.launch.train --arch llama3_8b --smoke \
      --steps 50 --e2train full --ckpt /tmp/ckpt

On a real cluster each host runs this with ``jax.distributed.initialize()``
(flag --distributed; pass --coordinator/--num-processes/--process-id
explicitly when the cluster env vars are absent, e.g. the test harness)
and the same counter-based data/SMD schedule; each process trains its own
data shard (``repro.distributed.process_shard``).  The checkpoint/elastic
machinery in ``repro.ft`` handles restarts, including onto a different
mesh shape (--mesh-data): resume picks the last *intact* checkpoint
(integrity-verified — a save torn by a crash is skipped) and
``ft/elastic.reshard_state`` places it onto the new mesh.  The
``ft/supervisor.Supervisor`` drives the kill-and-restart policy around
this entry point; ``--ft-kill-at-step`` is the matching fault hook
(DESIGN.md §Fault-tolerance).
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--e2train", default="off",
                    choices=["off", "full", "smd", "slu", "psg"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--coordinator", default=None, metavar="HOST:PORT",
                    help="jax.distributed coordinator address (with "
                         "--distributed; defaults to cluster auto-detect)")
    ap.add_argument("--num-processes", type=int, default=None,
                    help="jax.distributed world size (with --coordinator)")
    ap.add_argument("--process-id", type=int, default=None,
                    help="this process's rank (with --coordinator)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force host platform device count (testing)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--chunk-steps", type=int, default=1,
                    help="compile K executed steps into one device program "
                         "(DESIGN.md §Loop; 1 = per-step loop)")
    ap.add_argument("--mesh-data", type=int, default=0, metavar="N",
                    help="N-way data-parallel mesh over the batch axis "
                         "(0 = no mesh; 1 = single-device mesh, still "
                         "routes through sharding+reshard; combine with "
                         "--devices N)")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="per-step straggler deadline: steps over it arm "
                         "SMD-style forced drops (0 = off)")
    ap.add_argument("--ft-kill-at-step", type=int, default=None,
                    metavar="STEP",
                    help="fault injection: hard-kill (os._exit) this "
                         "process when the data path reaches STEP "
                         "(ft/faults.kill_at_step; testing only)")
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
            f" --xla_force_host_platform_device_count={args.devices}"

    import jax
    import jax.numpy as jnp

    if args.distributed:
        if args.coordinator is not None:
            jax.distributed.initialize(args.coordinator,
                                       num_processes=args.num_processes,
                                       process_id=args.process_id)
        else:
            jax.distributed.initialize()

    import dataclasses

    from repro.configs import get_experiment, smoke_experiment
    from repro.core.config import E2TrainConfig, PSGConfig, SLUConfig, SMDConfig
    from repro.data.synthetic import MarkovLMTask, make_lm_batch
    from repro.distributed import process_shard
    from repro.ft.checkpoint import latest_intact_step, restore_checkpoint
    from repro.training.train_step import init_train_state
    from repro.training.trainer import Trainer

    exp = smoke_experiment(args.arch) if args.smoke else get_experiment(args.arch)
    e2 = {
        "off": E2TrainConfig(),
        "full": E2TrainConfig.full(),
        "smd": E2TrainConfig(smd=SMDConfig(True)),
        "slu": E2TrainConfig(slu=SLUConfig(True)),
        "psg": E2TrainConfig(psg=PSGConfig(True)),
    }[args.e2train]
    tr_cfg = exp.train
    if args.e2train in ("full", "psg"):
        tr_cfg = dataclasses.replace(tr_cfg, optimizer="psg", lr=0.03)
    exp = exp.replace(e2=e2, train=tr_cfg)

    shard, num_shards = process_shard()
    ckpt_dir = args.ckpt
    if ckpt_dir and num_shards > 1:
        # each process owns its checkpoint stream: states are per-shard
        # on backends without cross-process collectives, and two ranks
        # racing one step file would tear the npz/manifest commit pair
        ckpt_dir = os.path.join(ckpt_dir, f"proc{shard:03d}")

    task = MarkovLMTask(vocab=exp.model.vocab_size)

    def make_batch(step, shard):
        b = make_lm_batch(task, exp.train.seed, step, shard,
                          exp.train.global_batch, exp.train.seq_len)
        if exp.model.frontend:
            key = jax.random.fold_in(jax.random.PRNGKey(7), step)
            b["frontend"] = jax.random.normal(
                key, (exp.train.global_batch, exp.model.frontend_tokens,
                      exp.model.d_model), exp.model.act_dtype)
        return b

    if args.ft_kill_at_step is not None:
        from repro.ft.faults import kill_at_step
        make_batch = kill_at_step(make_batch, args.ft_kill_at_step)

    mesh = None
    if args.mesh_data >= 1:
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((args.mesh_data, 1), ("data", "model"))

    state = init_train_state(jax.random.PRNGKey(exp.train.seed), exp)
    start = 0
    if args.resume and ckpt_dir and latest_intact_step(ckpt_dir) is not None:
        # integrity-verified restore: falls back past truncated/corrupt/
        # partial saves to the newest intact step (ft/checkpoint)
        tree, step = restore_checkpoint(ckpt_dir, state)
        state = jax.tree.map(lambda a, b: jnp.asarray(b), state, tree)
        if mesh is not None:
            from repro.ft.elastic import reshard_state
            state = reshard_state(state, mesh)
        start = int(tree.step)    # restored counter = next nominal step
        print(f"resumed from intact step {step} (counter at {start})"
              + (f" on mesh {dict(mesh.shape)}" if mesh is not None else ""))

    trainer = Trainer(exp, state, make_batch, checkpoint_dir=ckpt_dir,
                      checkpoint_every=args.ckpt_every,
                      chunk_steps=args.chunk_steps, mesh=mesh,
                      deadline_s=args.deadline_s, shard=shard)
    # --steps is the TOTAL nominal step budget: a resumed run executes only
    # the remainder, so kill-and-restart reproduces an uninterrupted run's
    # counter stream exactly (the supervisor test's bit-consistency pin)
    hist = trainer.run(max(args.steps - start, 0), log_every=args.log_every)
    if hist:
        sps = trainer.steps_per_s()
        print(f"final loss: {hist[-1]['total_loss']:.4f} "
              f"(executed {trainer.executed_steps}, "
              f"SMD-dropped {trainer.dropped_steps}, "
              f"straggler-dropped {trainer.straggler_dropped_steps}, "
              f"{sps:.2f} steps/s)" if sps else
              f"final loss: {hist[-1]['total_loss']:.4f}")
    if trainer.save_errors:
        # a run whose final checkpoint did not land must not exit green:
        # the supervisor / CI would otherwise treat an unpersisted run as
        # a success and resume from a stale step
        print(f"final save FAILED: {sorted(trainer.save_errors)}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
