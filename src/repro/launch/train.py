"""Training launcher.

Single-host demo / multi-host production entry point:

  PYTHONPATH=src python -m repro.launch.train --arch llama3_8b --smoke \
      --steps 50 --e2train full --ckpt /tmp/ckpt

On a real cluster each host runs this with ``jax.distributed.initialize()``
(flag --distributed) and the same counter-based data/SMD schedule; the
checkpoint/elastic machinery in ``repro.ft`` handles restarts, including
onto a different mesh shape (--mesh).
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--e2train", default="off",
                    choices=["off", "full", "smd", "slu", "psg"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--devices", type=int, default=0,
                    help="force host platform device count (testing)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--chunk-steps", type=int, default=1,
                    help="compile K executed steps into one device program "
                         "(DESIGN.md §Loop; 1 = per-step loop)")
    ap.add_argument("--mesh-data", type=int, default=0, metavar="N",
                    help="N-way data-parallel mesh over the batch axis "
                         "(0 = single device; combine with --devices N)")
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
            f" --xla_force_host_platform_device_count={args.devices}"

    import jax

    if args.distributed:
        jax.distributed.initialize()

    import dataclasses

    from repro.configs import get_experiment, smoke_experiment
    from repro.core.config import E2TrainConfig, PSGConfig, SLUConfig, SMDConfig
    from repro.data.synthetic import MarkovLMTask, make_lm_batch
    from repro.ft.checkpoint import latest_step, restore_checkpoint
    from repro.training.train_step import init_train_state
    from repro.training.trainer import Trainer

    exp = smoke_experiment(args.arch) if args.smoke else get_experiment(args.arch)
    e2 = {
        "off": E2TrainConfig(),
        "full": E2TrainConfig.full(),
        "smd": E2TrainConfig(smd=SMDConfig(True)),
        "slu": E2TrainConfig(slu=SLUConfig(True)),
        "psg": E2TrainConfig(psg=PSGConfig(True)),
    }[args.e2train]
    tr_cfg = exp.train
    if args.e2train in ("full", "psg"):
        tr_cfg = dataclasses.replace(tr_cfg, optimizer="psg", lr=0.03)
    exp = exp.replace(e2=e2, train=tr_cfg)

    task = MarkovLMTask(vocab=exp.model.vocab_size)

    def make_batch(step, shard):
        b = make_lm_batch(task, exp.train.seed, step, shard,
                          exp.train.global_batch, exp.train.seq_len)
        if exp.model.frontend:
            import jax.numpy as jnp
            key = jax.random.fold_in(jax.random.PRNGKey(7), step)
            b["frontend"] = jax.random.normal(
                key, (exp.train.global_batch, exp.model.frontend_tokens,
                      exp.model.d_model), exp.model.act_dtype)
        return b

    state = init_train_state(jax.random.PRNGKey(exp.train.seed), exp)
    if args.resume and args.ckpt and latest_step(args.ckpt) is not None:
        tree, step = restore_checkpoint(args.ckpt, state)
        state = jax.tree.map(lambda a, b: b, state, tree)
        print(f"resumed from step {step}")

    mesh = None
    if args.mesh_data > 1:
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((args.mesh_data, 1), ("data", "model"))
    trainer = Trainer(exp, state, make_batch, checkpoint_dir=args.ckpt,
                      checkpoint_every=args.ckpt_every,
                      chunk_steps=args.chunk_steps, mesh=mesh)
    hist = trainer.run(args.steps, log_every=args.log_every)
    if hist:
        sps = trainer.steps_per_s()
        print(f"final loss: {hist[-1]['total_loss']:.4f} "
              f"(executed {trainer.executed_steps}, "
              f"SMD-dropped {trainer.dropped_steps}, "
              f"{sps:.2f} steps/s)" if sps else
              f"final loss: {hist[-1]['total_loss']:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
