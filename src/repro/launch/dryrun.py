import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture x input-shape) cell, builds the production mesh
(single-pod 16x16 or multi-pod 2x16x16), lowers + compiles the real
train_step / prefill / decode step with the real sharding rules, and
records:

* ``memory_analysis()``  — proves the cell fits per-device HBM,
* ``cost_analysis()``    — HLO FLOPs / bytes for the roofline,
* collective bytes       — parsed from the optimized HLO (all-gather /
  all-reduce / reduce-scatter / all-to-all / collective-permute payloads),
* the collective schedule summary.

Usage:
  python -m repro.launch.dryrun --arch llama3_8b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod --out results.json
"""
import argparse
import json
import re
import sys
import time
import traceback
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_experiment
from repro.core.config import SHAPES, shape_applicable
from repro.core.energy import (TPU_V5E, model_flops_6nd, model_fwd_flops,
                               roofline_terms, train_step_flops)
from repro.distributed import sharding as shd
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh
from repro.models import transformer
from repro.serving.engine import make_decode_step, make_prefill_step
from repro.training.train_step import make_train_step

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|s16|s8|u32|u8|pred|f64|s64)\[([0-9,]*)\]")
_BYTES = {"f64": 8, "s64": 8, "f32": 4, "s32": 4, "u32": 4, "bf16": 2,
          "f16": 2, "s16": 2, "s8": 1, "u8": 1, "pred": 1}


def collective_bytes_from_hlo(hlo: str) -> Dict[str, float]:
    """Sum result-payload bytes of every collective op in the HLO text."""
    out = {c: 0.0 for c in COLLECTIVES}
    out["count"] = 0
    for line in hlo.splitlines():
        s = line.strip()
        # result type is at line start: '%name = TYPE op-name(...)'
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+(" + "|".join(COLLECTIVES)
                     + r")\(", s)
        if not m:
            continue
        kind = m.group(2)
        tbytes = 0.0
        for dt, dims in _SHAPE_RE.findall(m.group(1)):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            tbytes += n * _BYTES[dt]
        out[kind] += tbytes
        out["count"] += 1
    out["total"] = sum(out[c] for c in COLLECTIVES)
    return out


def build_cell(arch: str, shape: str, multi_pod: bool):
    """Lower + compile one cell; returns (compiled, lowered, exp)."""
    exp = get_experiment(arch).with_shape(shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    kind = SHAPES[shape]["kind"]
    specs = sp.input_specs(exp, shape)

    with mesh, shd.activation_sharding(mesh):
        if kind == "train":
            state_sh = shd.state_shardings(specs["state"], mesh, fsdp=exp.mesh.fsdp)
            batch_sh = jax.tree.map(
                lambda x: shd.batch_sharding(mesh, x.ndim, shape=x.shape),
                specs["batch"])
            fn = jax.jit(make_train_step(exp),
                         in_shardings=(state_sh, batch_sh),
                         donate_argnums=(0,))
            lowered = fn.lower(specs["state"], specs["batch"])
        elif kind == "prefill":
            param_sh = shd.param_shardings(specs["params"], mesh,
                                           fsdp=exp.mesh.fsdp)
            tok_sh = shd.batch_sharding(mesh, 2, shape=specs["tokens"].shape)
            args = [specs["params"], specs["tokens"]]
            shards = [param_sh, tok_sh]
            if "frontend" in specs:
                args.append(specs["frontend"])
                shards.append(shd.batch_sharding(mesh, 3, shape=specs["frontend"].shape))
            fn = jax.jit(make_prefill_step(exp), in_shardings=tuple(shards))
            lowered = fn.lower(*args)
        else:  # decode
            param_sh = shd.param_shardings(specs["params"], mesh,
                                           fsdp=exp.mesh.fsdp)
            st_sh = shd.decode_state_shardings(specs["state"], mesh)
            tok_sh = shd.batch_sharding(mesh, 2, shape=specs["token"].shape)
            args = [specs["params"], specs["token"], specs["state"]]
            shards = [param_sh, tok_sh, st_sh]
            if "memory" in specs:
                args.append(specs["memory"])
                shards.append(shd.batch_sharding(mesh, 3, shape=specs["memory"].shape))
            fn = jax.jit(make_decode_step(exp), in_shardings=tuple(shards),
                         donate_argnums=(2,))
            lowered = fn.lower(*args)
        compiled = lowered.compile()
    return compiled, lowered, exp, mesh


def analyze_cell(arch: str, shape: str, multi_pod: bool) -> Dict[str, Any]:
    t0 = time.time()
    exp = get_experiment(arch)
    ok, why = shape_applicable(exp.model, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "status": "skipped", "reason": why}
    try:
        compiled, lowered, exp, mesh = build_cell(arch, shape, multi_pod)
    except Exception as e:  # noqa
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:]}
    chips = mesh.size
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)          # body-once (reference)
    from repro.launch.hlo_cost import analyze as hlo_analyze
    loopcost = hlo_analyze(hlo)                    # loop-aware (authoritative)

    kind = SHAPES[shape]["kind"]
    scfg = exp.serve
    if kind == "train":
        B, S = exp.train.global_batch, exp.train.seq_len
        mflops = model_flops_6nd(exp.model, B, S)
        ana_flops = train_step_flops(exp.model, B, S)
    elif kind == "prefill":
        B, S = scfg.batch, scfg.prefill_len
        mflops = model_flops_6nd(exp.model, B, S) / 3.0   # fwd only: 2ND
        ana_flops = model_fwd_flops(exp.model, B, S)
    else:
        B, S = scfg.batch, 1
        mflops = model_flops_6nd(exp.model, B, 1) / 3.0
        ana_flops = model_fwd_flops(exp.model, B, 1, kv_len=scfg.max_kv_len)

    # XLA's cost_analysis counts while bodies ONCE — useless for scan-based
    # steps; the loop-aware analyzer (launch/hlo_cost.py) multiplies by trip
    # counts.  Terms are per-device quantities (HLO is post-SPMD), so the
    # roofline denominators use chips=1.
    hlo_flops = loopcost["flops"]
    hlo_bytes = loopcost["bytes"]
    coll_bytes = loopcost["collective_bytes"]
    terms = roofline_terms(hlo_flops, hlo_bytes, coll_bytes, 1)
    mflops_dev = None
    res = {
        "arch": arch, "shape": shape, "multi_pod": multi_pod,
        "status": "ok",
        "chips": chips,
        "params": exp.model.param_count(),
        "active_params": exp.model.active_param_count(),
        "bytes_per_device": {
            "argument": mem.argument_size_in_bytes,
            "output": mem.output_size_in_bytes,
            "temp": mem.temp_size_in_bytes,
            "alias": mem.alias_size_in_bytes,
            # donated inputs alias outputs; peak = args + temps + non-aliased out
            "peak": mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes,
        },
        "hlo_flops_per_device": hlo_flops,
        "hlo_bytes_per_device": hlo_bytes,
        "collective_bytes_per_device": coll_bytes,
        "xla_cost_analysis_flops_body_once": float(cost.get("flops", 0.0)),
        "model_flops_6nd": mflops,
        "analytic_flops": ana_flops,
        # useful compute: MODEL_FLOPS per device / loop-aware HLO flops
        "useful_ratio": (mflops / chips / hlo_flops) if hlo_flops else 0.0,
        "collectives_body_once": coll,
        "roofline": terms,
        "compile_s": time.time() - t0,
    }
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                r = analyze_cell(arch, shape, mp)
                results.append(r)
                status = r["status"]
                extra = ""
                if status == "ok":
                    bl = r["roofline"]["bottleneck"]
                    pk = r["bytes_per_device"]["peak"] / 2**30
                    extra = (f"peak={pk:.2f}GiB step={r['roofline']['step_s']*1e3:.2f}ms "
                             f"bound={bl} compile={r['compile_s']:.0f}s")
                elif status == "error":
                    extra = r["error"][:200]
                else:
                    extra = r["reason"][:80]
                print(f"[{'2x16x16' if mp else '16x16'}] {arch:20s} {shape:12s} "
                      f"{status:7s} {extra}", flush=True)
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"done: {len(results)} cells, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
