"""Serving launcher: wave-batched decode over a (smoke) model.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_5_3b --requests 8
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args(argv)

    import jax

    from repro.configs import smoke_experiment
    from repro.models import transformer
    from repro.serving.engine import Request, ServeEngine

    exp = smoke_experiment(args.arch)
    m = exp.model
    params = transformer.init_lm(jax.random.PRNGKey(0), m, exp.e2)
    engine = ServeEngine(exp, params, batch_slots=args.slots,
                         max_len=args.prompt_len + args.max_new + 8)
    rng = np.random.RandomState(0)
    for i in range(args.requests):
        engine.submit(Request(rid=i,
                              prompt=rng.randint(0, m.vocab_size,
                                                 size=args.prompt_len),
                              max_new=args.max_new))
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  rid={r.rid} out[:8]={r.out[:8]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
