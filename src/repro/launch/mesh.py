"""Production mesh construction (assignment §Multi-pod dry-run step 1).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state, so tests/benches that import the launcher still
see the default single-device runtime.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests / elastic restarts."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def data_axes(mesh) -> tuple:
    """Axes the global batch is sharded over ('pod' folds into data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def fsdp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
