"""deepseek-moe-16b [arXiv:2401.06066; hf]: 28L d_model=2048 16H (GQA kv=16)
d_ff=1408 vocab=102400; fine-grained MoE, 64 routed experts top-6 + 2 shared."""
from repro.core.config import Experiment, ModelConfig, TrainConfig


def get_config() -> Experiment:
    return Experiment(model=ModelConfig(
        name="deepseek-moe-16b", family="moe",
        num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1408, moe_d_ff=1408, vocab_size=102400,
        num_experts=64, num_shared_experts=2, top_k=6,
        rope_theta=10000.0,
    ), train=TrainConfig(optimizer="sgdm"))
