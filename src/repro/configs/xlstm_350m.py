"""xlstm-350m [arXiv:2405.04517; unverified]: 24L d_model=1024 4H d_ff=0
vocab=50304; mLSTM + sLSTM blocks (3:1 unit), recurrent decode — runs the
long_500k cell via O(1)-state decoding."""
from repro.core.config import (BLOCK_MLSTM, BLOCK_SLSTM, Experiment,
                               ModelConfig, TrainConfig)


def get_config() -> Experiment:
    return Experiment(model=ModelConfig(
        name="xlstm-350m", family="ssm",
        num_layers=24, d_model=1024, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=50304, glu=False,
        block_unit=(BLOCK_MLSTM, BLOCK_MLSTM, BLOCK_MLSTM, BLOCK_SLSTM),
    ), train=TrainConfig(optimizer="sgdm"))
