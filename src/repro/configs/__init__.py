"""Architecture config registry: one module per assigned arch (+ the
paper's own CNN backbones).  ``get_experiment(arch)`` returns the full
production config; ``smoke_experiment(arch)`` a reduced same-family config
for CPU smoke tests (small dims, tiny vocab, few experts)."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.core.config import Experiment, ModelConfig

ARCH_IDS: List[str] = [
    "deepseek_moe_16b",
    "grok_1_314b",
    "h2o_danube_3_4b",
    "starcoder2_15b",
    "llama3_8b",
    "qwen2_5_3b",
    "xlstm_350m",
    "whisper_small",
    "phi_3_vision_4_2b",
    "zamba2_1_2b",
]

PAPER_ARCHS: List[str] = ["resnet74", "resnet110", "mobilenetv2"]


def canon(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_experiment(arch: str) -> Experiment:
    mod = importlib.import_module(f"repro.configs.{canon(arch)}")
    return mod.get_config()


def smoke_experiment(arch: str) -> Experiment:
    mod = importlib.import_module(f"repro.configs.{canon(arch)}")
    if hasattr(mod, "smoke_config"):
        return mod.smoke_config()
    return reduce_experiment(mod.get_config())


def reduce_experiment(exp: Experiment) -> Experiment:
    """Generic reduction: same family/block structure, toy dims."""
    m = exp.model
    unit = m.block_unit or ()
    n_layers = max(len(unit), 2) if unit else 2
    heads = min(m.num_heads, 4)
    kv = max(1, min(m.num_kv_heads, heads))
    while heads % kv:
        kv -= 1
    small = dataclasses.replace(
        m,
        num_layers=n_layers,
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=64 // heads if m.head_dim == 0 else 16,
        d_ff=96 if m.d_ff else 0,
        moe_d_ff=48 if m.moe_d_ff else 0,
        num_experts=min(m.num_experts, 4),
        num_shared_experts=min(m.num_shared_experts, 1),
        top_k=min(m.top_k, 2),
        vocab_size=128,
        ssm_state=min(m.ssm_state, 8) if m.ssm_state else 0,
        sliding_window=min(m.sliding_window, 8) if m.sliding_window else 0,
        encoder_layers=min(m.encoder_layers, 2),
        frontend_tokens=8 if m.frontend else 0,
        dtype="float32",
    )
    tr = dataclasses.replace(exp.train, global_batch=2, seq_len=16,
                             total_steps=8, microbatches=1)
    sv = dataclasses.replace(exp.serve, batch=2, prefill_len=16, max_kv_len=32)
    return dataclasses.replace(exp, model=small, train=tr, serve=sv)
