"""The paper's own backbones: ResNet-74, ResNet-110, MobileNetV2 on
CIFAR-10/100 (§4.1) — the faithful-reproduction path."""
from dataclasses import dataclass
from typing import List, Tuple

from repro.core.config import E2TrainConfig, TrainConfig


@dataclass(frozen=True)
class CNNExperiment:
    name: str
    depth: int                 # ResNet depth; 0 -> MobileNetV2
    num_classes: int
    train: TrainConfig
    e2: E2TrainConfig


def resnet74(num_classes: int = 10, e2: E2TrainConfig = None) -> CNNExperiment:
    return CNNExperiment("resnet74", 74, num_classes,
                         TrainConfig(global_batch=128, lr=0.1,
                                     total_steps=64000, optimizer="sgdm"),
                         e2 or E2TrainConfig())


def resnet110(num_classes: int = 10, e2: E2TrainConfig = None) -> CNNExperiment:
    return CNNExperiment("resnet110", 110, num_classes,
                         TrainConfig(global_batch=128, lr=0.1,
                                     total_steps=64000, optimizer="sgdm"),
                         e2 or E2TrainConfig())


def mobilenetv2(num_classes: int = 10, e2: E2TrainConfig = None) -> CNNExperiment:
    return CNNExperiment("mobilenetv2", 0, num_classes,
                         TrainConfig(global_batch=128, lr=0.05,
                                     total_steps=64000, optimizer="sgdm"),
                         e2 or E2TrainConfig())


def resnet_im2col_shapes(depth: int = 74, width: int = 16, batch: int = 128,
                         image: int = 32) -> List[Tuple[int, int, int]]:
    """Distinct (N, din, dout) im2col matmul shapes of a CIFAR ResNet.

    These are exactly the operand shapes ``models/resnet.conv2d`` hands to
    ``psg.matmul`` — i.e. the shapes the PSG backward tile kernel sees
    during paper-faithful training (N = B*H'*W', din = k*k*Cin, dout =
    Cout).  Used by benchmarks/bench_kernels.py to compare the element-level
    oracle against the tile kernel on real workload geometry.
    """
    n = (depth - 2) // 6
    shapes: List[Tuple[int, int, int]] = [(batch * image * image, 9 * 3, width)]
    H, cin = image, width
    for stage, cout in enumerate((width, 2 * width, 4 * width)):
        for b in range(n):
            stride = 2 if (stage > 0 and b == 0) else 1
            H = H // stride
            shapes.append((batch * H * H, 9 * (cin if b == 0 else cout), cout))
            shapes.append((batch * H * H, 9 * cout, cout))
            if b == 0 and cin != cout:
                # 1x1 projection shortcut (models/resnet.py "downs"):
                # im2col din is just cin for k=1
                shapes.append((batch * H * H, cin, cout))
            cin = cout
    seen, uniq = set(), []
    for s in shapes:
        if s not in seen:
            seen.add(s)
            uniq.append(s)
    return uniq
