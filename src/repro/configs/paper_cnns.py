"""The paper's own backbones: ResNet-74, ResNet-110, MobileNetV2 on
CIFAR-10/100 (§4.1) — the faithful-reproduction path.

These are full :class:`Experiment` bundles with ``task="cifar_cnn"``; they
run through the same ``init_train_state`` / ``make_train_step`` / ``Trainer``
stack as every LM experiment (SMD, SLU, PSG probe, SWA, checkpointing).
"""
from typing import List, Optional, Tuple

from repro.core.config import (E2TrainConfig, Experiment, ModelConfig,
                               TrainConfig)


def cnn_model(name: str, depth: int, num_classes: int = 10,
              width: int = 16) -> ModelConfig:
    """``family="cnn"`` encoding understood by ``tasks/cifar_cnn.py``:
    ``num_layers`` is the CIFAR ResNet depth (6n+2), ``d_model`` the stage-0
    width, ``vocab_size`` the class count.  A model named ``"mobilenetv2"``
    selects the MobileNetV2 backbone (depth is ignored).  CNNs train in
    fp32 — the paper's precision story lives in PSG, not bf16 activations.
    """
    return ModelConfig(name=name, family="cnn", num_layers=depth,
                       d_model=width, num_heads=1, num_kv_heads=1, d_ff=0,
                       vocab_size=num_classes, glu=False, dtype="float32")


def _cnn_train(lr: float) -> TrainConfig:
    return TrainConfig(global_batch=128, lr=lr, total_steps=64000,
                       optimizer="sgdm", weight_decay=1e-4)


def resnet74(num_classes: int = 10,
             e2: Optional[E2TrainConfig] = None) -> Experiment:
    return Experiment(model=cnn_model("resnet74", 74, num_classes),
                      e2=e2 or E2TrainConfig(), train=_cnn_train(0.1),
                      task="cifar_cnn")


def resnet110(num_classes: int = 10,
              e2: Optional[E2TrainConfig] = None) -> Experiment:
    return Experiment(model=cnn_model("resnet110", 110, num_classes),
                      e2=e2 or E2TrainConfig(), train=_cnn_train(0.1),
                      task="cifar_cnn")


def mobilenetv2(num_classes: int = 10,
                e2: Optional[E2TrainConfig] = None) -> Experiment:
    return Experiment(model=cnn_model("mobilenetv2", 0, num_classes),
                      e2=e2 or E2TrainConfig(), train=_cnn_train(0.05),
                      task="cifar_cnn")


def resnet_im2col_shapes(depth: int = 74, width: int = 16, batch: int = 128,
                         image: int = 32) -> List[Tuple[int, int, int]]:
    """Distinct (N, din, dout) im2col matmul shapes of a CIFAR ResNet.

    These are exactly the operand shapes ``models/resnet.conv2d`` hands to
    ``psg.matmul`` — i.e. the shapes the PSG backward tile kernel sees
    during paper-faithful training (N = B*H'*W', din = k*k*Cin, dout =
    Cout).  Used by benchmarks/bench_kernels.py to compare the element-level
    oracle against the tile kernel on real workload geometry.
    """
    n = (depth - 2) // 6
    shapes: List[Tuple[int, int, int]] = [(batch * image * image, 9 * 3, width)]
    H, cin = image, width
    for stage, cout in enumerate((width, 2 * width, 4 * width)):
        for b in range(n):
            stride = 2 if (stage > 0 and b == 0) else 1
            H = H // stride
            shapes.append((batch * H * H, 9 * (cin if b == 0 else cout), cout))
            shapes.append((batch * H * H, 9 * cout, cout))
            if b == 0 and cin != cout:
                # 1x1 projection shortcut (models/resnet.py stage "trans"):
                # im2col din is just cin for k=1
                shapes.append((batch * H * H, cin, cout))
            cin = cout
    seen, uniq = set(), []
    for s in shapes:
        if s not in seen:
            seen.add(s)
            uniq.append(s)
    return uniq
