"""The paper's own backbones: ResNet-74, ResNet-110, MobileNetV2 on
CIFAR-10/100 (§4.1) — the faithful-reproduction path.

These are full :class:`Experiment` bundles with ``task="cifar_cnn"``; they
run through the same ``init_train_state`` / ``make_train_step`` / ``Trainer``
stack as every LM experiment (SMD, SLU, PSG probe, SWA, checkpointing).
"""
from typing import List, NamedTuple, Optional, Tuple

from repro.core.config import (E2TrainConfig, Experiment, ModelConfig,
                               TrainConfig)


def cnn_model(name: str, depth: int, num_classes: int = 10,
              width: int = 16) -> ModelConfig:
    """``family="cnn"`` encoding understood by ``tasks/cifar_cnn.py``:
    ``num_layers`` is the CIFAR ResNet depth (6n+2), ``d_model`` the stage-0
    width, ``vocab_size`` the class count.  A model named ``"mobilenetv2"``
    selects the MobileNetV2 backbone (depth is ignored).  CNNs train in
    fp32 — the paper's precision story lives in PSG, not bf16 activations.
    """
    return ModelConfig(name=name, family="cnn", num_layers=depth,
                       d_model=width, num_heads=1, num_kv_heads=1, d_ff=0,
                       vocab_size=num_classes, glu=False, dtype="float32")


def _cnn_train(lr: float) -> TrainConfig:
    return TrainConfig(global_batch=128, lr=lr, total_steps=64000,
                       optimizer="sgdm", weight_decay=1e-4)


def resnet74(num_classes: int = 10,
             e2: Optional[E2TrainConfig] = None) -> Experiment:
    return Experiment(model=cnn_model("resnet74", 74, num_classes),
                      e2=e2 or E2TrainConfig(), train=_cnn_train(0.1),
                      task="cifar_cnn")


def resnet110(num_classes: int = 10,
              e2: Optional[E2TrainConfig] = None) -> Experiment:
    return Experiment(model=cnn_model("resnet110", 110, num_classes),
                      e2=e2 or E2TrainConfig(), train=_cnn_train(0.1),
                      task="cifar_cnn")


def mobilenetv2(num_classes: int = 10,
                e2: Optional[E2TrainConfig] = None) -> Experiment:
    return Experiment(model=cnn_model("mobilenetv2", 0, num_classes),
                      e2=e2 or E2TrainConfig(), train=_cnn_train(0.05),
                      task="cifar_cnn")


class ConvShape(NamedTuple):
    """One convolution site of a CIFAR backbone, full geometry.

    ``hw`` is the *input* spatial extent; SAME padding ``k // 2`` is
    implied (the ``models/resnet.conv2d`` convention), so the output
    extent is ``ceil(hw / stride)``.
    """

    batch: int
    hw: int
    cin: int
    cout: int
    k: int
    stride: int

    @property
    def hw_out(self) -> int:
        return -(-self.hw // self.stride)

    @property
    def kind(self) -> str:
        """"body" (3x3 stride-1), "strided" (3x3 stride-2 transition),
        "down" (1x1 projection shortcut, stride 2), "point" (1x1)."""
        if self.k == 1:
            return "down" if self.stride > 1 else "point"
        return "strided" if self.stride > 1 else "body"

    @property
    def im2col(self) -> Tuple[int, int, int]:
        """The (N, din, dout) matmul this conv materializes on the
        im2col path: N = B*H'*W', din = k*k*Cin, dout = Cout."""
        return (self.batch * self.hw_out * self.hw_out,
                self.k * self.k * self.cin, self.cout)


def resnet_conv_shapes(depth: int = 74, width: int = 16, batch: int = 128,
                       image: int = 32, unique: bool = True
                       ) -> List[ConvShape]:
    """Convolution geometries of a CIFAR ResNet, in network order:
    stem, then per stage the transition conv1 (stride-2 from stage 1 on),
    conv2, the 1x1 stride-2 projection shortcut, and the body convs.

    This is the full geometry (k, stride included) behind
    :func:`resnet_im2col_shapes`; the conv kernel benches/tests sweep it
    directly so the stride-2 transitions and 1x1 downsamples are exercised
    as *convolutions*, not just as their flattened matmuls.  With
    ``unique=False`` every conv site is returned (with multiplicity) — the
    per-step traffic/energy totals need the repeat counts.
    """
    n = (depth - 2) // 6
    shapes: List[ConvShape] = [ConvShape(batch, image, 3, width, 3, 1)]
    H, cin = image, width
    for stage, cout in enumerate((width, 2 * width, 4 * width)):
        for b in range(n):
            stride = 2 if (stage > 0 and b == 0) else 1
            shapes.append(ConvShape(batch, H, cin if b == 0 else cout,
                                    cout, 3, stride))
            H = H // stride
            shapes.append(ConvShape(batch, H, cout, cout, 3, 1))
            if b == 0 and cin != cout:
                # 1x1 stride-2 projection shortcut (stage "trans" `down`)
                shapes.append(ConvShape(batch, H * stride, cin, cout, 1,
                                        stride))
            cin = cout
    if not unique:
        return shapes
    seen, uniq = set(), []
    for s in shapes:
        if s not in seen:
            seen.add(s)
            uniq.append(s)
    return uniq


def resnet_im2col_shapes(depth: int = 74, width: int = 16, batch: int = 128,
                         image: int = 32) -> List[Tuple[int, int, int]]:
    """Distinct (N, din, dout) im2col matmul shapes of a CIFAR ResNet.

    These are exactly the operand shapes ``models/resnet.conv2d`` hands to
    ``psg.matmul`` on the materialized path — i.e. the shapes the PSG
    backward tile kernel sees during paper-faithful training (N = B*H'*W',
    din = k*k*Cin, dout = Cout), including the stride-2 transitions and
    the 1x1 projection shortcuts.  Derived from
    :func:`resnet_conv_shapes`; used by benchmarks/bench_kernels.py to
    compare the element-level oracle against the tile kernel on real
    workload geometry.
    """
    seen, uniq = set(), []
    for s in resnet_conv_shapes(depth, width, batch, image):
        if s.im2col not in seen:
            seen.add(s.im2col)
            uniq.append(s.im2col)
    return uniq
