"""The paper's own backbones: ResNet-74, ResNet-110, MobileNetV2 on
CIFAR-10/100 (§4.1) — the faithful-reproduction path."""
from dataclasses import dataclass

from repro.core.config import E2TrainConfig, TrainConfig


@dataclass(frozen=True)
class CNNExperiment:
    name: str
    depth: int                 # ResNet depth; 0 -> MobileNetV2
    num_classes: int
    train: TrainConfig
    e2: E2TrainConfig


def resnet74(num_classes: int = 10, e2: E2TrainConfig = None) -> CNNExperiment:
    return CNNExperiment("resnet74", 74, num_classes,
                         TrainConfig(global_batch=128, lr=0.1,
                                     total_steps=64000, optimizer="sgdm"),
                         e2 or E2TrainConfig())


def resnet110(num_classes: int = 10, e2: E2TrainConfig = None) -> CNNExperiment:
    return CNNExperiment("resnet110", 110, num_classes,
                         TrainConfig(global_batch=128, lr=0.1,
                                     total_steps=64000, optimizer="sgdm"),
                         e2 or E2TrainConfig())


def mobilenetv2(num_classes: int = 10, e2: E2TrainConfig = None) -> CNNExperiment:
    return CNNExperiment("mobilenetv2", 0, num_classes,
                         TrainConfig(global_batch=128, lr=0.05,
                                     total_steps=64000, optimizer="sgdm"),
                         e2 or E2TrainConfig())
