"""phi-3-vision-4.2b [hf:microsoft/Phi-3-vision-128k-instruct; hf]: 32L
d_model=3072 32H (kv=32) d_ff=8192 vocab=32064; phi3-mini text backbone +
CLIP vision frontend.  The CLIP tower is a STUB: ``input_specs`` provides
precomputed patch embeddings (B, 576, d) prepended to the token stream."""
from repro.core.config import Experiment, ModelConfig, TrainConfig

PATCH_TOKENS = 576    # 24x24 CLIP-ViT-L/14 at 336px


def get_config() -> Experiment:
    return Experiment(model=ModelConfig(
        name="phi-3-vision-4.2b", family="vlm",
        num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=32064,
        frontend="vision", frontend_tokens=PATCH_TOKENS,
        rope_theta=10000.0,
    ), train=TrainConfig(optimizer="sgdm"))
