"""whisper-small [arXiv:2212.04356; unverified]: enc-dec, 12L each,
d_model=768 12H d_ff=3072 vocab=51865.  The conv/log-mel frontend is a STUB:
``input_specs`` provides precomputed frame embeddings (B, 1500, d) as the
encoder input (assignment note for [audio] entries).  RoPE replaces the
learned positional embeddings (TPU-idiomatic; documented deviation)."""
from repro.core.config import Experiment, ModelConfig, ServeConfig, TrainConfig

AUDIO_FRAMES = 1500   # 30 s at the whisper frame rate


def get_config() -> Experiment:
    return Experiment(model=ModelConfig(
        name="whisper-small", family="audio",
        num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
        d_ff=3072, vocab_size=51865,
        norm="layernorm", act="gelu", glu=False,
        encoder_layers=12, cross_attention=True,
        frontend="audio", frontend_tokens=AUDIO_FRAMES,
    ), train=TrainConfig(optimizer="sgdm"))
