"""qwen2.5-3b [hf:Qwen/Qwen2.5-0.5B; hf]: 36L d_model=2048 16H (GQA kv=2)
d_ff=11008 vocab=151936; QKV bias, tied embeddings."""
from repro.core.config import Experiment, ModelConfig, TrainConfig


def get_config() -> Experiment:
    return Experiment(model=ModelConfig(
        name="qwen2.5-3b", family="dense",
        num_layers=36, d_model=2048, num_heads=16, num_kv_heads=2,
        d_ff=11008, vocab_size=151936,
        qkv_bias=True, tie_embeddings=True, rope_theta=1000000.0,
    ), train=TrainConfig(optimizer="sgdm"))
