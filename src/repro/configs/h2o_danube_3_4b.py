"""h2o-danube-3-4b [arXiv:2401.16818; unverified]: 24L d_model=3840 32H
(GQA kv=8) d_ff=10240 vocab=32000; llama+mistral mix with sliding-window
attention (window 4096) — the SWA gives this dense arch a sub-quadratic
long-context path, so it runs the long_500k cell (DESIGN.md §5)."""
from repro.core.config import Experiment, ModelConfig, TrainConfig


def get_config() -> Experiment:
    return Experiment(model=ModelConfig(
        name="h2o-danube-3-4b", family="dense",
        num_layers=24, d_model=3840, num_heads=32, num_kv_heads=8,
        d_ff=10240, vocab_size=32000,
        sliding_window=4096, rope_theta=10000.0,
    ), train=TrainConfig(optimizer="sgdm"))
