"""grok-1-314b [hf:xai-org/grok-1; unverified]: 64L d_model=6144 48H
(GQA kv=8) d_ff=32768 vocab=131072; MoE 8 experts top-2."""
from repro.core.config import Experiment, ModelConfig, TrainConfig


def get_config() -> Experiment:
    return Experiment(model=ModelConfig(
        name="grok-1-314b", family="moe",
        num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=32768, moe_d_ff=32768, vocab_size=131072,
        num_experts=8, num_shared_experts=0, top_k=2,
        rope_theta=10000.0,
        # 314B on 256 chips: bf16 params+momentum (fp32 master would be
        # 2.5 TB with optimizer state; production pairing is bf16 +
        # stochastic rounding / sharded fp32 master at 512+ chips)
        param_dtype="bfloat16",
    ), train=TrainConfig(optimizer="sgdm", microbatches=8))
