"""zamba2-1.2b [arXiv:2411.15242; hf]: 38L d_model=2048 32H (kv=32)
d_ff=8192 ssm_state=64 vocab=32000; Mamba2 blocks + a weight-SHARED
attention block invoked periodically (2 shared invocations in the 38-block
schedule: unit = 18 mamba + 1 shared_attn, tiled x2).  The shared block is
exempt from SLU gating (DESIGN.md §5).  Runs long_500k via O(1) SSM state."""
from repro.core.config import (BLOCK_MAMBA, BLOCK_SHARED_ATTN, Experiment,
                               ModelConfig, TrainConfig)


def get_config() -> Experiment:
    unit = (BLOCK_MAMBA,) * 18 + (BLOCK_SHARED_ATTN,)
    return Experiment(model=ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=32000, ssm_state=64,
        block_unit=unit,
    ), train=TrainConfig(optimizer="sgdm", microbatches=4))
