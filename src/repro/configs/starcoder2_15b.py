"""starcoder2-15b [arXiv:2402.19173; hf]: 40L d_model=6144 48H (GQA kv=4)
d_ff=24576 vocab=49152; GQA + RoPE, layernorm, non-gated gelu MLP with
biases (the StarCoder2 recipe)."""
from repro.core.config import Experiment, ModelConfig, TrainConfig


def get_config() -> Experiment:
    return Experiment(model=ModelConfig(
        name="starcoder2-15b", family="dense",
        num_layers=40, d_model=6144, num_heads=48, num_kv_heads=4,
        d_ff=24576, vocab_size=49152,
        norm="layernorm", act="gelu", glu=False,
        qkv_bias=True, mlp_bias=True, rope_theta=100000.0,
    ), train=TrainConfig(optimizer="sgdm", microbatches=4))
