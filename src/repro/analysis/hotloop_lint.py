"""Hot-loop lint: the chunk program's contract, verified statically.

``training/loop.make_chunk_step`` compiles K executed train steps into one
device program — the repo's entire throughput story (DESIGN.md §Loop)
rests on that program having no hidden per-step host round-trips.  This
pass traces the chunk abstractly (``jax.make_jaxpr`` over
ShapeDtypeStruct trees — nothing runs) and checks every rule of
``training.loop.CHUNK_CONTRACT``:

==========================  ===============================================
rule                        check
==========================  ===============================================
``no-host-callback``        no callback/infeed/outfeed primitive anywhere
                            in the traced chunk (recursively, through
                            scan/cond/pjit/pallas bodies) — a
                            ``jax.debug.print`` inside the scanned body is
                            one host sync per step, the thing the chunk
                            loop exists to avoid
``static-trip-count``       the top level is a ``lax.scan`` whose static
                            ``length`` equals the chunk's K; any ``while``
                            in the program is a finding (unknown trips)
``shape-stable-body``       tracing at K and K+1 yields the same primitive
                            histogram — a Python-value-dependent operand
                            that bakes K into the *body* would recompile
                            per chunk length
``device-resident-metrics`` every metric leaf comes back stacked
                            ``(K, ...)`` (the per-step values stay on
                            device; the caller syncs once per boundary)
``no-donation-default``     the default lowering carries no
                            ``input_output_alias``, and
                            ``Trainer(donate_chunk_state=...)`` defaults
                            False (donation breaks the pinned bit-parity
                            with the per-step loop)
==========================  ===============================================

Run as a module (``python -m repro.analysis.hotloop_lint``) it lints the
chunk program for both registered task families (a CIFAR CNN and the
smoke LM) and exits nonzero on any finding — that is the CI hook.
"""
from __future__ import annotations

import inspect
from collections import Counter
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import core as jcore

from repro.analysis.jaxpr_cost import sub_jaxprs

# primitives that round-trip to the host when executed
_CALLBACK_MARKERS = ("callback",)
_CALLBACK_PRIMS = frozenset({"infeed", "outfeed"})


@dataclass(frozen=True)
class HotloopFinding:
    rule: str           # a CHUNK_CONTRACT entry
    site: str
    message: str

    def __str__(self) -> str:
        return f"{self.site}: [{self.rule}] {self.message}"


def _is_callback(prim: str) -> bool:
    return prim in _CALLBACK_PRIMS or any(m in prim
                                          for m in _CALLBACK_MARKERS)


def _walk_prims(jx, path: str, out: List[Tuple[str, str]]) -> None:
    for eqn in jx.eqns:
        prim = eqn.primitive.name
        out.append((prim, f"{path}/{prim}"))
        subs, _ = sub_jaxprs(eqn)
        for sub, _trips in subs:
            _walk_prims(sub.jaxpr, f"{path}/{prim}", out)


def _all_prims(closed: jcore.ClosedJaxpr, name: str) -> List[Tuple[str, str]]:
    out: List[Tuple[str, str]] = []
    _walk_prims(closed.jaxpr, name, out)
    return out


def _abstract_chunk_args(exp, K: int):
    """(state, batches, step_increment) ShapeDtypeStruct trees for the
    chunk program — nothing is allocated."""
    from repro.training.train_step import init_train_state

    S = jax.ShapeDtypeStruct
    key = S((2,), jnp.uint32)
    state = jax.eval_shape(lambda k: init_train_state(k, exp), key)
    B = exp.train.global_batch
    if exp.task == "lm":
        batches = {"tokens": S((K, B, exp.train.seq_len), jnp.int32),
                   "labels": S((K, B, exp.train.seq_len), jnp.int32)}
    else:
        batches = {"image": S((K, B, 32, 32, 3), jnp.float32),
                   "label": S((K, B), jnp.int32)}
    return state, batches, S((K,), jnp.int32)


def lint_program(chunk_fn, args, K: int, name: str = "chunk",
                 donate_argnums: Tuple[int, ...] = ()
                 ) -> List[HotloopFinding]:
    """Check one chunk-shaped program against CHUNK_CONTRACT (sans the
    Trainer-signature rule — see :func:`lint_trainer_default`).

    ``donate_argnums`` exists for fixtures: the contract's default is no
    donation, and passing a non-empty tuple here must produce a finding.
    """
    findings: List[HotloopFinding] = []
    closed = jax.make_jaxpr(chunk_fn)(*args)

    # no-host-callback
    for prim, site in _all_prims(closed, name):
        if _is_callback(prim):
            findings.append(HotloopFinding(
                "no-host-callback", site,
                f"'{prim}' inside the chunk program — one host round-trip "
                "per step re-creates the per-step loop's sync cost"))

    # static-trip-count: the top level must be a scan of static length K …
    top_scans = [e for e in closed.jaxpr.eqns
                 if e.primitive.name == "scan"]
    if not any(e.params.get("length") == K for e in top_scans):
        findings.append(HotloopFinding(
            "static-trip-count", name,
            f"no top-level lax.scan of static length K={K} — the chunk "
            "must be one statically-shaped scanned program"))
    # … and nothing anywhere may loop an unknown number of times
    for prim, site in _all_prims(closed, name):
        if prim == "while":
            findings.append(HotloopFinding(
                "static-trip-count", site,
                "while loop inside the chunk — trip count is not static "
                "(poisons the HLO cost audit, defeats AOT scheduling)"))

    # shape-stable-body: same primitive mix at K and K+1
    def bump(s, lead=K):
        if hasattr(s, "shape") and s.shape and s.shape[0] == lead:
            return jax.ShapeDtypeStruct((lead + 1,) + s.shape[1:], s.dtype)
        return s
    state, batches, incs = args
    args2 = (state, jax.tree.map(bump, batches), bump(incs))
    closed2 = jax.make_jaxpr(chunk_fn)(*args2)
    h1 = Counter(p for p, _ in _all_prims(closed, name))
    h2 = Counter(p for p, _ in _all_prims(closed2, name))
    if h1 != h2:
        diff = {p: (h1.get(p, 0), h2.get(p, 0))
                for p in set(h1) | set(h2) if h1.get(p) != h2.get(p)}
        findings.append(HotloopFinding(
            "shape-stable-body", name,
            f"primitive mix changes with K ({K} vs {K + 1}): {diff} — a "
            "Python-value-dependent operand is baking the chunk length "
            "into the body (recompiles per chunk)"))

    # device-resident-metrics: every metric leaf stacked (K, ...)
    _, metrics = jax.eval_shape(chunk_fn, *args)
    for path, leaf in jax.tree_util.tree_flatten_with_path(metrics)[0]:
        if not (getattr(leaf, "shape", ()) and leaf.shape[0] == K):
            findings.append(HotloopFinding(
                "device-resident-metrics",
                f"{name}/metrics{jax.tree_util.keystr(path)}",
                f"metric leaf has shape {getattr(leaf, 'shape', ())}, "
                f"expected leading chunk axis ({K}, ...) — per-step values "
                "must stay device-resident until the chunk boundary"))

    # no-donation-default: the documented default lowering never aliases.
    # Donation shows as tf.aliasing_output / jax.buffer_donor attrs in the
    # StableHLO text (input_output_alias is the post-compile HLO spelling).
    text = jax.jit(chunk_fn, donate_argnums=donate_argnums
                   ).lower(*args).as_text()
    if any(marker in text for marker in
           ("input_output_alias", "tf.aliasing_output", "jax.buffer_donor")):
        findings.append(HotloopFinding(
            "no-donation-default", name,
            "lowered chunk carries input_output_alias — donation is "
            "opt-in only (XLA CPU rewrites the scanned body in place and "
            "breaks bit-parity with the per-step loop; DESIGN.md §Loop)"))
    return findings


def lint_trainer_default() -> List[HotloopFinding]:
    """``Trainer(donate_chunk_state=...)`` must default False."""
    from repro.training.trainer import Trainer

    sig = inspect.signature(Trainer.__init__)
    param = sig.parameters.get("donate_chunk_state")
    if param is None or param.default is not False:
        return [HotloopFinding(
            "no-donation-default", "Trainer.__init__",
            f"donate_chunk_state default is "
            f"{None if param is None else param.default!r}, documented "
            "contract is False")]
    return []


def lint_chunk(exp, K: int = 3) -> List[HotloopFinding]:
    """Lint one experiment's real ``make_chunk_step`` program."""
    from repro.training.loop import make_chunk_step

    args = _abstract_chunk_args(exp, K)
    name = f"chunk:{exp.model.name}"
    return (lint_program(make_chunk_step(exp), args, K, name=name)
            + lint_trainer_default())


def _default_experiments():
    from repro.configs import smoke_experiment
    from repro.configs.paper_cnns import cnn_model
    from repro.core.config import E2TrainConfig, Experiment, TrainConfig

    cnn = Experiment(
        model=cnn_model("resnet14", 14), e2=E2TrainConfig(),
        train=TrainConfig(global_batch=8, lr=0.1, total_steps=100,
                          optimizer="sgdm"),
        task="cifar_cnn")
    return [cnn, smoke_experiment("llama3_8b")]


def lint_all(exps=None, K: int = 3) -> List[HotloopFinding]:
    findings: List[HotloopFinding] = []
    for exp in (exps if exps is not None else _default_experiments()):
        findings.extend(lint_chunk(exp, K=K))
    return findings


def hotloop_report(exps=None) -> dict:
    """The BENCH_audit.json ``hotloop`` section."""
    findings = lint_all(exps)
    return {"findings": [str(f) for f in findings],
            "passed": not findings}


def main() -> int:
    findings = lint_all()
    for f in findings:
        print(f)
    print(f"hotloop lint: {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
