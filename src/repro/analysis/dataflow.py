"""Jaxpr dataflow engine: abstract interpretation for precision provenance.

``analyze(fn, *args)`` traces ``fn`` abstractly (arguments may be
:class:`jax.ShapeDtypeStruct` trees — nothing executes) and interprets the
jaxpr over a small per-value lattice:

* **narrow** — the set of sub-32-bit dtypes anywhere in the value's lineage
  (``bfloat16``/``float16``/fp8/``int8``/…).  Upcasting does *not* clear it:
  a value that was ever bf16 stays bf16-descended, which is exactly what
  the precision lint needs ("operands descend from quantized values").
* **depth** — how many reductions the value has passed through.
* **chain** — length of the current consecutive-additive-op run, used to
  recognize unrolled accumulation loops (``acc = acc + tap`` k² times)
  without flagging every residual add.
* **taints** — ``(tag, through_add)`` markers that implement cycle
  detection: scan carries and Pallas refs are seeded with a tag, additive
  ops flip ``through_add`` to True, and a tagged value arriving back at its
  own carry slot / ref *through an add* is an accumulation.
* **origin** — where narrowness first entered the lineage (for reports).

Every reduction the interpreter meets is recorded as a
:class:`ReductionSite` with its **accumulator dtype** (the output / carry /
ref dtype — the dtype partial sums actually live in):

=================  ========================================================
kind               emitted for
=================  ========================================================
``dot_general``    every contraction (accumulator = out dtype)
``conv``           ``conv_general_dilated``
``reduce_sum``     ``reduce_sum`` / ``reduce_window_sum``
``cumsum``         ``cumsum``
``scatter-add``    indexed accumulation (``x.at[...].add`` — the PR 7
                   reference-path bug class)
``add-chain``      an additive run crossing :data:`ADD_CHAIN_SITE` ops
                   (unrolled tap loops)
``scan-carry``     a ``scan``/``while`` carry that feeds back into itself
                   through an add (running sums, EMA)
``ref-accum``      a Pallas ref written with a value derived from its own
                   contents through an add (``acc_ref[...] += v``), or any
                   ``addupdate``
=================  ========================================================

Control flow: ``scan``/``while`` bodies run twice (seed, then fixpoint pass
that records sites), ``cond`` branches are all interpreted and their
outputs joined, ``pjit``/``custom_vjp``/``remat`` recurse transparently,
and ``pallas_call`` maps operands onto the kernel's input refs so the
lattice flows *into* kernel bodies (scratch refs start untainted with
their declared dtype — a bf16 scratch accumulator is caught as narrow).

The lint layers on top: :meth:`DataflowResult.hazards` returns the sites
whose accumulator is narrower than 32 bits while their operands descend
from narrow values — the bug class PR 7 fixed by hand.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax import core as jcore

# dtypes whose presence in a lineage marks a value "narrow-descended"
NARROW_DTYPES = frozenset({
    "bfloat16", "float16",
    "float8_e4m3fn", "float8_e5m2", "float8_e4m3fnuz", "float8_e5m2fnuz",
    "float8_e4m3b11_fnuz",
    "int8", "uint8", "int16", "uint16", "int4", "uint4",
})

# additive primitives: these flip the through_add taint flag and grow chains
_ADDITIVE = frozenset({"add", "add_any", "sub"})

# an additive run at least this long is an unrolled accumulation loop
ADD_CHAIN_SITE = 3

# reduction primitives -> site kind (accumulator = output dtype)
_REDUCE_SITES = {
    "dot_general": "dot_general",
    "conv_general_dilated": "conv",
    "reduce_sum": "reduce_sum",
    "reduce_window_sum": "reduce_sum",
    "cumsum": "cumsum",
    "scatter-add": "scatter-add",
    "scatter_add": "scatter-add",
}

# shape/layout ops that neither mix values nor break an additive run
_PASSTHROUGH = frozenset({
    "convert_element_type", "bitcast_convert_type", "broadcast_in_dim",
    "reshape", "squeeze", "expand_dims", "transpose", "slice",
    "dynamic_slice", "rev", "copy", "stop_gradient", "optimization_barrier",
    "device_put", "sharding_constraint",
})


def _dtype_name(dtype) -> str:
    try:
        return np.dtype(dtype).name
    except TypeError:
        return str(dtype)


def _aval_dtype(aval) -> str:
    """Dtype name of an aval; sees through Pallas/state ref avals."""
    inner = getattr(aval, "inner_aval", aval)
    return _dtype_name(getattr(inner, "dtype", "void"))


def _is_ref(aval) -> bool:
    return hasattr(aval, "inner_aval") or type(aval).__name__.endswith("Ref")


def acc_is_narrow(dtype_name: str) -> bool:
    """True when partial sums in this dtype lose low-order contributions
    (any float/int accumulator under 32 bits)."""
    if dtype_name in NARROW_DTYPES:
        return True
    try:
        dt = np.dtype(dtype_name)
    except TypeError:
        return False
    return dt.kind in "fiu" and dt.itemsize < 4


@dataclass(frozen=True)
class Prov:
    """Per-value lattice element (see module doc)."""

    narrow: FrozenSet[str] = frozenset()
    depth: int = 0
    chain: int = 0
    taints: FrozenSet[Tuple[str, bool]] = frozenset()
    origin: str = ""


def join(*provs: Prov) -> Prov:
    narrow: FrozenSet[str] = frozenset()
    taints: FrozenSet[Tuple[str, bool]] = frozenset()
    depth = chain = 0
    origin = ""
    for p in provs:
        narrow |= p.narrow
        taints |= p.taints
        depth = max(depth, p.depth)
        chain = max(chain, p.chain)
        if p.narrow and not origin:
            origin = p.origin
    return Prov(narrow, depth, chain, taints, origin)


def _strip_taints(p: Prov, tags: Sequence[str]) -> Prov:
    ts = frozenset((t, f) for t, f in p.taints if t not in tags)
    return replace(p, taints=ts)


@dataclass(frozen=True)
class ReductionSite:
    """One reduction with the dtype its partial sums live in."""

    kind: str                           # see module table
    prim: str                           # jaxpr primitive name
    site: str                           # program path + name-stack scope
    acc_dtype: str                      # accumulator dtype name
    narrow_operands: Tuple[str, ...]    # narrow dtypes in operand lineage
    depth: int
    origin: str                         # where narrowness entered, "" if wide

    def __str__(self) -> str:
        ops = ",".join(self.narrow_operands) or "wide"
        via = f" (narrow via {self.origin})" if self.origin else ""
        return (f"{self.site}: [{self.kind}] accumulates {ops} operands "
                f"in {self.acc_dtype}{via}")


@dataclass
class DataflowResult:
    sites: List[ReductionSite] = field(default_factory=list)

    def hazards(self) -> List[ReductionSite]:
        """Sites accumulating narrow-descended operands in a sub-32-bit
        accumulator — the PR 7 bug class."""
        return [s for s in self.sites
                if s.narrow_operands and acc_is_narrow(s.acc_dtype)]


class _Interp:
    def __init__(self, name: str):
        self.name = name
        self.sites: Dict[Tuple, ReductionSite] = {}
        self.record = True
        self._ref_dtype: Dict[str, str] = {}
        self._ref_state: Dict[str, Prov] = {}
        self._uid = 0

    def _fresh(self, prefix: str) -> str:
        self._uid += 1
        return f"{prefix}{self._uid}"

    # -- environment ------------------------------------------------------

    def _read(self, env: Dict, atom) -> Prov:
        if isinstance(atom, jcore.Literal):
            dt = _dtype_name(getattr(atom.aval, "dtype", "void"))
            nar = frozenset({dt}) if dt in NARROW_DTYPES else frozenset()
            return Prov(narrow=nar, origin="literal" if nar else "")
        return env.get(atom, Prov())

    def _bind(self, env: Dict, var, prov: Prov, where: str) -> None:
        dt = _aval_dtype(var.aval)
        if dt in NARROW_DTYPES and dt not in prov.narrow:
            prov = replace(prov, narrow=prov.narrow | {dt},
                           origin=prov.origin or f"{where}:{dt}")
        env[var] = prov

    def _site(self, kind: str, prim: str, where: str, acc_dtype: str,
              operands: Prov) -> None:
        if not self.record:
            return
        key = (kind, prim, where, acc_dtype,
               tuple(sorted(operands.narrow)))
        if key not in self.sites:
            self.sites[key] = ReductionSite(
                kind=kind, prim=prim, site=where, acc_dtype=acc_dtype,
                narrow_operands=tuple(sorted(operands.narrow)),
                depth=operands.depth, origin=operands.origin)

    # -- interpretation ---------------------------------------------------

    def run_closed(self, closed: jcore.ClosedJaxpr, in_provs: Sequence[Prov],
                   path: str) -> List[Prov]:
        jx = closed.jaxpr
        env: Dict = {}
        for cv in jx.constvars:
            dt = _aval_dtype(cv.aval)
            nar = frozenset({dt}) if dt in NARROW_DTYPES else frozenset()
            env[cv] = Prov(narrow=nar, origin="const" if nar else "")
        for i, (v, p) in enumerate(zip(jx.invars, in_provs)):
            self._bind(env, v, p, f"{path}/in{i}")
        self.run_eqns(jx, env, path)
        return [self._read(env, ov) for ov in jx.outvars]

    def run_eqns(self, jx, env: Dict, path: str) -> None:
        for eqn in jx.eqns:
            self._eqn(env, eqn, path)

    def _where(self, eqn, path: str) -> str:
        stack = str(eqn.source_info.name_stack)
        return f"{path}/{stack}" if stack else path

    def _eqn(self, env: Dict, eqn, path: str) -> None:
        prim = eqn.primitive.name
        p = eqn.params
        where = self._where(eqn, path)

        if prim == "scan":
            self._loop(env, eqn, path, p["jaxpr"],
                       n_pre=p["num_consts"], n_carry=p["num_carry"],
                       prim="scan")
            return
        if prim == "while":
            self._loop(env, eqn, path, p["body_jaxpr"],
                       n_pre=p["cond_nconsts"] + p["body_nconsts"],
                       n_carry=len(eqn.outvars), prim="while")
            return
        if prim == "cond":
            ops = [self._read(env, a) for a in eqn.invars[1:]]
            outs: Optional[List[Prov]] = None
            for br in p["branches"]:
                bouts = self.run_closed(br, ops, path)
                outs = bouts if outs is None else \
                    [join(a, b) for a, b in zip(outs, bouts)]
            for ov, pr in zip(eqn.outvars, outs or []):
                self._bind(env, ov, pr, where)
            return
        if prim == "pallas_call":
            self._pallas(env, eqn, path)
            return
        if prim == "reduce":
            # generic lax.reduce: a sum iff its computation jaxpr adds
            comp = p.get("jaxpr")
            comp_j = comp.jaxpr if isinstance(comp, jcore.ClosedJaxpr) \
                else comp
            additive = any(e.primitive.name in _ADDITIVE
                           for e in getattr(comp_j, "eqns", []))
            ops = [self._read(env, a) for a in eqn.invars]
            opj = join(*ops) if ops else Prov()
            if additive:
                self._site("reduce_sum", prim, where,
                           _aval_dtype(eqn.outvars[0].aval), opj)
            out = Prov(narrow=opj.narrow, depth=opj.depth + 1, chain=0,
                       taints=frozenset((t, True) for t, _ in opj.taints)
                       if additive else opj.taints, origin=opj.origin)
            for ov in eqn.outvars:
                self._bind(env, ov, out, where)
            return
        for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            if key in p:
                sub = p[key]
                closed = sub if isinstance(sub, jcore.ClosedJaxpr) \
                    else jcore.ClosedJaxpr(sub, ())
                ops = [self._read(env, a) for a in eqn.invars]
                outs = self.run_closed(closed, ops, path)
                for ov, pr in zip(eqn.outvars, outs):
                    self._bind(env, ov, pr, where)
                return

        if prim == "get":
            tag = self._ref_tag(env, eqn.invars[0])
            if tag is not None:
                content = self._ref_state.get(tag, Prov())
                out = Prov(narrow=content.narrow, depth=content.depth,
                           chain=0, taints=frozenset({(tag, False)}),
                           origin=content.origin)
                self._bind(env, eqn.outvars[0], out, where)
                return
        if prim in ("swap", "addupdate"):
            tag = self._ref_tag(env, eqn.invars[0])
            if tag is not None:
                val = self._read(env, eqn.invars[1])
                accumulates = (prim == "addupdate"
                               or (tag, True) in val.taints)
                if accumulates:
                    self._site("ref-accum", prim, where,
                               self._ref_dtype[tag], val)
                old = self._ref_state.get(tag, Prov())
                self._ref_state[tag] = join(
                    old, replace(val, taints=frozenset(), chain=0))
                for ov in eqn.outvars:
                    self._bind(env, ov, replace(old, chain=0,
                               taints=frozenset({(tag, False)})), where)
                return

        ops = [self._read(env, a) for a in eqn.invars]
        opj = join(*ops) if ops else Prov()

        if prim in _REDUCE_SITES:
            out_dt = _aval_dtype(eqn.outvars[0].aval)
            self._site(_REDUCE_SITES[prim], prim, where, out_dt, opj)
            out = Prov(narrow=opj.narrow, depth=opj.depth + 1, chain=0,
                       taints=frozenset((t, True) for t, _ in opj.taints),
                       origin=opj.origin)
            for ov in eqn.outvars:
                self._bind(env, ov, out, where)
            return

        if prim in _ADDITIVE:
            chain = max((o.chain for o in ops), default=0) + 1
            if chain == ADD_CHAIN_SITE:
                self._site("add-chain", prim, where,
                           _aval_dtype(eqn.outvars[0].aval), opj)
            out = Prov(narrow=opj.narrow, depth=opj.depth, chain=chain,
                       taints=frozenset((t, True) for t, _ in opj.taints),
                       origin=opj.origin)
            self._bind(env, eqn.outvars[0], out, where)
            return

        chain = opj.chain if prim in _PASSTHROUGH else 0
        out = replace(opj, chain=chain)
        for ov in eqn.outvars:
            self._bind(env, ov, out, where)

    # -- control flow -----------------------------------------------------

    def _loop(self, env: Dict, eqn, path: str, body, n_pre: int,
              n_carry: int, prim: str) -> None:
        invals = [self._read(env, a) for a in eqn.invars]
        pre, carries = invals[:n_pre], invals[n_pre:n_pre + n_carry]
        xs = invals[n_pre + n_carry:]
        where = self._where(eqn, path)
        tags = [self._fresh("carry") for _ in range(n_carry)]
        # while: eqn carries cond+body consts but the body only takes its own
        nb = len(body.jaxpr.invars) - n_carry - len(xs)
        body_pre = pre[len(pre) - nb:] if nb else []
        seeded = [join(c, Prov(taints=frozenset({(t, False)})))
                  for c, t in zip(carries, tags)]

        was = self.record
        self.record = False
        out1 = self.run_closed(body, body_pre + seeded + xs, path)
        self.record = was
        carried = [join(s, _strip_taints(o, tags))
                   for s, o in zip(seeded, out1[:n_carry])]
        outs = self.run_closed(body, body_pre + carried + xs, path)

        for i, (t, o) in enumerate(zip(tags, outs[:n_carry])):
            if (t, True) in o.taints:
                self._site("scan-carry", prim, where,
                           _aval_dtype(eqn.outvars[i].aval), o)
        for i, ov in enumerate(eqn.outvars):
            src = outs[i] if i < len(outs) else Prov()
            pr = _strip_taints(src, tags)
            if i < n_carry and (tags[i], True) in outs[i].taints:
                pr = replace(pr, depth=pr.depth + 1)
            self._bind(env, ov, replace(pr, chain=0), where)

    def _ref_tag(self, env: Dict, atom) -> Optional[str]:
        for t, _ in self._read(env, atom).taints:
            if t.startswith("ref"):
                return t
        return None

    def _pallas(self, env: Dict, eqn, path: str) -> None:
        p = eqn.params
        gm = p["grid_mapping"]
        inner = p["jaxpr"]
        jx = inner.jaxpr if isinstance(inner, jcore.ClosedJaxpr) else inner
        n_in, n_out = gm.num_inputs, gm.num_outputs
        kname = p.get("name", "kernel")
        kpath = f"{path}/pallas:{kname}"
        opvals = [self._read(env, a) for a in eqn.invars[-n_in:]] \
            if n_in else []

        env2: Dict = {}
        tag_of: Dict[int, str] = {}
        for i, v in enumerate(jx.invars):
            tag = self._fresh("ref")
            tag_of[i] = tag
            dt = _aval_dtype(v.aval)
            self._ref_dtype[tag] = dt
            content = opvals[i] if i < n_in else Prov()
            nar = frozenset({dt}) if dt in NARROW_DTYPES else frozenset()
            self._ref_state[tag] = join(
                replace(content, taints=frozenset(), chain=0),
                Prov(narrow=nar, origin=f"{kpath}/ref{i}:{dt}"
                     if nar else ""))
            env2[v] = Prov(taints=frozenset({(tag, False)}))
        self.run_eqns(jx, env2, kpath)

        where = self._where(eqn, path)
        for j, ov in enumerate(eqn.outvars):
            tag = tag_of.get(n_in + j)
            content = self._ref_state.get(tag, Prov()) if tag else Prov()
            self._bind(env, ov, replace(content, taints=frozenset()), where)


def analyze_jaxpr(closed: jcore.ClosedJaxpr,
                  name: str = "program") -> DataflowResult:
    """Interpret an already-traced program (see :func:`analyze`)."""
    it = _Interp(name)
    it.run_closed(closed, [Prov() for _ in closed.jaxpr.invars], name)
    return DataflowResult(sites=sorted(
        it.sites.values(), key=lambda s: (s.site, s.kind, s.acc_dtype)))


def analyze(fn, *args, name: str = "program", **kwargs) -> DataflowResult:
    """Trace ``fn`` abstractly (args may be ShapeDtypeStruct trees) and
    interpret the resulting jaxpr for precision provenance."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return analyze_jaxpr(closed, name=name)
