"""Jaxpr cost walker: per-primitive FLOPs/bytes, attributed to named layers.

``jaxpr_costs(fn, *args)`` traces ``fn`` (abstract tracing only — arguments
may be :class:`jax.ShapeDtypeStruct` trees, nothing executes) and walks the
resulting jaxpr recursively, deriving per-primitive operation counts and
attributing every equation back to a *layer scope* read from the equation's
source-info name stack.  Model code tags layers with
``jax.named_scope("cost:<name>")`` (``models/resnet.py``,
``models/transformer.py``); the tag survives ``lax.scan`` bodies and the
``jvp``/``transpose`` wrappers of a gradient trace, so the same walker
attributes forward and train-step programs alike.

Counting semantics (MACs are the currency of ``core/cost.py``):

* ``dot_general`` — MACs = numel(out) x prod(lhs contracting dims).
* ``conv_general_dilated`` — MACs = numel(out) x prod(kernel spatial) x
  cin-per-group, **except** patch-extraction convolutions
  (``conv_general_dilated_patches``: identity kernel, one input channel per
  group, k*k*cin output channels) which move data rather than multiply it —
  those land in ``gather_flops``, never in MACs.  Counting them as compute
  would inflate a CIFAR stage-0 conv by k²/cout ≈ 56%.
* ``mul`` — tracked separately (``mul_flops``): the MobileNetV2 depthwise
  conv is an explicit broadcast-multiply + sum, so its MACs are exactly the
  multiply count of its layer scope.
* other elementwise / reduce ops — ``other_flops`` (one op per output
  element; reductions count their operand).
* control flow — ``scan`` bodies scale by trip count, ``while`` bodies by 1
  with ``unknown_trips`` flagged (mirroring ``launch/hlo_cost.py``'s
  explicit unknown-trip-count accounting), ``cond`` takes the most
  expensive branch, ``pjit``/``custom_vjp``/``remat`` recurse, and
  ``pallas_call`` kernels are walked once per grid step.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax import core as jcore

SCOPE_RE = re.compile(r"cost:([\w.\-]+)")
UNATTRIBUTED = ""

# one-output elementwise float ops: one flop per output element
_ELEMWISE = frozenset({
    "add", "sub", "div", "neg", "exp", "log", "tanh", "logistic", "rsqrt",
    "sqrt", "pow", "integer_pow", "max", "min", "abs", "sign", "floor",
    "ceil", "round", "cos", "sin", "erf", "expm1", "log1p", "add_any",
    "atan2", "cbrt", "clamp", "nextafter", "rem", "square",
})
# reductions: one flop per *operand* element
_REDUCE = frozenset({
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "reduce_and",
    "reduce_or", "cumsum", "cumprod", "cummax", "cummin", "argmax", "argmin",
})
# pure data movement / metadata: zero flops, zero bytes charged
_FREE = frozenset({
    "broadcast_in_dim", "reshape", "squeeze", "expand_dims", "transpose",
    "convert_element_type", "bitcast_convert_type", "stop_gradient", "copy",
    "iota", "eq", "ne", "lt", "le", "gt", "ge", "and", "or", "not", "xor",
    "select_n", "is_finite", "sign", "device_put", "sharding_constraint",
    "optimization_barrier", "split", "concatenate", "pad", "slice",
    "dynamic_slice", "dynamic_update_slice", "rev", "gather", "scatter",
    "scatter-add", "program_id", "num_programs",
})


@dataclass
class OpCounts:
    """Operation counts of one attribution scope (or a whole program)."""

    dot_macs: float = 0.0       # dot_general contractions
    conv_macs: float = 0.0      # real conv_general_dilated contractions
    gather_flops: float = 0.0   # patch-extraction convs (data movement)
    mul_flops: float = 0.0      # elementwise multiplies
    other_flops: float = 0.0    # remaining elementwise/reduce work
    out_bytes: float = 0.0      # bytes written by non-metadata ops

    def macs(self) -> float:
        """MAC-bearing compute: contractions only (BN/activations excluded)."""
        return self.dot_macs + self.conv_macs

    def flops(self) -> float:
        return (2.0 * (self.dot_macs + self.conv_macs) + self.mul_flops
                + self.other_flops)

    def add(self, other: "OpCounts", scale: float = 1.0) -> None:
        self.dot_macs += scale * other.dot_macs
        self.conv_macs += scale * other.conv_macs
        self.gather_flops += scale * other.gather_flops
        self.mul_flops += scale * other.mul_flops
        self.other_flops += scale * other.other_flops
        self.out_bytes += scale * other.out_bytes

    def to_dict(self) -> Dict[str, float]:
        return {"dot_macs": self.dot_macs, "conv_macs": self.conv_macs,
                "gather_flops": self.gather_flops, "mul_flops": self.mul_flops,
                "other_flops": self.other_flops, "out_bytes": self.out_bytes}


@dataclass
class ProgramCosts:
    """Walk result: per-scope counts plus program-level flags."""

    by_scope: Dict[str, OpCounts] = field(default_factory=dict)
    unknown_trips: int = 0      # while loops whose trip count is not static

    def scope(self, tag: str) -> OpCounts:
        if tag not in self.by_scope:
            self.by_scope[tag] = OpCounts()
        return self.by_scope[tag]

    def total(self) -> OpCounts:
        t = OpCounts()
        for c in self.by_scope.values():
            t.add(c)
        return t

    def to_dict(self) -> Dict[str, Any]:
        return {"by_scope": {k: v.to_dict()
                             for k, v in sorted(self.by_scope.items())},
                "total": self.total().to_dict(),
                "unknown_trips": self.unknown_trips}


def scope_tag(eqn) -> str:
    """Innermost ``cost:<name>`` tag of an equation's name stack, or ''.

    Transform wrappers (``jvp(...)``, ``transpose(...)``, ``rematted(...)``)
    decorate but do not erase the scope, so the last match is the layer the
    primal computation belonged to.
    """
    m = SCOPE_RE.findall(str(eqn.source_info.name_stack))
    return m[-1] if m else UNATTRIBUTED


def _numel(aval) -> float:
    return float(math.prod(aval.shape)) if hasattr(aval, "shape") else 1.0


def _out_bytes(eqn) -> float:
    total = 0.0
    for v in eqn.outvars:
        aval = v.aval
        if hasattr(aval, "shape") and hasattr(aval, "dtype"):
            try:
                itemsize = np.dtype(aval.dtype).itemsize
            except TypeError:     # extended dtypes (PRNG keys): 4-word state
                itemsize = 16
            total += _numel(aval) * itemsize
    return total


def _dot_macs(eqn) -> float:
    (lhs_c, _), _ = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval
    k = 1.0
    for d in lhs_c:
        k *= lhs.shape[d]
    return _numel(eqn.outvars[0].aval) * k


def _conv_counts(eqn) -> Tuple[float, float]:
    """(conv_macs, gather_flops) of one conv_general_dilated equation."""
    dn = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval
    rhs = eqn.invars[1].aval
    out_n = _numel(eqn.outvars[0].aval)
    cin_per_group = rhs.shape[dn.rhs_spec[1]]
    spatial = 1.0
    for d in dn.rhs_spec[2:]:
        spatial *= rhs.shape[d]
    groups = eqn.params.get("feature_group_count", 1)
    lhs_channels = lhs.shape[dn.lhs_spec[1]]
    macs_per_out = spatial * cin_per_group
    if cin_per_group == 1 and groups == lhs_channels and groups > 1:
        # conv_general_dilated_patches: depth-separated identity kernel that
        # *rearranges* the input into im2col rows — movement, not MACs.
        return 0.0, out_n * macs_per_out
    return out_n * macs_per_out, 0.0


def sub_jaxprs(eqn):
    """(closed_jaxpr, trip_multiplier) children of an equation, plus
    whether they are a branch set (``cond``) rather than a sequence.

    Public: the dataflow/hot-loop analyzers reuse this as the one place
    that knows where every higher-order primitive hides its sub-programs
    (scan/while/cond/pallas_call/pjit/custom_vjp/remat)."""
    prim = eqn.primitive.name
    p = eqn.params
    if prim == "scan":
        return [(p["jaxpr"], float(p["length"]))], False
    if prim == "while":
        # body once per trip; trips are not static in general — the caller
        # flags it (cond jaxpr cost is negligible and skipped).
        return [(p["body_jaxpr"], 1.0)], False
    if prim == "cond":
        return [(b, 1.0) for b in p["branches"]], True
    if prim == "pallas_call":
        gm = p["grid_mapping"]
        trips = float(math.prod(gm.grid)) if gm.grid else 1.0
        inner = p["jaxpr"]
        closed = jcore.ClosedJaxpr(inner, ()) \
            if not isinstance(inner, jcore.ClosedJaxpr) else inner
        return [(closed, trips)], False
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if key in p:
            sub = p[key]
            closed = sub if isinstance(sub, jcore.ClosedJaxpr) \
                else jcore.ClosedJaxpr(sub, ())
            return [(closed, 1.0)], False
    return [], False


def _walk(jaxpr, costs: ProgramCosts, scale: float,
          outer_scope: str) -> None:
    for eqn in jaxpr.eqns:
        tag = scope_tag(eqn) or outer_scope
        prim = eqn.primitive.name

        subs, is_branches = sub_jaxprs(eqn)
        if subs:
            if prim == "while":
                costs.unknown_trips += 1
            if is_branches:
                # max-cost branch: mirrors hlo_cost's conditional handling
                best, best_macs = None, -1.0
                for sub, _ in subs:
                    probe = ProgramCosts()
                    _walk(sub.jaxpr, probe, 1.0, tag)
                    t = probe.total()
                    key = (t.macs(), t.flops())
                    if best is None or key > best_macs:
                        best, best_macs = probe, key
                if best is not None:
                    costs.unknown_trips += best.unknown_trips
                    for s, c in best.by_scope.items():
                        costs.scope(s or tag).add(c, scale)
            else:
                for sub, trips in subs:
                    _walk(sub.jaxpr, costs, scale * trips, tag)
            continue

        c = costs.scope(tag)
        if prim == "dot_general":
            c.dot_macs += scale * _dot_macs(eqn)
            c.out_bytes += scale * _out_bytes(eqn)
        elif prim == "conv_general_dilated":
            macs, gather = _conv_counts(eqn)
            c.conv_macs += scale * macs
            c.gather_flops += scale * gather
            c.out_bytes += scale * _out_bytes(eqn)
        elif prim == "mul":
            c.mul_flops += scale * _numel(eqn.outvars[0].aval)
            c.out_bytes += scale * _out_bytes(eqn)
        elif prim in _ELEMWISE:
            c.other_flops += scale * _numel(eqn.outvars[0].aval)
            c.out_bytes += scale * _out_bytes(eqn)
        elif prim in _REDUCE:
            c.other_flops += scale * _numel(eqn.invars[0].aval)
            c.out_bytes += scale * _out_bytes(eqn)
        elif prim in _FREE:
            pass
        else:
            # unknown primitive: charge bytes only, never silent compute
            c.out_bytes += scale * _out_bytes(eqn)


def walk_jaxpr(closed: jcore.ClosedJaxpr) -> ProgramCosts:
    costs = ProgramCosts()
    _walk(closed.jaxpr, costs, 1.0, UNATTRIBUTED)
    return costs


def jaxpr_costs(fn, *args, **kwargs) -> ProgramCosts:
    """Trace ``fn`` abstractly and walk the program's cost.

    ``args``/``kwargs`` may be (trees of) arrays or
    :class:`jax.ShapeDtypeStruct` — nothing is executed or compiled.
    """
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return walk_jaxpr(closed)
