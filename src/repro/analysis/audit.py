"""Three-way cost reconciliation: CostModel vs traced jaxpr vs compiled HLO.

The energy claims rest on ``core/cost.py``'s hand-written tables.  This
module checks them against two independent witnesses of the same program:

* **jaxpr** — :mod:`repro.analysis.jaxpr_cost` walks the abstractly traced
  predict program and attributes MACs to the ``cost:`` scopes the models
  declare.  Compared *per layer group* against the table.
* **HLO** — ``launch/hlo_cost.analyze`` re-derives FLOP totals from the
  compiled module.  HLO carries no layer attribution (fusion destroys it),
  so this column reconciles at the *totals* level only.

Semantics (DESIGN.md §Analysis):

* ``None`` ≠ 0 everywhere.  A group priced by only one witness gets
  ``None`` in the other column and **fails** — a layer the table forgot,
  or a scope the table prices but the trace never runs, is exactly the
  bug this audit exists to catch.  A group both witnesses price at zero
  passes trivially.
* Tolerance is *declared per audit* and recorded in the report.  CIFAR
  backbones reconcile to within 1% (the table and the trace count the
  same convolutions); the LM table is an analytic model
  (``core/energy.block_fwd_flops``) and gets 5%.  Divergence above
  tolerance is a verdict, not a warning.
* An ``unknown_trip_count`` from the HLO analyzer poisons the HLO column:
  a guessed while-trip can understate totals by orders of magnitude, so
  the audit fails rather than reconciling against a guess.

Per-group MAC witnesses: conv/fc/block/head table kinds reconcile against
``dot_macs + conv_macs``; the MobileNetV2 depthwise kind (an explicit
broadcast-multiply + sum in ``models/resnet.py``) reconciles against
``mul_flops``; bn/embed kinds carry no MAC-bearing compute and are
excluded from the MAC reconciliation (their movement is still in the
byte totals).
"""
from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.jaxpr_cost import ProgramCosts, jaxpr_costs
from repro.core.config import Experiment
from repro.core.cost import TableCostModel

# MAC-bearing table kinds and which jaxpr counter witnesses them
_DOT_KINDS = ("conv", "fc", "block", "head", "embed")
_MUL_KINDS = ("dw",)

# declared per-task tolerances: the CNN tables count the very convolutions
# the trace runs; the LM table is analytic
TOL_BY_TASK = {"cifar_cnn": 0.01, "lm": 0.05}
# compiled-HLO totals include the fused elementwise selects/pads/clamp
# expansions the walker classifies as data movement; measured divergence is
# 0.02% (resnet110), 0.4% (lm), 2.3% (mobilenetv2 — elementwise-heavy)
HLO_TOL = 0.03

_RESNET_LAYER = re.compile(r"^s(\d+)b(\d+)\.")
_MBV2_LAYER = re.compile(r"^b(\d+)\.")
_LM_BLOCK = re.compile(r"^block\d+\.")


@dataclass(frozen=True)
class LayerRow:
    """One layer group's two-way CostModel-vs-jaxpr reconciliation.

    ``None`` means that witness prices nothing MAC-bearing for the group —
    which is a failure when the other witness does (None ≠ 0).
    """

    group: str
    cost_macs: Optional[float]
    jaxpr_macs: Optional[float]
    abs_diff: Optional[float]
    rel_diff: Optional[float]
    ok: bool

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclass(frozen=True)
class AuditReport:
    """Per-layer + totals verdict for one experiment's predict program."""

    model: str
    task: str
    batch: int
    seq_len: Optional[int]
    tolerance: float
    hlo_tolerance: float
    rows: Tuple[LayerRow, ...]
    cost_total_macs: float
    jaxpr_total_macs: float
    jaxpr_total_flops: float
    jaxpr_unknown_trips: int
    hlo_total_flops: Optional[float]        # None = HLO column not computed
    hlo_rel_diff: Optional[float]
    hlo_unknown_trips: Optional[float]
    passed: bool

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["rows"] = [r.to_dict() for r in self.rows]
        return d

    def failures(self) -> Tuple[str, ...]:
        out = [f"layer {r.group}: cost={r.cost_macs} jaxpr={r.jaxpr_macs} "
               f"rel={r.rel_diff}" for r in self.rows if not r.ok]
        if self.hlo_unknown_trips:
            out.append(f"hlo: {self.hlo_unknown_trips:.0f} unknown-trip "
                       "while loop(s) — totals untrustworthy")
        if self.hlo_rel_diff is not None and self.hlo_rel_diff > self.hlo_tolerance:
            out.append(f"hlo totals: rel={self.hlo_rel_diff:.4f} > "
                       f"{self.hlo_tolerance}")
        return tuple(out)

    def summary(self) -> str:
        lines = [f"cost audit: {self.model} ({self.task}) batch={self.batch}"
                 f" tol={self.tolerance:.0%}"
                 f" -> {'PASS' if self.passed else 'FAIL'}"]
        for r in self.rows:
            fmt = lambda v: "—" if v is None else f"{v:,.0f}"
            rel = "—" if r.rel_diff is None else f"{r.rel_diff:.4%}"
            lines.append(f"  {'ok' if r.ok else 'XX'} {r.group:<12}"
                         f" cost={fmt(r.cost_macs):>16} jaxpr="
                         f"{fmt(r.jaxpr_macs):>16} rel={rel}")
        hlo = ("—" if self.hlo_total_flops is None
               else f"{self.hlo_total_flops:,.0f}"
                    f" (rel={self.hlo_rel_diff:.4%})")
        lines.append(f"  totals: cost_macs={self.cost_total_macs:,.0f}"
                     f" jaxpr_macs={self.jaxpr_total_macs:,.0f}"
                     f" hlo_flops={hlo}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# layer-name -> scope-group mapping (inverse of the models' cost: scopes)
# ---------------------------------------------------------------------------


def _group_of(layer_name: str, task: str) -> str:
    """The ``cost:`` scope group a cost-table layer's compute lands in.

    Mirrors the ``jax.named_scope`` placement in ``models/resnet.py`` /
    ``models/transformer.py``: scanned ResNet stages collapse to
    ``s{i}.rest``, MobileNetV2 depthwise stays its own group inside
    ``b{i}``, LM blocks all run inside the single scanned ``unit`` scope.
    """
    if task == "lm":
        if _LM_BLOCK.match(layer_name):
            return "unit"
        return layer_name                       # embed / head
    m = _RESNET_LAYER.match(layer_name)
    if m:
        return (f"s{m.group(1)}.trans" if int(m.group(2)) == 0
                else f"s{m.group(1)}.rest")
    m = _MBV2_LAYER.match(layer_name)
    if m:
        return layer_name if layer_name.endswith(".dw") else f"b{m.group(1)}"
    if layer_name in ("stem_bn",):
        return "stem"
    if layer_name in ("head_bn",):
        return "head"
    return layer_name                           # stem / head / fc


def _table_group_macs(cost: TableCostModel, task: str
                      ) -> Dict[str, Tuple[float, str]]:
    """group -> (MAC total, witness kind: 'dot'|'mul') over MAC-bearing
    layers.  bn layers are excluded (no contraction to witness)."""
    groups: Dict[str, Tuple[float, str]] = {}
    for layer in cost.layers:
        if layer.kind in _MUL_KINDS:
            witness = "mul"
        elif layer.kind in _DOT_KINDS:
            witness = "dot"
        else:
            continue
        g = _group_of(layer.name, task)
        macs, w = groups.get(g, (0.0, witness))
        groups[g] = (macs + layer.macs, w)
    return groups


def _trace_group_macs(pc: ProgramCosts, witness_of: Dict[str, str],
                      batch: int) -> Dict[str, float]:
    """group -> per-example MACs from the walked trace.  Groups the table
    doesn't know get the dot witness (so stray compute still surfaces)."""
    out: Dict[str, float] = {}
    for scope, c in pc.by_scope.items():
        w = witness_of.get(scope, "dot")
        macs = c.mul_flops if w == "mul" else c.macs()
        if macs > 0 or scope in witness_of:
            out[scope] = macs / batch
    return out


# ---------------------------------------------------------------------------
# the audit
# ---------------------------------------------------------------------------


def _abstract_inputs(exp: Experiment, batch: int):
    """(params, model_state, batch) ShapeDtypeStruct trees for the task's
    predict program — nothing is allocated or executed."""
    from repro.tasks import get_task
    task = get_task(exp.task)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params, mstate = jax.eval_shape(lambda k: task.init(k, exp), key)
    if exp.task == "lm":
        data = {"tokens": jax.ShapeDtypeStruct((batch, exp.train.seq_len),
                                               jnp.int32)}
    else:
        data = {"image": jax.ShapeDtypeStruct((batch, 32, 32, 3),
                                              jnp.float32),
                "label": jax.ShapeDtypeStruct((batch,), jnp.int32)}
    return task.make_predict(exp), params, mstate, data


def audit_experiment(exp: Experiment, batch: int = 8,
                     tolerance: Optional[float] = None,
                     with_hlo: bool = True) -> AuditReport:
    """Reconcile one experiment's CostModel against its traced predict
    program (per layer group) and its compiled HLO (totals)."""
    from repro.launch import hlo_cost
    from repro.tasks import cost_model

    tol = TOL_BY_TASK.get(exp.task, 0.05) if tolerance is None else tolerance
    cost = cost_model(exp)
    predict, params, mstate, data = _abstract_inputs(exp, batch)
    pc = jaxpr_costs(predict, params, mstate, data)

    table = _table_group_macs(cost, exp.task)
    witness_of = {g: w for g, (_, w) in table.items()}
    trace = _trace_group_macs(pc, witness_of, batch)

    rows = []
    for g in sorted(set(table) | set(trace)):
        cm = table.get(g, (None,))[0]
        jm = trace.get(g)
        if cm is None or jm is None:
            both_zero = (cm in (None, 0.0)) and (jm in (None, 0.0))
            rows.append(LayerRow(g, cm, jm, None, None, ok=both_zero))
            continue
        abs_d = abs(cm - jm)
        denom = max(cm, jm)
        rel = abs_d / denom if denom else 0.0
        rows.append(LayerRow(g, cm, jm, abs_d, rel, ok=rel <= tol))

    tot = pc.total()
    jaxpr_macs = sum(v for v in trace.values())
    cost_macs = sum(m for m, _ in table.values())

    hlo_flops = hlo_rel = hlo_unknown = None
    if with_hlo:
        hlo = jax.jit(predict).lower(params, mstate, data).compile().as_text()
        totals = hlo_cost.analyze(hlo)
        hlo_flops = totals["flops"]
        hlo_unknown = totals["unknown_trip_count"]
        denom = max(hlo_flops, tot.flops())
        hlo_rel = abs(hlo_flops - tot.flops()) / denom if denom else 0.0

    passed = all(r.ok for r in rows) and pc.unknown_trips == 0
    if with_hlo:
        passed = passed and not hlo_unknown and hlo_rel <= HLO_TOL

    return AuditReport(
        model=exp.model.name, task=exp.task, batch=batch,
        seq_len=exp.train.seq_len if exp.task == "lm" else None,
        tolerance=tol, hlo_tolerance=HLO_TOL, rows=tuple(rows),
        cost_total_macs=cost_macs, jaxpr_total_macs=jaxpr_macs,
        jaxpr_total_flops=tot.flops() / batch,
        jaxpr_unknown_trips=pc.unknown_trips,
        hlo_total_flops=hlo_flops, hlo_rel_diff=hlo_rel,
        hlo_unknown_trips=hlo_unknown, passed=passed)


def audit_totals(exp: Experiment, batch: int = 8,
                 with_hlo: bool = True) -> Dict[str, Any]:
    """Totals-level view of :func:`audit_experiment` (the BENCH record)."""
    rep = audit_experiment(exp, batch=batch, with_hlo=with_hlo)
    return {"model": rep.model, "task": rep.task,
            "cost_total_macs": rep.cost_total_macs,
            "jaxpr_total_macs": rep.jaxpr_total_macs,
            "hlo_total_flops": rep.hlo_total_flops,
            "hlo_rel_diff": rep.hlo_rel_diff,
            "passed": rep.passed, "failures": list(rep.failures())}


# verdict cache for EnergyReport.validated_against_hlo: the audit traces and
# compiles the predict program, so the ledger must not re-run it per report
# (the Table 3 sweep prices the same backbone three times)
_VERDICT_CACHE: Dict[Tuple[str, str, int, Optional[int]], bool] = {}


def validated_verdict(exp: Experiment, batch: int = 4) -> bool:
    """Cached pass/fail of the three-way audit for this experiment's
    architecture (PSG/SLU operating points don't change the eval program,
    so the verdict is keyed on model identity, not the full config)."""
    key = (exp.model.name, exp.task, batch,
           exp.train.seq_len if exp.task == "lm" else None)
    if key not in _VERDICT_CACHE:
        _VERDICT_CACHE[key] = audit_experiment(exp, batch=batch).passed
    return _VERDICT_CACHE[key]
