"""Static Pallas kernel linter.

Lints every kernel registered through ``kernels/dispatch.shipped_kernels``
without executing anything: each kernel is traced abstractly
(``jax.make_jaxpr``), its ``pallas_call`` equations are located, and four
rules are checked against the grid mapping and the kernel jaxpr
(DESIGN.md §Analysis lists the rules and their rationale):

* ``vmem-budget`` — double-buffered input/output blocks plus scratch must
  fit the per-core VMEM budget (16 MiB).
* ``tile-alignment`` — every block dimension must either span the full
  array extent or align to the MXU/VPU lattice (last dim % 128,
  second-to-last % 8).  Sub-tile blocks (scalar thresholds, per-tile
  statistics smaller than one 8x128 tile) are padding-dominated either way
  and exempt.
* ``coverage`` / ``oob-index`` — output BlockSpec index maps, enumerated
  over the full grid, must write every tile of the output lattice exactly
  (an uncovered tile is silent garbage memory) and no input/output index
  map may address a block outside its array.
* ``accumulator-discipline`` — a kernel with VMEM scratch accumulators and
  a reduction grid axis (an axis no output index map depends on) must gate
  accumulator init on ``program_id(axis) == 0`` and the finish/writeback on
  ``program_id(axis) == grid[axis] - 1`` via ``pl.when``; otherwise the
  revisited output tile reads stale or unwritten accumulator state.

``lint_shipped()`` is the CI entry point: it returns all findings across
the shipped-kernel registry, and the test suite asserts the list is empty.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Dict, List, Sequence, Set, Tuple

import jax
import numpy as np
from jax import core as jcore

VMEM_BUDGET_BYTES = 16 * 1024 * 1024
SUBLANE, LANE = 8, 128
# blocks smaller than one MXU tile (scalars, per-tile stats) are exempt
# from alignment: the compiler pads them whatever we do.
_SUBTILE_NUMEL = SUBLANE * LANE
# coverage enumeration walks the full grid; past this it is skipped (no
# shipped kernel is near it — a representative registry shape should keep
# grids small on purpose).
MAX_GRID_POINTS = 8192


@dataclass(frozen=True)
class LintFinding:
    """One rule violation in one kernel."""

    kernel: str
    rule: str
    message: str

    def __str__(self) -> str:
        return f"[{self.rule}] {self.kernel}: {self.message}"


def _kernel_jaxpr(eqn):
    kj = eqn.params["jaxpr"]
    return kj.jaxpr if isinstance(kj, jcore.ClosedJaxpr) else kj


def _block_shape(bm) -> Tuple[int, ...]:
    return tuple(1 if d is None else int(d) for d in bm.block_shape)


def _eval_index_map(cj: jcore.ClosedJaxpr, point: Sequence[int]
                    ) -> Tuple[int, ...]:
    outs = jcore.eval_jaxpr(cj.jaxpr, cj.consts,
                            *[np.int32(p) for p in point])
    return tuple(int(o) for o in outs)


def _find_pallas_eqns(jaxpr) -> List:
    """All pallas_call equations in a jaxpr, recursing through call eqns."""
    found = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            found.append(eqn)
            continue
        for val in eqn.params.values():
            subs = val if isinstance(val, (list, tuple)) else (val,)
            for sub in subs:
                if isinstance(sub, jcore.ClosedJaxpr):
                    found.extend(_find_pallas_eqns(sub.jaxpr))
                elif isinstance(sub, jcore.Jaxpr):
                    found.extend(_find_pallas_eqns(sub))
    return found


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------


def _lint_vmem(name: str, gm, kj) -> List[LintFinding]:
    block_bytes = 0.0
    for bm in gm.block_mappings:
        dt = np.dtype(bm.array_shape_dtype.dtype)
        block_bytes += math.prod(_block_shape(bm)) * dt.itemsize
    scratch_bytes = 0.0
    n_io = gm.num_inputs + gm.num_outputs
    for v in kj.invars[n_io:]:
        aval = v.aval
        try:
            itemsize = np.dtype(aval.dtype).itemsize
        except TypeError:
            itemsize = 16
        scratch_bytes += math.prod(aval.shape) * itemsize
    vmem = 2.0 * block_bytes + scratch_bytes       # 2x: double buffering
    if vmem > VMEM_BUDGET_BYTES:
        return [LintFinding(name, "vmem-budget",
                            f"{vmem / 2**20:.1f} MiB (2x blocks + scratch) "
                            f"exceeds the {VMEM_BUDGET_BYTES // 2**20} MiB "
                            "VMEM budget")]
    return []


def _lint_alignment(name: str, gm) -> List[LintFinding]:
    findings = []
    for pos, bm in enumerate(gm.block_mappings):
        kind = "in" if pos < gm.num_inputs else "out"
        bs = _block_shape(bm)
        full = tuple(int(d) for d in bm.array_shape_dtype.shape)
        if math.prod(bs) < _SUBTILE_NUMEL:
            continue
        bad = []
        if bs[-1] % LANE != 0 and bs[-1] != full[-1]:
            bad.append(f"last dim {bs[-1]} (want %{LANE} or full {full[-1]})")
        if len(bs) >= 2 and bs[-2] % SUBLANE != 0 and bs[-2] != full[-2]:
            bad.append(f"dim -2 {bs[-2]} (want %{SUBLANE} or full {full[-2]})")
        if bad:
            findings.append(LintFinding(
                name, "tile-alignment",
                f"{kind}[{pos if kind == 'in' else pos - gm.num_inputs}] "
                f"block {bs} of {full}: " + "; ".join(bad)))
    return findings


def _lint_coverage(name: str, gm) -> List[LintFinding]:
    grid = tuple(int(g) for g in gm.grid)
    if not grid or math.prod(grid) > MAX_GRID_POINTS:
        return []
    findings = []
    points = list(itertools.product(*[range(g) for g in grid]))
    for pos, bm in enumerate(gm.block_mappings):
        is_out = pos >= gm.num_inputs
        opos = pos - gm.num_inputs
        cj = bm.index_map_jaxpr
        if len(cj.jaxpr.invars) != len(grid):
            continue                       # scalar-prefetch args: skip
        bs = _block_shape(bm)
        full = tuple(int(d) for d in bm.array_shape_dtype.shape)
        nblocks = tuple(-(-f // b) for f, b in zip(full, bs))
        covered: Set[Tuple[int, ...]] = set()
        oob_reported = False
        for pt in points:
            idx = _eval_index_map(cj, pt)
            if not oob_reported and any(
                    i < 0 or i >= n for i, n in zip(idx, nblocks)):
                findings.append(LintFinding(
                    name, "oob-index",
                    f"{'out' if is_out else 'in'}"
                    f"[{opos if is_out else pos}] index map sends grid point "
                    f"{pt} to block {idx}, outside the "
                    f"{nblocks} block lattice of {full}"))
                oob_reported = True
            covered.add(idx)
        if is_out:
            lattice = set(itertools.product(*[range(n) for n in nblocks]))
            missing = len(lattice - covered)
            if missing == 0:
                continue
            findings.append(LintFinding(
                name, "coverage",
                f"out[{opos}] index map covers {len(covered)} of "
                f"{math.prod(nblocks)} output tiles over the full grid "
                f"({missing} tiles never written)"))
    return findings


def _output_depends_on_axis(gm, grid: Tuple[int, ...], axis: int) -> bool:
    base = [0] * len(grid)
    for bm in gm.block_mappings[gm.num_inputs:]:
        cj = bm.index_map_jaxpr
        if len(cj.jaxpr.invars) != len(grid):
            return True                    # unknown signature: be permissive
        lo = _eval_index_map(cj, base)
        hi_pt = list(base)
        hi_pt[axis] = grid[axis] - 1
        if _eval_index_map(cj, hi_pt) != lo:
            return True
    return False


def _lint_accumulators(name: str, gm, kj) -> List[LintFinding]:
    grid = tuple(int(g) for g in gm.grid)
    if gm.num_scratch_operands == 0 or not grid:
        return []
    red_axes = [a for a in range(len(grid))
                if grid[a] > 1 and not _output_depends_on_axis(gm, grid, a)]
    findings = []
    for axis in red_axes:
        # program_id(axis) vars at the kernel's top level
        pid_vars = {e.outvars[0] for e in kj.eqns
                    if e.primitive.name == "program_id"
                    and int(e.params.get("axis", -1)) == axis}
        # eq(program_id, literal) guards, following bool->int32 converts
        guards: Dict[int, Set] = {0: set(), grid[axis] - 1: set()}
        aliases: Dict = {}
        for e in kj.eqns:
            if e.primitive.name == "eq":
                lit, pid = None, None
                for iv in e.invars:
                    if isinstance(iv, jcore.Literal):
                        try:
                            lit = int(iv.val)
                        except (TypeError, ValueError):
                            lit = None
                    elif iv in pid_vars:
                        pid = iv
                if pid is not None and lit in guards:
                    guards[lit].add(e.outvars[0])
            elif e.primitive.name == "convert_element_type" \
                    and not isinstance(e.invars[0], jcore.Literal):
                aliases[e.outvars[0]] = e.invars[0]
        gated = {0: False, grid[axis] - 1: False}
        for e in kj.eqns:
            if e.primitive.name != "cond" or not e.invars:
                continue
            pred = e.invars[0]
            pred = aliases.get(pred, pred)
            for lit, vars_ in guards.items():
                if pred in vars_:
                    gated[lit] = True
        if not gated[0]:
            findings.append(LintFinding(
                name, "accumulator-discipline",
                f"reduction axis {axis} (grid {grid}): no pl.when-gated "
                f"init on program_id({axis}) == 0 — the first grid step "
                "reads uninitialized scratch"))
        if not gated[grid[axis] - 1]:
            findings.append(LintFinding(
                name, "accumulator-discipline",
                f"reduction axis {axis} (grid {grid}): no pl.when-gated "
                f"finish on program_id({axis}) == {grid[axis] - 1} — the "
                "output tile is written before the reduction completes"))
    return findings


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def lint_jaxpr(closed: jcore.ClosedJaxpr, name: str = "kernel"
               ) -> List[LintFinding]:
    """Lint every pallas_call inside an already-traced program."""
    findings: List[LintFinding] = []
    for eqn in _find_pallas_eqns(closed.jaxpr):
        gm = eqn.params["grid_mapping"]
        kj = _kernel_jaxpr(eqn)
        findings += _lint_vmem(name, gm, kj)
        findings += _lint_alignment(name, gm)
        findings += _lint_coverage(name, gm)
        findings += _lint_accumulators(name, gm, kj)
    return findings


def lint_kernel(fn, *args, name: str = "kernel") -> List[LintFinding]:
    """Trace ``fn`` abstractly (ShapeDtypeStruct args allowed) and lint it."""
    return lint_jaxpr(jax.make_jaxpr(fn)(*args), name=name)


def lint_shipped() -> List[LintFinding]:
    """Lint the whole shipped-kernel registry (CI gate; [] = clean)."""
    from repro.kernels.dispatch import shipped_kernels

    findings: List[LintFinding] = []
    for name, (fn, args) in shipped_kernels().items():
        findings += lint_kernel(fn, *args, name=name)
    return findings
