"""Repository convention linter (AST-based, no imports executed).

Three conventions this repo's architecture depends on (DESIGN.md
§Dispatch, §Analysis), enforced statically over ``src/repro``:

* ``pallas-outside-kernels`` — only modules under ``kernels/`` may call
  ``pl.pallas_call``.  Everything else goes through the dispatch layer
  (``kernels/dispatch.py``), which is what keeps backend selection in one
  place and keeps the kernel linter's shipped-kernel registry exhaustive.
* ``env-read`` — no module may read ``REPRO_*`` environment variables
  except the single import-time read of ``REPRO_KERNEL_BACKEND`` in
  ``kernels/dispatch.py``.  The seed repo's scattered trace-time env reads
  (``REPRO_PALLAS_COMPILE``, ``REPRO_PSG_INT8_GATHER``) were retired in the
  dispatch refactor precisely because an env read inside traced code bakes
  into whichever jit cache entry traced first.

* ``host-sync`` — device→host synchronization (``jax.device_get``,
  ``.block_until_ready()``, ``np.asarray`` on device values) is confined
  to ``training/`` (plus the repo-level ``benchmarks/``/``examples/``
  trees, which are host drivers by definition).  The chunked loop's whole
  throughput story is "one sync per chunk boundary" (DESIGN.md §Loop); a
  stray ``device_get`` in a model or kernel module reintroduces the
  per-step stall the hot-loop lint exists to prevent.  Modules that are
  host-side *by design* are allowlisted with a justification string
  (same convention as ``analysis/precision_lint.ALLOWLIST``).

* ``swallowed-exception`` — bare ``except:`` and
  ``except Exception/BaseException`` with a pass-only body are forbidden.
  The fault-tolerance layer's correctness rests on errors *surfacing*: a
  checkpoint write that fails silently resumes from a stale step, a data
  producer that dies silently hangs the loop (both were live bugs before
  the FT PR — DESIGN.md §Fault-tolerance).  A module that must swallow
  broadly is allowlisted with a justification, same convention as
  ``host-sync``.

Run as a module (``python -m repro.analysis.repo_lint``) it exits nonzero
on any finding — that is the CI hook.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

# files (relative to the src root, posix separators) allowed to call
# pl.pallas_call
_PALLAS_ALLOWED_PREFIX = "repro/kernels/"
# the one sanctioned REPRO_* env read: (file, variable)
_ENV_ALLOWED = {("repro/kernels/dispatch.py", "REPRO_KERNEL_BACKEND")}
# trees where host syncs are the module's job, not a hazard
_HOST_SYNC_ALLOWED_PREFIXES = ("repro/training/", "benchmarks/", "examples/")
# file -> justification: modules that are host-side by design.  A new
# entry REQUIRES a justification string (enforced by lint_source) — an
# exception without a recorded why is how conventions rot.
_HOST_SYNC_ALLOWED: Dict[str, str] = {
    "repro/core/smd.py":
        "counter-based SMD decides drops ON the host so a dropped step "
        "never reaches the device — the paper's zero-overhead property "
        "(DESIGN.md §Loop)",
    "repro/ft/checkpoint.py":
        "checkpoint save/restore is host I/O; np.asarray is the "
        "device->host copy at the serialization boundary",
    "repro/data/synthetic.py":
        "synthetic data generation is host-side numpy by design — batches "
        "reach the device in one device_put per chunk",
    "repro/serving/engine.py":
        "single-host wave-batching demo decodes on the host; the ROADMAP "
        "open item rebuilds it on the chunk compiler",
    "repro/ft/faults.py":
        "fault injection rewrites on-disk checkpoints with host numpy by "
        "design — it never touches device values in the hot loop",
}

# file -> justification: modules allowed to swallow exceptions broadly.
# Same contract as _HOST_SYNC_ALLOWED: an entry REQUIRES a justification
# string — silent error-eating without a recorded why is exactly the bug
# class the rule exists to kill (the async checkpoint writer and the data
# producer thread both shipped with it).
_SWALLOW_ALLOWED: Dict[str, str] = {}


@dataclass(frozen=True)
class RepoFinding:
    path: str          # src-root-relative, posix
    line: int
    rule: str          # "pallas-outside-kernels" | "env-read" | "host-sync"
                       # | "swallowed-exception"
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _attr_chain(node: ast.AST) -> Optional[str]:
    """Dotted name of an attribute/name chain (``os.environ.get``), or None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _const_str(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def _env_var_of(node: ast.AST) -> Optional[Tuple[str, int]]:
    """(REPRO_* name, lineno) if this node reads such an env var."""
    if isinstance(node, ast.Call):
        chain = _attr_chain(node.func) or ""
        if chain.endswith("os.getenv") or chain == "getenv" \
                or chain.endswith("environ.get"):
            if node.args:
                name = _const_str(node.args[0])
                if name and name.startswith("REPRO_"):
                    return name, node.lineno
    if isinstance(node, ast.Subscript):
        chain = _attr_chain(node.value) or ""
        if chain.endswith("os.environ") or chain == "environ":
            name = _const_str(node.slice)
            if name and name.startswith("REPRO_"):
                return name, node.lineno
    return None


def _host_sync_of(node: ast.AST) -> Optional[Tuple[str, int]]:
    """(description, lineno) if this node is a device→host sync call."""
    if not isinstance(node, ast.Call):
        return None
    if isinstance(node.func, ast.Attribute) \
            and node.func.attr == "block_until_ready":
        return ".block_until_ready()", node.lineno
    chain = _attr_chain(node.func) or ""
    if chain == "jax.device_get" or chain.endswith(".device_get") \
            or chain == "device_get":
        return "jax.device_get", node.lineno
    if chain in ("np.asarray", "numpy.asarray", "onp.asarray",
                 "np.array", "numpy.array"):
        return chain, node.lineno
    return None


def check_host_sync_allowlist(
        allowed: Optional[Dict[str, str]] = None) -> None:
    """Every host-sync allowlist entry must carry a justification."""
    entries = _HOST_SYNC_ALLOWED if allowed is None else allowed
    for path, why in entries.items():
        if not (isinstance(why, str) and why.strip()):
            raise ValueError(
                f"host-sync allowlist entry {path!r} has no justification "
                "— record why this module is host-side by design")


def check_swallow_allowlist(
        allowed: Optional[Dict[str, str]] = None) -> None:
    """Every swallowed-exception allowlist entry must carry a justification."""
    entries = _SWALLOW_ALLOWED if allowed is None else allowed
    for path, why in entries.items():
        if not (isinstance(why, str) and why.strip()):
            raise ValueError(
                f"swallowed-exception allowlist entry {path!r} has no "
                "justification — record why this module must swallow "
                "exceptions broadly")


_BROAD_EXC = ("Exception", "BaseException")


def _swallow_of(node: ast.AST) -> Optional[Tuple[str, int]]:
    """(description, lineno) if this except handler swallows broadly.

    Flags bare ``except:`` always, and ``except Exception/BaseException``
    (bound or not, alone or in a tuple) whose body does nothing but
    ``pass``/``...`` — the handler shapes under which the async-writer and
    producer-thread bugs hid.
    """
    if not isinstance(node, ast.ExceptHandler):
        return None
    if node.type is None:
        return "bare except:", node.lineno
    types = node.type.elts if isinstance(node.type, ast.Tuple) \
        else [node.type]
    names = [t.id if isinstance(t, ast.Name) else
             (t.attr if isinstance(t, ast.Attribute) else "")
             for t in types]
    broad = next((n for n in names if n in _BROAD_EXC), None)
    if broad is None:
        return None
    body_is_noop = all(
        isinstance(st, ast.Pass)
        or (isinstance(st, ast.Expr) and isinstance(st.value, ast.Constant))
        for st in node.body)
    if body_is_noop:
        return f"except {broad}: pass", node.lineno
    return None


def lint_source(src: str, relpath: str) -> List[RepoFinding]:
    """Lint one module's source text (``relpath`` is src-root-relative)."""
    check_host_sync_allowlist()
    check_swallow_allowlist()
    findings: List[RepoFinding] = []
    tree = ast.parse(src, filename=relpath)
    in_kernels = relpath.startswith(_PALLAS_ALLOWED_PREFIX)
    host_ok = (relpath.startswith(_HOST_SYNC_ALLOWED_PREFIXES)
               or relpath in _HOST_SYNC_ALLOWED)
    swallow_ok = relpath in _SWALLOW_ALLOWED
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr == "pallas_call" \
                and not in_kernels:
            findings.append(RepoFinding(
                relpath, node.lineno, "pallas-outside-kernels",
                "pl.pallas_call outside kernels/ — route through "
                "repro.kernels.dispatch"))
        sync = _host_sync_of(node)
        if sync is not None and not host_ok:
            what, line = sync
            findings.append(RepoFinding(
                relpath, line, "host-sync",
                f"{what} outside training/ — device->host syncs belong to "
                "the loop boundary (one per chunk); host-side-by-design "
                "modules need a justified _HOST_SYNC_ALLOWED entry"))
        swallow = _swallow_of(node)
        if swallow is not None and not swallow_ok:
            what, line = swallow
            findings.append(RepoFinding(
                relpath, line, "swallowed-exception",
                f"{what} — errors must surface (a silent failure here is "
                "the async-writer/producer-thread bug class); catch the "
                "specific exception or add a justified _SWALLOW_ALLOWED "
                "entry"))
        env = _env_var_of(node)
        if env is not None:
            name, line = env
            if (relpath, name) not in _ENV_ALLOWED:
                findings.append(RepoFinding(
                    relpath, line, "env-read",
                    f"reads {name} — environment selection belongs to the "
                    "single import-time read in kernels/dispatch.py"))
    return findings


def _src_root() -> str:
    # .../src/repro/analysis/repo_lint.py -> .../src
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


def lint_repo(src_root: Optional[str] = None) -> List[RepoFinding]:
    """Lint every ``.py`` under ``<src_root>/repro``; [] means clean."""
    root = src_root or _src_root()
    findings: List[RepoFinding] = []
    pkg = os.path.join(root, "repro")
    for dirpath, _dirnames, filenames in os.walk(pkg):
        for fname in sorted(filenames):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            with open(path, "r", encoding="utf-8") as f:
                findings.extend(lint_source(f.read(), rel))
    return sorted(findings, key=lambda f: (f.path, f.line))


def main() -> int:
    findings = lint_repo()
    for f in findings:
        print(f)
    print(f"repo lint: {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
