"""Repository convention linter (AST-based, no imports executed).

Two conventions this repo's architecture depends on (DESIGN.md §Dispatch,
§Analysis), enforced statically over ``src/repro``:

* ``pallas-outside-kernels`` — only modules under ``kernels/`` may call
  ``pl.pallas_call``.  Everything else goes through the dispatch layer
  (``kernels/dispatch.py``), which is what keeps backend selection in one
  place and keeps the kernel linter's shipped-kernel registry exhaustive.
* ``env-read`` — no module may read ``REPRO_*`` environment variables
  except the single import-time read of ``REPRO_KERNEL_BACKEND`` in
  ``kernels/dispatch.py``.  The seed repo's scattered trace-time env reads
  (``REPRO_PALLAS_COMPILE``, ``REPRO_PSG_INT8_GATHER``) were retired in the
  dispatch refactor precisely because an env read inside traced code bakes
  into whichever jit cache entry traced first.

Run as a module (``python -m repro.analysis.repo_lint``) it exits nonzero
on any finding — that is the CI hook.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass
from typing import List, Optional, Tuple

# files (relative to the src root, posix separators) allowed to call
# pl.pallas_call
_PALLAS_ALLOWED_PREFIX = "repro/kernels/"
# the one sanctioned REPRO_* env read: (file, variable)
_ENV_ALLOWED = {("repro/kernels/dispatch.py", "REPRO_KERNEL_BACKEND")}


@dataclass(frozen=True)
class RepoFinding:
    path: str          # src-root-relative, posix
    line: int
    rule: str          # "pallas-outside-kernels" | "env-read"
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _attr_chain(node: ast.AST) -> Optional[str]:
    """Dotted name of an attribute/name chain (``os.environ.get``), or None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _const_str(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def _env_var_of(node: ast.AST) -> Optional[Tuple[str, int]]:
    """(REPRO_* name, lineno) if this node reads such an env var."""
    if isinstance(node, ast.Call):
        chain = _attr_chain(node.func) or ""
        if chain.endswith("os.getenv") or chain == "getenv" \
                or chain.endswith("environ.get"):
            if node.args:
                name = _const_str(node.args[0])
                if name and name.startswith("REPRO_"):
                    return name, node.lineno
    if isinstance(node, ast.Subscript):
        chain = _attr_chain(node.value) or ""
        if chain.endswith("os.environ") or chain == "environ":
            name = _const_str(node.slice)
            if name and name.startswith("REPRO_"):
                return name, node.lineno
    return None


def lint_source(src: str, relpath: str) -> List[RepoFinding]:
    """Lint one module's source text (``relpath`` is src-root-relative)."""
    findings: List[RepoFinding] = []
    tree = ast.parse(src, filename=relpath)
    in_kernels = relpath.startswith(_PALLAS_ALLOWED_PREFIX)
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr == "pallas_call" \
                and not in_kernels:
            findings.append(RepoFinding(
                relpath, node.lineno, "pallas-outside-kernels",
                "pl.pallas_call outside kernels/ — route through "
                "repro.kernels.dispatch"))
        env = _env_var_of(node)
        if env is not None:
            name, line = env
            if (relpath, name) not in _ENV_ALLOWED:
                findings.append(RepoFinding(
                    relpath, line, "env-read",
                    f"reads {name} — environment selection belongs to the "
                    "single import-time read in kernels/dispatch.py"))
    return findings


def _src_root() -> str:
    # .../src/repro/analysis/repo_lint.py -> .../src
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


def lint_repo(src_root: Optional[str] = None) -> List[RepoFinding]:
    """Lint every ``.py`` under ``<src_root>/repro``; [] means clean."""
    root = src_root or _src_root()
    findings: List[RepoFinding] = []
    pkg = os.path.join(root, "repro")
    for dirpath, _dirnames, filenames in os.walk(pkg):
        for fname in sorted(filenames):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            with open(path, "r", encoding="utf-8") as f:
                findings.extend(lint_source(f.read(), rel))
    return sorted(findings, key=lambda f: (f.path, f.line))


def main() -> int:
    findings = lint_repo()
    for f in findings:
        print(f)
    print(f"repo lint: {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
