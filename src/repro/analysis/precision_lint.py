"""Precision lint: the PR 7 bug class, caught statically (DESIGN.md
§Analysis).

PR 7 found by hand that the conv dx backward accumulated its k² tap sums
in ``gq.dtype`` — bf16 cotangents silently collapsed.  This pass makes
that class of bug a CI failure instead of a review catch: it runs the
:mod:`repro.analysis.dataflow` engine over every surface the repo ships
and flags **any reduction whose accumulator is narrower than 32 bits
while its operands descend from narrow (bf16/fp16/fp8/int8/…) values** —
including Pallas scratch accumulators, scan-carry running sums, unrolled
``acc += tap`` chains, and ``x.at[...].add`` scatter loops.

Lint surfaces:

* every ``shipped_kernels()`` registry entry, traced **twice** — once with
  its registered operand dtypes and once with every f32 operand swapped to
  bf16.  The swap is the regression probe: an accumulator that *follows*
  the operand dtype (``jnp.zeros(..., x.dtype)`` — the PR 7 pattern) is
  invisible at f32 and flagrant at bf16.
* both CNN backbones' traced forward+backward train step (the real
  program PSG/SLU/SMD run in), via abstract ``init_train_state`` +
  ``make_train_step`` tracing — nothing executes.
* the declared accumulator-dtype intent: ``dispatch.kernel_acc_dtypes()``
  records what each kernel *means* to accumulate in; any float-dtype
  ``ref-accum`` site that disagrees, or a shipped kernel with no declared
  intent, is a finding even when no narrow operand reaches it today.

Allowlist convention: ``ALLOWLIST`` maps a site-substring pattern to a
**non-empty justification string** (e.g. PSG's intentional int8 sign
votes, should one ever accumulate).  An empty justification raises — an
allowlist entry without a recorded *why* is how intentional exceptions
rot into unexamined ones.  Run as a module
(``python -m repro.analysis.precision_lint``) it exits nonzero on any
unallowlisted finding — that is the CI hook.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.dataflow import (DataflowResult, ReductionSite, analyze,
                                     acc_is_narrow)

# site-substring pattern -> justification.  Empty on main: every shipped
# surface accumulates in f32.  (Example shape, should a narrow accumulator
# ever be intentional:
#   "psg_grad_w_pallas/pallas": "int8 sign votes are saturating counters,"
#                               " not partial sums — Eq. (2) needs signs")
ALLOWLIST: Dict[str, str] = {}


@dataclass(frozen=True)
class PrecisionFinding:
    surface: str        # which lint surface produced it
    rule: str           # "narrow-accumulator" | "acc-intent" | "acc-intent-missing"
    site: str
    kind: str
    acc_dtype: str
    narrow_operands: Tuple[str, ...]
    message: str

    def __str__(self) -> str:
        return (f"{self.surface}: [{self.rule}] {self.site} "
                f"({self.kind}, acc={self.acc_dtype}): {self.message}")


def check_allowlist(allowlist: Dict[str, str]) -> None:
    """Every allowlist entry must carry a non-empty justification."""
    for pattern, why in allowlist.items():
        if not (isinstance(why, str) and why.strip()):
            raise ValueError(
                f"precision allowlist entry {pattern!r} has no "
                "justification — record why the narrow accumulator is "
                "intentional")


def _allowlisted(site: str, allowlist: Dict[str, str]) -> Optional[str]:
    for pattern in allowlist:
        if pattern in site:
            return pattern
    return None


def split_findings(findings: Sequence[PrecisionFinding],
                   allowlist: Optional[Dict[str, str]] = None
                   ) -> Tuple[List[PrecisionFinding], List[PrecisionFinding]]:
    """(unallowlisted, allowlisted) under a justified allowlist."""
    al = ALLOWLIST if allowlist is None else allowlist
    check_allowlist(al)
    out, suppressed = [], []
    for f in findings:
        (suppressed if _allowlisted(f.site, al) else out).append(f)
    return out, suppressed


def _hazard_findings(surface: str, result: DataflowResult
                     ) -> List[PrecisionFinding]:
    out = []
    for s in result.hazards():
        via = f" (narrow via {s.origin})" if s.origin else ""
        out.append(PrecisionFinding(
            surface=surface, rule="narrow-accumulator", site=s.site,
            kind=s.kind, acc_dtype=s.acc_dtype,
            narrow_operands=s.narrow_operands,
            message=f"accumulates {','.join(s.narrow_operands)}-descended "
                    f"operands in {s.acc_dtype}{via} — force a >=32-bit "
                    "accumulator (the PR 7 bug class)"))
    return out


def narrow_variant(args):
    """The registry entry's args with every f32 array swapped to bf16 —
    the probe that exposes dtype-following accumulators."""
    def swap(s):
        if getattr(s, "dtype", None) == jnp.float32 and s.shape:
            return jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        return s
    return jax.tree.map(swap, args)


def _float_ref_accums(result: DataflowResult) -> List[ReductionSite]:
    def is_float(name: str) -> bool:
        return name.startswith(("float", "bfloat"))
    return [s for s in result.sites
            if s.kind == "ref-accum" and is_float(s.acc_dtype)]


def lint_kernels() -> List[PrecisionFinding]:
    """Dataflow-lint every shipped kernel (registered + bf16-narrowed) and
    cross-check detected ref accumulators against the declared intent."""
    from repro.kernels.dispatch import kernel_acc_dtypes, shipped_kernels

    intents = kernel_acc_dtypes()
    findings: List[PrecisionFinding] = []
    for name, (fn, args) in shipped_kernels().items():
        base = name.split("[")[0]
        if base not in intents:
            findings.append(PrecisionFinding(
                surface=f"kernel:{name}", rule="acc-intent-missing",
                site=name, kind="registry", acc_dtype="?",
                narrow_operands=(),
                message="shipped kernel has no declared accumulator dtype "
                        "in dispatch.kernel_acc_dtypes()"))
            continue
        for variant, a in (("", args), ("~bf16", narrow_variant(args))):
            surface = f"kernel:{name}{variant}"
            res = analyze(fn, *a, name=surface)
            findings.extend(_hazard_findings(surface, res))
            if not variant:     # intent is checked on the shipped dtypes
                for s in _float_ref_accums(res):
                    if s.acc_dtype != intents[base]:
                        findings.append(PrecisionFinding(
                            surface=surface, rule="acc-intent",
                            site=s.site, kind=s.kind,
                            acc_dtype=s.acc_dtype,
                            narrow_operands=s.narrow_operands,
                            message=f"ref accumulator is {s.acc_dtype} but "
                                    f"dispatch declares {intents[base]}"))
    return findings


def _abstract_batch(exp, batch: int):
    S = jax.ShapeDtypeStruct
    if exp.task == "lm":
        return {"tokens": S((batch, exp.train.seq_len), jnp.int32),
                "labels": S((batch, exp.train.seq_len), jnp.int32)}
    return {"image": S((batch, 32, 32, 3), jnp.float32),
            "label": S((batch,), jnp.int32)}


def lint_experiment(exp, batch: Optional[int] = None
                    ) -> List[PrecisionFinding]:
    """Dataflow-lint one experiment's traced fwd+bwd train step."""
    from repro.training.train_step import init_train_state, make_train_step

    b = exp.train.global_batch if batch is None else batch
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    state = jax.eval_shape(lambda k: init_train_state(k, exp), key)
    surface = f"train:{exp.model.name}"
    res = analyze(make_train_step(exp), state, _abstract_batch(exp, b),
                  name=surface)
    return _hazard_findings(surface, res)


def _default_experiments():
    from repro.configs.paper_cnns import mobilenetv2, resnet74
    return [resnet74(), mobilenetv2()]


def lint_all(exps=None, allowlist: Optional[Dict[str, str]] = None
             ) -> Tuple[List[PrecisionFinding], List[PrecisionFinding]]:
    """(unallowlisted, allowlisted) findings over every lint surface."""
    findings = lint_kernels()
    for exp in (exps if exps is not None else _default_experiments()):
        findings.extend(lint_experiment(exp))
    return split_findings(findings, allowlist)


def precision_report(exps=None) -> dict:
    """The BENCH_audit.json ``precision`` section."""
    findings, allowlisted = lint_all(exps)
    return {"findings": [str(f) for f in findings],
            "allowlisted": [str(f) for f in allowlisted],
            "passed": not findings}


def main() -> int:
    findings, allowlisted = lint_all()
    for f in findings:
        print(f)
    for f in allowlisted:
        print(f"allowlisted: {f}")
    print(f"precision lint: {len(findings)} finding(s), "
          f"{len(allowlisted)} allowlisted")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
