"""Static cost-audit subsystem (DESIGN.md §Analysis).

The energy story rests on three independent witnesses of the same program:

* ``core/cost.py`` — hand-written per-layer MAC/byte tables (what the
  paper's arithmetic *assumes*);
* ``analysis/jaxpr_cost.py`` — per-primitive counts walked out of the
  *traced* train/predict jaxprs, attributed back to named layers
  (what jax will actually ask the compiler to run);
* ``launch/hlo_cost.py`` — counts re-derived from the *compiled* HLO
  (what the backend actually schedules).

``analysis/audit.py`` three-way-diffs them into an :class:`AuditReport`
with a pass/fail verdict under a declared tolerance — divergence is a bug
in one of the witnesses, never a rounding detail to shrug at.

``analysis/kernel_lint.py`` statically checks every Pallas kernel
registered through ``kernels/dispatch.py`` (VMEM budget, MXU tile
alignment, BlockSpec index-map coverage, accumulator init/finish
discipline), and ``analysis/repo_lint.py`` enforces repo conventions
(no ``pl.pallas_call`` outside ``kernels/``, no ``REPRO_*`` env reads
outside the dispatch layer, no device→host syncs outside ``training/``).

``analysis/dataflow.py`` is the numerics counterpart to the cost walker:
an abstract interpreter that propagates precision provenance (narrow-
dtype lineage, reduction depth, accumulation cycles) through the same
traced programs — including scan carries, cond branches and
``pallas_call`` bodies.  Two lint passes ride on it:
``analysis/precision_lint.py`` flags sub-32-bit accumulators fed by
narrow-descended operands (the PR 7 bug class) over every shipped kernel
and both CNN backbones' traced fwd+bwd, and ``analysis/hotloop_lint.py``
verifies the chunk program's ``CHUNK_CONTRACT`` (no host callbacks,
static trips, shape-stable body, device-resident metrics, no donation by
default).  All of it lands in BENCH_audit.json and gates CI.
"""
from repro.analysis.audit import (AuditReport, LayerRow, audit_experiment,
                                  audit_totals)
from repro.analysis.dataflow import (DataflowResult, Prov, ReductionSite,
                                     analyze, analyze_jaxpr)
from repro.analysis.hotloop_lint import (HotloopFinding, hotloop_report,
                                         lint_chunk)
from repro.analysis.jaxpr_cost import (OpCounts, ProgramCosts, jaxpr_costs,
                                       scope_tag, sub_jaxprs)
from repro.analysis.kernel_lint import LintFinding, lint_jaxpr, lint_shipped
from repro.analysis.precision_lint import (PrecisionFinding, lint_kernels,
                                           precision_report)
from repro.analysis.repo_lint import lint_repo

__all__ = [
    "AuditReport", "LayerRow", "audit_experiment", "audit_totals",
    "OpCounts", "ProgramCosts", "jaxpr_costs", "scope_tag", "sub_jaxprs",
    "DataflowResult", "Prov", "ReductionSite", "analyze", "analyze_jaxpr",
    "PrecisionFinding", "lint_kernels", "precision_report",
    "HotloopFinding", "lint_chunk", "hotloop_report",
    "LintFinding", "lint_jaxpr", "lint_shipped", "lint_repo",
]
