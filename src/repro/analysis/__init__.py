"""Static cost-audit subsystem (DESIGN.md §Analysis).

The energy story rests on three independent witnesses of the same program:

* ``core/cost.py`` — hand-written per-layer MAC/byte tables (what the
  paper's arithmetic *assumes*);
* ``analysis/jaxpr_cost.py`` — per-primitive counts walked out of the
  *traced* train/predict jaxprs, attributed back to named layers
  (what jax will actually ask the compiler to run);
* ``launch/hlo_cost.py`` — counts re-derived from the *compiled* HLO
  (what the backend actually schedules).

``analysis/audit.py`` three-way-diffs them into an :class:`AuditReport`
with a pass/fail verdict under a declared tolerance — divergence is a bug
in one of the witnesses, never a rounding detail to shrug at.

``analysis/kernel_lint.py`` statically checks every Pallas kernel
registered through ``kernels/dispatch.py`` (VMEM budget, MXU tile
alignment, BlockSpec index-map coverage, accumulator init/finish
discipline), and ``analysis/repo_lint.py`` enforces repo conventions
(no ``pl.pallas_call`` outside ``kernels/``, no ``REPRO_*`` env reads
outside the dispatch layer).
"""
from repro.analysis.audit import (AuditReport, LayerRow, audit_experiment,
                                  audit_totals)
from repro.analysis.jaxpr_cost import (OpCounts, ProgramCosts, jaxpr_costs,
                                       scope_tag)
from repro.analysis.kernel_lint import LintFinding, lint_jaxpr, lint_shipped
from repro.analysis.repo_lint import lint_repo

__all__ = [
    "AuditReport", "LayerRow", "audit_experiment", "audit_totals",
    "OpCounts", "ProgramCosts", "jaxpr_costs", "scope_tag",
    "LintFinding", "lint_jaxpr", "lint_shipped", "lint_repo",
]
