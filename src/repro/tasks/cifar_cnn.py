"""``cifar_cnn`` task: the paper's own backbones on CIFAR-shaped batches.

Model selection rides on the ``family="cnn"`` :class:`ModelConfig` encoding
(``configs/paper_cnns.cnn_model``): ``num_layers`` is the ResNet depth
(6n+2), ``d_model`` the stage-0 width, ``vocab_size`` the class count; a
model named ``"mobilenetv2"`` selects the MobileNetV2 backbone instead.

``model_state`` is the BatchNorm running-stat tree: the loss returns the
EMA-updated tree so ``train=False`` prediction normalizes with learned
statistics — the regression this fixes is pinned in
``tests/test_resnet_scan.py``.
"""
from __future__ import annotations

from typing import Any, Tuple

from repro.core.config import Experiment
from repro.models import resnet as R
from repro.tasks import Task, register


def _is_mobilenet(exp: Experiment) -> bool:
    return exp.model.name == "mobilenetv2"


def _init(key, exp: Experiment) -> Tuple[Any, Any]:
    m = exp.model
    if _is_mobilenet(exp):
        return R.init_mobilenetv2(key, num_classes=m.vocab_size)
    return R.init_resnet(key, m.num_layers, num_classes=m.vocab_size,
                         e2=exp.e2, width=m.d_model)


def _make_loss(exp: Experiment):
    e2, depth = exp.e2, exp.model.num_layers
    if _is_mobilenet(exp):
        def loss(params, model_state, batch, rng):
            return R.mobilenetv2_loss(params, model_state, batch, rng,
                                      train=True)
        return loss

    def loss(params, model_state, batch, rng):
        return R.resnet_loss(params, model_state, batch, depth, e2, rng,
                             train=True)

    return loss


def _make_predict(exp: Experiment):
    e2, depth = exp.e2, exp.model.num_layers
    if _is_mobilenet(exp):
        def predict(params, model_state, batch):
            logits, _ = R.mobilenetv2_fwd(params, model_state, batch["image"],
                                          train=False)
            return logits
        return predict

    def predict(params, model_state, batch):
        logits, _, _ = R.resnet_fwd(params, model_state, batch["image"],
                                    depth, e2, train=False)
        return logits

    return predict


def _cost(exp: Experiment):
    from repro.core.cost import cnn_cost
    return cnn_cost(exp.model)


CIFAR_CNN_TASK = register(Task(name="cifar_cnn", init=_init,
                               make_loss=_make_loss,
                               make_predict=_make_predict, cost=_cost))
