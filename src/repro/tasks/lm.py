"""``lm`` task: the generic transformer LM stack (``models/transformer``).

Stateless (no non-trainable buffers): ``model_state`` is ``None`` and passes
through the loss untouched.
"""
from __future__ import annotations

from typing import Any, Tuple

from repro.core.config import Experiment
from repro.models import transformer
from repro.tasks import Task, register


def _init(key, exp: Experiment) -> Tuple[Any, Any]:
    return transformer.init_lm(key, exp.model, exp.e2), None


def _make_loss(exp: Experiment):
    cfg, e2, tc = exp.model, exp.e2, exp.train

    def loss(params, model_state, batch, rng):
        total, metrics = transformer.lm_loss(params, batch, cfg, e2, rng,
                                             remat=tc.remat)
        return total, (metrics, model_state)

    return loss


def _make_predict(exp: Experiment):
    cfg = exp.model

    def predict(params, model_state, batch):
        out = transformer.lm_fwd(params, batch["tokens"], cfg, exp.e2,
                                 frontend_embeds=batch.get("frontend"),
                                 train=False, remat="none")
        return out.logits

    return predict


def _cost(exp: Experiment):
    from repro.core.cost import lm_cost
    return lm_cost(exp.model, exp.train.seq_len)


LM_TASK = register(Task(name="lm", init=_init, make_loss=_make_loss,
                        make_predict=_make_predict, cost=_cost))
