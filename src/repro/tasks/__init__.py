"""Model-agnostic task registry (DESIGN.md §Tasks).

A :class:`Task` adapts one model family to the shared training stack:
``training/train_step.py`` and ``training/trainer.py`` know nothing about
transformers or CNNs — they resolve the experiment's ``task`` key here and
get back ``init`` / ``loss`` callables.  Everything the stack layers on top
(SMD drops, microbatch accumulation, the PSG sign-vote backward and its
measured ``psg_fallback_ratio`` probe, majority vote, SWA, checkpoint +
resume) therefore works for every registered task unchanged.

Contract:

* ``init(key, exp) -> (params, model_state)``.  ``model_state`` is the
  task's non-trainable buffers (e.g. BatchNorm running statistics), ``None``
  when the task has none.  The optimizer never sees it: the train step
  threads it next to the params and stores it on ``TrainState.model_state``.
* ``make_loss(exp) -> loss(params, model_state, batch, rng)`` returning
  ``(total_loss, (metrics, new_model_state))`` with *scalar* metrics (the
  trainer logs them as floats; microbatch accumulation means them).
* ``make_predict(exp) -> predict(params, model_state, batch)`` — eval-mode
  logits: stored statistics, no RNG, no SLU sampling.
* ``cost(exp) -> CostModel`` — the per-layer op-count model for the
  experiment's architecture (``core/cost.py``): energy accounting resolves
  through here (``cost_model(exp)``), so it prices what actually trains —
  never transformer math for a CNN (DESIGN.md §Energy).

Built-in tasks: ``"lm"`` (the generic transformer stack) and ``"cifar_cnn"``
(the paper's ResNet-74/110 + MobileNetV2 backbones).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

from repro.core.config import Experiment
from repro.core.cost import TableCostModel

LossFn = Callable[..., Tuple[Any, Tuple[Dict[str, Any], Any]]]


@dataclass(frozen=True)
class Task:
    name: str
    init: Callable[[Any, Experiment], Tuple[Any, Any]]
    make_loss: Callable[[Experiment], LossFn]
    make_predict: Optional[Callable[[Experiment], Callable]] = None
    cost: Optional[Callable[[Experiment], TableCostModel]] = None


_REGISTRY: Dict[str, Task] = {}


def register(task: Task) -> Task:
    if task.name in _REGISTRY:
        raise ValueError(f"task {task.name!r} already registered")
    _REGISTRY[task.name] = task
    return task


def get_task(name: str) -> Task:
    _ensure_builtin()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown task {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def task_names() -> Tuple[str, ...]:
    _ensure_builtin()
    return tuple(sorted(_REGISTRY))


def cost_model(exp: Experiment) -> TableCostModel:
    """The experiment's per-layer cost model, resolved through its task.

    This is the ONE entry point energy accounting uses to price an
    experiment (core/ledger.py); a task without a cost model cannot be
    priced, and that is an error — not a silent fallback to another
    family's arithmetic.
    """
    task = get_task(exp.task)
    if task.cost is None:
        raise ValueError(f"task {task.name!r} registered no cost model; "
                         "energy accounting cannot price this experiment")
    return task.cost(exp)


def _ensure_builtin() -> None:
    # import for the registration side effect; deferred so that importing
    # repro.tasks never drags in model code the caller doesn't use
    from repro.tasks import cifar_cnn, lm  # noqa: F401
