"""Stochastic Weight Averaging — the paper stabilizes PSG/SignSGD with SWA
(§4.1, following SWALP [Yang et al. 2019]).

The average is maintained as a running mean of the parameter trajectory
from ``start_step`` on; ``swa_params`` returns the averaged weights for
eval.  At multi-pod scale the averaging is element-wise on already-sharded
params — no extra collectives — and is scheduled off the critical path
(it reads the step's output params, it does not feed the next step).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp


def swa_init(params) -> Dict[str, Any]:
    # copy=True: the average must not alias the live params (donation safety)
    return {"avg": jax.tree.map(
        lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params),
            "count": jnp.zeros((), jnp.int32)}


def swa_update(state, params, step, start_step):
    active = step >= start_step
    c = state["count"] + jnp.where(active, 1, 0)

    def upd(a, p):
        w = jnp.where(active, 1.0 / jnp.maximum(c, 1).astype(jnp.float32), 0.0)
        return a + w * (p.astype(jnp.float32) - a)

    return {"avg": jax.tree.map(upd, state["avg"], params), "count": c}


def swa_params(state, like):
    return jax.tree.map(lambda a, p: a.astype(p.dtype), state["avg"], like)
