"""Error-feedback for sign compression (EF-SignSGD, Karimireddy et al. '19).

Beyond-paper robustness: plain SignSGD/PSG discards gradient magnitude; at
large data-parallel fan-in the majority vote can stall on near-tie
coordinates.  Error feedback accumulates the discarded residual
``e <- e + g - lr*sign(g + e)`` locally and re-injects it next step,
restoring convergence guarantees while keeping the 1-bit wire format —
it composes with ``majority_vote`` (the residual never crosses the wire).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def ef_init(params) -> Dict[str, Any]:
    return {"residual": jax.tree.map(
        lambda p: jnp.zeros_like(p, jnp.float32), params)}


def ef_compress(grads, state, scale: float = 1.0):
    """Returns (sign payload to transmit, new state).

    ``scale`` rescales the sign to preserve the corrected gradient's mean
    magnitude (the 'scaled sign' variant)."""
    def one(g, e):
        corr = g.astype(jnp.float32) + e
        payload = jnp.sign(corr)
        mag = jnp.mean(jnp.abs(corr))
        new_e = corr - scale * mag * payload
        return payload, new_e

    out = jax.tree.map(one, grads, state["residual"])
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    return pick(0), {"residual": pick(1)}
