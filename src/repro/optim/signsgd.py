"""SignSGD [Bernstein et al. 2018] — the update rule PSG plugs into.

``w <- w - lr * sign(g)``.  When the gradient tree already contains signs
(PSG's custom-vjp emits {-1, 0, +1}) the sign() here is idempotent; when
gradients were mean-aggregated across data-parallel replicas, sign(mean of
signs) IS the majority vote of distributed SignSGD — which is why PSG
composes into 1-bit gradient compression (optim/majority_vote.py).

Optional momentum = Signum (sign of the momentum buffer).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp


def signsgd_init(params) -> Dict[str, Any]:
    return {"momentum": jax.tree.map(jnp.zeros_like, params)}


def signsgd_apply(params, grads, state, lr, *, momentum: float = 0.0,
                  weight_decay: float = 0.0):
    def upd(p, g, m):
        g = g.astype(jnp.float32)
        m_new = momentum * m.astype(jnp.float32) + (1 - momentum) * g \
            if momentum > 0 else g
        step = jnp.sign(m_new) + weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * step).astype(p.dtype),
                m_new.astype(m.dtype))

    out = jax.tree.map(upd, params, grads, state["momentum"])
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    return pick(0), {"momentum": pick(1)}
