"""Unified optimizer facade used by the trainer."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax.numpy as jnp

from repro.core.config import TrainConfig
from repro.optim import schedules, sgd, signsgd


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Dict[str, Any]]
    apply: Callable[..., Any]          # (params, grads, state, step) -> (p, s)
    name: str


def make_optimizer(cfg: TrainConfig) -> Optimizer:
    sched = schedules.make_schedule(cfg)

    if cfg.optimizer == "sgdm":
        def apply(params, grads, state, step):
            return sgd.sgd_apply(params, grads, state, sched(step),
                                 momentum=cfg.momentum,
                                 weight_decay=cfg.weight_decay)
        return Optimizer(sgd.sgd_init, apply, "sgdm")

    if cfg.optimizer in ("signsgd", "psg"):
        # paper §4.1/App. B: lr 0.03, wd 5e-4 when Sign/PSG is used
        def apply(params, grads, state, step):
            return signsgd.signsgd_apply(params, grads, state, sched(step),
                                         momentum=cfg.momentum
                                         if cfg.optimizer == "signsgd" else 0.0,
                                         weight_decay=cfg.weight_decay)
        return Optimizer(signsgd.signsgd_init, apply, cfg.optimizer)

    if cfg.optimizer == "adamw":
        def apply(params, grads, state, step):
            return sgd.adamw_apply(params, grads, state, sched(step),
                                   weight_decay=cfg.weight_decay)
        return Optimizer(sgd.adamw_init, apply, "adamw")

    raise ValueError(cfg.optimizer)
