"""LR schedules.  The paper: lr 0.1, step-decay x0.1 at 32k/48k of 64k
iterations (He et al. protocol); lr 0.03 constant-ish when PSG/SignSGD is on.
Scaling rule for reduced-iteration baselines (§4.2): decay points scale
proportionally with the total budget."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.config import TrainConfig


def make_schedule(cfg: TrainConfig):
    base = cfg.lr
    total = cfg.total_steps

    def step_fn(step):
        step = jnp.asarray(step, jnp.float32)
        if cfg.schedule == "constant":
            lr = jnp.full_like(step, base)
        elif cfg.schedule == "cosine":
            t = jnp.clip(step / total, 0.0, 1.0)
            lr = 0.5 * base * (1.0 + jnp.cos(jnp.pi * t))
        else:  # step decay (paper)
            lr = base * jnp.ones_like(step)
            for frac in cfg.decay_points:
                lr = jnp.where(step >= frac * total, lr * cfg.decay_factor, lr)
        if cfg.warmup_steps:
            lr = lr * jnp.clip(step / cfg.warmup_steps, 0.0, 1.0)
        return lr

    return step_fn
