"""1-bit sign gradient compression (beyond-paper, enabled by PSG).

Distributed SignSGD with majority vote [Bernstein et al. 2018]: each
data-parallel worker contributes sign(g) in {-1, 0, +1}; the aggregate is
sign(sum of signs).  Under pjit the mean-all-reduce of a gradient tree is
what XLA inserts for data parallelism; by casting signs to int8 *before*
the psum (inside shard_map) the all-reduce payload shrinks 4x vs fp32
(16x for what would otherwise be fp32 full gradients + sign afterwards).

This attacks the collective roofline term directly: the data-parallel
gradient all-reduce for an N-param model drops from 4N bytes to N bytes.

Robustness bonus (DESIGN.md §7): majority vote degrades gracefully when a
voter is missing — a straggler pod that skips its contribution (SMD-style
drop) just abstains; no renormalization needed, which is what makes the
SMD-based straggler policy sound.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax


def compress_signs(grads) -> Any:
    """Clamp a (possibly already sign-valued) gradient tree to int8 signs."""
    return jax.tree.map(lambda g: jnp.sign(g.astype(jnp.float32)).astype(jnp.int8),
                        grads)


def majority_vote_psum(sign_grads, axis_name) -> Any:
    """int8 sign psum + majority decision; use inside shard_map over the
    data(/pod) axes.  Returns float32 signs in {-1, 0, +1}."""
    def vote(g):
        total = lax.psum(g.astype(jnp.int32), axis_name)
        return jnp.sign(total.astype(jnp.float32))

    return jax.tree.map(vote, sign_grads)


def majority_vote_tree(grads) -> Any:
    """SPMD-friendly variant: when gradients were already mean-reduced by
    pjit (mean of per-replica signs), the majority vote is just sign()."""
    return jax.tree.map(lambda g: jnp.sign(g.astype(jnp.float32)), grads)
