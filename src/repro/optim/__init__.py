"""Optimizers (pure-functional, optax-style trees of state).

The paper's recipes: SGD + momentum 0.9 + wd 1e-4 for baselines (He et al.
settings); SignSGD / PSG with lr 0.03 and SWA.
"""
from repro.optim.sgd import sgd_init, sgd_apply, adamw_init, adamw_apply
from repro.optim.signsgd import signsgd_init, signsgd_apply
from repro.optim.swa import swa_init, swa_update, swa_params
from repro.optim.schedules import make_schedule
from repro.optim.majority_vote import compress_signs, majority_vote_psum
from repro.optim.api import make_optimizer, Optimizer
