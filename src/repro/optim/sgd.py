"""SGD with momentum/Nesterov + decoupled weight decay; AdamW for reference."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def sgd_init(params) -> Dict[str, Any]:
    return {"momentum": jax.tree.map(jnp.zeros_like, params)}


def sgd_apply(params, grads, state, lr, *, momentum: float = 0.9,
              weight_decay: float = 1e-4, nesterov: bool = False):
    def upd(p, g, m):
        g = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
        m_new = momentum * m.astype(jnp.float32) + g
        step = (g + momentum * m_new) if nesterov else m_new
        return ((p.astype(jnp.float32) - lr * step).astype(p.dtype),
                m_new.astype(m.dtype))

    out = jax.tree.map(upd, params, grads, state["momentum"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"momentum": new_m}


def adamw_init(params) -> Dict[str, Any]:
    return {"mu": jax.tree.map(jnp.zeros_like, params),
            "nu": jax.tree.map(jnp.zeros_like, params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_apply(params, grads, state, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                weight_decay=0.1):
    c = state["count"] + 1

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu_n = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        nu_n = b2 * nu.astype(jnp.float32) + (1 - b2) * g * g
        mu_h = mu_n / (1 - b1 ** c)
        nu_h = nu_n / (1 - b2 ** c)
        step = mu_h / (jnp.sqrt(nu_h) + eps) + weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * step).astype(p.dtype),
                mu_n.astype(mu.dtype), nu_n.astype(nu.dtype))

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    return pick(0), {"mu": pick(1), "nu": pick(2), "count": c}
