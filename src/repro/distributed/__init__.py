from repro.distributed.sharding import (param_shardings, batch_sharding,
                                        state_shardings, logical_rules)


def process_shard():
    """``(shard, num_shards)`` for the counter-based data path.

    The canonical way a launcher picks its data shard: under
    ``jax.distributed`` each process generates only its shard of the
    global batch (``make_batch(step, shard)`` is a pure function of
    ``(seed, step, shard)``, so shards never overlap and never require
    host data exchange); single-process runs get ``(0, 1)``.  Elastic
    restarts on a smaller world re-derive shard ids from the new process
    set — the counter-based schedule makes the re-sharded stream
    deterministic by construction (DESIGN.md §Fault-tolerance).
    """
    import jax
    return jax.process_index(), jax.process_count()
