from repro.distributed.sharding import (param_shardings, batch_sharding,
                                        state_shardings, logical_rules)
