"""Logical-axis sharding rules: FSDP + TP + EP + SP on one mesh.

Every parameter leaf is matched by key-path against a rule table that
assigns *logical* axes per dimension; logical axes map to mesh axes
("tp" -> model, "fsdp" -> data [+pod], "expert" -> model).  A logical axis
is silently dropped when the dimension is not divisible by the mesh axis
size (e.g. qwen2.5's 2 KV heads on a 16-way model axis) — the framework
guarantee is "always compiles, shards as much as divisibility allows",
which is the property the 40-cell dry-run certifies.

Layout conventions (models/layers.py): up-projections shard the output
axis over TP, down-projections the input axis — Megatron-style, so each
block needs only one reduce-scatter/all-reduce pair.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_abstract_mesh(shape, axes):
    """Device-free mesh for spec logic (tests, shape-only planning).

    Current JAX's ``AbstractMesh`` takes ``((name, size), ...)`` pairs;
    older releases took ``(shape_tuple, axis_names)`` positionally.  Accept
    the classic ``(shape, axes)`` call and translate.
    """
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(zip(axes, shape)))
    except TypeError:        # pre-pairs API
        return AbstractMesh(tuple(shape), tuple(axes))

# ---------------------------------------------------------------------------
# activation-sharding hints (trace-time context, like core.psg.enable)
# ---------------------------------------------------------------------------

_act = threading.local()

# logical activation axes -> mesh axes
ACT_AXES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "heads": ("model",),
    "mlp": ("model",),
    "seq": ("model",),        # SP: sequence over model axis (training path)
    "tokens": ("pod", "data", "model"),   # flattened batch*seq (MoE groups)
}


@contextlib.contextmanager
def activation_sharding(mesh: Mesh):
    """Enable ``hint`` constraints while tracing under this mesh."""
    prev = getattr(_act, "mesh", None)
    _act.mesh = mesh
    try:
        yield
    finally:
        _act.mesh = prev


def hint(x, *logical_axes: Optional[str], free: bool = False):
    """with_sharding_constraint by logical activation axes; no-op when no
    mesh context is active (single-host smoke tests) or when an axis size
    does not divide the dimension.

    ``free=True`` maps unnamed dims to ``P.UNCONSTRAINED`` instead of
    replicated — use inside scan bodies where other dims carry model-axis
    sharding from the params (a plain ``None`` would FORCE replication,
    e.g. de-sharding Mamba's 64 internal heads: observed +40 GiB)."""
    mesh = getattr(_act, "mesh", None)
    if mesh is None:
        return x
    unnamed = P.UNCONSTRAINED if free else None
    spec = []
    used: set = set()
    for i, name in enumerate(logical_axes):
        if name is None:
            spec.append(unnamed)
            continue
        axes = tuple(a for a in ACT_AXES.get(name, ())
                     if a in mesh.axis_names and a not in used)
        size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        if axes and x.shape[i] % size == 0 and size > 1:
            spec.append(axes if len(axes) > 1 else axes[0])
            used.update(axes)
        else:
            spec.append(unnamed)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def ctx_mesh_axis_size(name: str) -> int:
    """Size of a mesh axis in the active activation-sharding context (1 when
    tracing without a mesh — keeps model code mesh-agnostic)."""
    mesh = getattr(_act, "mesh", None)
    if mesh is None or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def replicate(x):
    """Force a tensor replicated (all-gather on the wire) — used to place
    FSDP gathers on *int8 quantized codes* instead of bf16 weights (PSG
    int8-gather: the paper's low-precision data-movement insight applied to
    the collective roofline term).  No-op outside a mesh context."""
    mesh = getattr(_act, "mesh", None)
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, P(*([None] * x.ndim)))


def hint_batch(x, axis: int = 0):
    """Constrain only the batch axis (common case for activations inside
    scan bodies, where SPMD propagation into while-loop backwards is weak);
    other dims stay UNCONSTRAINED so param-derived shardings (e.g. TP'd
    head/state axes) survive."""
    spec: list = [None] * x.ndim
    spec[axis] = "batch"
    return hint(x, *spec, free=True)

# rule table: (path regex, candidate logical-axes specs).  Axes are
# right-aligned against the array shape (leading stacked 'units' axes get
# None), so the same rule covers scanned and unscanned params.  When a rule
# lists multiple candidates, the first whose named axes all divide is used
# (e.g. MoE weights: expert-parallel when num_experts % model == 0, else
# tensor-parallel within experts — grok's 8 experts on a 16-way model axis).
RULES: Tuple[Tuple[str, Any], ...] = (
    # embeddings / head
    (r"embed$",                ("tp_vocab", "fsdp")),
    (r"head$",                 ("fsdp", "tp_vocab")),
    # attention
    (r"attn/w[q]$",            ("fsdp", "tp", None)),
    (r"attn/w[kv]$",           ("fsdp", "tp", None)),
    (r"attn/wo$",              ("tp", None, "fsdp")),
    (r"attn/b[qkv]$",          ("tp", None)),
    # dense MLP
    (r"mlp/w_(up|gate)$",      ("fsdp", "tp")),
    (r"mlp/w_down$",           ("tp", "fsdp")),
    (r"mlp/b_up$",             ("tp",)),
    (r"mlp/b_down$",           (None,)),
    # MoE (expert parallelism over the model axis; TP fallback)
    (r"moe/router$",           (None, None)),
    (r"moe/w_(up|gate)$",      [("expert", "fsdp", None),
                                (None, "fsdp", "tp")]),
    (r"moe/w_down$",           [("expert", None, "fsdp"),
                                (None, "tp", "fsdp")]),
    (r"moe/shared/.*w_(up|gate)$", ("fsdp", "tp")),
    (r"moe/shared/.*w_down$",  ("tp", "fsdp")),
    # Mamba2
    (r"mamba/w_in$",           ("fsdp", "tp")),
    (r"mamba/w_out$",          ("tp", "fsdp")),
    (r"mamba/conv$",           (None, "tp")),
    (r"mamba/w_bc$",           ("fsdp", None)),
    (r"mamba/w_dt$",           ("fsdp", None)),
    # xLSTM
    (r"mlstm/w_in$",           ("fsdp", "tp")),
    (r"mlstm/w_out$",          ("tp", "fsdp")),
    (r"mlstm/w(q|k|v)$",       ("tp", None, None)),
    (r"mlstm/w_if$",           ("tp", None)),
    (r"slstm/w_g$",            ("fsdp", "tp")),
    (r"slstm/w_out$",          ("tp", "fsdp")),
    (r"slstm/r_g$",            ("tp_heads", None, None)),
    # norms, gates, scalars: replicated
    (r".*",                    ()),
)

LOGICAL_TO_MESH: Dict[str, Tuple[str, ...]] = {
    "tp": ("model",),
    "tp_vocab": ("model",),
    "tp_heads": ("model",),
    "expert": ("model",),
    "fsdp": ("data",),           # extended with 'pod' when multi-pod
}


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
    return "/".join(parts)


def logical_rules(path_s: str):
    for pat, axes in RULES:
        if re.search(pat, path_s):
            return axes
    return ()


def _candidates(logical):
    if isinstance(logical, list):
        return logical
    return [logical]


def _mesh_axes_for(name: str, mesh: Mesh, fsdp: bool):
    if name == "fsdp" and not fsdp:
        return ()
    mesh_axes = tuple(a for a in LOGICAL_TO_MESH.get(name, ())
                      if a in mesh.axis_names)
    if name == "fsdp":
        pod = tuple(a for a in ("pod",) if a in mesh.axis_names)
        mesh_axes = pod + mesh_axes
    return mesh_axes


def _try_spec(shape, logical, mesh: Mesh, fsdp: bool):
    """Returns (spec, all_named_axes_applied)."""
    ndim = len(shape)
    axes: list = [None] * ndim
    complete = True
    offset = ndim - len(logical)
    for i, name in enumerate(logical):
        if name is None or offset + i < 0:
            continue
        mesh_axes = _mesh_axes_for(name, mesh, fsdp)
        size = int(np.prod([mesh.shape[a] for a in mesh_axes])) if mesh_axes else 1
        if mesh_axes and size > 1 and shape[offset + i] % size == 0:
            axes[offset + i] = mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]
        elif name != "fsdp":
            complete = False
    return P(*axes), complete


def _spec_for(shape: Tuple[int, ...], logical, mesh: Mesh, fsdp: bool) -> P:
    """Right-align logical axes to shape; drop non-divisible shardings.
    For candidate lists, pick the first candidate whose non-fsdp axes all
    apply; fall back to the first candidate's partial application."""
    cands = _candidates(logical)
    if not cands or not cands[0]:
        return P(*([None] * len(shape)))
    first = None
    for cand in cands:
        spec, complete = _try_spec(shape, cand, mesh, fsdp)
        if first is None:
            first = spec
        if complete:
            return spec
    return first


def constrain_like_params(tree, fsdp: bool = True):
    """with_sharding_constraint a param-shaped tree (e.g. gradients, the
    microbatch grad-accumulator carry) to the rule-table shardings.  Without
    this, XLA tends to materialize *replicated* fp32 gradients for the
    embedding/LM-head (all-reduce instead of reduce-scatter) — multi-GiB per
    device at 128k vocabs.  No-op outside an activation-sharding context."""
    mesh = getattr(_act, "mesh", None)
    if mesh is None:
        return tree

    def one(path, leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim == 0:
            return leaf
        spec = _spec_for(tuple(leaf.shape), logical_rules(_path_str(path)),
                         mesh, fsdp)
        return jax.lax.with_sharding_constraint(leaf, spec)

    return jax.tree_util.tree_map_with_path(one, tree)


def param_shardings(params_shape, mesh: Mesh, fsdp: bool = True):
    """Pytree of NamedSharding matching a pytree of ShapeDtypeStruct/arrays."""
    def one(path, leaf):
        spec = _spec_for(tuple(leaf.shape), logical_rules(_path_str(path)),
                         mesh, fsdp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_sharding(mesh: Mesh, batch: Any = 2, seq_shard: bool = False,
                   shape: Optional[Tuple[int, ...]] = None,
                   batch_axis: int = 0):
    """Data-batch sharding: the batch axis over pod+data, optionally the
    following (sequence) axis over model.  Axes that do not divide the
    dimension are dropped (e.g. the long_500k cell's global_batch=1).

    ``batch`` is either an int rank (the classic single-leaf call, with the
    optional concrete ``shape`` for divisibility checks) or a *batch pytree*
    (dict batches): rank and shape are then inferred per leaf — rank-1
    labels, rank-2 token batches, rank-4 NHWC CIFAR images, and their
    rank+1 chunk-stacked forms all resolve from one call.  ``batch_axis``
    points at the batch dimension (1 for chunk-stacked batches, where axis
    0 is the scan/K axis and stays unsharded — every device runs every
    scan step).
    """
    if isinstance(batch, int):
        return _leaf_batch_sharding(mesh, batch, shape, seq_shard, batch_axis)

    def one(leaf):
        shp = tuple(np.shape(leaf))
        return _leaf_batch_sharding(mesh, len(shp), shp, seq_shard,
                                    batch_axis)

    return jax.tree.map(one, batch)


def _leaf_batch_sharding(mesh: Mesh, ndim: int,
                         shape: Optional[Tuple[int, ...]],
                         seq_shard: bool, batch_axis: int) -> NamedSharding:
    if batch_axis >= ndim:
        return NamedSharding(mesh, P(*([None] * ndim)))
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bsize = int(np.prod([mesh.shape[a] for a in batch_axes])) if batch_axes else 1
    if bsize <= 1 or (shape is not None and shape[batch_axis] % bsize != 0):
        batch_axes = ()
    axes: list = [None] * ndim
    axes[batch_axis] = (batch_axes if len(batch_axes) > 1 else
                        (batch_axes[0] if batch_axes else None))
    seq_axis = batch_axis + 1
    if seq_shard and "model" in mesh.axis_names and ndim > seq_axis:
        msize = mesh.shape["model"]
        if shape is None or shape[seq_axis] % msize == 0:
            axes[seq_axis] = "model"
    return NamedSharding(mesh, P(*axes))


def state_shardings(state_shape, mesh: Mesh, fsdp: bool = True):
    """Optimizer / SWA state mirrors parameter shardings (momentum etc. have
    identical shapes); scalars are replicated."""
    def one(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        spec = _spec_for(tuple(leaf.shape), logical_rules(_path_str(path)),
                         mesh, fsdp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, state_shape)


def decode_state_shardings(state_shape, mesh: Mesh):
    """KV caches (B, T, nkv, hd): B over pod+data, T over model (ring-buffer
    slots shard cleanly; softmax reductions over the sharded T axis become
    small all-reduces XLA inserts).  Recurrent states: B over pod+data, the
    widest inner axis over model when divisible."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bsize = int(np.prod([mesh.shape[a] for a in batch_axes])) if batch_axes else 1
    b_axis = batch_axes if len(batch_axes) > 1 else (
        batch_axes[0] if batch_axes else None)
    msize = mesh.shape.get("model", 1)

    def one(path, leaf):
        shape = tuple(leaf.shape)
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        path_s = _path_str(path)
        axes: list = [None] * leaf.ndim
        # leading axis may be the stacked units axis: detect batch position
        # by convention — decode states are (units, B, ...) after stacking
        bpos = 1 if "units" in path_s else 0
        if leaf.ndim > bpos and bsize > 1 and shape[bpos] % bsize == 0:
            axes[bpos] = b_axis
        if "kv" in path_s and leaf.ndim >= bpos + 3 and "model" in mesh.axis_names:
            # prefer head-sharding (TP attention, keeps softmax local);
            # fall back to ring-slot (T) sharding for small KV-head counts
            if shape[bpos + 2] % msize == 0:
                axes[bpos + 2] = "model"          # kv-heads axis
            elif shape[bpos + 1] % msize == 0:
                axes[bpos + 1] = "model"          # T axis
        elif leaf.ndim > bpos + 1 and "model" in mesh.axis_names:
            # recurrent state: shard the largest trailing axis if divisible
            rest = list(range(bpos + 1, leaf.ndim))
            if rest:
                j = max(rest, key=lambda i: shape[i])
                if shape[j] % msize == 0:
                    axes[j] = "model"
        return NamedSharding(mesh, P(*axes))

    return jax.tree_util.tree_map_with_path(one, state_shape)
