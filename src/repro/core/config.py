"""Central configuration system for the E2-Train framework.

Everything in the framework is driven by three frozen dataclasses:

* :class:`ModelConfig`   — architecture definition (family, dims, block pattern)
* :class:`E2TrainConfig` — the paper's technique knobs (SMD / SLU / PSG)
* :class:`TrainConfig` / :class:`ServeConfig` — run shapes and optimizer knobs

plus :class:`MeshConfig` for distribution and an :class:`Experiment` bundle
that ties them together.  ``repro.configs`` registers one Experiment factory
per assigned architecture.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

# Block kinds understood by models/transformer.py
BLOCK_ATTN = "attn"              # self-attention + dense MLP
BLOCK_MOE = "moe"                # self-attention + MoE FFN
BLOCK_MAMBA = "mamba"            # Mamba2 SSM mixer + (optional) MLP
BLOCK_MLSTM = "mlstm"            # xLSTM matrix-memory block
BLOCK_SLSTM = "slstm"            # xLSTM scalar-memory block
BLOCK_SHARED_ATTN = "shared_attn"  # zamba2-style weight-shared attention


@dataclass(frozen=True)
class ModelConfig:
    """Architecture definition.  All assigned archs reduce to this."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm | cnn
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0                # 0 -> d_model // num_heads
    qkv_bias: bool = False
    mlp_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0          # 0 -> full (causal) attention
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu"                # silu | gelu | relu
    glu: bool = True                 # gated MLP (SwiGLU-style) if True
    tie_embeddings: bool = False

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim (0 -> d_ff)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- SSM / xLSTM ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    shared_attn_every: int = 0       # zamba2: invoke shared attn after every k blocks

    # --- block pattern ---
    # Repeating unit of block kinds; tiled to num_layers.  Empty -> inferred
    # from family ("attn" for dense, "moe" for moe, ...).
    block_unit: Tuple[str, ...] = ()

    # --- encoder/decoder + multimodal frontends ---
    encoder_layers: int = 0          # >0 -> enc-dec (whisper)
    cross_attention: bool = False
    frontend: str = ""               # "" | "audio" | "vision"   (stubs)
    frontend_tokens: int = 0         # number of frontend embedding positions

    # --- numerics ---
    dtype: str = "bfloat16"          # activation dtype
    param_dtype: str = "float32"

    # ----- derived -----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def blocks(self) -> Tuple[str, ...]:
        """Full per-layer block-kind tuple of length num_layers."""
        unit = self.block_unit
        if not unit:
            unit = {
                "moe": (BLOCK_MOE,),
                "ssm": (BLOCK_MLSTM,),
            }.get(self.family, (BLOCK_ATTN,))
        reps = -(-self.num_layers // len(unit))
        return (unit * reps)[: self.num_layers]

    @property
    def act_dtype(self) -> Any:
        return jnp.dtype(self.dtype)

    @property
    def padded_vocab(self) -> int:
        """Embedding/head table size: vocab rounded up to a multiple of 128
        so the vocab axis shards on any realistic model-axis size (Megatron-
        style vocab padding; whisper's 51865 -> 51968).  Logits for the pad
        ids are masked to -inf, so the *logical* vocab is unchanged.  Tiny
        vocabs (<1024: smoke/test configs) are left unpadded."""
        if self.vocab_size < 1024:
            return self.vocab_size
        return -(-self.vocab_size // 128) * 128

    @property
    def is_subquadratic(self) -> bool:
        """Whether the arch defines a sub-quadratic long-context path."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count.

        ``family="cnn"`` configs delegate to the per-layer CNN cost model
        (``core/cost.py``) — the transformer arithmetic below has no CNN
        meaning, and silently applying it was the seed repo's bug.
        """
        if self.family == "cnn":
            from repro.core.cost import cnn_cost   # deferred: cost imports us
            return cnn_cost(self).param_count()
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        n = 0
        n += v * d                                           # embed
        if not self.tie_embeddings:
            n += v * d                                       # lm head
        for kind in self.blocks:
            n += self._block_params(kind, d, hd)
        if self.shared_attn_every:
            n += self._attn_params(d, hd)
        n += d                                               # final norm
        if self.encoder_layers:
            n += self.encoder_layers * self._block_params(BLOCK_ATTN, d, hd)
            # cross-attention params in each decoder layer
            n += self.num_layers * self._attn_params(d, hd)
        return n

    def _attn_params(self, d: int, hd: int) -> int:
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        b = (self.num_heads * hd + 2 * self.num_kv_heads * hd) if self.qkv_bias else 0
        return q + kv + o + b + 2 * d   # + norms

    def _mlp_params(self, d: int, dff: int) -> int:
        m = 3 if self.glu else 2
        return m * d * dff

    def _block_params(self, kind: str, d: int, hd: int) -> int:
        if kind == BLOCK_ATTN:
            return self._attn_params(d, hd) + self._mlp_params(d, self.d_ff)
        if kind == BLOCK_MOE:
            dff = self.moe_d_ff or self.d_ff
            routed = self.num_experts * self._mlp_params(d, dff)
            shared = self.num_shared_experts * self._mlp_params(d, dff)
            router = d * self.num_experts
            return self._attn_params(d, hd) + routed + shared + router
        if kind == BLOCK_MAMBA:
            di = self.ssm_expand * d
            # in_proj (x,z), conv, ssm params (A,dt,B,C heads), out_proj, norm
            return 2 * d * di + self.ssm_conv_width * di + 2 * di * self.ssm_state + 2 * di + di * d + 2 * d
        if kind in (BLOCK_MLSTM, BLOCK_SLSTM):
            di = self.ssm_expand * d
            # qkv + gates + out_proj (+ up/down ffn-ish projections)
            return 2 * d * di + 3 * di * hd_or(di) + di * d + 2 * d
        if kind == BLOCK_SHARED_ATTN:
            return 0  # shared params counted once at top level
        raise ValueError(kind)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, hd = self.d_model, self.resolved_head_dim
        dff = self.moe_d_ff or self.d_ff
        n = self.param_count()
        for kind in self.blocks:
            if kind == BLOCK_MOE:
                inactive = (self.num_experts - self.top_k) * self._mlp_params(d, dff)
                n -= inactive
        return n


def hd_or(x: int) -> int:   # tiny helper for mlstm param estimate
    return max(x // 8, 1)


# ---------------------------------------------------------------------------
# E2-Train technique
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SMDConfig:
    enabled: bool = False
    drop_prob: float = 0.5            # paper default
    # 'replacement' sampling interpretation: each step independently dropped
    # Protocol choice: run `epochs_multiplier` x the nominal epochs so SMD
    # trades energy for accuracy at a declared operating point.  Executed
    # compute relative to the baseline budget is
    # `epochs_multiplier * (1 - drop_prob)`; the paper's Fig. 3a point is
    # p=0.5, m=4/3 -> energy ratio 0.67.  Energy accounting derives the
    # ratio from here (core/ledger.py) instead of hard-coding 1.3333.
    epochs_multiplier: float = 4.0 / 3.0


@dataclass(frozen=True)
class SLUConfig:
    enabled: bool = False
    alpha: float = 1e-3               # FLOPs-regularizer weight (Eq. 1)
    gate_hidden: int = 10             # LSTM hidden dim (paper: 10)
    gate_proj: int = 10               # pooled-feature projection dim (paper: 10)
    min_keep_prob: float = 0.05       # numerical floor on gate output
    target_skip: float = 0.0          # optional target ratio for reg normalization
    never_skip_first_last: bool = True


@dataclass(frozen=True)
class PSGConfig:
    enabled: bool = False
    bits_x: int = 8                   # activation precision (paper: 8)
    bits_g: int = 16                  # output-grad precision (paper: 16)
    bits_x_msb: int = 4               # predictor activation MSBs (paper: 4)
    bits_g_msb: int = 10              # predictor grad MSBs (paper: 10)
    beta: float = 0.05                # adaptive threshold ratio (paper: 0.05)
    swa: bool = True                  # stochastic weight averaging (paper uses SWA)
    swa_start_frac: float = 0.5
    majority_vote: bool = False       # beyond-paper: 1-bit sign all-reduce
    # kernel backend for the PSG backward: "auto" defers to the dispatch
    # layer's platform probe; "reference" | "interpret" | "mosaic" pin it
    # per-experiment (DESIGN.md §Dispatch).
    backend: str = "auto"
    # FSDP all-gather of the weight on int8 codes instead of bf16 (replaces
    # the retired REPRO_PSG_INT8_GATHER trace-time env read).
    int8_gather: bool = False
    # Route CNN convolutions through the fused implicit-GEMM Pallas kernels
    # (kernels/conv.py): forward, PSG weight gradient AND the input
    # gradient run in-kernel; no conv path materializes a patch tensor in
    # either direction (DESIGN.md §Kernels).  None (the default) = auto:
    # fused on the reference/interpret backends, materialized im2col on
    # Mosaic (opt-in pending a real-TPU profile — ROADMAP "Finish the
    # Pallas kernel story").  Explicit True/False pins it per-experiment
    # (the frozen config is a static jit argument, so the selection is
    # jit-cache-correct); resolution lives in core/psg.fused_conv_active.
    fused_conv: Optional[bool] = None
    # Route transformer self-attention through the flash Pallas kernels
    # (kernels/flash_attn.py): forward streams KV tiles through VMEM and
    # the backward recomputes probability tiles from the logsumexp
    # residual, with the PSG predictor applied to the dk/dv contractions —
    # no (S, T) tensor in HBM in either direction (DESIGN.md §Kernels).
    # None (the default) = auto: fused on the reference/interpret
    # backends, the materialized/chunked softmax paths on Mosaic (same
    # opt-in-pending-TPU-profile posture as fused_conv).  Explicit
    # True/False pins it per-experiment; resolution lives in
    # core/psg.fused_attention_active.
    fused_attention: Optional[bool] = None


@dataclass(frozen=True)
class E2TrainConfig:
    smd: SMDConfig = field(default_factory=SMDConfig)
    slu: SLUConfig = field(default_factory=SLUConfig)
    psg: PSGConfig = field(default_factory=PSGConfig)

    @classmethod
    def full(cls) -> "E2TrainConfig":
        return cls(
            smd=SMDConfig(enabled=True),
            slu=SLUConfig(enabled=True),
            psg=PSGConfig(enabled=True),
        )

    @classmethod
    def off(cls) -> "E2TrainConfig":
        return cls()


# ---------------------------------------------------------------------------
# Run shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 256
    seq_len: int = 4096
    microbatches: int = 1             # gradient accumulation
    lr: float = 0.1
    schedule: str = "step"            # step | cosine | constant
    warmup_steps: int = 0
    total_steps: int = 64_000         # paper: 64k iterations
    decay_points: Tuple[float, ...] = (0.5, 0.75)   # paper: 32k, 48k
    decay_factor: float = 0.1
    momentum: float = 0.9
    weight_decay: float = 1e-4
    optimizer: str = "sgdm"           # sgdm | signsgd | psg | adamw
    grad_clip: float = 0.0
    remat: str = "block"              # none | block | full
    loss: str = "xent"
    seed: int = 0
    label_smoothing: float = 0.0


@dataclass(frozen=True)
class ServeConfig:
    batch: int = 32
    prefill_len: int = 32768
    max_kv_len: int = 32768
    decode_steps: int = 1


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")
    # logical->physical rules, e.g. fsdp shards params over "data"
    fsdp: bool = True
    seq_shard: bool = False           # SP: shard sequence over model axis


# ---------------------------------------------------------------------------
# Experiment bundle + input shapes
# ---------------------------------------------------------------------------

# The four assigned shape cells (LM shapes are seq_len x global_batch).
SHAPES: Mapping[str, Mapping[str, Any]] = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


@dataclass(frozen=True)
class Experiment:
    model: ModelConfig
    e2: E2TrainConfig = field(default_factory=E2TrainConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    serve: ServeConfig = field(default_factory=ServeConfig)
    mesh: MeshConfig = field(default_factory=MeshConfig)
    # Which entry of the ``repro.tasks`` registry provides init/loss for this
    # experiment ("lm" for the transformer stack, "cifar_cnn" for the paper's
    # ResNet/MobileNetV2 backbones).  The training stack resolves everything
    # model-specific through this key.
    task: str = "lm"

    def with_shape(self, shape: str) -> "Experiment":
        s = SHAPES[shape]
        if s["kind"] == "train":
            return dataclasses.replace(
                self, train=dataclasses.replace(
                    self.train, seq_len=s["seq_len"], global_batch=s["global_batch"]))
        return dataclasses.replace(
            self, serve=dataclasses.replace(
                self.serve, batch=s["global_batch"], prefill_len=s["seq_len"],
                max_kv_len=s["seq_len"]))

    def replace(self, **kw) -> "Experiment":
        return dataclasses.replace(self, **kw)


def shape_applicable(model: ModelConfig, shape: str) -> Tuple[bool, str]:
    """Whether a shape cell applies to an arch (per DESIGN.md §5)."""
    if shape == "long_500k" and not model.is_subquadratic:
        return False, "pure full-attention arch: no sub-quadratic 500k path (DESIGN.md §5)"
    return True, ""
