"""EnergyLedger: measured training telemetry → the paper's headline numbers.

The middle layer of the energy API (DESIGN.md §Energy).  The trainer already
measures what actually executed — SMD executed/dropped step counts, per-step
SLU execution ratios, the MAC-weighted PSG fallback-tile ratio — and the
cost model (``core/cost.py``, resolved through ``repro.tasks``) knows the
per-layer op counts.  The ledger composes the two with the 45nm per-op
tables (``core/energy.py``) into an :class:`EnergyReport` that always shows
**measured next to assumed**:

* *assumed* — the operating point the config declares (``smd.drop_prob`` ×
  ``smd.epochs_multiplier``, ``slu.target_skip``, the 0.4 PSG fallback
  design assumption);
* *measured* — what the telemetry says, ``None`` when no measurement exists
  (a baseline run has no PSG fallback measurement — that is not a
  measurement of zero).

The paper's Table 3/4 composition law
(``savings = 1 − smd_ratio · (1 − slu_skip) · psg_factor``) is carried as a
cross-check column (``paper_composition``, using the paper's implied
r = 0.368) so every report can be compared against the published rows
(80.27 / 85.20 / 90.13 % at skip 20/40/60%).

Entry point: ``Trainer.energy_report()``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

from repro.core.config import Experiment
from repro.core.cost import TableCostModel
from repro.core.energy import (FP32_MAC_PJ, PSG_FACTOR_PAPER,
                               PSG_FALLBACK_ASSUMED, computational_savings,
                               measured_psg_factor, move_energy_pj,
                               psg_factor_from_energy_model, psg_mac_pj)


@dataclass(frozen=True)
class TechniqueEntry:
    """One technique's operating point, measured next to assumed.

    ``assumed`` is config-derived; ``measured`` comes from telemetry and is
    ``None`` when nothing was measured — ``None`` ≠ 0.
    """

    name: str
    enabled: bool
    assumed: Optional[float]
    measured: Optional[float]

    def resolved(self) -> Optional[float]:
        """Best available value: measured when present, else assumed."""
        return self.measured if self.measured is not None else self.assumed


@dataclass(frozen=True)
class EnergyReport:
    """The paper's accounting for one run/config, measured vs assumed.

    Ratios: ``smd`` is executed compute relative to the baseline step budget
    (``epochs_multiplier × (1 − drop_prob)``); ``slu`` is the skip ratio
    over gatable blocks; ``psg`` is the fallback-tile ratio.
    ``paper_composition`` applies the paper's own Table 3/4 law with its
    implied PSG factor r = 0.368 to the config-derived operating point —
    the cross-check against the published rows.  Energy columns integrate
    the 45nm per-op model over ``steps`` nominal training steps.
    """

    model: str
    task: str
    steps: int
    batch: int
    fwd_macs_per_example: float
    params: int
    gated_fraction: float
    smd: TechniqueEntry
    slu: TechniqueEntry
    psg: TechniqueEntry
    psg_factor_assumed: Optional[float]
    psg_factor_measured: Optional[float]
    computational_savings_assumed: float
    computational_savings_measured: Optional[float]
    paper_composition: float
    energy_pj_baseline: float
    energy_pj_assumed: float
    energy_pj_measured: Optional[float]
    energy_savings_assumed: float
    energy_savings_measured: Optional[float]
    # cost-table verdict from the static audit (analysis/audit.py): True
    # when the CostModel MACs behind this report reconciled against the
    # traced-jaxpr and compiled-HLO counts, False when the audit ran and
    # diverged, None when no audit was requested — None ≠ False.
    validated_against_hlo: Optional[bool] = None
    # straggler telemetry (DESIGN.md §Fault-tolerance): steps force-dropped
    # because they exceeded the per-step deadline.  A subset of the SMD
    # dropped count (the measured smd ratio already reflects them); carried
    # separately so a report distinguishes "dropped by schedule" from
    # "dropped because the hardware straggled".
    straggler_dropped: int = 0

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def summary(self) -> str:
        """Human-readable measured-vs-assumed table."""
        def fmt(v, pct=False):
            if v is None:
                return "—"
            return f"{v:.2%}" if pct else f"{v:.4f}"

        lines = [
            f"energy report: {self.model} ({self.task}), "
            f"{self.fwd_macs_per_example/1e6:.1f}M MACs/example, "
            f"{self.params/1e6:.2f}M params, {self.steps} nominal steps",
            f"  {'technique':<12}{'assumed':>10}{'measured':>10}",
        ]
        for t in (self.smd, self.slu, self.psg):
            tag = t.name + ("" if t.enabled else " (off)")
            lines.append(f"  {tag:<12}{fmt(t.assumed):>10}{fmt(t.measured):>10}")
        lines += [
            f"  {'psg factor':<12}{fmt(self.psg_factor_assumed):>10}"
            f"{fmt(self.psg_factor_measured):>10}",
            f"  computational savings: assumed {fmt(self.computational_savings_assumed, True)}"
            f" | measured {fmt(self.computational_savings_measured, True)}"
            f" | paper composition {fmt(self.paper_composition, True)}",
            f"  45nm energy savings:   assumed {fmt(self.energy_savings_assumed, True)}"
            f" | measured {fmt(self.energy_savings_measured, True)}"
            f" (baseline {self.energy_pj_baseline:.3e} pJ)",
            f"  cost table validated vs jaxpr/HLO: "
            + ("—" if self.validated_against_hlo is None
               else "yes" if self.validated_against_hlo else "NO"),
        ]
        return "\n".join(lines)


class EnergyLedger:
    """Accumulates per-step telemetry and turns it into an EnergyReport.

    Feed it a trainer (:meth:`from_trainer`) or record manually:
    ``record_step(metrics)`` per executed step, ``record_dropped()`` per
    SMD-dropped step.  A ledger with no recorded telemetry still reports —
    with every ``measured`` column ``None`` (config-derived accounting
    only), which is how the Table 3 sweep is produced without training.
    """

    def __init__(self, exp: Experiment, cost: Optional[TableCostModel] = None):
        if cost is None:
            from repro.tasks import cost_model   # deferred: tasks imports core
            cost = cost_model(exp)
        self.exp = exp
        self.cost = cost
        self.executed_steps = 0
        self.dropped_steps = 0
        self.straggler_dropped = 0
        self._slu_exec: List[float] = []
        self._psg_fallback: List[float] = []

    # ----- recording -----

    def record_step(self, metrics: Dict[str, float]) -> None:
        self.executed_steps += 1
        if "slu_exec_ratio" in metrics:
            self._slu_exec.append(float(metrics["slu_exec_ratio"]))
        if "psg_fallback_ratio" in metrics:
            self._psg_fallback.append(float(metrics["psg_fallback_ratio"]))

    def record_dropped(self, n: int = 1) -> None:
        self.dropped_steps += n

    @classmethod
    def from_trainer(cls, trainer) -> "EnergyLedger":
        led = cls(trainer.exp)
        for h in trainer.history:
            led.record_step(h)
        # the trainer's counters are authoritative (drops leave no metrics)
        led.executed_steps = trainer.executed_steps
        led.dropped_steps = trainer.dropped_steps
        led.straggler_dropped = getattr(trainer, "straggler_dropped_steps", 0)
        return led

    # ----- measured quantities (None = not measured, never 0) -----

    def measured_exec_fraction(self) -> Optional[float]:
        """Executed / attempted nominal steps (the measured keep rate, ≈
        1 − drop_prob); None before any step."""
        total = self.executed_steps + self.dropped_steps
        if not self.exp.e2.smd.enabled or total == 0:
            return None
        return self.executed_steps / total

    def measured_smd_ratio(self, steps: int) -> Optional[float]:
        """Executed compute relative to a ``steps``-step baseline budget —
        the run's *actual* SMD energy ratio, executed_steps / steps.

        This deliberately does NOT scale the measured keep rate by the
        config's ``epochs_multiplier``: the multiplier is a protocol
        *assumption*, and a run that attempted a different number of
        nominal steps than the declared protocol (e.g. a bench running 2x
        the baseline budget) must report what it actually executed.  For a
        partial-telemetry ledger, pass the attempted window as ``steps``.
        """
        if not self.exp.e2.smd.enabled or \
                self.executed_steps + self.dropped_steps == 0:
            return None
        return self.executed_steps / steps

    def measured_slu_skip(self) -> Optional[float]:
        if not self.exp.e2.slu.enabled or not self._slu_exec:
            return None
        return 1.0 - sum(self._slu_exec) / len(self._slu_exec)

    def measured_psg_fallback(self) -> Optional[float]:
        if not self._psg_fallback:
            return None
        return sum(self._psg_fallback) / len(self._psg_fallback)

    # ----- the report -----

    def report(self, steps: Optional[int] = None,
               validate_against_hlo: bool = False) -> EnergyReport:
        """Build the report; with ``validate_against_hlo`` also run the
        static cost audit (cached per config) and stamp its verdict into
        ``EnergyReport.validated_against_hlo``."""
        exp, cost = self.exp, self.cost
        verdict: Optional[bool] = None
        if validate_against_hlo:
            # deferred: analysis imports tasks imports core
            from repro.analysis.audit import validated_verdict
            verdict = validated_verdict(exp)
        e2, tc = exp.e2, exp.train
        steps = steps if steps is not None else tc.total_steps
        batch = tc.global_batch

        # SMD: compute executed relative to the baseline step budget.
        # assumed = the declared protocol (m x epochs at keep rate 1-p);
        # measured = what this run actually executed vs that budget.
        m = e2.smd.epochs_multiplier
        smd = TechniqueEntry(
            "smd", e2.smd.enabled,
            m * (1.0 - e2.smd.drop_prob) if e2.smd.enabled else None,
            self.measured_smd_ratio(steps))
        slu = TechniqueEntry(
            "slu", e2.slu.enabled,
            e2.slu.target_skip if e2.slu.enabled else None,
            self.measured_slu_skip())
        psg = TechniqueEntry(
            "psg", e2.psg.enabled,
            PSG_FALLBACK_ASSUMED if e2.psg.enabled else None,
            self.measured_psg_fallback())

        p = e2.psg
        bits = (p.bits_x, p.bits_g, p.bits_x_msb, p.bits_g_msb)
        factor_a = (psg_factor_from_energy_model(bits, PSG_FALLBACK_ASSUMED)
                    if p.enabled else None)
        factor_m = (measured_psg_factor(e2, psg.measured)
                    if psg.measured is not None else None)

        # --- composition law (paper Tables 3/4) on MAC counts ---
        smd_a = smd.assumed if smd.assumed is not None else 1.0
        skip_a = slu.assumed if slu.assumed is not None else 0.0
        comp_a = computational_savings(smd_a, skip_a,
                                       factor_a if factor_a is not None else 1.0)
        paper = computational_savings(
            smd_a, skip_a, PSG_FACTOR_PAPER if p.enabled else 1.0)

        measured_any = any(t.measured is not None for t in (smd, slu, psg))
        comp_m = None
        if measured_any:
            smd_r = smd.resolved() if smd.enabled else 1.0
            skip_r = slu.resolved() if slu.enabled else 0.0
            f_r = 1.0
            if p.enabled:
                f_r = factor_m if factor_m is not None else factor_a
            comp_m = computational_savings(smd_r, skip_r, f_r)

        # --- 45nm energy integration over the nominal step budget ---
        def step_energy(slu_exec: float, fallback: Optional[float]) -> float:
            if p.enabled:
                mac_pj = psg_mac_pj(p, PSG_FALLBACK_ASSUMED
                                    if fallback is None else fallback)
                move_bits = p.bits_x
            else:
                mac_pj, move_bits = FP32_MAC_PJ, 32
            return (cost.train_macs(batch, slu_exec) * mac_pj
                    + cost.moved_words(batch, slu_exec)
                    * move_energy_pj(move_bits))

        # baseline: every nominal step executed, full network, fp32
        baseline = steps * (cost.train_macs(batch) * FP32_MAC_PJ
                            + cost.moved_words(batch) * move_energy_pj(32))
        e_assumed = steps * smd_a * step_energy(1.0 - skip_a, None)
        e_measured = None
        if measured_any:
            smd_r = smd.resolved() if smd.enabled else 1.0
            skip_r = slu.resolved() if slu.enabled else 0.0
            e_measured = steps * smd_r * step_energy(
                1.0 - skip_r, psg.resolved() if p.enabled else None)

        return EnergyReport(
            model=exp.model.name, task=exp.task, steps=int(steps),
            batch=int(batch),
            fwd_macs_per_example=cost.fwd_macs(),
            params=cost.param_count(),
            gated_fraction=cost.gated_fraction(),
            smd=smd, slu=slu, psg=psg,
            psg_factor_assumed=factor_a, psg_factor_measured=factor_m,
            computational_savings_assumed=comp_a,
            computational_savings_measured=comp_m,
            paper_composition=paper,
            energy_pj_baseline=baseline,
            energy_pj_assumed=e_assumed,
            energy_pj_measured=e_measured,
            energy_savings_assumed=1.0 - e_assumed / baseline,
            energy_savings_measured=(
                None if e_measured is None else 1.0 - e_measured / baseline),
            validated_against_hlo=verdict,
            straggler_dropped=int(self.straggler_dropped))
