"""Data-level technique: Stochastic Mini-batch Dropping (SMD), paper §3.1.

At each training step, the mini-batch is skipped with probability
``drop_prob`` (paper default 0.5).  The decision is a *counter-based*
deterministic function of ``(seed, step)`` so that in a multi-pod SPMD
setting every host independently computes the same decision — no collective
is needed to agree on a drop, which is what lets SMD double as straggler
mitigation (DESIGN.md §7): a pod that would miss the step deadline declares
the step dropped, and because SMD-style sampling-with-replacement is exactly
what the training dynamics already tolerate, convergence is unaffected.

``equivalent_steps`` maps a full-training iteration budget to the number of
*executed* steps under SMD; the paper's adopted operating point is energy
ratio 0.67 (i.e. SMD with 2x the nominal epochs costs 0.67x the energy but
reaches higher accuracy than the standard protocol, Fig. 3a).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import SMDConfig


def smd_keep(seed: int, step, drop_prob: float):
    """Traceable keep-decision for step ``step`` (jnp scalar or int)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    return jax.random.uniform(key) >= drop_prob


def smd_keep_host(seed: int, step: int, drop_prob: float) -> bool:
    """Host-side (non-traced) version: decides whether to even fetch data."""
    return bool(np.asarray(smd_keep(seed, int(step), drop_prob)))


def smd_schedule(cfg: SMDConfig, seed: int, total_steps: int) -> np.ndarray:
    """Boolean keep-mask for a whole run (for logging / tests)."""
    if not cfg.enabled:
        return np.ones((total_steps,), bool)
    return np.array([smd_keep_host(seed, t, cfg.drop_prob)
                     for t in range(total_steps)])


def expected_energy_ratio(cfg: SMDConfig,
                          epochs_multiplier: Optional[float] = None) -> float:
    """Energy of SMD training relative to standard training.

    Running SMD for ``m x`` the nominal iterations costs ``m * (1 - p)``
    of standard training's per-sample compute.  ``m`` defaults to the
    config's declared protocol (``cfg.epochs_multiplier``); the paper's
    operating point (Fig. 3a) is m=4/3, p=0.5 -> 0.67.
    """
    if not cfg.enabled:
        return 1.0 if epochs_multiplier is None else epochs_multiplier
    m = cfg.epochs_multiplier if epochs_multiplier is None else epochs_multiplier
    return m * (1.0 - cfg.drop_prob)


class SMDIterator:
    """Wrap a data iterator; yields (step, batch_or_None).

    ``None`` means the step is dropped — the training loop must skip compute
    *and data fetch* (the underlying iterator is not advanced), which is the
    zero-overhead property the paper relies on.
    """

    def __init__(self, it, cfg: SMDConfig, seed: int, start_step: int = 0):
        self._it = it
        self._cfg = cfg
        self._seed = seed
        self._step = start_step

    def __iter__(self):
        return self

    def __next__(self):
        step = self._step
        self._step += 1
        if self._cfg.enabled and not smd_keep_host(self._seed, step,
                                                   self._cfg.drop_prob):
            return step, None
        return step, next(self._it)
