"""Algorithm-level technique: Predictive Sign Gradient descent (PSG), §3.3.

The paper's insight: SignSGD only needs ``sign(g_w)``, so instead of
computing the full-precision weight gradient ``g_w = x^T g_y`` and then
taking signs, *predict* the sign from an MSB-only low-precision product

    g_w_msb = (x_msb)^T (g_y_msb)          # 4-bit x, 10-bit g_y

and fall back to the (fixed-point) full product only where the predictor's
magnitude is below an adaptive threshold ``tau = beta * max|g_w_msb|``
(Eq. 2).  The failure probability decays exponentially in predictor
precision (Eq. 3).

TPU adaptation (DESIGN.md §3.2): the paper's predictor reuses MSBs inside a
bit-serial MAC — a circuit trick with no TPU analogue.  Here the predictor
is an int8xint8 MXU matmul of the quantized operands (int ops run at >=2x
bf16 peak on v5e) and the *fallback* is tile-level inside the Pallas kernel
(``repro.kernels.psg_matmul``) rather than element-level, because the MXU is
dense.  This module holds the pure-jnp element-level reference semantics
(the oracle the kernel is tested against) and the ``custom_vjp`` integration
that routes model matmuls through PSG at trace time.

Mixed precision follows the paper (after [Banner et al. 2018]): activations/
weights at ``bits_x`` (8), output-gradients at ``bits_g`` (16) — gradients
need more headroom; predictors at 4/10 bits.

Distributed bonus (beyond paper): the weight-gradient leaves PSG as a sign
tensor in {-1, 0, +1}; the data-parallel mean of signs followed by the
SignSGD sign() IS majority vote — i.e. PSG composes into 1-bit gradient
all-reduce compression for free (``optim/majority_vote.py``).
"""
from __future__ import annotations

import contextlib
import threading
from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import PSGConfig

# ---------------------------------------------------------------------------
# quantization primitives
# ---------------------------------------------------------------------------


def qscale(x: jnp.ndarray, bits: int, axis=None) -> jnp.ndarray:
    """Symmetric per-tensor (or per-axis) scale: max|x| / (2^(b-1) - 1)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=axis is not None)
    return jnp.maximum(amax, 1e-12) / (2.0 ** (bits - 1) - 1.0)


def quantize(x: jnp.ndarray, bits: int, axis=None) -> jnp.ndarray:
    """Fake-quantize: round to a ``bits``-bit symmetric fixed-point grid."""
    s = qscale(x, bits, axis)
    q = jnp.round(x.astype(jnp.float32) / s)
    lim = 2.0 ** (bits - 1) - 1.0
    return (jnp.clip(q, -lim, lim) * s).astype(x.dtype)


def quantize_int(x: jnp.ndarray, bits: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Integer codes + scale (used by the Pallas kernel path)."""
    s = qscale(x, bits)
    lim = 2.0 ** (bits - 1) - 1.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -lim, lim)
    dt = jnp.int8 if bits <= 8 else jnp.int32 if bits > 16 else jnp.int16
    return q.astype(dt), s


def msb_of(x: jnp.ndarray, bits_full: int, bits_msb: int) -> jnp.ndarray:
    """Keep the ``bits_msb`` most significant bits of a ``bits_full`` code.

    On the fixed-point grid of ``bits_full`` this means re-rounding onto the
    coarser ``bits_msb`` grid *with the same dynamic range* — exactly the
    paper's MSB-part operand (quantization step Delta = 2^-(B_msb - 1) on a
    [-1, 1]-normalized range).
    """
    return quantize(x, bits_msb)


# ---------------------------------------------------------------------------
# reference (element-level) PSG weight-gradient — the oracle
# ---------------------------------------------------------------------------


def psg_grad_w_ref(x2: jnp.ndarray, gy2: jnp.ndarray, cfg: PSGConfig
                   ) -> jnp.ndarray:
    """Element-level Eq. (2).  x2: (N, din), gy2: (N, dout) -> (din, dout).

    Returns the sign-valued weight gradient in {-1, 0, +1} (float32).
    """
    xq = quantize(x2, cfg.bits_x)
    gq = quantize(gy2, cfg.bits_g)
    xm = msb_of(x2, cfg.bits_x, cfg.bits_x_msb)
    gm = msb_of(gy2, cfg.bits_g, cfg.bits_g_msb)
    g_msb = xm.astype(jnp.float32).T @ gm.astype(jnp.float32)
    g_full = xq.astype(jnp.float32).T @ gq.astype(jnp.float32)
    tau = cfg.beta * jnp.max(jnp.abs(g_msb))
    pred_ok = jnp.abs(g_msb) >= tau
    return jnp.where(pred_ok, jnp.sign(g_msb), jnp.sign(g_full))


def psg_predictor_usage(x2, gy2, cfg: PSGConfig) -> jnp.ndarray:
    """Fraction of weight-grad entries decided by the MSB predictor."""
    xm = msb_of(x2, cfg.bits_x, cfg.bits_x_msb)
    gm = msb_of(gy2, cfg.bits_g, cfg.bits_g_msb)
    g_msb = xm.astype(jnp.float32).T @ gm.astype(jnp.float32)
    tau = cfg.beta * jnp.max(jnp.abs(g_msb))
    return jnp.mean((jnp.abs(g_msb) >= tau).astype(jnp.float32))


def prediction_error_bound(x2, gy2, cfg: PSGConfig) -> jnp.ndarray:
    """Empirical Chebyshev bound of Eq. (3) on a normalized [-1,1] range."""
    xs = x2 / jnp.maximum(jnp.max(jnp.abs(x2)), 1e-12)
    gs = gy2 / jnp.maximum(jnp.max(jnp.abs(gy2)), 1e-12)
    dx = 2.0 ** (-(cfg.bits_x_msb - 1))
    dg = 2.0 ** (-(cfg.bits_g_msb - 1))
    g_full = xs.T @ gs
    tau = cfg.beta * jnp.max(jnp.abs(g_full))
    # E1/E2 with the H_{p,n} denominators lower-bounded by tau (worst case)
    e1 = jnp.sum(jnp.sum(gs ** 2, axis=0)) / (12.0 * tau ** 2)
    e2 = jnp.sum(jnp.sum(xs ** 2, axis=0)) / (12.0 * tau ** 2)
    return dx ** 2 * e1 + dg ** 2 * e2


# ---------------------------------------------------------------------------
# custom_vjp matmul with PSG backward
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def psg_matmul(x2: jnp.ndarray, w: jnp.ndarray, cfg: PSGConfig) -> jnp.ndarray:
    """(N, din) @ (din, dout) with PSG semantics.

    Forward runs on the ``bits_x`` fixed-point grid (the mixed-precision
    training regime of [15] the paper adopts).  The weight is quantized to
    *integer codes on its FSDP shard* and explicitly replicated before
    dequantization — placing the FSDP all-gather on int8 bytes (2x less
    wire traffic than bf16; the paper's §3.3 low-precision data-movement
    saving applied to the collective term).
    """
    import os
    xq = quantize(x2, cfg.bits_x)
    if os.environ.get("REPRO_PSG_INT8_GATHER", "0") == "1":
        from repro.distributed.sharding import replicate
        codes, s = quantize_int(w, cfg.bits_x)
        codes = replicate(codes)              # int8 on the wire
        wq = codes.astype(xq.dtype) * s.astype(xq.dtype)
    else:
        wq = quantize(w, cfg.bits_x).astype(xq.dtype)
    return xq @ wq


def _psg_fwd(x2, w, cfg):
    return psg_matmul(x2, w, cfg), (x2, w)


def _psg_bwd(cfg, res, gy):
    x2, w = res
    gq = quantize(gy, cfg.bits_g)
    wq = quantize(w, cfg.bits_x)
    dx = (gq @ wq.T.astype(gq.dtype)).astype(x2.dtype)
    dw = psg_grad_w_ref(x2, gy, cfg).astype(w.dtype)
    return dx, dw


psg_matmul.defvjp(_psg_fwd, _psg_bwd)


# ---------------------------------------------------------------------------
# trace-time dispatch: layers call psg.einsum / psg.matmul
# ---------------------------------------------------------------------------

_state = threading.local()


def active_config() -> Optional[PSGConfig]:
    cfg = getattr(_state, "cfg", None)
    return cfg if (cfg is not None and cfg.enabled) else None


@contextlib.contextmanager
def enable(cfg: Optional[PSGConfig]):
    """Route model matmuls through PSG while tracing under this context."""
    prev = getattr(_state, "cfg", None)
    _state.cfg = cfg
    try:
        yield
    finally:
        _state.cfg = prev


def matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: (..., din) @ w: (din, dout), PSG-routed when enabled."""
    cfg = active_config()
    if cfg is None:
        return x @ w.astype(x.dtype)
    lead = x.shape[:-1]
    y2 = psg_matmul(x.reshape(-1, x.shape[-1]), w, cfg)
    return y2.reshape(*lead, w.shape[-1])


def einsum(pattern: str, x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """PSG-aware einsum for the weight-matmul patterns used by the models."""
    cfg = active_config()
    if cfg is None:
        return jnp.einsum(pattern, x, w.astype(x.dtype))
    if pattern in ("bsd,dnh->bsnh", "btd,dnh->btnh"):
        B, S, d = x.shape
        _, n, h = w.shape
        y = psg_matmul(x.reshape(B * S, d), w.reshape(d, n * h), cfg)
        return y.reshape(B, S, n, h)
    if pattern == "bsnh,nhd->bsd":
        B, S, n, h = x.shape
        d = w.shape[-1]
        y = psg_matmul(x.reshape(B * S, n * h), w.reshape(n * h, d), cfg)
        return y.reshape(B, S, d)
    if pattern == "bd,dnh->bnh":
        B, d = x.shape
        _, n, h = w.shape
        return psg_matmul(x, w.reshape(d, n * h), cfg).reshape(B, n, h)
    if pattern in ("ecd,edf->ecf", "ecf,efd->ecd"):
        return jax.vmap(lambda xe, we: psg_matmul(xe, we, cfg))(x, w.astype(x.dtype))
    if pattern in ("gecd,edf->gecf", "gecf,efd->gecd"):
        G, E, C, din = x.shape
        dout = w.shape[-1]
        xe = jnp.moveaxis(x, 1, 0).reshape(E, G * C, din)
        ye = jax.vmap(lambda xi, wi: psg_matmul(xi, wi, cfg))(
            xe, w.astype(x.dtype))
        return jnp.moveaxis(ye.reshape(E, G, C, dout), 0, 1)
    # unknown pattern: fall back (no PSG) — keeps correctness, logged by tests
    return jnp.einsum(pattern, x, w.astype(x.dtype))
