"""Algorithm-level technique: Predictive Sign Gradient descent (PSG), §3.3.

The paper's insight: SignSGD only needs ``sign(g_w)``, so instead of
computing the full-precision weight gradient ``g_w = x^T g_y`` and then
taking signs, *predict* the sign from an MSB-only low-precision product

    g_w_msb = (x_msb)^T (g_y_msb)          # 4-bit x, 10-bit g_y

and fall back to the (fixed-point) full product only where the predictor's
magnitude is below an adaptive threshold ``tau = beta * max|g_w_msb|``
(Eq. 2).  The failure probability decays exponentially in predictor
precision (Eq. 3).

TPU adaptation (DESIGN.md §Dispatch): the paper's predictor reuses MSBs
inside a bit-serial MAC — a circuit trick with no TPU analogue.  Here the
predictor is an int8xint8 MXU matmul of the quantized operands (int ops run
at >=2x bf16 peak on v5e) and the *fallback* is tile-level inside the Pallas
kernel (``repro.kernels.psg_matmul``) rather than element-level, because the
MXU is dense.  The ``custom_vjp`` backward below routes the weight gradient
through that tile kernel via ``repro.kernels.dispatch`` — the element-level
reference now lives in ``repro.kernels.ref`` and is test-only.

The backward also *measures* how often tiles fell back to the full product
and reports it as the gradient of a probe input (see :func:`enable` /
:func:`probe_fallback_ratio`): cotangents of a shared probe accumulate
across every PSG matmul in the model, so one extra ``grad`` argument yields
the per-step MAC-weighted fallback ratio that drives ``core/energy.py`` —
measured, not assumed, predictor usage.

Mixed precision follows the paper (after [Banner et al. 2018]): activations/
weights at ``bits_x`` (8), output-gradients at ``bits_g`` (16) — gradients
need more headroom; predictors at 4/10 bits.

Distributed bonus (beyond paper): the weight-gradient leaves PSG as a sign
tensor in {-1, 0, +1}; the data-parallel mean of signs followed by the
SignSGD sign() IS majority vote — i.e. PSG composes into 1-bit gradient
all-reduce compression for free (``optim/majority_vote.py``).
"""
from __future__ import annotations

import contextlib
import threading
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.config import PSGConfig
from repro.core.quant import msb_of, quantize, quantize_int
from repro.kernels import dispatch
from repro.kernels.ref import (predictor_confidence_ref,  # test-only oracle
                               psg_grad_w_ref)            # (re-exports)

# (PROBE_FALLBACK_MACS, PROBE_TOTAL_MACS) slots of the probe vector: each
# PSG matmul's backward contributes [fallback_ratio * macs, macs], so the
# accumulated ratio is MAC-weighted — a tiny all-fallback layer cannot
# swamp a huge mostly-predicted one (the energy model charges MACs, so
# MACs are the right weight).
PROBE_SIZE = 2


def zero_probe() -> jnp.ndarray:
    return jnp.zeros((PROBE_SIZE,), jnp.float32)


def probe_fallback_ratio(probe_grad: jnp.ndarray) -> jnp.ndarray:
    """MAC-weighted measured fallback ratio from a probe cotangent."""
    return probe_grad[0] / jnp.maximum(probe_grad[1], 1.0)


# ---------------------------------------------------------------------------
# element-level reference statistics (kept here: they are *analysis* tools,
# not kernels — tests and notebooks call them through this module)
# ---------------------------------------------------------------------------


def psg_predictor_usage(x2, gy2, cfg: PSGConfig) -> jnp.ndarray:
    """Fraction of weight-grad entries decided by the MSB predictor."""
    _, pred_ok = predictor_confidence_ref(x2, gy2, cfg)
    return jnp.mean(pred_ok.astype(jnp.float32))


def prediction_error_bound(x2, gy2, cfg: PSGConfig) -> jnp.ndarray:
    """Empirical Chebyshev bound of Eq. (3) on a normalized [-1,1] range."""
    xs = x2 / jnp.maximum(jnp.max(jnp.abs(x2)), 1e-12)
    gs = gy2 / jnp.maximum(jnp.max(jnp.abs(gy2)), 1e-12)
    dx = 2.0 ** (-(cfg.bits_x_msb - 1))
    dg = 2.0 ** (-(cfg.bits_g_msb - 1))
    g_full = xs.T @ gs
    tau = cfg.beta * jnp.max(jnp.abs(g_full))
    # E1/E2 with the H_{p,n} denominators lower-bounded by tau (worst case)
    e1 = jnp.sum(jnp.sum(gs ** 2, axis=0)) / (12.0 * tau ** 2)
    e2 = jnp.sum(jnp.sum(xs ** 2, axis=0)) / (12.0 * tau ** 2)
    return dx ** 2 * e1 + dg ** 2 * e2


# ---------------------------------------------------------------------------
# custom_vjp matmul with PSG backward (tile-level kernel via dispatch)
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def _psg_matmul(x2: jnp.ndarray, w: jnp.ndarray, probe: jnp.ndarray,
                cfg: PSGConfig) -> jnp.ndarray:
    """(N, din) @ (din, dout) with PSG semantics.

    Forward runs on the ``bits_x`` fixed-point grid (the mixed-precision
    training regime of [15] the paper adopts).  With ``cfg.int8_gather`` the
    weight is quantized to *integer codes on its FSDP shard* and explicitly
    replicated before dequantization — placing the FSDP all-gather on int8
    bytes (2x less wire traffic than bf16; the paper's §3.3 low-precision
    data-movement saving applied to the collective term).

    ``probe`` is a zeros((2,)) carrier whose cotangent reports
    [fallback_ratio * macs, macs] from the backward kernel — see module
    docstring.
    """
    xq = quantize(x2, cfg.bits_x)
    if cfg.int8_gather:
        from repro.distributed.sharding import replicate
        codes, s = quantize_int(w, cfg.bits_x)
        codes = replicate(codes)              # int8 on the wire
        wq = codes.astype(xq.dtype) * s.astype(xq.dtype)
    else:
        wq = quantize(w, cfg.bits_x).astype(xq.dtype)
    return xq @ wq


def _psg_fwd(x2, w, probe, cfg):
    return _psg_matmul(x2, w, probe, cfg), (x2, w)


def _psg_bwd(cfg, res, gy):
    # precision: scope — origin tag for analysis/dataflow.py reports: any
    # narrow accumulator found downstream names this backward as its site
    with jax.named_scope("precision:psg_bwd"):
        return _psg_bwd_impl(cfg, res, gy)


def _psg_bwd_impl(cfg, res, gy):
    x2, w = res
    gq = quantize(gy, cfg.bits_g)
    wq = quantize(w, cfg.bits_x)
    dx = (gq @ wq.T.astype(gq.dtype)).astype(x2.dtype)
    # weight gradient: tile-level Eq. (2) through the kernel dispatch layer
    # (Pallas interpret on CPU, Mosaic on TPU, element-level oracle only
    # when explicitly pinned to the reference backend).
    sign, fallback = dispatch.psg_grad_w(x2, gy, cfg)
    dw = sign.astype(w.dtype)
    macs = jnp.float32(x2.shape[0]) * x2.shape[1] * gy.shape[1]
    dprobe = jnp.stack([fallback * macs, macs])
    return dx, dw, dprobe


_psg_matmul.defvjp(_psg_fwd, _psg_bwd)


def psg_matmul(x2: jnp.ndarray, w: jnp.ndarray, cfg: PSGConfig) -> jnp.ndarray:
    """Public PSG matmul; picks up the active stats probe (if any)."""
    return _psg_matmul(x2, w, _current_probe(), cfg)


# ---------------------------------------------------------------------------
# fused implicit-GEMM convolution (PSGConfig.fused_conv)
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _psg_conv2d(xp: jnp.ndarray, w: jnp.ndarray, probe: jnp.ndarray,
                k: int, stride: int, cfg: PSGConfig) -> jnp.ndarray:
    """NHWC conv ``(B, Hp, Wp, C) x (k*k*C, dout)`` with PSG semantics,
    without materializing the im2col operand.

    ``xp`` is pre-padded (padding lives OUTSIDE the custom_vjp so autodiff
    crops ``dx`` for free).  Forward quantizes both operands onto the
    ``bits_x`` grid — element-wise on the padded input, which is the same
    grid the im2col path puts on the patch tensor (gathering commutes with
    the per-tensor code map; the ``k < stride`` case where it would not is
    normalized away in :func:`conv2d`) — and runs the implicit-GEMM kernel
    through the dispatch layer.  ``probe`` is the shared fallback-stats
    carrier (module docstring).
    """
    xq = quantize(xp, cfg.bits_x)
    if cfg.int8_gather:
        from repro.distributed.sharding import replicate
        codes, s = quantize_int(w, cfg.bits_x)
        codes = replicate(codes)              # int8 on the wire
        wq = codes.astype(xq.dtype) * s.astype(xq.dtype)
    else:
        wq = quantize(w, cfg.bits_x).astype(xq.dtype)
    return dispatch.conv_fwd(xq, wq, cfg, k=k, stride=stride)


def _psg_conv2d_fwd(xp, w, probe, k, stride, cfg):
    return _psg_conv2d(xp, w, probe, k, stride, cfg), (xp, w)


def _psg_conv2d_bwd(k, stride, cfg, res, gy):
    # precision: scope — see _psg_bwd; the PR 7 bug lived exactly here
    with jax.named_scope("precision:psg_conv2d_bwd"):
        return _psg_conv2d_bwd_impl(k, stride, cfg, res, gy)


def _psg_conv2d_bwd_impl(k, stride, cfg, res, gy):
    xp, w = res
    B, Hp, Wp, C = xp.shape
    dout = w.shape[-1]
    ho, wo = gy.shape[1], gy.shape[2]
    gq = quantize(gy, cfg.bits_g)
    wq = quantize(w, cfg.bits_x)
    # input gradient: implicit transposed-conv kernel via the dispatch
    # layer — gy windows and tap-major weight slices are gathered inside
    # the kernel (dilated-window indexing for stride > 1), dx accumulates
    # in an f32 VMEM tile and each block is written exactly once.  The
    # old per-tap col2im scatter-add loop (k^2 strided HBM
    # read-modify-write passes) is demoted to kernels/ref.py and serves
    # as the reference-backend anchor; both accumulate in float32.
    dxp = dispatch.conv_grad_x(gq, wq, cfg, k=k, stride=stride,
                               hp=Hp, wp=Wp)
    # weight gradient: tile-level Eq. (2) with the patch gather inside the
    # kernel's reduction loop (dispatch: Pallas interpret on CPU, Mosaic on
    # TPU, element-level oracle when pinned to the reference backend).
    sign, fallback = dispatch.conv_grad_w(xp, gy, cfg, k=k, stride=stride)
    dw = sign.astype(w.dtype)
    macs = jnp.float32(B * ho * wo) * (k * k * C) * dout
    dprobe = jnp.stack([fallback * macs, macs])
    return dxp.astype(xp.dtype), dw, dprobe


_psg_conv2d.defvjp(_psg_conv2d_fwd, _psg_conv2d_bwd)


# ---------------------------------------------------------------------------
# fused flash attention with PSG dk/dv backward (PSGConfig.fused_attention)
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _psg_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   probe: jnp.ndarray, causal: bool,
                   cfg: PSGConfig) -> jnp.ndarray:
    """Self-attention ``(B, S, nh, hd) x (B, T, nkv, hd)`` with PSG
    backward semantics; no (S, T) tensor in HBM in either direction.

    Forward is the flash kernel (dispatch layer); the backward recomputes
    probability tiles from the logsumexp residual — fp32 dq, and the
    Eq. (2) MSB-predictor/fallback treatment on the dk/dv contractions.
    ``probe`` is the shared fallback-stats carrier (module docstring):
    attention MACs land in the same MAC-weighted ratio as the matmul/conv
    PSG ops.
    """
    o, _ = dispatch.attention_fwd(q, k, v, cfg, causal=causal)
    return o


def _psg_attention_fwd(q, k, v, probe, causal, cfg):
    o, lse = dispatch.attention_fwd(q, k, v, cfg, causal=causal)
    return o, (q, k, v, o, lse)


def _psg_attention_bwd(causal, cfg, res, gy):
    # precision: scope — origin tag for analysis/dataflow.py (see _psg_bwd)
    with jax.named_scope("precision:psg_attention_bwd"):
        return _psg_attention_bwd_impl(causal, cfg, res, gy)


def _psg_attention_bwd_impl(causal, cfg, res, gy):
    q, k, v, o, lse = res
    dq, dk, dv, fallback = dispatch.attention_bwd(q, k, v, o, lse, gy, cfg,
                                                  causal=causal)
    B, S, nh, hd = q.shape
    T = k.shape[1]
    # score pairs actually computed (causal self-attention: the upper
    # triangle is skipped); x2 for the dv and dk contractions
    pairs = S * (S + 1) // 2 if (causal and S == T) else S * T
    macs = jnp.float32(2 * B * nh * hd) * pairs
    dprobe = jnp.stack([fallback * macs, macs])
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype), dprobe


_psg_attention.defvjp(_psg_attention_fwd, _psg_attention_bwd)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True) -> jnp.ndarray:
    """Public fused-attention entry point; picks up the active PSG config
    and stats probe.  Callers gate on :func:`fused_attention_active`."""
    cfg = active_config()
    return _psg_attention(q, k, v, _current_probe(), causal, cfg)


def fused_attention_active(cfg: Optional[PSGConfig]) -> bool:
    """Resolve a config's ``fused_attention`` selection at trace time.

    Mirrors :func:`fused_conv_active`: explicit ``True``/``False`` wins;
    the default (``None`` = auto) runs the flash kernels on the
    reference/interpret backends and keeps the materialized/chunked
    softmax paths on Mosaic, which stays opt-in pending a real-TPU
    profile (ROADMAP "Finish the Pallas kernel story").
    """
    if cfg is None:
        return False
    if cfg.fused_attention is not None:
        return cfg.fused_attention
    return dispatch.resolve_backend(cfg) != dispatch.BACKEND_MOSAIC


def fused_conv_active(cfg: Optional[PSGConfig]) -> bool:
    """Resolve a config's ``fused_conv`` selection at trace time.

    Explicit ``True``/``False`` wins; the default (``None`` = auto) runs
    the fused implicit-GEMM path on the reference/interpret backends and
    keeps the materialized im2col path on Mosaic, which stays opt-in
    pending a real-TPU profile (ROADMAP "Finish the Pallas kernel story").
    """
    if cfg is None:
        return False
    if cfg.fused_conv is not None:
        return cfg.fused_conv
    return dispatch.resolve_backend(cfg) != dispatch.BACKEND_MOSAIC


def conv2d(x: jnp.ndarray, w: jnp.ndarray, *, k: int = 3,
           stride: int = 1) -> jnp.ndarray:
    """Fused-conv entry point: NHWC ``x`` with a patch-major ``(k*k*C,
    dout)`` weight, SAME padding ``k // 2`` (the models' convention).

    With an active PSG config this routes forward AND weight-gradient
    through the implicit-GEMM kernels; with none it falls back to the
    materialized im2col + plain matmul (correctness anchor — model code
    only selects this path when ``cfg.fused_conv`` is set anyway).

    The ``k < stride`` case (1x1 stride-2 projection shortcut) is
    normalized to a pre-subsampled stride-1 conv first: its im2col patch
    tensor IS the subsample, so quantizing after subsampling keeps the
    quantization grid — and therefore the PSG signs — identical to the
    im2col path's.
    """
    cfg = active_config()
    if k < stride:
        x = x[:, ::stride, ::stride, :]
        stride = 1
    pad = k // 2
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0))) if pad else x
    if cfg is None:
        from repro.kernels.ref import conv_fwd_ref
        return conv_fwd_ref(xp, w, k, stride)
    return _psg_conv2d(xp, w, _current_probe(), k, stride, cfg)


# ---------------------------------------------------------------------------
# trace-time dispatch: layers call psg.einsum / psg.matmul
# ---------------------------------------------------------------------------

_state = threading.local()


def active_config() -> Optional[PSGConfig]:
    cfg = getattr(_state, "cfg", None)
    return cfg if (cfg is not None and cfg.enabled) else None


def _current_probe() -> jnp.ndarray:
    probe = getattr(_state, "probe", None)
    return probe if probe is not None else zero_probe()


@contextlib.contextmanager
def enable(cfg: Optional[PSGConfig], probe: Optional[jnp.ndarray] = None):
    """Route model matmuls through PSG while tracing under this context.

    ``probe``: an optional zeros((2,)) array threaded into every PSG matmul;
    differentiate the enclosing loss w.r.t. it to read the accumulated
    [sum of fallback_ratio * macs, sum of macs] — the measured MAC-weighted
    per-step ``psg_fallback_ratio`` (see training/train_step.py).
    """
    prev = getattr(_state, "cfg", None)
    prev_probe = getattr(_state, "probe", None)
    _state.cfg = cfg
    _state.probe = probe
    try:
        yield
    finally:
        _state.cfg = prev
        _state.probe = prev_probe


def matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: (..., din) @ w: (din, dout), PSG-routed when enabled."""
    cfg = active_config()
    if cfg is None:
        return x @ w.astype(x.dtype)
    lead = x.shape[:-1]
    y2 = psg_matmul(x.reshape(-1, x.shape[-1]), w, cfg)
    return y2.reshape(*lead, w.shape[-1])


def einsum(pattern: str, x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """PSG-aware einsum for the weight-matmul patterns used by the models."""
    cfg = active_config()
    if cfg is None:
        return jnp.einsum(pattern, x, w.astype(x.dtype))
    if pattern in ("bsd,dnh->bsnh", "btd,dnh->btnh"):
        B, S, d = x.shape
        _, n, h = w.shape
        y = psg_matmul(x.reshape(B * S, d), w.reshape(d, n * h), cfg)
        return y.reshape(B, S, n, h)
    if pattern == "bsnh,nhd->bsd":
        B, S, n, h = x.shape
        d = w.shape[-1]
        y = psg_matmul(x.reshape(B * S, n * h), w.reshape(n * h, d), cfg)
        return y.reshape(B, S, d)
    if pattern == "bd,dnh->bnh":
        B, d = x.shape
        _, n, h = w.shape
        return psg_matmul(x, w.reshape(d, n * h), cfg).reshape(B, n, h)
    if pattern in ("ecd,edf->ecf", "ecf,efd->ecd"):
        return jax.vmap(lambda xe, we: psg_matmul(xe, we, cfg))(x, w.astype(x.dtype))
    if pattern in ("gecd,edf->gecf", "gecf,efd->gecd"):
        G, E, C, din = x.shape
        dout = w.shape[-1]
        xe = jnp.moveaxis(x, 1, 0).reshape(E, G * C, din)
        ye = jax.vmap(lambda xi, wi: psg_matmul(xi, wi, cfg))(
            xe, w.astype(x.dtype))
        return jnp.moveaxis(ye.reshape(E, G, C, dout), 0, 1)
    # unknown pattern: fall back (no PSG) — keeps correctness, logged by tests
    return jnp.einsum(pattern, x, w.astype(x.dtype))
