"""Per-layer cost models — the bottom layer of the energy API (DESIGN.md §Energy).

The paper's headline number is an *energy* figure, and energy is op counts
times per-op joules — so the op counts must be honest, per layer, for the
architecture that actually trained.  This module provides that substrate:

* :class:`LayerCost` — one layer's forward MACs / parameters / activation
  elements, plus whether SLU can gate it (identity-shortcut residual blocks
  only, mirroring ``models/resnet.py``).
* :class:`TableCostModel` — an immutable table of layers with the derived
  totals every consumer needs (``fwd_macs``, ``param_count``,
  ``train_macs``, gated fractions, moved words).
* Builders: :func:`resnet_cost` / :func:`mobilenet_cost` for the paper's
  CIFAR backbones (``family="cnn"`` configs), :func:`lm_cost` wrapping the
  analytic transformer model in ``core/energy.py``.

Resolution is *through the task registry*: ``repro.tasks.cost_model(exp)``
returns the experiment's model, so the training/benchmark stack never
hard-codes which family it is accounting for.  This retires the seed repo's
silent path where ``model_fwd_flops`` walked ``ModelConfig.blocks`` and
priced a ResNet as a stack of attention blocks.

Validation: ``tests/test_cost.py`` pins the CIFAR ResNet MAC totals against
independently computed values (ResNet-110 ≈ 253.1M MACs — the figure the
literature reports as "253 MFLOPs" — ResNet-74 ≈ 168.2M) and checks
parameter counts leaf-by-leaf against the actual jax parameter trees.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

from repro.core.config import ModelConfig

BYTES_FP32 = 4

# MobileNetV2 inverted-residual schedule, CIFAR variant: (expansion, cout,
# blocks, stride).  Must match ``models/resnet.MBV2_CFG`` — the cost model
# stays import-free of model code (core may not depend on models), so the
# table is restated here and ``tests/test_cost.py`` pins the two against
# each other.
MBV2_CFG = [
    (1, 16, 1, 1), (6, 24, 2, 1), (6, 32, 3, 2),
    (6, 64, 4, 2), (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]


@dataclass(frozen=True)
class LayerCost:
    """Forward cost of one layer for one example (one image / one sequence).

    ``macs``       multiply-accumulates of the forward pass;
    ``params``     trainable parameters (bias/affine included);
    ``out_elems``  activation elements written (drives movement energy);
    ``gated``      True when the layer lives inside an SLU-gatable block
                   (identity-shortcut residual blocks; the paper never gates
                   projection-shortcut transitions — ``models/resnet.py``).
    """

    name: str
    kind: str            # conv | bn | fc | embed | block | head | dw
    macs: float
    params: int
    out_elems: float
    gated: bool = False


@dataclass(frozen=True)
class TableCostModel:
    """A resolved per-layer cost table with the derived totals."""

    name: str
    layers: Tuple[LayerCost, ...]

    # ----- totals -----
    def fwd_macs(self) -> float:
        """Forward MACs per example."""
        return sum(l.macs for l in self.layers)

    def param_count(self) -> int:
        return sum(l.params for l in self.layers)

    def act_elems(self) -> float:
        """Activation elements written per example per forward."""
        return sum(l.out_elems for l in self.layers)

    # ----- SLU structure -----
    def gated_macs(self) -> float:
        return sum(l.macs for l in self.layers if l.gated)

    def gated_fraction(self) -> float:
        """Fraction of forward MACs that SLU gates can skip."""
        total = self.fwd_macs()
        return self.gated_macs() / total if total else 0.0

    def gated_act_elems(self) -> float:
        return sum(l.out_elems for l in self.layers if l.gated)

    # ----- training-step costs -----
    def train_macs(self, batch: int, slu_exec: float = 1.0) -> float:
        """MACs of one training step: fwd + bwd-x + bwd-w ≈ 3 × fwd.

        ``slu_exec``: fraction of gated-block compute that executed (1.0 =
        no skipping).  Skipped blocks cost neither forward nor backward.
        """
        per_ex = self.fwd_macs() - (1.0 - slu_exec) * self.gated_macs()
        return 3.0 * batch * per_ex

    def moved_words(self, batch: int, slu_exec: float = 1.0) -> float:
        """Words streamed through SRAM per training step: parameters plus
        the executed activations, each touched ~once per pass (×3 passes) —
        the same movement model ``core/energy.training_energy_pj`` uses."""
        acts = self.act_elems() - (1.0 - slu_exec) * self.gated_act_elems()
        return 3.0 * (self.param_count() + batch * acts)


# ---------------------------------------------------------------------------
# CIFAR ResNet (6n+2) — mirrors models/resnet.py layer by layer
# ---------------------------------------------------------------------------


def _conv(name: str, hw: int, k: int, cin: int, cout: int,
          gated: bool = False) -> LayerCost:
    return LayerCost(name, "conv", float(hw * hw * k * k * cin * cout),
                     k * k * cin * cout, float(hw * hw * cout), gated)


def _bn(name: str, hw: int, c: int, gated: bool = False) -> LayerCost:
    # one multiply-add per element (scale + shift); affine params only —
    # running stats are non-trainable state, not parameters
    return LayerCost(name, "bn", float(hw * hw * c), 2 * c,
                     float(hw * hw * c), gated)


def resnet_cost(cfg: ModelConfig, image: int = 32) -> TableCostModel:
    """Per-layer cost of the CIFAR ResNet encoded by a ``family="cnn"``
    config (``num_layers`` = depth 6n+2, ``d_model`` = stage-0 width,
    ``vocab_size`` = classes) — ``configs/paper_cnns.cnn_model``."""
    depth, width, classes = cfg.num_layers, cfg.d_model, cfg.vocab_size
    assert (depth - 2) % 6 == 0, "CIFAR ResNet depth must be 6n+2"
    n = (depth - 2) // 6
    layers: List[LayerCost] = [
        _conv("stem", image, 3, 3, width), _bn("stem_bn", image, width)]
    hw, cin = image, width
    for stage, cout in enumerate((width, 2 * width, 4 * width)):
        for b in range(n):
            stride = 2 if (stage > 0 and b == 0) else 1
            hw_in, hw = hw, hw // stride
            # identity-shortcut blocks gate; the projection transition
            # (channel change, owns `down`) never does — models/resnet.py
            gated = not (b == 0 and cin != cout)
            tag = f"s{stage}b{b}"
            layers += [
                _conv(f"{tag}.conv1", hw, 3, cin, cout, gated),
                _bn(f"{tag}.bn1", hw, cout, gated),
                _conv(f"{tag}.conv2", hw, 3, cout, cout, gated),
                _bn(f"{tag}.bn2", hw, cout, gated)]
            if b == 0 and cin != cout:
                layers.append(_conv(f"{tag}.down", hw, 1, cin, cout))
            cin = cout
    layers.append(LayerCost("fc", "fc", float(4 * width * classes),
                            4 * width * classes + classes, float(classes)))
    return TableCostModel(cfg.name, tuple(layers))


def _mbv2_layout() -> List[Tuple[int, int, int, int]]:
    """Static per-block (cin, hidden, cout, stride) from MBV2_CFG."""
    cin, out = 32, []
    for t, c, nblk, s in MBV2_CFG:
        for b in range(nblk):
            out.append((cin, cin * t, c, s if b == 0 else 1))
            cin = c
    return out


def mobilenet_cost(cfg: ModelConfig, image: int = 32) -> TableCostModel:
    """Per-layer cost of the CIFAR MobileNetV2 (models/resnet.py's variant:
    stride-1 stem at 32², inverted residuals per MBV2_CFG, 1280-d head)."""
    classes = cfg.vocab_size
    layers: List[LayerCost] = [
        _conv("stem", image, 3, 3, 32), _bn("stem_bn", image, 32)]
    hw = image
    for i, (cin, hidden, cout, stride) in enumerate(_mbv2_layout()):
        hw_out = hw // stride
        layers += [
            _conv(f"b{i}.expand", hw, 1, cin, hidden),
            _bn(f"b{i}.bn1", hw, hidden),
            # 3x3 depthwise: 9 MACs per output element per channel
            LayerCost(f"b{i}.dw", "dw", float(hw_out * hw_out * 9 * hidden),
                      9 * hidden, float(hw_out * hw_out * hidden)),
            _bn(f"b{i}.bn2", hw_out, hidden),
            _conv(f"b{i}.project", hw_out, 1, hidden, cout),
            _bn(f"b{i}.bn3", hw_out, cout)]
        hw = hw_out
    last = _mbv2_layout()[-1][2]
    layers += [_conv("head", hw, 1, last, 1280), _bn("head_bn", hw, 1280),
               LayerCost("fc", "fc", float(1280 * classes),
                         1280 * classes + classes, float(classes))]
    return TableCostModel(cfg.name, tuple(layers))


def cnn_cost(cfg: ModelConfig, image: int = 32) -> TableCostModel:
    """Dispatch on the ``family="cnn"`` encoding's model name."""
    if cfg.family != "cnn":
        raise ValueError(f"cnn_cost: {cfg.name!r} has family={cfg.family!r}")
    if cfg.name == "mobilenetv2":
        return mobilenet_cost(cfg, image)
    return resnet_cost(cfg, image)


# ---------------------------------------------------------------------------
# Transformer LM — wraps the analytic model in core/energy.py
# ---------------------------------------------------------------------------


def lm_cost(cfg: ModelConfig, seq_len: int) -> TableCostModel:
    """Per-block cost table for the transformer stack at ``seq_len``.

    MACs = analytic FLOPs / 2 (``core/energy.block_fwd_flops``), per batch
    element.  Every block is SLU-gatable (the paper's granularity: the gate
    sits on every residual unit); embedding and head are not.
    """
    from repro.core import energy  # deferred: energy imports nothing from here

    if cfg.family == "cnn":
        raise ValueError("lm_cost cannot price a CNN config; use cnn_cost")
    d = cfg.d_model
    layers: List[LayerCost] = [
        LayerCost("embed", "embed", 0.0, cfg.padded_vocab * d,
                  float(seq_len * d))]
    for i, kind in enumerate(cfg.blocks):
        layers.append(LayerCost(
            f"block{i}.{kind}", "block",
            energy.block_fwd_flops(cfg, kind, seq_len) / 2.0,
            cfg._block_params(kind, d, cfg.resolved_head_dim),
            float(seq_len * d), gated=True))
    head_params = 0 if cfg.tie_embeddings else cfg.padded_vocab * d
    layers.append(LayerCost(
        "head", "head", seq_len * d * cfg.vocab_size, head_params + d,
        float(seq_len * cfg.vocab_size)))
    return TableCostModel(cfg.name, tuple(layers))
