"""Model-level technique: input-dependent Selective Layer Update (SLU), §3.2.

The paper attaches a tiny weight-shared RNN gate (GAP -> linear proj to 10
-> LSTM(10) -> binary scalar) to every residual block; the gate decides per
input whether the block is executed, for BOTH forward and backward, and a
FLOPs regularizer ``alpha * C(W, G)`` (Eq. 1) drives the skip ratio up
without any RL post-refinement.

TPU adaptation (DESIGN.md §3.1): the decision is per-(block, step) rather
than per-sample — the gate input is the batch-pooled block input, so every
data-parallel replica reaches the same decision and collectives stay
matched; the skip is a ``jax.lax.cond`` inside the scanned layer stack, so a
skipped block contributes ~zero FLOPs at runtime.

Gradient path: when a block executes, a straight-through factor
``g_st = 1 + p - stop_grad(p)`` multiplies the residual branch so the task
loss produces a gradient on the keep-probability; when skipped, the only
gradient to the gate is from the FLOPs regularizer (pushing p down) — the
same asymmetry the paper's hard-skipping induces.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.config import SLUConfig
from repro.models.layers import dense_init

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# gate network (shared across all blocks, carried through the layer scan)
# ---------------------------------------------------------------------------


def init_gate(key, d_in: int, slu: SLUConfig) -> Params:
    """``d_in``: feature dim the gate pools over — ``d_model`` for the LM
    stack, the *maximum* channel width for CNNs (narrower block inputs are
    zero-padded up to ``d_in`` in :func:`gate_apply`, so one weight-shared
    gate serves every stage of a widening backbone)."""
    d, h = d_in, slu.gate_hidden
    pj = slu.gate_proj
    ks = jax.random.split(key, 4)
    return {
        "proj": dense_init(ks[0], (d, pj), jnp.float32),
        "lstm_wx": dense_init(ks[1], (pj, 4 * h), jnp.float32),
        "lstm_wh": dense_init(ks[2], (h, 4 * h), jnp.float32),
        "lstm_b": jnp.zeros((4 * h,), jnp.float32),
        "head_w": dense_init(ks[3], (h, 1), jnp.float32),
        "head_b": jnp.zeros((1,), jnp.float32),
    }


def init_gate_state(slu: SLUConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h = slu.gate_hidden
    return jnp.zeros((h,), jnp.float32), jnp.zeros((h,), jnp.float32)


def gate_apply(gp: Params, x: jnp.ndarray, state, slu: SLUConfig):
    """x: (B, S, d) block input -> (keep_prob scalar, new lstm state).

    Pool over batch AND sequence (the per-minibatch adaptation): under pjit
    the mean over the batch axis is a tiny all-reduce that XLA fuses.

    CNN inputs (B, H, W, C) pool over the spatial axes the same way; block
    inputs narrower than the gate's projection (early, thin stages) are
    zero-padded to it — the CNN-specific gate copy this replaces lived in
    ``models/resnet.py``.
    """
    pooled = jnp.mean(x.astype(jnp.float32), axis=tuple(range(x.ndim - 1)))
    d_in = gp["proj"].shape[0]
    if pooled.shape[0] < d_in:
        pooled = jnp.pad(pooled, (0, d_in - pooled.shape[0]))
    z = pooled @ gp["proj"]
    h_prev, c_prev = state
    g = z @ gp["lstm_wx"] + h_prev @ gp["lstm_wh"] + gp["lstm_b"]
    i_t, f_t, o_t, u_t = jnp.split(g, 4)
    c = jax.nn.sigmoid(f_t + 1.0) * c_prev + jax.nn.sigmoid(i_t) * jnp.tanh(u_t)
    h = jax.nn.sigmoid(o_t) * jnp.tanh(c)
    logit = (h @ gp["head_w"] + gp["head_b"])[0]
    p = jnp.clip(jax.nn.sigmoid(logit), slu.min_keep_prob, 1.0)
    return p, (h, c)


# ---------------------------------------------------------------------------
# gated residual execution
# ---------------------------------------------------------------------------


def gated_residual(block_fn: Callable[[jnp.ndarray], jnp.ndarray],
                   x: jnp.ndarray,
                   keep_prob: jnp.ndarray,
                   rng: jnp.ndarray,
                   force_keep) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Execute ``x + block(x)`` with probability keep_prob, else identity.

    Returns (output, executed in {0.,1.}).  ``force_keep`` (bool scalar)
    overrides the sample (first/last block, or eval mode).
    """
    keep = jax.random.bernoulli(rng, keep_prob) | force_keep
    # straight-through: scale executed branch so d(out)/d(keep_prob) = block(x)
    g_st = 1.0 + keep_prob - lax.stop_gradient(keep_prob)

    def run(x):
        return x + g_st.astype(x.dtype) * block_fn(x)

    out = lax.cond(keep, run, lambda x: x, x)
    return out, keep.astype(jnp.float32)


def flops_regularizer(keep_probs: jnp.ndarray, block_flops: jnp.ndarray,
                      slu: SLUConfig) -> jnp.ndarray:
    """C(W, G) of Eq. 1: expected executed FLOPs, normalized to [0, 1]."""
    total = jnp.sum(block_flops)
    return jnp.sum(keep_probs * block_flops) / jnp.maximum(total, 1.0)


def expected_compute_ratio(skip_ratio: float) -> float:
    """Fraction of block compute executed at a given average skip ratio."""
    return 1.0 - skip_ratio
