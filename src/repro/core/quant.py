"""Symmetric fixed-point quantization primitives shared by PSG and kernels.

These are the grid definitions everything else agrees on: the element-level
PSG reference (``kernels/ref.py``), the tile-level Pallas kernels
(``kernels/psg_matmul.py`` via ``kernels/ops.py``), and the ``custom_vjp``
integration (``core/psg.py``).  They live in their own leaf module so the
kernel package never has to import ``core.psg`` (which imports the kernel
dispatch layer for its backward pass — see DESIGN.md §Dispatch).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def qscale(x: jnp.ndarray, bits: int, axis=None) -> jnp.ndarray:
    """Symmetric per-tensor (or per-axis) scale: max|x| / (2^(b-1) - 1)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis,
                   keepdims=axis is not None)
    return jnp.maximum(amax, 1e-12) / (2.0 ** (bits - 1) - 1.0)


def quantize(x: jnp.ndarray, bits: int, axis=None) -> jnp.ndarray:
    """Fake-quantize: round to a ``bits``-bit symmetric fixed-point grid."""
    # precision: scope — marks quantized provenance for analysis/dataflow.py
    with jax.named_scope("precision:quantize"):
        s = qscale(x, bits, axis)
        q = jnp.round(x.astype(jnp.float32) / s)
        lim = 2.0 ** (bits - 1) - 1.0
        return (jnp.clip(q, -lim, lim) * s).astype(x.dtype)


def quantize_int(x: jnp.ndarray, bits: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Integer codes + scale (used by the Pallas kernel path)."""
    s = qscale(x, bits)
    lim = 2.0 ** (bits - 1) - 1.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -lim, lim)
    dt = jnp.int8 if bits <= 8 else jnp.int32 if bits > 16 else jnp.int16
    return q.astype(dt), s


def msb_of(x: jnp.ndarray, bits_full: int, bits_msb: int) -> jnp.ndarray:
    """Keep the ``bits_msb`` most significant bits of a ``bits_full`` code.

    On the fixed-point grid of ``bits_full`` this means re-rounding onto the
    coarser ``bits_msb`` grid *with the same dynamic range* — exactly the
    paper's MSB-part operand (quantization step Delta = 2^-(B_msb - 1) on a
    [-1, 1]-normalized range).
    """
    return quantize(x, bits_msb)
