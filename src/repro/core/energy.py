"""Energy & FLOPs primitives — the paper's measurement substrate, in software.

The paper's quantitative pathway is: per-op energies from Horowitz (ISSCC'14,
45nm CMOS, the paper's ref [59]) x op counts + data-movement bytes x per-byte
access energy; FPGA power-meter numbers validate the model.  No power meter
exists here, so this module *is* the measurement instrument:

* ``ENERGY_45NM`` — the paper's own constants (pJ); "8-bit mult/add/move save
  95/97/75% vs fp32" (§3.3) emerges from these numbers.
* ``TPU_V5E`` — target-hardware constants for the roofline analysis
  (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI per the assignment).
* Analytic FLOPs for the transformer archs (MODEL_FLOPS = 6*N*D dense /
  6*N_active*D MoE, plus attention terms) — fed to EXPERIMENTS.md §Roofline.
  ``family="cnn"`` configs delegate to the per-layer CNN cost model
  (``core/cost.py``); the seed's silent transformer-math-for-CNNs path is
  retired.
* The paper's composition law for computational savings
  (Tables 3/4):   executed = smd_ratio * (1 - slu_skip) * psg_factor.
  The paper's rows (80.27/85.20/90.13 % at skip 20/40/60%) are reproduced by
  this law with the PSG mixed-precision compute factor r = 0.368 implied by
  the paper's numbers; our first-principles factor from ENERGY_45NM is
  reported alongside.

This module is the *primitive* layer of the energy API (DESIGN.md §Energy):
per-op tables and conversion laws only.  Per-layer op counts live in
``core/cost.py`` (CostModel, resolved through ``repro.tasks``); composing
measured telemetry into headline numbers lives in ``core/ledger.py``
(EnergyLedger → EnergyReport, via ``Trainer.energy_report()``).  Callers
should not hand-compose these functions with assumed operating points —
that is the ledger's job.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Mapping, Tuple

from repro.core.config import (BLOCK_ATTN, BLOCK_MAMBA, BLOCK_MLSTM, BLOCK_MOE,
                               BLOCK_SHARED_ATTN, BLOCK_SLSTM, E2TrainConfig,
                               ModelConfig, SHAPES)

# ---------------------------------------------------------------------------
# per-op energy tables
# ---------------------------------------------------------------------------

# Horowitz ISSCC'14 45nm, picojoules.
ENERGY_45NM: Mapping[str, float] = {
    # multiplies
    "mul_fp32": 3.7, "mul_fp16": 1.1, "mul_int32": 3.1, "mul_int8": 0.2,
    # adds
    "add_fp32": 0.9, "add_fp16": 0.4, "add_int32": 0.1, "add_int8": 0.03,
    # memory access per 32-bit word
    "sram_8kb": 10.0, "sram_32kb": 20.0, "sram_1mb": 100.0, "dram": 1300.0,
}


def mult_energy_pj(bits_a: int, bits_b: int) -> float:
    """Fixed-point multiplier energy ~ bits_a * bits_b (array multiplier),
    anchored at int8 (0.2 pJ for 8x8)."""
    return ENERGY_45NM["mul_int8"] * (bits_a * bits_b) / 64.0


def add_energy_pj(bits: int) -> float:
    return ENERGY_45NM["add_int8"] * bits / 8.0


def move_energy_pj(bits: int, level: str = "sram_32kb") -> float:
    return ENERGY_45NM[level] * bits / 32.0


def mac_energy_pj(bits_a: int, bits_b: int, acc_bits: int = 32) -> float:
    return mult_energy_pj(bits_a, bits_b) + add_energy_pj(acc_bits)


FP32_MAC_PJ = ENERGY_45NM["mul_fp32"] + ENERGY_45NM["add_fp32"]


@dataclass(frozen=True)
class HW:
    name: str
    peak_flops: float          # per chip, /s
    hbm_bw: float              # bytes/s
    ici_bw: float              # bytes/s/link
    int8_speedup: float = 2.0  # int8 vs bf16 MXU throughput ratio


TPU_V5E = HW(name="tpu_v5e", peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)


# ---------------------------------------------------------------------------
# analytic FLOPs per architecture
# ---------------------------------------------------------------------------


def _attn_flops(cfg: ModelConfig, S: int, kv_len: int) -> Tuple[float, float]:
    """(projection flops, score/value flops) per token-batch of S queries."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nh, nkv = cfg.num_heads, cfg.num_kv_heads
    proj = 2 * S * d * (nh * hd + 2 * nkv * hd) + 2 * S * nh * hd * d
    eff_kv = min(kv_len, cfg.sliding_window) if cfg.sliding_window else kv_len
    qk = 2 * S * eff_kv * nh * hd * 2            # scores + weighted values
    return float(proj), float(qk)


def _mlp_flops(cfg: ModelConfig, S: int, d_ff: int) -> float:
    m = 3 if cfg.glu else 2
    return float(2 * S * cfg.d_model * d_ff * m)


def block_fwd_flops(cfg: ModelConfig, kind: str, S: int, kv_len: int = 0) -> float:
    """Forward FLOPs of one block for S tokens (per batch element)."""
    if cfg.family == "cnn":
        raise ValueError(
            f"{cfg.name!r} is a CNN config: it has no transformer blocks — "
            "use core/cost.cnn_cost (DESIGN.md §Energy)")
    kv_len = kv_len or S
    d = cfg.d_model
    if kind in (BLOCK_ATTN, BLOCK_SHARED_ATTN):
        p, a = _attn_flops(cfg, S, kv_len)
        return p + a + _mlp_flops(cfg, S, cfg.d_ff)
    if kind == BLOCK_MOE:
        p, a = _attn_flops(cfg, S, kv_len)
        dff = cfg.moe_d_ff or cfg.d_ff
        routed = (cfg.top_k) * _mlp_flops(cfg, S, dff)
        shared = cfg.num_shared_experts * _mlp_flops(cfg, S, dff)
        router = 2 * S * d * cfg.num_experts
        return p + a + routed + shared + router
    if kind == BLOCK_MAMBA:
        di = cfg.ssm_expand * d
        st = cfg.ssm_state
        proj = 2 * S * d * (2 * di + 2 * st) + 2 * S * di * d
        scan = 2 * S * di * st * 2               # state update + readout
        return float(proj + scan + S * di * cfg.ssm_conv_width * 2)
    if kind == BLOCK_MLSTM:
        di = cfg.ssm_expand * d
        hd = di // cfg.num_heads
        proj = 2 * S * d * 2 * di + 2 * S * di * 3 * di + 2 * S * di * d
        mem = 2 * S * cfg.num_heads * hd * hd * 2
        return float(proj + mem)
    if kind == BLOCK_SLSTM:
        proj = 2 * S * d * 4 * d + 2 * S * d * d
        rec = 2 * S * cfg.num_heads * (d // cfg.num_heads) ** 2 * 4
        return float(proj + rec)
    raise ValueError(kind)


def model_fwd_flops(cfg: ModelConfig, batch: int, S: int, kv_len: int = 0) -> float:
    if cfg.family == "cnn":
        # per-layer CNN cost model (conv/BN/shortcut); S is a token count
        # for LMs and has no CNN meaning — images are fixed 32x32 CIFAR.
        from repro.core.cost import cnn_cost
        return float(batch) * 2.0 * cnn_cost(cfg).fwd_macs()
    per = sum(block_fwd_flops(cfg, k, S, kv_len) for k in cfg.blocks)
    if cfg.shared_attn_every:
        n_inv = cfg.num_layers // cfg.shared_attn_every
        p, a = _attn_flops(cfg, S, kv_len or S)
        per += n_inv * (p + a)
    if cfg.encoder_layers:
        enc_S = cfg.frontend_tokens or S
        per += cfg.encoder_layers * block_fwd_flops(cfg, BLOCK_ATTN, enc_S)
        # decoder cross-attention
        d, hd = cfg.d_model, cfg.resolved_head_dim
        per += cfg.num_layers * (2 * S * d * cfg.num_heads * hd * 2
                                 + 2 * S * enc_S * cfg.num_heads * hd * 2)
    head = 2 * S * cfg.d_model * cfg.vocab_size
    return float(batch) * (per + head)


def train_step_flops(cfg: ModelConfig, batch: int, S: int) -> float:
    """fwd + bwd ~ 3x fwd (dL/dx + dL/dw each ~ fwd)."""
    return 3.0 * model_fwd_flops(cfg, batch, S)


def model_flops_6nd(cfg: ModelConfig, batch: int, S: int) -> float:
    """The assignment's MODEL_FLOPS: 6*N*D (dense) / 6*N_active*D (MoE)."""
    return 6.0 * cfg.active_param_count() * batch * S


# ---------------------------------------------------------------------------
# E2-Train savings composition (paper Tables 3/4)
# ---------------------------------------------------------------------------

# PSG mixed-precision compute factor implied by the paper's own table rows
# (1 - 0.67*(1-s)*r matches 80.27/85.20/90.13% at s=0.2/0.4/0.6 for r=0.368).
PSG_FACTOR_PAPER = 0.368


def psg_factor_from_energy_model(cfg_bits=(8, 16, 4, 10), fallback_rate=0.4) -> float:
    """First-principles PSG compute-energy factor vs fp32 training.

    Training = fwd (x*w) + bwd-x (g*w) + bwd-w (x*g), each ~1/3 of MACs.
    """
    bx, bg, bxm, bgm = cfg_bits
    fwd = mac_energy_pj(bx, bx) / FP32_MAC_PJ
    bwd_x = mac_energy_pj(bg, bx) / FP32_MAC_PJ
    pred = mac_energy_pj(bxm, bgm) / FP32_MAC_PJ
    full = mac_energy_pj(bx, bg) / FP32_MAC_PJ
    bwd_w = pred + fallback_rate * full   # predictor always; fallback on a share
    return (fwd + bwd_x + bwd_w) / 3.0


def computational_savings(smd_ratio: float, slu_skip: float,
                          psg_factor: float = PSG_FACTOR_PAPER) -> float:
    """Paper's composition law: fraction of baseline compute *saved*."""
    return 1.0 - smd_ratio * (1.0 - slu_skip) * psg_factor


# Design-point fallback rate assumed when no measurement is available; the
# training path now *measures* the true tile-level rate per step (the
# backward kernel's fallback-tile stats surface as the train-step metric
# ``psg_fallback_ratio`` — see core/psg.py and training/train_step.py) and
# callers should pass that measurement in.
PSG_FALLBACK_ASSUMED = 0.4


def measured_psg_factor(e2: E2TrainConfig, fallback_ratio: float) -> float:
    """PSG compute-energy factor from a *measured* fallback-tile ratio."""
    p = e2.psg
    return psg_factor_from_energy_model(
        (p.bits_x, p.bits_g, p.bits_x_msb, p.bits_g_msb), fallback_ratio)


def psg_mac_pj(psg, fallback_rate: float) -> float:
    """Absolute per-MAC energy (pJ) of PSG training, averaged over the three
    passes (fwd x·w, bwd-x g·w, bwd-w x·g with predictor + fallback share).

    The normalized counterpart (divided by ``FP32_MAC_PJ``) is
    :func:`psg_factor_from_energy_model`.
    """
    fwd = mac_energy_pj(psg.bits_x, psg.bits_x)
    bwd_x = mac_energy_pj(psg.bits_g, psg.bits_x)
    bwd_w = mac_energy_pj(psg.bits_x_msb, psg.bits_g_msb) \
        + fallback_rate * mac_energy_pj(psg.bits_x, psg.bits_g)
    return (fwd + bwd_x + bwd_w) / 3.0


def training_energy_pj(cfg: ModelConfig, batch: int, S: int,
                       e2: E2TrainConfig, steps: int,
                       bits_default: int = 32,
                       psg_fallback_rate: float = PSG_FALLBACK_ASSUMED
                       ) -> float:
    """End-to-end training energy under the 45nm model (compute + movement).

    A *primitive*: the SMD/SLU scaling comes from the config's declared
    operating point (``smd.epochs_multiplier × (1 − drop_prob)``,
    ``slu.target_skip``) — for accounting driven by what actually executed,
    use ``Trainer.energy_report()`` (core/ledger.py) instead.

    ``psg_fallback_rate``: fraction of backward weight-gradient compute that
    ran the full-precision product — pass ``Trainer.measured_psg_fallback()``
    for measured-rather-than-assumed accounting.
    """
    macs = train_step_flops(cfg, batch, S) / 2.0
    if e2.psg.enabled:
        mac_pj = psg_mac_pj(e2.psg, psg_fallback_rate)
        move_bits = e2.psg.bits_x
    else:
        mac_pj = FP32_MAC_PJ if bits_default == 32 else mac_energy_pj(
            bits_default, bits_default)
        move_bits = bits_default
    compute = macs * mac_pj
    # data movement: every MAC's operands stream through SRAM once per tile
    if cfg.family == "cnn":
        from repro.core.cost import cnn_cost
        moved_words = cnn_cost(cfg).moved_words(batch)
    else:
        n_params = cfg.param_count()
        moved_words = 3.0 * (n_params
                             + batch * S * cfg.d_model * cfg.num_layers)
    movement = moved_words * move_energy_pj(move_bits)
    per_step = compute + movement
    eff_steps = steps
    if e2.smd.enabled:
        # config-derived operating point: m x the nominal epochs, each step
        # kept with probability (1 - p).  The paper's Fig. 3a point
        # (p=0.5, m=4/3 -> 0.67) is the SMDConfig default, not a constant
        # baked in here.
        eff_steps = steps * (1 - e2.smd.drop_prob) * e2.smd.epochs_multiplier
    slu_keep = 1.0
    if e2.slu.enabled and e2.slu.target_skip:
        slu_keep = 1.0 - e2.slu.target_skip
    return per_step * eff_steps * slu_keep


# ---------------------------------------------------------------------------
# roofline terms (used by benchmarks/roofline.py on dry-run artifacts)
# ---------------------------------------------------------------------------


def roofline_terms(hlo_flops: float, hlo_bytes: float, coll_bytes: float,
                   chips: int, hw: HW = TPU_V5E) -> Dict[str, float]:
    ct = hlo_flops / (chips * hw.peak_flops)
    mt = hlo_bytes / (chips * hw.hbm_bw)
    kt = coll_bytes / (chips * hw.ici_bw)
    dom = max((ct, "compute"), (mt, "memory"), (kt, "collective"))
    return {"compute_s": ct, "memory_s": mt, "collective_s": kt,
            "bottleneck": dom[1], "step_s": max(ct, mt, kt)}
