"""Host-side data pipeline: per-pod sharding, background prefetch, SMD.

At scale each host generates/loads only its shard of the global batch (the
synthetic generators are counter-based so shards never overlap).  A small
background thread keeps ``prefetch`` batches ready; SMD drops are decided
*before* generation, so a dropped step costs nothing — the zero-overhead
property the paper's data-level technique relies on.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.core.config import SMDConfig
from repro.core.smd import smd_keep_host


class DataPipeline:
    def __init__(self, make_batch: Callable[[int, int], Dict],
                 smd: Optional[SMDConfig] = None,
                 seed: int = 0, shard: int = 0,
                 prefetch: int = 2, start_step: int = 0):
        """make_batch(step, shard) -> batch dict."""
        self._make = make_batch
        self._smd = smd or SMDConfig()
        self._seed = seed
        self._shard = shard
        self._step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        # a make_batch exception must not die with the producer thread: it
        # is captured here and re-raised in the CONSUMER (__next__), so the
        # trainer sees it within one get-timeout instead of spinning on an
        # empty queue forever (the pre-PR 10 hang)
        self._error: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        step = self._step
        while not self._stop.is_set():
            try:
                if self._smd.enabled and not smd_keep_host(
                        self._seed, step, self._smd.drop_prob):
                    item = (step, None)             # SMD drop: no generation
                else:
                    item = (step, self._make(step, self._shard))
            except BaseException as e:              # surfaced, never swallowed
                self._error = e
                return
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        while True:
            if self._stop.is_set():
                raise StopIteration
            try:
                return self._q.get(timeout=0.1)     # (step, batch | None)
            except queue.Empty:
                if self._error is not None:
                    # producer died on this exception; queue is drained by
                    # now, so every already-generated batch was consumed —
                    # re-raise the ORIGINAL exception at the call site
                    self._stop.set()
                    raise self._error
                continue

    def close(self, timeout: float = 5.0) -> bool:
        """Stop the producer and join it.

        Draining the queue once is not enough: the producer may be parked in
        ``put`` with a ready item and complete the put right after the
        drain, then go generate the next batch — a shutdown race that leaves
        the thread alive holding references.  So: signal stop, then
        alternate drain + short join until the thread exits (it re-checks
        the stop flag at least every 0.1 s put timeout).  Returns whether
        the producer actually terminated within ``timeout``.
        """
        self._stop.set()
        deadline = time.monotonic() + timeout
        while self._thread.is_alive():
            self._drain()
            self._thread.join(timeout=0.05)
            if time.monotonic() > deadline:
                break
        self._drain()                    # a post-join straggler put
        return not self._thread.is_alive()

    def _drain(self):
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
