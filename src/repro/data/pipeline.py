"""Host-side data pipeline: per-pod sharding, background prefetch, SMD.

At scale each host generates/loads only its shard of the global batch (the
synthetic generators are counter-based so shards never overlap).  A small
background thread keeps ``prefetch`` batches ready; SMD drops are decided
*before* generation, so a dropped step costs nothing — the zero-overhead
property the paper's data-level technique relies on.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.core.config import SMDConfig
from repro.core.smd import smd_keep_host


class DataPipeline:
    def __init__(self, make_batch: Callable[[int, int], Dict],
                 smd: Optional[SMDConfig] = None,
                 seed: int = 0, shard: int = 0,
                 prefetch: int = 2, start_step: int = 0):
        """make_batch(step, shard) -> batch dict."""
        self._make = make_batch
        self._smd = smd or SMDConfig()
        self._seed = seed
        self._shard = shard
        self._step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        step = self._step
        while not self._stop.is_set():
            if self._smd.enabled and not smd_keep_host(
                    self._seed, step, self._smd.drop_prob):
                item = (step, None)                 # SMD drop: no generation
            else:
                item = (step, self._make(step, self._shard))
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        while True:
            item = self._q.get()
            return item                             # (step, batch | None)

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
