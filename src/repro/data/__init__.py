from repro.data.synthetic import (MarkovLMTask, GaussianImageTask,
                                  make_lm_batch, make_image_batch)
from repro.data.pipeline import DataPipeline
