"""Deterministic synthetic datasets (DESIGN.md §6: no network access).

Both tasks carry *learnable structure* so convergence-mechanism claims
(SMD>=SMB, SLU>=SD, PSG~SignSGD) can be validated: loss decreases
substantially iff training works, and the final loss separates methods.

* ``MarkovLMTask`` — tokens follow a fixed random 1st-order Markov chain
  (peaked transition per state + uniform noise floor).  The Bayes-optimal
  cross-entropy is analytically known, so "accuracy" is measured as
  next-token top-1 agreement with the chain's mode.
* ``GaussianImageTask`` — class-conditional Gaussian images (CIFAR-shaped,
  32x32x3, K classes) with controllable SNR.

Every batch is a pure function of (seed, step, shard) — counter-based
generation, no state — which is what makes SMD-dropped steps free and
restarts/elastic resharding trivially deterministic.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class MarkovLMTask:
    vocab: int = 256
    peak: float = 0.9           # prob of the designated next token
    seed: int = 1234

    def transition(self) -> np.ndarray:
        rng = np.random.RandomState(self.seed)
        return rng.permutation(self.vocab)

    def bayes_xent(self) -> float:
        p, v = self.peak, self.vocab
        q = (1 - p) / (v - 1)
        return float(-(p * np.log(p) + (v - 1) * q * np.log(q)))


@partial(jax.jit, static_argnames=("task", "batch", "seq"))
def make_lm_batch(task: MarkovLMTask, seed: int, step, shard,
                  batch: int, seq: int) -> Dict[str, jnp.ndarray]:
    key = jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(seed), step), shard)
    perm = jnp.asarray(np.asarray(MarkovLMTask(
        task.vocab, task.peak, task.seed).transition()))
    k0, k1, k2 = jax.random.split(key, 3)
    t0 = jax.random.randint(k0, (batch,), 0, task.vocab)
    noise = jax.random.uniform(k1, (batch, seq)) > task.peak
    rand_next = jax.random.randint(k2, (batch, seq), 0, task.vocab)

    def step_fn(t, inp):
        nz, rn = inp
        nxt = jnp.where(nz, rn, perm[t])
        return nxt, nxt

    _, toks = jax.lax.scan(step_fn, t0,
                           (noise.T, rand_next.T))
    toks = jnp.moveaxis(toks, 0, 1)                  # (B, seq)
    tokens = toks[:, :-1] if seq > 1 else toks
    labels = toks[:, 1:] if seq > 1 else toks
    # pad back to seq for static shapes
    tokens = jnp.pad(tokens, ((0, 0), (0, 1)))
    labels = jnp.pad(labels, ((0, 0), (0, 1)), constant_values=-1)
    return {"tokens": tokens, "labels": labels}


@dataclass(frozen=True)
class GaussianImageTask:
    num_classes: int = 10
    hw: int = 32
    snr: float = 1.0
    seed: int = 99

    def means(self) -> np.ndarray:
        rng = np.random.RandomState(self.seed)
        return rng.randn(self.num_classes, self.hw, self.hw, 3).astype(np.float32)


@partial(jax.jit, static_argnames=("task", "batch"))
def make_image_batch(task: GaussianImageTask, seed: int, step, shard,
                     batch: int) -> Dict[str, jnp.ndarray]:
    key = jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(seed), step), shard)
    k0, k1 = jax.random.split(key)
    labels = jax.random.randint(k0, (batch,), 0, task.num_classes)
    means = jnp.asarray(task.means())
    noise = jax.random.normal(k1, (batch, task.hw, task.hw, 3))
    images = task.snr * means[labels] + noise
    return {"image": images, "label": labels}
