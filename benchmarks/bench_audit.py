"""Static cost-audit record (``run.py --json-audit`` -> BENCH_audit.json).

No training, no timing: this bench reconciles the three static views of
the repo's cost story (DESIGN.md §Analysis) —

* the config-derived :class:`CostModel` tables (``core/cost.py``),
* the jaxpr walker's per-layer counts over the traced predict programs
  (``analysis/jaxpr_cost.py``),
* the compiled-HLO totals (``launch/hlo_cost.py``),

for both paper CIFAR backbones and the smoke LM, and runs the full lint
battery: the Pallas kernel linter, the repository convention linter, the
precision-flow lint (sub-32-bit accumulators fed by narrow operands —
the PR 7 bug class) and the hot-loop lint (the chunk program's
``CHUNK_CONTRACT``).  ``all_passed`` is the CI gate: any per-layer
divergence above the declared tolerance, any unknown-trip-count loop, or
any lint finding flips it false.

Schema (``schema_version`` 2): every lint section is
``{"findings": [...], "passed": bool, "error": null | str}`` — a linter
that *crashes* records its exception in ``error``, lands in the
top-level ``lint_errors`` list, and fails the record with a distinct
exit code in ``run.py`` (a crashing linter must never pass CI silently).
"""
from __future__ import annotations

import traceback
from typing import Callable, Iterable, List

SCHEMA_VERSION = 2


def _experiments():
    from repro.configs import smoke_experiment
    from repro.configs.paper_cnns import mobilenetv2, resnet110, resnet74

    return [resnet74(), resnet110(), mobilenetv2(),
            smoke_experiment("llama3_8b")]


def _lint_section(run: Callable[[], dict]) -> dict:
    """Run one lint pass, capturing a crash as ``error`` (≠ a failure)."""
    try:
        section = dict(run())
        section.setdefault("error", None)
        return section
    except Exception as e:  # noqa: BLE001 — the point is to record it
        return {"findings": None, "passed": False,
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc(limit=8)}


def _lint_sections() -> dict:
    from repro.analysis import (hotloop_report, lint_repo, lint_shipped,
                                precision_report)

    def kernel_section():
        findings = [str(f) for f in lint_shipped()]
        return {"findings": findings, "passed": not findings}

    def repo_section():
        findings = [str(f) for f in lint_repo()]
        return {"findings": findings, "passed": not findings}

    return {
        "kernel_lint": _lint_section(kernel_section),
        "repo_lint": _lint_section(repo_section),
        "precision": _lint_section(precision_report),
        "hotloop": _lint_section(hotloop_report),
    }


def audit_json(fast: bool = True) -> dict:
    from repro.analysis import audit_experiment

    audits = []
    for exp in _experiments():
        rep = audit_experiment(exp, batch=4)
        audits.append(rep.to_dict())

    sections = _lint_sections()
    lint_errors = [name for name, s in sections.items() if s.get("error")]
    all_passed = (all(a["passed"] for a in audits)
                  and all(s["passed"] for s in sections.values())
                  and not lint_errors)
    return {"schema_version": SCHEMA_VERSION,
            "audits": audits,
            **sections,
            "lint_errors": lint_errors,
            "all_passed": all_passed}


def run(fast: bool = True) -> Iterable[str]:
    """CSV rows for the default bench table (pass/fail as derived column)."""
    from repro.analysis import audit_experiment

    rows: List[str] = []
    for exp in _experiments():
        rep = audit_experiment(exp, batch=4)
        rows.append(f"audit_{rep.model},0.0,"
                    f"{'pass' if rep.passed else 'FAIL'}:"
                    f"hlo_rel={rep.hlo_rel_diff:.4f}")
    sections = _lint_sections()
    for name in ("kernel_lint", "repo_lint", "precision", "hotloop"):
        s = sections[name]
        if s.get("error"):
            rows.append(f"{name},0.0,ERROR:{s['error']}")
        else:
            n = len(s["findings"])
            rows.append(f"{name},0.0,{'pass' if n == 0 else f'FAIL:{n}'}")
    return rows
