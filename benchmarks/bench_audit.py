"""Static cost-audit record (``run.py --json-audit`` -> BENCH_audit.json).

No training, no timing: this bench reconciles the three static views of
the repo's cost story (DESIGN.md §Analysis) —

* the config-derived :class:`CostModel` tables (``core/cost.py``),
* the jaxpr walker's per-layer counts over the traced predict programs
  (``analysis/jaxpr_cost.py``),
* the compiled-HLO totals (``launch/hlo_cost.py``),

for both paper CIFAR backbones and the smoke LM, and runs the Pallas
kernel linter plus the repository convention linter.  ``all_passed`` is
the CI gate: any per-layer divergence above the declared tolerance, any
unknown-trip-count loop, or any lint finding flips it false.
"""
from __future__ import annotations

from typing import Iterable, List


def _experiments():
    from repro.configs import smoke_experiment
    from repro.configs.paper_cnns import mobilenetv2, resnet110, resnet74

    return [resnet74(), resnet110(), mobilenetv2(),
            smoke_experiment("llama3_8b")]


def audit_json(fast: bool = True) -> dict:
    from repro.analysis import audit_experiment, lint_repo, lint_shipped

    audits = []
    for exp in _experiments():
        rep = audit_experiment(exp, batch=4)
        audits.append(rep.to_dict())

    kernel_findings = [str(f) for f in lint_shipped()]
    repo_findings = [str(f) for f in lint_repo()]
    all_passed = (all(a["passed"] for a in audits)
                  and not kernel_findings and not repo_findings)
    return {"audits": audits,
            "kernel_lint": {"findings": kernel_findings,
                            "passed": not kernel_findings},
            "repo_lint": {"findings": repo_findings,
                          "passed": not repo_findings},
            "all_passed": all_passed}


def run(fast: bool = True) -> Iterable[str]:
    """CSV rows for the default bench table (pass/fail as derived column)."""
    from repro.analysis import audit_experiment, lint_repo, lint_shipped

    rows: List[str] = []
    for exp in _experiments():
        rep = audit_experiment(exp, batch=4)
        rows.append(f"audit_{rep.model},0.0,"
                    f"{'pass' if rep.passed else 'FAIL'}:"
                    f"hlo_rel={rep.hlo_rel_diff:.4f}")
    nk, nr = len(lint_shipped()), len(lint_repo())
    rows.append(f"kernel_lint,0.0,{'pass' if nk == 0 else f'FAIL:{nk}'}")
    rows.append(f"repo_lint,0.0,{'pass' if nr == 0 else f'FAIL:{nr}'}")
    return rows
