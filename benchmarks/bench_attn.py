"""PSG flash-attention backward vs the materialized (S, T) path.

Two quantities per attention site of a paper-shaped LM, mirroring
bench_conv's precedent:

* **HBM attention bytes moved** — the quantity of record: wall time on
  the CPU Pallas interpreter is not TPU-representative, but which
  tensors each path streams through HBM is a property of the
  dispatch/BlockSpec structure and is computed exactly below;
* **wall time** of a jitted forward+backward on both paths (CPU
  interpreter trend only, clearly labeled).

The byte accounting covers the WHOLE attention step per path in two
named directions (``assert_complete`` enforces that every path reports
both and that the totals reconcile — ``run.py --json-attn`` exits
nonzero otherwise):

``fwd``   forward traffic.  The materialized path writes+reads the
          (B, nh, S, T) fp32 score tensor and the bf16 probability
          tensor (models/layers ``_softmax_lowp``); the flash kernel
          streams K/V tiles (each causal run-tile re-read per query
          block) and never materializes an (S, T) tensor — it
          additionally writes the (B, nh, S) fp32 lse rows the backward
          recomputes from.
``bwd``   backward traffic.  The materialized path re-reads the saved
          bf16 probabilities and writes+reads two more (S, T) fp32
          tensors (dP = do·vᵀ and dS); the flash backward re-reads
          operand tiles per causal run-tile across its dq and dkv
          kernel passes and writes the four per-query-head fp32 PSG
          code products (MSB/full × dv/dk) that the group-sum +
          Eq. (2) select consumes (kernels/ops.flash_attention_bwd).

Operand dtype matters and is part of the shape record: the flash path's
dominant term is K/V (and q/do) tile re-reads at the OPERAND width,
while the materialized path's (S, T) score/dP/dS tensors are fp32
regardless (softmax/grad precision) — so the ratio is ~2.5x at fp32
operands and >3.5x at the bf16 operands the paper-shaped LM trains
with (the ``flash_attention[bf16]``/``flash_bwd_*[bf16]`` registry
entries).  The acceptance quantity is ``bytes_ratio`` on
``paper_lm_s4096`` — whole-step (fwd + bwd) materialized / flash, which
must stay >= 3x.

``attn_json`` additionally records a CPU-interpreter LM training A/B
with ``fused_attention`` on/off, including the measured
``psg_fallback_ratio`` the attention backward feeds the EnergyLedger.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

from repro.kernels.flash_attn import DEFAULT_BK, DEFAULT_BQ

FP32 = 4
BF16 = 2

#: every path's accounting must report exactly these traffic directions
#: (plus optional informational extras).
REQUIRED_COMPONENTS = ("fwd", "bwd")


class IncompleteAccountingError(RuntimeError):
    """An attention path's byte accounting is missing a direction."""


def assert_complete(acct: Dict[str, int], path: str) -> None:
    """Fail loudly if ``acct`` omits a traffic direction or its total
    does not reconcile with the components (run.py --json-attn gate)."""
    missing = [c for c in REQUIRED_COMPONENTS if not acct.get(c, 0) > 0]
    if missing:
        raise IncompleteAccountingError(
            f"{path}: byte accounting incomplete — missing/zero "
            f"components {missing} (have {sorted(acct)})")
    if acct.get("total") != sum(acct[c] for c in REQUIRED_COMPONENTS):
        raise IncompleteAccountingError(
            f"{path}: total {acct.get('total')} != sum of "
            f"{REQUIRED_COMPONENTS}")


@dataclasses.dataclass(frozen=True)
class AttnShape:
    """One self-attention site: GQA geometry + operand width."""
    batch: int
    seq: int
    heads: int
    kv_heads: int
    head_dim: int
    causal: bool = True
    op_bytes: int = BF16          # operand (q/k/v/do) element width
    kind: str = "lm"

    @property
    def q_elems(self) -> int:
        return self.batch * self.seq * self.heads * self.head_dim

    @property
    def kv_elems(self) -> int:
        return self.batch * self.seq * self.kv_heads * self.head_dim

    @property
    def st_elems(self) -> int:
        """(B, nh, S, T) score-tensor element count (T = S here)."""
        return self.batch * self.heads * self.seq * self.seq

    @property
    def rows_elems(self) -> int:
        """One (B, nh, S) fp32 row statistic (lse / delta)."""
        return self.batch * self.heads * self.seq


def _run_tiles(s: AttnShape, bq: int = DEFAULT_BQ,
               bk: int = DEFAULT_BK) -> int:
    """Exact count of (iq, ikv) tile pairs the causal block-skip runs
    (kernels/flash_attn: ``ik*bk <= iq*bq + bq - 1``), times B*nh —
    each query head streams its OWN pass over its group's K/V tiles."""
    n_q = -(-s.seq // bq)
    n_kv = -(-s.seq // bk)
    if s.causal:
        pairs = sum(1 for iq in range(n_q) for ik in range(n_kv)
                    if ik * bk <= iq * bq + bq - 1)
    else:
        pairs = n_q * n_kv
    return s.batch * s.heads * pairs


def materialized_bytes(s: AttnShape) -> Dict[str, int]:
    """Whole-step HBM traffic of the materialized (S, T) path.

    Scores, dP and dS are fp32 (softmax/grad precision) regardless of
    operand dtype; the probability tensor is the bf16 residual
    ``_softmax_lowp`` saves for the backward.
    """
    op = s.op_bytes
    fwd = ((s.q_elems + 2 * s.kv_elems) * op        # read q, k, v
           + 2 * s.st_elems * FP32                  # write+read scores
           + 2 * s.st_elems * BF16                  # write+read probs
           + s.q_elems * op)                        # write o
    bwd = (s.st_elems * BF16                        # re-read saved probs
           + 2 * s.st_elems * FP32                  # write+read dP = do.vT
           + 2 * s.st_elems * FP32                  # write+read dS
           + (2 * s.q_elems + 2 * s.kv_elems) * op  # read do, q, k, v
           + (s.q_elems + 2 * s.kv_elems) * FP32)   # write dq, dk, dv
    return {"fwd": fwd, "bwd": bwd, "total": fwd + bwd}


def flash_bytes(s: AttnShape, bq: int = DEFAULT_BQ,
                bk: int = DEFAULT_BK) -> Dict[str, int]:
    """Whole-step HBM traffic of the flash + PSG-backward path.

    No (S, T) tensor exists in either direction; the dominant term is
    operand tile re-reads — one K/V (fwd + dq pass) or q/do (dkv pass)
    tile read per causal run-tile per query head.  The dkv pass's four
    per-query-head fp32 code products (kernels/ops group-sums them over
    each GQA group before the Eq. (2) select) are charged explicitly.
    """
    op = s.op_bytes
    tiles = _run_tiles(s, bq, bk)
    tile_kv = tiles * bk * s.head_dim               # one K (or V) tile stream
    tile_q = tiles * bq * s.head_dim                # one q (or do) tile stream
    prods = s.batch * s.seq * s.heads * s.head_dim  # one per-query-head product
    group = s.batch * s.seq * s.kv_heads * s.head_dim
    fwd = (s.q_elems * op                           # read q once per block row
           + 2 * tile_kv * op                       # K/V per run-tile
           + s.q_elems * op                         # write o
           + s.rows_elems * FP32)                   # write lse rows
    bwd = (  # delta = sum(o * do) row statistic
           2 * s.q_elems * op + s.rows_elems * FP32
           # dq pass: q/do/rows resident per block row, K/V per run-tile
           + 2 * s.q_elems * op + 2 * tile_kv * op
           + 2 * s.rows_elems * FP32 + s.q_elems * FP32
           # scale pass reads q/do/v row norms
           + (s.q_elems * 2 + s.kv_elems) * op
           # dkv pass: K/V resident per kv block, q/do per run-tile
           + 2 * s.kv_elems * op + 2 * tile_q * op + 2 * s.rows_elems * FP32
           # four per-query-head code products: write, group-sum read,
           # grouped write, Eq.(2)-select read, dk/dv write
           + 4 * prods * FP32 + 4 * prods * FP32
           + 4 * group * FP32 + 4 * group * FP32 + 2 * s.kv_elems * FP32)
    return {"fwd": fwd, "bwd": bwd, "total": fwd + bwd}


def _ratios(b_mat: Dict[str, int], b_flash: Dict[str, int]) -> Dict:
    return {"bytes_ratio": b_mat["total"] / b_flash["total"],
            "fwd_bytes_ratio": b_mat["fwd"] / b_flash["fwd"],
            "bwd_bytes_ratio": b_mat["bwd"] / b_flash["bwd"]}


#: paper-shaped LM attention site: llama-class bf16 GQA geometry.
PAPER_LM = AttnShape(batch=8, seq=4096, heads=32, kv_heads=8, head_dim=128,
                     causal=True, op_bytes=BF16, kind="paper_lm")


def _paper_totals(layers: int = 32) -> Dict:
    """Per-training-step attention-byte totals over every layer of the
    paper-shaped LM — the acceptance quantity is ``bytes_ratio``
    (whole step, fwd + bwd, must stay >= 3x)."""
    b_mat = {c: materialized_bytes(PAPER_LM)[c] * layers
             for c in (*REQUIRED_COMPONENTS, "total")}
    b_flash = {c: flash_bytes(PAPER_LM)[c] * layers
               for c in (*REQUIRED_COMPONENTS, "total")}
    assert_complete(b_mat, "materialized/paper_totals")
    assert_complete(b_flash, "flash/paper_totals")
    return {"batch": PAPER_LM.batch, "seq": PAPER_LM.seq,
            "heads": PAPER_LM.heads, "kv_heads": PAPER_LM.kv_heads,
            "head_dim": PAPER_LM.head_dim, "layers": layers,
            "operand_dtype": "bfloat16",
            "materialized_bytes_per_step": b_mat,
            "flash_bytes_per_step": b_flash,
            **_ratios(b_mat, b_flash)}


def _shape_rows(fast: bool) -> List[Dict]:
    """Timed fwd+bwd A/B per small GQA shape (CPU interpreter) plus the
    exact byte model for the same geometry at both operand widths."""
    import jax
    import jax.numpy as jnp

    from benchmarks.common import time_us as _time
    from repro.core import psg
    from repro.core.config import PSGConfig
    from repro.kernels.ref import flash_attention_oracle

    cfg = PSGConfig(enabled=True, fused_attention=True)
    shapes = [AttnShape(1, 128, 4, 2, 32, kind="gqa_small"),
              AttnShape(1, 256, 4, 2, 64, kind="gqa_body")]
    if fast:
        shapes = shapes[:1]

    rows = []
    for s in shapes:
        ks = jax.random.split(jax.random.PRNGKey(s.seq + s.head_dim), 4)
        q = jax.random.normal(ks[0], (s.batch, s.seq, s.heads, s.head_dim))
        k = jax.random.normal(ks[1], (s.batch, s.seq, s.kv_heads, s.head_dim))
        v = jax.random.normal(ks[2], (s.batch, s.seq, s.kv_heads, s.head_dim))
        gy = jax.random.normal(
            ks[3], (s.batch, s.seq, s.heads, s.head_dim)) * 0.01

        def mat_loss(q_, k_, v_):
            return jnp.sum(flash_attention_oracle(q_, k_, v_,
                                                  causal=s.causal) * gy)

        def flash_loss(q_, k_, v_):
            with psg.enable(cfg):
                return jnp.sum(psg.attention(q_, k_, v_,
                                             causal=s.causal) * gy)

        us_mat, _ = _time(jax.jit(jax.grad(mat_loss, argnums=(0, 1, 2))),
                          q, k, v)
        us_flash, _ = _time(jax.jit(jax.grad(flash_loss, argnums=(0, 1, 2))),
                            q, k, v)
        b_mat = materialized_bytes(s)
        b_flash = flash_bytes(s)
        assert_complete(b_mat, f"materialized/{s.kind}")
        assert_complete(b_flash, f"flash/{s.kind}")
        rows.append({
            "batch": s.batch, "seq": s.seq, "heads": s.heads,
            "kv_heads": s.kv_heads, "head_dim": s.head_dim,
            "causal": s.causal, "kind": s.kind,
            "us_materialized_cpu_interpret": us_mat,
            "us_flash_cpu_interpret": us_flash,
            "materialized_bytes": b_mat,
            "flash_bytes": b_flash,
            **_ratios(b_mat, b_flash),
            "bytes_ratio_f32_operands": _ratios(
                materialized_bytes(dataclasses.replace(s, op_bytes=FP32)),
                flash_bytes(dataclasses.replace(s, op_bytes=FP32)),
            )["bytes_ratio"],
        })
    return rows


def _train_proxy(fast: bool) -> Dict:
    """Measured steps/s of a short CPU LM training A/B with
    ``fused_attention`` on/off, plus the measured attention-backward
    fallback ratio the fused path feeds ``energy_report()``.  The Pallas
    interpreter executes the flash kernels here, so this is a
    loop-plumbing check, NOT a hardware speed claim — the byte totals
    above are the quantity of record (module docstring)."""
    import time as _t

    from benchmarks.common import final_loss, run_lm
    from repro.core.config import E2TrainConfig, PSGConfig

    steps = 3 if fast else 8
    out: Dict = {"steps": steps,
                 "note": "CPU Pallas-interpreter proxy; the byte ratios are "
                         "the quantity of record"}
    for label, fused in (("materialized", False), ("flash", True)):
        e2 = E2TrainConfig(psg=PSGConfig(enabled=True, swa=False,
                                         fused_attention=fused))
        t0 = _t.perf_counter()
        hist, tr, _ = run_lm(e2, steps, optimizer="psg", lr=0.05)
        out[f"{label}_steps_per_s"] = steps / (_t.perf_counter() - t0)
        out[f"{label}_final_loss"] = final_loss(hist, k=2)
        if fused:
            fb = tr.measured_psg_fallback()
            out["psg_fallback_ratio_measured"] = (
                None if fb is None else float(fb))
            rep = tr.energy_report(steps=steps)
            out["comp_saving_measured"] = rep.computational_savings_measured
    return out


def attn_json(fast: bool = True) -> dict:
    """The BENCH_attn.json record (CI artifact).  Raises
    :class:`IncompleteAccountingError` if any path omits a traffic
    direction — run.py --json-attn turns that into a nonzero exit."""
    return {"paper_lm_s4096": _paper_totals(),
            "shapes": _shape_rows(fast),
            "train_proxy_cpu_interpret": _train_proxy(fast)}


def run(fast: bool = True):
    """CSV rows for benchmarks/run.py."""
    from benchmarks.common import csv_row
    totals = _paper_totals()
    yield csv_row(
        "attn/paper_lm_s4096",
        0.0,
        f"bytes_ratio={totals['bytes_ratio']:.2f};"
        f"fwd_bytes_ratio={totals['fwd_bytes_ratio']:.2f};"
        f"bwd_bytes_ratio={totals['bwd_bytes_ratio']:.2f};"
        f"materialized_GB={totals['materialized_bytes_per_step']['total']/1e9:.2f};"
        f"flash_GB={totals['flash_bytes_per_step']['total']/1e9:.2f}")
    for r in _shape_rows(fast):
        yield csv_row(
            f"attn/{r['kind']}/{r['batch']}x{r['seq']}x{r['heads']}-"
            f"{r['kv_heads']}h{r['head_dim']}",
            r["us_flash_cpu_interpret"],
            f"materialized_us={r['us_materialized_cpu_interpret']:.1f};"
            f"bytes_ratio={r['bytes_ratio']:.2f};"
            f"bwd_bytes_ratio={r['bwd_bytes_ratio']:.2f}")
