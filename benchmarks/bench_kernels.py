"""Kernel microbenchmarks: PSG pallas kernel vs jnp reference (interpret
mode on CPU — wall time is NOT TPU-representative; the derived column
reports the energy-model MAC ratio, which is the quantity of record).

The oracle-vs-kernel rows sweep the actual ResNet-74 im2col shapes from
``configs/paper_cnns.py`` — the geometry the PSG backward sees in
paper-faithful training — and report the measured fallback-tile ratio per
shape (the input to ``core/energy.measured_psg_factor``)."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.configs.paper_cnns import resnet_conv_shapes
from repro.core.config import PSGConfig
from repro.core.energy import FP32_MAC_PJ, mac_energy_pj
from repro.kernels import dispatch
from repro.kernels.ref import psg_grad_w_ref

from benchmarks.common import csv_row, one_per_kind, time_us as _time


def run(fast: bool = True) -> List[str]:
    cfg = PSGConfig(enabled=True)
    N, din, dout = (512, 256, 256) if fast else (2048, 1024, 1024)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (N, din))
    gy = jax.random.normal(k2, (N, dout)) * 0.01
    rows = []
    us_k, _ = _time(lambda a, b: dispatch.psg_grad_w(a, b, cfg), x, gy)
    us_r, _ = _time(lambda a, b: psg_grad_w_ref(a, b, cfg), x, gy)
    pred_mac = mac_energy_pj(cfg.bits_x_msb, cfg.bits_g_msb) / FP32_MAC_PJ
    rows.append(csv_row("kernel/psg_pallas", us_k,
                        f"ref_us={us_r:.1f};pred_mac_vs_fp32={pred_mac:.4f}"))
    us_q, _ = _time(lambda a: dispatch.quantize(a, 8), x)
    rows.append(csv_row("kernel/quantize", us_q, "bits=8"))

    # oracle vs tile kernel on ResNet-74 im2col geometry (batch reduced for
    # the CPU interpreter; din/dout/k/stride-structure are the paper's).
    # Fast mode sweeps one shape of each KIND — 3x3 body, 3x3 stride-2
    # transition, 1x1 stride-2 downsample — instead of the first three body
    # shapes, so the non-uniform geometries are always on record.
    batch = 2 if fast else 16
    convs = resnet_conv_shapes(depth=74, width=16, batch=batch)
    if fast:
        convs = one_per_kind(convs)
    seen = set()
    for c in convs:
        Ns, din, dout = c.im2col
        if (Ns, din, dout) in seen:
            continue
        seen.add((Ns, din, dout))
        kk1, kk2 = jax.random.split(jax.random.PRNGKey(Ns + din))
        xs = jax.random.normal(kk1, (Ns, din))
        gs = jax.random.normal(kk2, (Ns, dout)) * 0.01
        us_tile, (_, fb) = _time(
            lambda a, b: dispatch.psg_grad_w(a, b, cfg), xs, gs)
        us_ref, _ = _time(lambda a, b: psg_grad_w_ref(a, b, cfg), xs, gs)
        rows.append(csv_row(
            f"kernel/psg_resnet74_im2col/{c.kind}/{Ns}x{din}x{dout}", us_tile,
            f"ref_us={us_ref:.1f};k={c.k};stride={c.stride};"
            f"fallback_tile_ratio={float(fb):.3f}"))

    # flash attention vs unfused oracle, BOTH directions (interpret mode;
    # the derived column reports the two-direction HBM byte model from
    # bench_attn — the quantity that matters on TPU).  The backward is the
    # PSG flash backward (recompute dq + dual-accumulator dkv kernels).
    from benchmarks.bench_attn import (AttnShape, FP32, flash_bytes,
                                       materialized_bytes)
    from repro.kernels import ops
    from repro.kernels.flash_attn import flash_attention
    from repro.kernels.ref import flash_attention_oracle
    B, S, nh, hd = (1, 256, 4, 64) if fast else (2, 1024, 8, 128)
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, S, nh, hd))
    kk = jax.random.normal(ks[1], (B, S, nh, hd))
    vv = jax.random.normal(ks[2], (B, S, nh, hd))
    do = jax.random.normal(ks[3], (B, S, nh, hd)) * 0.01
    us_f, (o, lse) = _time(
        lambda a, b, c: ops.flash_attention_fwd(a, b, c), q, kk, vv)
    us_o, _ = _time(flash_attention_oracle, q, kk, vv)
    us_b, _ = _time(
        lambda a, b, c, d: ops.flash_attention_bwd(a, b, c, o, lse, d, cfg),
        q, kk, vv, do)
    shape = AttnShape(B, S, nh, nh, hd, op_bytes=FP32, kind="bench")
    b_mat, b_flash = materialized_bytes(shape), flash_bytes(shape)
    rows.append(csv_row(
        "kernel/flash_attn", us_f,
        f"oracle_us={us_o:.1f};bwd_us={us_b:.1f};"
        f"flash_MB_fwd_bwd={b_flash['total']/1e6:.1f};"
        f"hbm_bytes_ratio={b_mat['total'] / b_flash['total']:.2f}"))
    return rows
