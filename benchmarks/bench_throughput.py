"""Chunked-loop throughput: executed steps/s vs the per-step reference loop.

The chunked loop (DESIGN.md §Loop) compiles K executed steps into one
``lax.scan`` program, prefetches data on a background thread, and syncs
metrics once per chunk.  This bench measures what that buys on the CPU
container for the paper's depth-14 CIFAR ResNet at two operating points:

* ``resnet14_cifar`` — paper-shaped (32×32×3, width 16, batch 8): the
  step body dominates on CPU, so the win is the scan-compiled body plus
  amortized dispatch (~1.3–1.5x observed);
* ``resnet14_overhead_bound`` — the loop-overhead-bound shape (8×8
  images, width 4, batch 2, K=32): per-step Python dispatch + per-metric
  host syncs are comparable to the body, which is where the compiled
  chunk's ≥2x shows up.  This is the regime that matters at scale: on an
  accelerator the body shrinks toward this point while host overhead does
  not.

Rows are also recorded as ``BENCH_throughput.json`` via
``benchmarks/run.py --json-throughput`` so CI accumulates the trajectory.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

CONFIGS = {
    # name: (hw, width, batch, chunk_steps, measure_steps)
    "resnet14_cifar": (32, 16, 8, 8, 48),
    "resnet14_overhead_bound": (8, 4, 2, 32, 128),
}


def _throughput(hw: int, width: int, batch: int, chunk_steps: int,
                steps: int) -> Dict[str, float]:
    import jax

    from repro.configs.paper_cnns import cnn_model
    from repro.core.config import E2TrainConfig, Experiment, TrainConfig
    from repro.data.synthetic import GaussianImageTask, make_image_batch
    from repro.training.train_step import init_train_state
    from repro.training.trainer import Trainer

    task = GaussianImageTask(num_classes=10, snr=2.0, hw=hw)
    exp = Experiment(model=cnn_model("resnet14", 14, width=width),
                     e2=E2TrainConfig(),
                     train=TrainConfig(global_batch=batch, lr=0.03,
                                       optimizer="sgdm",
                                       total_steps=1_000_000,
                                       schedule="constant"),
                     task="cifar_cnn")
    mk = lambda s, sh: make_image_batch(task, 0, s, sh, batch)

    out: Dict[str, float] = {"hw": hw, "width": width, "batch": batch,
                             "chunk_steps": chunk_steps, "steps": steps}
    for label, k in (("per_step", 1), ("chunked", chunk_steps)):
        tr = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk,
                     chunk_steps=k)
        tr.run(2 * chunk_steps)              # compile + warm both paths
        n0 = tr.executed_steps
        t0 = time.perf_counter()
        tr.run(steps)
        wall = time.perf_counter() - t0
        out[f"{label}_steps_per_s"] = (tr.executed_steps - n0) / wall
    out["chunk_speedup"] = (out["chunked_steps_per_s"] /
                            out["per_step_steps_per_s"])
    return out


def throughput_json(fast: bool = True) -> dict:
    """All configs' rows, for ``BENCH_throughput.json`` (CI artifact)."""
    rows = {}
    for name, (hw, width, batch, k, steps) in CONFIGS.items():
        rows[name] = _throughput(hw, width, batch, k,
                                 steps if fast else 2 * steps)
    return rows


def run(fast: bool = True):
    """CSV rows for benchmarks/run.py: us per executed step + speedup."""
    for name, row in throughput_json(fast=fast).items():
        us = 1e6 / row["chunked_steps_per_s"]
        yield (f"throughput_{name},{us:.1f},"
               f"speedup={row['chunk_speedup']:.2f}x_"
               f"per_step={row['per_step_steps_per_s']:.1f}/s")
