"""Paper Fig. 3a/3b + Tab. 1: SMD vs SMB at matched energy budgets."""
from __future__ import annotations

from typing import List

from repro.core.config import E2TrainConfig, SMDConfig

from benchmarks.common import csv_row, eval_accuracy, final_loss, run_lm


def run(fast: bool = True) -> List[str]:
    steps = 100 if fast else 400
    rows = []
    # SMB baseline at energy ratios {1, 0.83, 0.67}: fewer iterations,
    # schedule scaled (paper's "off-the-shelf" option 1)
    for ratio in (1.0, 0.83, 0.67):
        n = int(steps * ratio)
        hist, tr, wall = run_lm(E2TrainConfig(), n, total_steps=n)
        rows.append(csv_row(
            f"fig3a/smb@{ratio:.2f}", wall / max(n, 1) * 1e6,
            f"loss={final_loss(hist):.4f};acc={eval_accuracy(tr):.4f};"
            f"energy_ratio={ratio:.2f}"))
    # SMD at the same *executed* budgets (2x nominal steps, p=0.5)
    for ratio in (1.0, 0.83, 0.67):
        n = int(2 * steps * ratio)
        e2 = E2TrainConfig(smd=SMDConfig(enabled=True, drop_prob=0.5))
        hist, tr, wall = run_lm(e2, n, total_steps=n)
        executed_ratio = tr.executed_steps / max(steps, 1)
        rows.append(csv_row(
            f"fig3a/smd@{ratio:.2f}", wall / max(n, 1) * 1e6,
            f"loss={final_loss(hist):.4f};acc={eval_accuracy(tr):.4f};"
            f"energy_ratio={executed_ratio:.2f}"))
    # Fig. 3b: SMB with increased lr at 2/3 budget vs SMD
    for lr in (0.1, 0.14, 0.2):
        n = int(steps * 0.67)
        hist, tr, wall = run_lm(E2TrainConfig(), n, lr=lr, total_steps=n)
        rows.append(csv_row(
            f"fig3b/smb_lr{lr}", wall / max(n, 1) * 1e6,
            f"loss={final_loss(hist):.4f};acc={eval_accuracy(tr):.4f}"))
    return rows
