"""Paper Fig. 3a/3b + Tab. 1: SMD vs SMB at matched energy budgets.

The paper's adopted operating point (energy ratio 0.67) is *derived* from
the SMD config — ``expected_energy_ratio(drop 0.5, m=4/3)`` — and the SMD
rows report the ratio that actually executed (trainer telemetry), not the
nominal one.
"""
from __future__ import annotations

from typing import List

from repro.core.config import E2TrainConfig, SMDConfig
from repro.core.smd import expected_energy_ratio

from benchmarks.common import csv_row, eval_accuracy, final_loss, run_lm

SMD_CFG = SMDConfig(enabled=True, drop_prob=0.5)
# {1, 0.83, paper-op-point}: the last is config-derived (= 0.67)
RATIOS = (1.0, 0.83, expected_energy_ratio(SMD_CFG))


def run(fast: bool = True) -> List[str]:
    steps = 100 if fast else 400
    rows = []
    # SMB baseline at the matched energy ratios: fewer iterations,
    # schedule scaled (paper's "off-the-shelf" option 1)
    for ratio in RATIOS:
        n = int(steps * ratio)
        hist, tr, wall = run_lm(E2TrainConfig(), n, total_steps=n)
        rows.append(csv_row(
            f"fig3a/smb@{ratio:.2f}", wall / max(n, 1) * 1e6,
            f"loss={final_loss(hist):.4f};acc={eval_accuracy(tr):.4f};"
            f"energy_ratio={ratio:.2f}"))
    # SMD at the same *executed* budgets (2x nominal steps, p=0.5)
    for ratio in RATIOS:
        n = int(2 * steps * ratio)
        e2 = E2TrainConfig(smd=SMD_CFG)
        hist, tr, wall = run_lm(e2, n, total_steps=n)
        executed_ratio = tr.executed_steps / max(steps, 1)
        rows.append(csv_row(
            f"fig3a/smd@{ratio:.2f}", wall / max(n, 1) * 1e6,
            f"loss={final_loss(hist):.4f};acc={eval_accuracy(tr):.4f};"
            f"energy_ratio={executed_ratio:.2f}"))
    # Fig. 3b: SMB with increased lr at the SMD op-point budget vs SMD
    for lr in (0.1, 0.14, 0.2):
        n = int(steps * expected_energy_ratio(SMD_CFG))
        hist, tr, wall = run_lm(E2TrainConfig(), n, lr=lr, total_steps=n)
        rows.append(csv_row(
            f"fig3b/smb_lr{lr}", wall / max(n, 1) * 1e6,
            f"loss={final_loss(hist):.4f};acc={eval_accuracy(tr):.4f}"))
    return rows
