"""§Roofline: render the per-(arch x shape x mesh) roofline table from the
dry-run artifact (dryrun_results.json), plus an analytic single-chip table
for the paper's CNN backbones priced by the per-layer cost model
(``repro.tasks.cost_model`` — never transformer math for a CNN).

For each cell: compute/memory/collective terms in seconds, dominant
bottleneck, MODEL_FLOPS (6ND / 6N_active*D), useful-compute ratio, and a
one-line "what would move the dominant term".
"""
from __future__ import annotations

import json
import os
from typing import List

SUGGEST = {
    "compute": "increase arithmetic intensity (fuse, larger microbatch) or "
               "drop compute via SLU/PSG int paths",
    "memory": "keep activations sharded (SP), bf16 residuals, fewer "
              "stacked-residual bytes per unit (deeper remat)",
    "collective": "overlap FSDP all-gathers with compute (prefetch next "
                  "unit), PSG 1-bit majority-vote all-reduce, reduce "
                  "resharding between blocks",
}


def render(results_path: str = "dryrun_results.json") -> List[str]:
    if not os.path.exists(results_path):
        return [f"roofline: missing {results_path} — run "
                f"python -m repro.launch.dryrun --all --both-meshes --out "
                f"{results_path}"]
    with open(results_path) as f:
        cells = json.load(f)
    rows = []
    for c in cells:
        mesh = "2x16x16" if c.get("multi_pod") else "16x16"
        tag = f"{c['arch']}/{c['shape']}/{mesh}"
        if c["status"] == "skipped":
            rows.append(f"roofline/{tag},0.0,SKIPPED:{c['reason'][:60]}")
            continue
        if c["status"] != "ok":
            rows.append(f"roofline/{tag},0.0,ERROR:{c['error'][:60]}")
            continue
        r = c["roofline"]
        useful = c["useful_ratio"]
        peak_gib = c["bytes_per_device"]["peak"] / 2**30
        rows.append(
            f"roofline/{tag},{r['step_s']*1e6:.1f},"
            f"compute_s={r['compute_s']:.2e};memory_s={r['memory_s']:.2e};"
            f"collective_s={r['collective_s']:.2e};bound={r['bottleneck']};"
            f"model_flops={c['model_flops_6nd']:.3e};"
            f"useful_ratio={useful:.3f};peak_GiB={peak_gib:.2f};"
            f"fix={SUGGEST[r['bottleneck']][:48]}")
    return rows


def render_cnn_analytic() -> List[str]:
    """Single-chip roofline for the paper backbones from the CostModel —
    no dry-run artifact needed (CNN steps fit one chip)."""
    from repro.configs import paper_cnns
    from repro.core.cost import BYTES_FP32
    from repro.core.energy import roofline_terms
    from repro.tasks import cost_model

    rows = []
    for factory in (paper_cnns.resnet74, paper_cnns.resnet110,
                    paper_cnns.mobilenetv2):
        exp = factory()
        cost = cost_model(exp)
        B = exp.train.global_batch
        flops = 2.0 * cost.train_macs(B)
        hbm_bytes = BYTES_FP32 * cost.moved_words(B)
        r = roofline_terms(flops, hbm_bytes, coll_bytes=0.0, chips=1)
        rows.append(
            f"roofline/{cost.name}/train_cifar/1chip,{r['step_s']*1e6:.1f},"
            f"compute_s={r['compute_s']:.2e};memory_s={r['memory_s']:.2e};"
            f"bound={r['bottleneck']};macs={cost.fwd_macs():.3e};"
            f"params={cost.param_count()};"
            f"fix={SUGGEST[r['bottleneck']][:48]}")
    return rows


def run(fast: bool = True) -> List[str]:
    return render(os.path.join(os.path.dirname(__file__), "..",
                               "dryrun_results.json")) + render_cnn_analytic()
