"""Paper Tab. 4: E²-Train on the paper's own backbones (ResNet family +
MobileNetV2) — the faithful-reproduction path, reduced depths for CPU.

Rows: baseline SMB vs E²-Train at the three operating points, on the
class-conditional Gaussian image task; computational savings from the
composition law (exact, tests/test_energy.py)."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import psg as psgmod
from repro.core.config import (E2TrainConfig, PSGConfig, SLUConfig,
                               SMDConfig, TrainConfig)
from repro.core.energy import PSG_FACTOR_PAPER, computational_savings
from repro.core.smd import smd_keep_host
from repro.data.synthetic import GaussianImageTask, make_image_batch
from repro.models import resnet as R
from repro.optim.api import make_optimizer

from benchmarks.common import csv_row

TASK = GaussianImageTask(num_classes=10, snr=2.0)


def _train_resnet(depth: int, e2: E2TrainConfig, steps: int, *,
                  optimizer="sgdm", lr=0.1):
    tcfg = TrainConfig(lr=lr, optimizer=optimizer, total_steps=steps,
                       schedule="step", weight_decay=5e-4)
    params = R.init_resnet(jax.random.PRNGKey(0), depth, 10, e2)
    opt = make_optimizer(tcfg)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, batch, i):
        def loss_fn(p):
            with psgmod.enable(e2.psg if e2.psg.enabled else None):
                return R.resnet_loss(p, batch, depth, e2,
                                     jax.random.fold_in(jax.random.PRNGKey(1), i))
        (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        p2, o2 = opt.apply(params, g, opt_state, i)
        return p2, o2, l

    t0 = time.perf_counter()
    executed = 0
    for i in range(steps):
        if e2.smd.enabled and not smd_keep_host(0, i, e2.smd.drop_prob):
            continue
        batch = make_image_batch(TASK, 0, i, 0, 16)
        params, opt_state, l = step(params, opt_state, batch, jnp.int32(i))
        executed += 1
    wall = time.perf_counter() - t0

    # eval accuracy on held-out batches
    correct = total = 0
    for i in range(4):
        b = make_image_batch(TASK, 99, i, 0, 32)
        # batch-stat normalization at eval (running stats are not tracked
        # in this reduced harness; batch stats are unbiased at B=32)
        logits, _ = R.resnet_fwd(params, b["image"], depth,
                                 E2TrainConfig(), train=True)
        correct += (np.asarray(jnp.argmax(logits, -1)) ==
                    np.asarray(b["label"])).sum()
        total += 32
    return correct / total, executed, wall


def run(fast: bool = True) -> List[str]:
    steps = 80 if fast else 240
    depth = 14 if fast else 26          # reduced ResNet (6n+2 family)
    rows = []
    acc, n, wall = _train_resnet(depth, E2TrainConfig(), steps)
    rows.append(csv_row(f"tab4/resnet{depth}_smb", wall / max(n, 1) * 1e6,
                        f"acc={acc:.4f};savings=0.0"))
    e2 = E2TrainConfig(smd=SMDConfig(True), slu=SLUConfig(True, alpha=5e-3),
                       psg=PSGConfig(True, swa=False))
    acc2, n2, wall2 = _train_resnet(depth, e2, 2 * steps,
                                    optimizer="psg", lr=0.03)
    sav = computational_savings(0.67, 0.2, PSG_FACTOR_PAPER)
    rows.append(csv_row(f"tab4/resnet{depth}_e2train",
                        wall2 / max(n2, 1) * 1e6,
                        f"acc={acc2:.4f};savings={sav:.4f};paper=0.8027"))

    # MobileNetV2 (compact backbone, paper's last Tab. 4 block) — fwd-only
    # smoke at bench scale: verify the compact arch runs under the harness
    pmv = R.init_mobilenetv2(jax.random.PRNGKey(2))
    b = make_image_batch(TASK, 0, 0, 0, 8)
    t0 = time.perf_counter()
    logits = R.mobilenetv2_fwd(pmv, b["image"])
    wallm = (time.perf_counter() - t0) * 1e6
    rows.append(csv_row("tab4/mobilenetv2_fwd", wallm,
                        f"logits_finite={bool(np.isfinite(np.asarray(logits)).all())}"))
    return rows
