"""Paper Tab. 4: E²-Train on the paper's own backbones (ResNet family +
MobileNetV2) — the faithful-reproduction path, reduced depths for CPU.

Rows: baseline SMB vs E²-Train, on the class-conditional Gaussian image
task; savings come from ``Trainer.energy_report()`` — config-derived paper
composition next to the run's measured telemetry, priced by the per-layer
CNN cost model (core/cost.py).

Runs through the shared training stack (``repro.tasks`` "cifar_cnn" +
``Trainer``) — SMD drops, the PSG fallback probe, SLU metrics, and
eval-mode BatchNorm all come from the same code path the LM experiments
use; there is no CNN-specific training loop here.
"""
from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from repro.configs.paper_cnns import cnn_model
from repro.core.config import (E2TrainConfig, Experiment, PSGConfig,
                               SLUConfig, SMDConfig, TrainConfig)
from repro.data.synthetic import GaussianImageTask, make_image_batch
from repro.tasks import get_task
from repro.training.train_step import eval_params, init_train_state
from repro.training.trainer import Trainer

from benchmarks.common import csv_row, energy_fields

TASK = GaussianImageTask(num_classes=10, snr=2.0)
BATCH = 16


def _cnn_experiment(depth: int, e2: E2TrainConfig, steps: int, *,
                    optimizer="sgdm", lr=0.1) -> Experiment:
    return Experiment(
        model=cnn_model(f"resnet{depth}", depth),
        e2=e2,
        train=TrainConfig(global_batch=BATCH, lr=lr, optimizer=optimizer,
                          total_steps=steps, schedule="step",
                          weight_decay=5e-4),
        task="cifar_cnn")


def _train_resnet(depth: int, e2: E2TrainConfig, steps: int, *,
                  optimizer="sgdm", lr=0.1):
    exp = _cnn_experiment(depth, e2, steps, optimizer=optimizer, lr=lr)
    state = init_train_state(jax.random.PRNGKey(0), exp)
    trainer = Trainer(exp, state, lambda s, sh: make_image_batch(
        TASK, 0, s, sh, BATCH))
    t0 = time.perf_counter()
    trainer.run(steps)
    wall = time.perf_counter() - t0

    # eval accuracy on held-out batches: train=False normalization with the
    # EMA BatchNorm statistics the training run accumulated
    predict = get_task("cifar_cnn").make_predict(exp)
    params = eval_params(trainer.state, exp)
    correct = total = 0
    for i in range(4):
        b = make_image_batch(TASK, 99, i, 0, 32)
        logits = predict(params, trainer.state.model_state, b)
        correct += (np.asarray(jax.numpy.argmax(logits, -1)) ==
                    np.asarray(b["label"])).sum()
        total += 32
    return correct / total, trainer.executed_steps, wall, trainer


def run(fast: bool = True) -> List[str]:
    steps = 80 if fast else 240
    depth = 14 if fast else 26          # reduced ResNet (6n+2 family)
    rows = []
    acc, n, wall, tr0 = _train_resnet(depth, E2TrainConfig(), steps)
    rows.append(csv_row(f"tab4/resnet{depth}_smb", wall / max(n, 1) * 1e6,
                        f"acc={acc:.4f};{energy_fields(tr0, steps=steps)}"))
    e2 = E2TrainConfig(smd=SMDConfig(True),
                       slu=SLUConfig(True, alpha=5e-3, target_skip=0.2),
                       psg=PSGConfig(True, swa=False))
    acc2, n2, wall2, tr2 = _train_resnet(depth, e2, 2 * steps,
                                         optimizer="psg", lr=0.03)
    rows.append(csv_row(f"tab4/resnet{depth}_e2train",
                        wall2 / max(n2, 1) * 1e6,
                        f"acc={acc2:.4f};{energy_fields(tr2, steps=steps)};"
                        f"paper=0.8027;"
                        f"measured_psg_fallback={tr2.measured_psg_fallback()}"))

    # MobileNetV2 (compact backbone, paper's last Tab. 4 block) — fwd-only
    # smoke at bench scale: verify the compact arch runs under the harness
    from repro.models import resnet as R
    pmv, smv = R.init_mobilenetv2(jax.random.PRNGKey(2))
    b = make_image_batch(TASK, 0, 0, 0, 8)
    t0 = time.perf_counter()
    logits, _ = R.mobilenetv2_fwd(pmv, smv, b["image"])
    wallm = (time.perf_counter() - t0) * 1e6
    rows.append(csv_row("tab4/mobilenetv2_fwd", wallm,
                        f"logits_finite={bool(np.isfinite(np.asarray(logits)).all())}"))
    return rows
