"""Fault-injection recovery record (DESIGN.md §Fault-tolerance).

Every scenario injects a REAL fault through ``repro.ft.faults`` and records
whether the recovery machinery did its job:

* ``corrupt_recovery`` — one scenario per corruption mode
  (truncate/flip/tamper/partial): integrity verification must detect the
  damage and restore must fall back to the previous intact step;
* ``producer_raise`` — a raising data producer must propagate to the
  consumer within one step (the pre-PR 10 silent-hang bug);
* ``failing_writer`` — transient write failures are absorbed by
  retry-with-backoff; terminal failures surface as CheckpointWriteError
  (never a silently dead daemon thread);
* ``kill_restart`` — the end-to-end tentpole: a launcher worker
  hard-killed mid-run, supervised kill-and-restart onto a smaller world,
  resume from the last intact checkpoint, final checkpoint BIT-IDENTICAL
  to an uninterrupted run (counter-based schedule consistency).

``ft_json`` returns the record; ``run.py --json-ft`` writes BENCH_ft.json
and exits nonzero when any recovery failed — this is the CI gate.
"""
from __future__ import annotations

import os
import subprocess
import sys
import tempfile
import time

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tiny_state():
    import jax.numpy as jnp
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((4,))},
            "step": jnp.int32(5)}


def _corrupt_recovery_scenarios():
    from repro.ft import faults
    from repro.ft.checkpoint import (latest_intact_step, restore_checkpoint,
                                     save_checkpoint, verify_checkpoint)
    rows = []
    for mode in faults.CORRUPT_MODES:
        with tempfile.TemporaryDirectory() as d:
            st = _tiny_state()
            save_checkpoint(d, st, 3)
            save_checkpoint(d, st, 7)
            faults.corrupt_checkpoint(d, 7, mode)
            detected, reason = verify_checkpoint(d, 7)
            detected = not detected
            fell_back = latest_intact_step(d) == 3
            try:
                _, got = restore_checkpoint(d, st)
                restored_ok = got == 3
            except Exception as e:  # noqa: BLE001 - recorded, not swallowed
                restored_ok, reason = False, repr(e)
            rows.append({"scenario": f"corrupt_{mode}",
                         "detected": detected, "fell_back": fell_back,
                         "reason": reason,
                         "recovered": detected and fell_back and restored_ok})
    return rows


def _producer_raise_scenario():
    from repro.data.pipeline import DataPipeline
    from repro.ft.faults import raising_at_step
    mk = raising_at_step(lambda s, sh: {"x": np.full((2,), s)}, 3)
    pipe = DataPipeline(mk, None, prefetch=2)
    got, err, t0 = [], None, time.perf_counter()
    try:
        for _ in range(10):
            got.append(next(pipe)[0])
    except RuntimeError as e:
        err = e
    surfaced_s = time.perf_counter() - t0
    pipe.close()
    recovered = (err is not None and got == [0, 1, 2] and surfaced_s < 5.0)
    return {"scenario": "producer_raise", "good_steps_consumed": got,
            "surfaced_s": round(surfaced_s, 3), "recovered": recovered}


def _failing_writer_scenarios():
    from repro.ft import faults
    from repro.ft.checkpoint import (WRITE_RETRIES, CheckpointWriteError,
                                     save_checkpoint, verify_checkpoint,
                                     wait_for_saves)
    rows = []
    with tempfile.TemporaryDirectory() as d:
        with faults.failing_writer(fails=WRITE_RETRIES - 1) as count:
            save_checkpoint(d, _tiny_state(), 1)
        intact = verify_checkpoint(d, 1)[0]
        rows.append({"scenario": "writer_transient_retry",
                     "injected_failures": count["n"],
                     "recovered": intact and count["n"] == WRITE_RETRIES - 1})
    with tempfile.TemporaryDirectory() as d:
        surfaced = False
        with faults.failing_writer():            # never recovers
            save_checkpoint(d, _tiny_state(), 1, async_save=True)
            try:
                wait_for_saves()
            except CheckpointWriteError:
                surfaced = True
        rows.append({"scenario": "writer_terminal_surfaced",
                     "recovered": surfaced and wait_for_saves() == {}})
    return rows


def _launcher(*args):
    return [sys.executable, "-m", "repro.launch.train",
            "--arch", "llama3_8b", "--smoke", "--log-every", "0", *args]


def _kill_restart_scenario(fast: bool = True):
    from repro.ft.checkpoint import latest_intact_step
    from repro.ft.faults import KILL_EXIT_CODE
    from repro.ft.supervisor import Supervisor
    steps = 8 if fast else 16
    env = dict(os.environ)
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    with tempfile.TemporaryDirectory() as d:
        ckpt, scratch, ref = (os.path.join(d, n)
                              for n in ("ckpt", "scratch", "ref"))

        def make_cmd(world, rank, resume):
            args = ["--steps", str(steps), "--e2train", "smd",
                    "--ckpt-every", "1",
                    "--ckpt", ckpt if rank == 0 else scratch]
            if resume is not None:
                args += ["--resume"]
            elif world > 1 and rank == world - 1:
                args += ["--ft-kill-at-step", str(steps // 2 + 1)]
            return _launcher(*args)

        sup = Supervisor(make_cmd, world=2, ckpt_dir=ckpt, env=env)
        try:
            sup.run()
            supervised_ok = True
        except Exception as e:  # noqa: BLE001 - recorded, not swallowed
            supervised_ok, err = False, repr(e)
        att = sup.summary()
        final_intact = latest_intact_step(ckpt)

        ref_run = subprocess.run(
            _launcher("--steps", str(steps), "--e2train", "smd",
                      "--ckpt-every", "1", "--ckpt", ref),
            cwd=REPO, env=env, capture_output=True, text=True, timeout=580)

        bitwise = False
        if supervised_ok and ref_run.returncode == 0 \
                and final_intact == steps - 1:
            a = np.load(os.path.join(ckpt, f"step_{steps - 1:08d}.npz"))
            b = np.load(os.path.join(ref, f"step_{steps - 1:08d}.npz"))
            bitwise = set(a.files) == set(b.files) and all(
                np.array_equal(a[k], b[k]) for k in a.files)
        row = {"scenario": "kill_restart", "steps": steps,
               "kill_exit_code": KILL_EXIT_CODE, "attempts": att["attempts"],
               "restarts": att["restarts"], "final_intact_step": final_intact,
               "bitwise_match_vs_uninterrupted": bitwise,
               "recovered": supervised_ok and bitwise}
        if not supervised_ok:
            row["error"] = err
        return row


def ft_json(fast: bool = True) -> dict:
    """The fault-injection recovery record (see module doc)."""
    scenarios = []
    scenarios += _corrupt_recovery_scenarios()
    scenarios.append(_producer_raise_scenario())
    scenarios += _failing_writer_scenarios()
    scenarios.append(_kill_restart_scenario(fast=fast))
    return {"scenarios": scenarios,
            "all_recovered": all(s["recovered"] for s in scenarios)}


def run(fast: bool = True):
    """CSV rows for the bench driver."""
    record = ft_json(fast=fast)
    for s in record["scenarios"]:
        yield f"ft_{s['scenario']},0.0,recovered={s['recovered']}"
    yield f"ft_all,0.0,all_recovered={record['all_recovered']}"
