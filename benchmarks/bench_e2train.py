"""Paper Tab. 3/4: combined E²-Train (SMD+SLU+PSG) savings + accuracy.

Each operating point is an ``E2TrainConfig`` (SMD drop 0.5 at the paper's
epochs multiplier, SLU ``target_skip`` 20/40/60%); the savings columns come
from ``Trainer.energy_report()`` — the config-derived paper composition
(cross-checked against the published rows in tests/test_energy.py) next to
the run's measured telemetry.  Accuracy is measured at each point on the
synthetic task.
"""
from __future__ import annotations

from typing import List

from repro.core.config import (E2TrainConfig, PSGConfig, SLUConfig,
                               SMDConfig)

from benchmarks.common import (csv_row, energy_fields, eval_accuracy,
                               final_loss, run_lm)


def run(fast: bool = True) -> List[str]:
    steps = 160 if fast else 480
    rows = []
    # paper's three operating points: SLU skip 20/40/60%
    for skip, alpha in ((0.2, 2e-3), (0.4, 1e-2), (0.6, 4e-2)):
        e2 = E2TrainConfig(
            smd=SMDConfig(enabled=True, drop_prob=0.5),
            slu=SLUConfig(enabled=True, alpha=alpha, target_skip=skip,
                          never_skip_first_last=False),
            psg=PSGConfig(enabled=True))
        hist, tr, wall = run_lm(e2, steps, lr=0.03, optimizer="psg")
        rows.append(csv_row(
            f"tab3/e2train_skip{int(skip*100)}",
            wall / max(tr.executed_steps, 1) * 1e6,
            f"loss={final_loss(hist):.4f};acc={eval_accuracy(tr):.4f};"
            f"{energy_fields(tr, steps=steps)};"
            f"paper={'0.8027' if skip == 0.2 else '0.8520' if skip == 0.4 else '0.9013'}"))
    return rows
