"""Paper Fig. 4: SLU (learned gates) vs Stochastic Depth (random skipping)."""
from __future__ import annotations

from typing import List

from repro.core.config import E2TrainConfig, SLUConfig

from benchmarks.common import csv_row, eval_accuracy, final_loss, run_lm


def _run_sd(keep_prob: float, steps: int):
    """Stochastic depth baseline: SLU machinery with a *frozen* random gate
    (clip the keep prob by setting min_keep_prob == the target and alpha
    huge so the learned gate saturates at the floor = random skipping)."""
    e2 = E2TrainConfig(slu=SLUConfig(enabled=True, alpha=50.0,
                                     min_keep_prob=keep_prob,
                                     never_skip_first_last=False))
    return run_lm(e2, steps, alpha=50.0)


def run(fast: bool = True) -> List[str]:
    steps = 100 if fast else 400
    rows = []
    for alpha, tag in ((1e-3, "slu_mild"), (0.05, "slu_strong")):
        e2 = E2TrainConfig(slu=SLUConfig(enabled=True, alpha=alpha,
                                         never_skip_first_last=False))
        hist, tr, wall = run_lm(e2, steps)
        # measured whole-run gate execution, via the ledger (None ≠ 0)
        skip = tr.energy_report(steps=steps).slu.measured
        exec_ratio = 1.0 - (skip or 0.0)
        rows.append(csv_row(
            f"fig4/{tag}", wall / steps * 1e6,
            f"loss={final_loss(hist):.4f};acc={eval_accuracy(tr):.4f};"
            f"exec_ratio={exec_ratio:.2f}"))
    for kp, tag in ((0.8, "sd_skip20"), (0.6, "sd_skip40")):
        hist, tr, wall = _run_sd(kp, steps)
        rows.append(csv_row(
            f"fig4/{tag}", wall / steps * 1e6,
            f"loss={final_loss(hist):.4f};acc={eval_accuracy(tr):.4f};"
            f"exec_ratio={kp:.2f}"))
    return rows
