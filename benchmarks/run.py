"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` runs longer budgets.

``--json [PATH]`` (default ``BENCH_energy.json``) instead records the
energy trajectory: a short measured E²-Train run on the paper's ResNet
through ``Trainer.energy_report()``, plus the config-derived Table 3 sweep
for ResNet-74 — every field straight from :class:`EnergyReport`, so CI can
diff the numbers PR over PR.

``--json-throughput [PATH]`` (default ``BENCH_throughput.json``) records
the loop-throughput trajectory: executed steps/s of the per-step vs
chunked loop and the chunk speedup on the depth-14 ResNet CPU configs
(benchmarks/bench_throughput.py).

``--json-conv [PATH]`` (default ``BENCH_conv.json``) records the
fused-conv trajectory: implicit-GEMM vs materialized-im2col activation
bytes moved per training step on the paper-shaped ResNet-74 config plus
per-shape rows and a CPU proxy steps/s A/B (benchmarks/bench_conv.py).
Both traffic directions (fwd/bwd x-side AND the dx side) are counted per
path; exits nonzero if any path's byte accounting is incomplete.

``--json-attn [PATH]`` (default ``BENCH_attn.json``) records the
flash-attention trajectory: PSG flash backward vs materialized (S, T)
path attention bytes moved per training step on the paper-shaped LM
config, per-shape rows and a CPU proxy LM A/B with the measured
attention-backward fallback ratio (benchmarks/bench_attn.py).  Both
traffic directions are counted per path; exits nonzero if any path's
byte accounting is incomplete.

``--json-audit [PATH]`` (default ``BENCH_audit.json``) records the static
cost audit: per-layer CostModel vs jaxpr vs compiled-HLO reconciliation
for the paper backbones and the smoke LM, plus the full lint battery —
Pallas kernel linter, repo convention linter, precision-flow lint and
hot-loop lint (benchmarks/bench_audit.py).  Exits 1 when the audit or a
linter *fails*, 2 when a lint pass *errors* (a crashing linter must not
pass CI silently) — this is the CI gate.

``--json-ft [PATH]`` (default ``BENCH_ft.json``) records the
fault-injection recovery battery (benchmarks/bench_ft.py): corruption
detection + fallback per injected mode, producer-raise propagation,
write-failure retry/surfacing, and the supervised kill-and-restart smoke
with its bitwise-vs-uninterrupted verdict.  Exits 1 when any recovery
failed — the fault-injection CI gate.  CI uploads all BENCH JSONs.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# invoked as `python benchmarks/run.py`: sys.path[0] is benchmarks/, so put
# the repo root there too for the `from benchmarks import ...` bench imports
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def energy_json(fast: bool = True) -> dict:
    """EnergyReport fields for the trajectory record (see module doc)."""
    import jax

    from repro.configs.paper_cnns import cnn_model, resnet74
    from repro.core.config import (E2TrainConfig, Experiment, PSGConfig,
                                   SLUConfig, SMDConfig, TrainConfig)
    from repro.core.ledger import EnergyLedger
    from repro.data.synthetic import GaussianImageTask, make_image_batch
    from repro.training.train_step import init_train_state
    from repro.training.trainer import Trainer

    # config-derived Table 3 sweep: ResNet-74 at the paper's three operating
    # points, no training required — measured columns are null (≠ 0)
    table3 = []
    for skip in (0.2, 0.4, 0.6):
        op = E2TrainConfig(smd=SMDConfig(enabled=True, drop_prob=0.5),
                           slu=SLUConfig(enabled=True, target_skip=skip),
                           psg=PSGConfig(enabled=True))
        table3.append(EnergyLedger(resnet74(e2=op))
                      .report(validate_against_hlo=True).to_dict())

    # measured: a short full-E²-Train CNN run through the shared Trainer
    depth, steps = (14, 12) if fast else (26, 40)
    e2 = E2TrainConfig(smd=SMDConfig(enabled=True, drop_prob=0.5),
                       slu=SLUConfig(enabled=True, alpha=5e-3,
                                     target_skip=0.2),
                       psg=PSGConfig(enabled=True, swa=False))
    exp = Experiment(model=cnn_model(f"resnet{depth}", depth), e2=e2,
                     train=TrainConfig(global_batch=8, lr=0.03,
                                       optimizer="psg", total_steps=steps,
                                       schedule="constant"),
                     task="cifar_cnn")
    task = GaussianImageTask(num_classes=10, snr=2.0)
    tr = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp),
                 lambda s, sh: make_image_batch(task, 0, s, sh, 8))
    tr.run(steps)
    return {"table3_config_derived": table3,
            "measured_run": tr.energy_report(
                steps=steps, validate_against_hlo=True).to_dict()}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names (smd,slu,psg,e2train,"
                         "cnn,convergence,kernels,conv,attn,throughput,"
                         "roofline,audit,ft)")
    ap.add_argument("--json", nargs="?", const="BENCH_energy.json",
                    default=None, metavar="PATH",
                    help="write the EnergyReport trajectory record to PATH "
                         "and exit (skips the CSV benches)")
    ap.add_argument("--json-throughput", nargs="?",
                    const="BENCH_throughput.json", default=None,
                    metavar="PATH",
                    help="write the chunked-loop throughput record "
                         "(steps/s per-step vs chunked + speedup) to PATH "
                         "and exit (skips the CSV benches)")
    ap.add_argument("--json-conv", nargs="?", const="BENCH_conv.json",
                    default=None, metavar="PATH",
                    help="write the fused-conv record (implicit-GEMM vs "
                         "im2col: activation bytes moved + CPU proxy "
                         "steps/s) to PATH and exit (skips the CSV benches)")
    ap.add_argument("--json-attn", nargs="?", const="BENCH_attn.json",
                    default=None, metavar="PATH",
                    help="write the flash-attention record (PSG flash "
                         "backward vs materialized path: attention bytes "
                         "moved + CPU proxy steps/s + measured fallback) to "
                         "PATH and exit (skips the CSV benches)")
    ap.add_argument("--json-audit", nargs="?", const="BENCH_audit.json",
                    default=None, metavar="PATH",
                    help="write the static cost-audit record (CostModel vs "
                         "jaxpr vs HLO + kernel/repo lint) to PATH and exit "
                         "nonzero on divergence or lint findings")
    ap.add_argument("--json-ft", nargs="?", const="BENCH_ft.json",
                    default=None, metavar="PATH",
                    help="write the fault-injection recovery record "
                         "(corruption fallback, producer-raise, write "
                         "retry/surfacing, kill-and-restart) to PATH and "
                         "exit nonzero if any recovery failed")
    args = ap.parse_args(argv)
    fast = not args.full

    if args.json or args.json_throughput or args.json_conv \
            or args.json_attn or args.json_audit \
            or args.json_ft:                                 # write all given
        if args.json:
            with open(args.json, "w") as f:
                json.dump(energy_json(fast=fast), f, indent=2)
            print(f"wrote {args.json}", file=sys.stderr)
        if args.json_throughput:
            from benchmarks.bench_throughput import throughput_json
            with open(args.json_throughput, "w") as f:
                json.dump(throughput_json(fast=fast), f, indent=2)
            print(f"wrote {args.json_throughput}", file=sys.stderr)
        if args.json_conv:
            from benchmarks.bench_conv import (IncompleteAccountingError,
                                               conv_json)
            try:
                record = conv_json(fast=fast)
            except IncompleteAccountingError as e:
                print(f"conv byte accounting incomplete: {e}",
                      file=sys.stderr)
                sys.exit(1)
            with open(args.json_conv, "w") as f:
                json.dump(record, f, indent=2)
            print(f"wrote {args.json_conv}", file=sys.stderr)
        if args.json_attn:
            from benchmarks.bench_attn import (IncompleteAccountingError,
                                               attn_json)
            try:
                record = attn_json(fast=fast)
            except IncompleteAccountingError as e:
                print(f"attention byte accounting incomplete: {e}",
                      file=sys.stderr)
                sys.exit(1)
            with open(args.json_attn, "w") as f:
                json.dump(record, f, indent=2)
            print(f"wrote {args.json_attn}", file=sys.stderr)
        if args.json_audit:
            from benchmarks.bench_audit import audit_json
            record = audit_json(fast=fast)
            with open(args.json_audit, "w") as f:
                json.dump(record, f, indent=2)
            print(f"wrote {args.json_audit}", file=sys.stderr)
            # a linter that CRASHED is not a linter that passed: distinct
            # exit code so CI can tell "findings" (1) from "broken
            # tooling" (2) — a crashing lint pass must never gate green
            if record.get("lint_errors"):
                print(f"lint pass(es) errored: "
                      f"{', '.join(record['lint_errors'])}", file=sys.stderr)
                sys.exit(2)
            if not record["all_passed"]:
                sys.exit(1)
        if args.json_ft:
            from benchmarks.bench_ft import ft_json
            record = ft_json(fast=fast)
            with open(args.json_ft, "w") as f:
                json.dump(record, f, indent=2)
            print(f"wrote {args.json_ft}", file=sys.stderr)
            if not record["all_recovered"]:
                failed = [s["scenario"] for s in record["scenarios"]
                          if not s["recovered"]]
                print(f"recovery failed: {', '.join(failed)}",
                      file=sys.stderr)
                sys.exit(1)
        return

    from benchmarks import (bench_attn, bench_audit, bench_cnn, bench_conv,
                            bench_convergence, bench_e2train, bench_ft,
                            bench_kernels, bench_psg, bench_slu, bench_smd,
                            bench_throughput, roofline)

    benches = {
        "smd": bench_smd.run,           # Fig. 3a/3b, Tab. 1
        "slu": bench_slu.run,           # Fig. 4
        "psg": bench_psg.run,           # Tab. 2
        "e2train": bench_e2train.run,   # Tab. 3
        "cnn": bench_cnn.run,           # Tab. 4 (paper backbones)
        "convergence": bench_convergence.run,  # Fig. 5
        "kernels": bench_kernels.run,
        "conv": bench_conv.run,         # §Kernels (implicit-GEMM vs im2col)
        "attn": bench_attn.run,         # §Kernels (PSG flash bwd vs (S,T))
        "throughput": bench_throughput.run,  # §Loop (chunked vs per-step)
        "roofline": roofline.run,       # §Roofline (from dry-run artifact)
        "audit": bench_audit.run,       # §Analysis (static cost audit)
        "ft": bench_ft.run,             # §Fault-tolerance (injected faults)
    }
    only = set(args.only.split(",")) if args.only else set(benches)

    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if name not in only:
            continue
        t0 = time.time()
        try:
            for row in fn(fast=fast):
                print(row, flush=True)
        except Exception as e:  # noqa
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}", flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
