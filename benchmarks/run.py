"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` runs longer budgets.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names (smd,slu,psg,e2train,"
                         "cnn,convergence,kernels,roofline)")
    args = ap.parse_args(argv)
    fast = not args.full

    from benchmarks import (bench_cnn, bench_convergence, bench_e2train,
                            bench_kernels, bench_psg, bench_slu, bench_smd,
                            roofline)

    benches = {
        "smd": bench_smd.run,           # Fig. 3a/3b, Tab. 1
        "slu": bench_slu.run,           # Fig. 4
        "psg": bench_psg.run,           # Tab. 2
        "e2train": bench_e2train.run,   # Tab. 3
        "cnn": bench_cnn.run,           # Tab. 4 (paper backbones)
        "convergence": bench_convergence.run,  # Fig. 5
        "kernels": bench_kernels.run,
        "roofline": roofline.run,       # §Roofline (from dry-run artifact)
    }
    only = set(args.only.split(",")) if args.only else set(benches)

    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if name not in only:
            continue
        t0 = time.time()
        try:
            for row in fn(fast=fast):
                print(row, flush=True)
        except Exception as e:  # noqa
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}", flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
