"""Paper Tab. 2: 32-bit SGD vs 8-bit fixed point vs SignSGD vs PSG.

Accuracy comes from training the bench model with each regime; energy
savings come from the paper's own 45nm per-op model (core/energy.py) — the
same pathway the paper uses to convert op counts to energy.
"""
from __future__ import annotations

from typing import List

from repro.core.config import E2TrainConfig, PSGConfig
from repro.core.energy import FP32_MAC_PJ, mac_energy_pj

from benchmarks.common import csv_row, eval_accuracy, final_loss, run_lm


def run(fast: bool = True) -> List[str]:
    steps = 60 if fast else 240
    rows = []

    # 32-bit SGD baseline
    hist, tr, wall = run_lm(E2TrainConfig(), steps)
    rows.append(csv_row("tab2/sgd32", wall / steps * 1e6,
                        f"loss={final_loss(hist):.4f};"
                        f"acc={eval_accuracy(tr):.4f};energy_saving=0.000"))

    # 8-bit fixed point [Banner et al.]: quantized fwd/bwd, fp32 update —
    # PSG machinery with predictors disabled (beta=0 -> always full product)
    e2_8bit = E2TrainConfig(psg=PSGConfig(enabled=True, beta=0.0, swa=False))
    hist, tr, wall = run_lm(e2_8bit, steps, lr=0.03, optimizer="signsgd")
    s8 = 1 - (mac_energy_pj(8, 8) + mac_energy_pj(16, 8)
              + mac_energy_pj(8, 16)) / (3 * FP32_MAC_PJ)
    rows.append(csv_row("tab2/fixed8", wall / steps * 1e6,
                        f"loss={final_loss(hist):.4f};"
                        f"acc={eval_accuracy(tr):.4f};energy_saving={s8:.3f}"))

    # SignSGD (full-precision grads, sign update) — paper: no energy saving
    hist, tr, wall = run_lm(E2TrainConfig(), steps, lr=0.03,
                            optimizer="signsgd")
    rows.append(csv_row("tab2/signsgd", wall / steps * 1e6,
                        f"loss={final_loss(hist):.4f};"
                        f"acc={eval_accuracy(tr):.4f};energy_saving=0.000"))

    # PSG (predictive sign, mixed precision, SWA) — energy saving from the
    # run's EnergyReport: the *measured* fallback-tile ratio the backward
    # kernel reported per step, alongside the 0.4-assumption design point.
    e2_psg = E2TrainConfig(psg=PSGConfig(enabled=True))
    hist, tr, wall = run_lm(e2_psg, steps, lr=0.03, optimizer="psg")
    rep = tr.energy_report(steps=steps)
    assert rep.psg.measured is not None, \
        "PSG run produced no fallback measurements"
    rows.append(csv_row("tab2/psg", wall / steps * 1e6,
                        f"loss={final_loss(hist):.4f};"
                        f"acc={eval_accuracy(tr):.4f};"
                        f"energy_saving={1 - rep.psg_factor_assumed:.3f};"
                        f"measured_fallback={rep.psg.measured:.3f};"
                        f"energy_saving_measured={1 - rep.psg_factor_measured:.3f}"))
    return rows
