"""Shared benchmark harness: tiny-but-learnable task + timed training runs.

Every paper table/figure gets one module; ``run.py`` drives them all and
emits ``name,us_per_call,derived`` CSV rows.  The CNN/LM models are reduced
(CPU container) but the *structure* matches the paper's experiments; energy
numbers come from the paper's own 45nm per-op model (core/energy.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.config import (E2TrainConfig, Experiment, ModelConfig,
                               PSGConfig, SLUConfig, SMDConfig, TrainConfig)
from repro.data.synthetic import MarkovLMTask, make_lm_batch
from repro.training.train_step import init_train_state
from repro.training.trainer import Trainer

TINY = ModelConfig(name="bench", family="dense", num_layers=4, d_model=64,
                   num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                   dtype="float32")
TASK = MarkovLMTask(vocab=64)


def run_lm(e2: E2TrainConfig, steps: int, *, lr: float = 0.1,
           optimizer: str = "sgdm", seed: int = 0,
           alpha: float = 1e-3, total_steps: Optional[int] = None,
           model: ModelConfig = TINY) -> Tuple[List[Dict], Trainer, float]:
    """Train the bench model; returns (history, trainer, wall_seconds)."""
    exp = Experiment(
        model=model, e2=e2,
        train=TrainConfig(global_batch=16, seq_len=32, lr=lr,
                          optimizer=optimizer, schedule="step",
                          total_steps=total_steps or steps, seed=seed))
    mk = lambda s, sh: make_lm_batch(TASK, seed, s, sh, 16, 32)
    state = init_train_state(jax.random.PRNGKey(seed), exp)
    tr = Trainer(exp, state, mk)
    t0 = time.perf_counter()
    hist = tr.run(steps)
    wall = time.perf_counter() - t0
    return hist, tr, wall


def final_loss(hist: List[Dict], k: int = 5) -> float:
    return float(np.mean([h["loss"] for h in hist[-k:]])) if hist else float("nan")


def eval_accuracy(trainer: Trainer, n_batches: int = 4) -> float:
    """Next-token top-1 accuracy on held-out synthetic batches."""
    import jax.numpy as jnp
    from repro.models import transformer as T
    from repro.training.train_step import eval_params
    params = eval_params(trainer.state, trainer.exp)
    cfg = trainer.exp.model
    correct = total = 0
    for i in range(n_batches):
        b = make_lm_batch(TASK, 999, i, 0, 16, 32)
        out = T.lm_fwd(params, b["tokens"], cfg, train=False, remat="none")
        pred = np.asarray(jnp.argmax(out.logits, -1))
        lab = np.asarray(b["labels"])
        m = lab >= 0
        correct += (pred[m] == lab[m]).sum()
        total += m.sum()
    return correct / max(total, 1)


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def time_us(fn, *args, reps: int = 3):
    """(us_per_call, last_result) — the result is returned so callers don't
    re-execute the (interpret-mode, expensive) kernel just to read it.
    The first call compiles and is excluded from the timing."""
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6, out


def one_per_kind(shapes):
    """First ConvShape of each ``kind`` — the fast-mode sweep subset that
    still covers body / stride-2 transition / 1x1 downsample geometry."""
    by_kind = {}
    for s in shapes:
        by_kind.setdefault(s.kind, s)
    return list(by_kind.values())


def energy_fields(trainer: Trainer, steps: Optional[int] = None) -> str:
    """Derived-CSV fragment from the run's EnergyReport — the single path
    every bench reports energy through (DESIGN.md §Energy).

    ``paper_composition`` is the config-derived Table 3/4 cross-check;
    ``comp_saving_measured`` is the telemetry-driven column (empty when the
    run produced no measurement — absence, not zero).
    """
    rep = trainer.energy_report(steps=steps)
    meas = rep.computational_savings_measured
    return (f"paper_composition={rep.paper_composition:.4f};"
            f"comp_saving_assumed={rep.computational_savings_assumed:.4f};"
            f"comp_saving_measured="
            + ("" if meas is None else f"{meas:.4f}")
            + f";energy_saving_45nm={rep.energy_savings_assumed:.4f}")
