"""Paper Fig. 5: convergence (loss) vs cumulative energy for SMB / SD /
SLU / SLU+SMD / E²-Train."""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.config import (E2TrainConfig, PSGConfig, SLUConfig,
                               SMDConfig)
from repro.core.energy import PSG_FACTOR_PAPER

from benchmarks.common import csv_row, final_loss, run_lm


def run(fast: bool = True) -> List[str]:
    steps = 60 if fast else 240
    variants = {
        "smb": (E2TrainConfig(), dict()),
        "slu": (E2TrainConfig(slu=SLUConfig(True, alpha=1e-3)), dict()),
        "slu_smd": (E2TrainConfig(smd=SMDConfig(True),
                                  slu=SLUConfig(True, alpha=1e-3)), dict()),
        "e2train": (E2TrainConfig.full(),
                    dict(lr=0.03, optimizer="psg")),
    }
    rows = []
    for tag, (e2, kw) in variants.items():
        hist, tr, wall = run_lm(e2, steps, **kw)
        # per-executed-step energy factor for the x-axis
        f = 1.0
        if e2.slu.enabled:
            f *= float(np.mean([h["slu_exec_ratio"] for h in hist[-10:]]))
        if e2.psg.enabled:
            f *= PSG_FACTOR_PAPER
        curve = [round(h["loss"], 3) for h in hist[:: max(len(hist) // 8, 1)]]
        rows.append(csv_row(
            f"fig5/{tag}", wall / max(len(hist), 1) * 1e6,
            f"final={final_loss(hist):.4f};energy_per_step={f:.3f};"
            f"curve={'|'.join(map(str, curve))}"))
    return rows
