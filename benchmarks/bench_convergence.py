"""Paper Fig. 5: convergence (loss) vs cumulative energy for SMB / SD /
SLU / SLU+SMD / E²-Train.  The per-step energy factor on the x-axis comes
from the run's EnergyReport (measured SLU execution, measured PSG fallback
→ 45nm factor), not an assumed constant."""
from __future__ import annotations

from typing import List

from repro.core.config import (E2TrainConfig, PSGConfig, SLUConfig,
                               SMDConfig)

from benchmarks.common import csv_row, final_loss, run_lm


def run(fast: bool = True) -> List[str]:
    steps = 60 if fast else 240
    variants = {
        "smb": (E2TrainConfig(), dict()),
        "slu": (E2TrainConfig(slu=SLUConfig(True, alpha=1e-3)), dict()),
        "slu_smd": (E2TrainConfig(smd=SMDConfig(True),
                                  slu=SLUConfig(True, alpha=1e-3)), dict()),
        "e2train": (E2TrainConfig.full(),
                    dict(lr=0.03, optimizer="psg")),
    }
    rows = []
    for tag, (e2, kw) in variants.items():
        hist, tr, wall = run_lm(e2, steps, **kw)
        # per-executed-step energy factor for the x-axis, from measured
        # telemetry (assumed operating point only where nothing measured)
        rep = tr.energy_report(steps=steps)
        f = 1.0
        if e2.slu.enabled and rep.slu.resolved() is not None:
            f *= 1.0 - rep.slu.resolved()
        if e2.psg.enabled:
            f *= (rep.psg_factor_measured
                  if rep.psg_factor_measured is not None
                  else rep.psg_factor_assumed)
        curve = [round(h["loss"], 3) for h in hist[:: max(len(hist) // 8, 1)]]
        rows.append(csv_row(
            f"fig5/{tag}", wall / max(len(hist), 1) * 1e6,
            f"final={final_loss(hist):.4f};energy_per_step={f:.3f};"
            f"curve={'|'.join(map(str, curve))}"))
    return rows
