"""Fused implicit-GEMM conv kernels vs the materialized im2col path.

Two quantities per convolution site of the paper's CIFAR ResNet:

* **HBM activation bytes moved** — the quantity of record (the same
  precedent as bench_kernels' flash-attention ``hbm_ratio`` column): wall
  time on the CPU Pallas interpreter is not TPU-representative, but the
  operand lifecycle each path streams through HBM is a property of the
  dispatch/BlockSpec structure and is computed exactly below;
* **wall time** of a jitted forward+weight-grad on both paths (recorded
  for the CPU trend only, clearly labeled as interpreter numbers).

What the byte accounting counts (x-side activation traffic only — the
output-gradient and output tensors move identically on both paths and are
excluded from both sides):

im2col path (``models/resnet.conv2d`` default, N = B*H'*W', din = k*k*C):
  forward   reads the input once, then WRITES the (N, din) fp32 patch
            tensor and reads it back for the GEMM;
  backward  re-reads the saved patch tensor twice to build the MSB/full
            quantization code grids, writes both int8 code copies, and the
            kernel passes read the codes three times (predictor pass: msb;
            gated pass: msb + full).

fused path (``kernels/conv.py``, Xp = B*Hp*Wp*C padded-input elements):
  forward   reads the padded input once per dout tile (n_j = ceil(dout /
            BN)); no patch tensor exists;
  backward  reads the padded input twice for code building, writes both
            int8 code copies, and the two kernel passes read the codes
            once per dout tile each (predictor: msb; gated: msb + full).

For a 3x3 conv the patch tensor is a ~9x copy of the input, so the ratio
lands around an order of magnitude; ``conv_json`` records the per-step
totals over every conv site of the paper-shaped ResNet-74 batch-128
config (``BENCH_conv.json``, uploaded by CI next to the other BENCH
artifacts).
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.kernels.conv import DEFAULT_BN

FP32 = 4
INT8 = 1


def _geom(shape):
    """Per-path operand extents of a conv site: (patch elems, kernel-operand
    elems, full-input elems, pre-subsample elems or 0, dout tiles).

    For ``k >= stride`` the kernel operand is the padded input.  For
    ``k < stride`` (the 1x1 stride-2 projection shortcut) both paths
    consume the ``[::s, ::s]`` subsample — ``core/psg.conv2d`` normalizes
    to a stride-1 conv over it, and the materialized path's patch tensor
    IS it — but BUILDING it still reads the full input once on either
    path, so that read is charged separately (``sub_elems`` marks the
    subsample-write the fused path additionally pays).
    """
    pad = shape.k // 2
    full_elems = shape.batch * shape.hw * shape.hw * shape.cin
    if shape.k < shape.stride:
        xp_elems = shape.batch * shape.hw_out * shape.hw_out * shape.cin
        sub_elems = xp_elems
    else:
        hw_in = shape.hw + 2 * pad
        xp_elems = shape.batch * hw_in * hw_in * shape.cin
        sub_elems = 0
    patch_elems = (shape.batch * shape.hw_out * shape.hw_out *
                   shape.k * shape.k * shape.cin)
    n_j = -(-shape.cout // DEFAULT_BN)        # the kernel's dout tile count
    return patch_elems, xp_elems, full_elems, sub_elems, n_j


def im2col_activation_bytes(shape) -> int:
    """x-side HBM traffic of one fwd+bwd on the materialized path."""
    patch_elems, xp_elems, full_elems, sub_elems, _ = _geom(shape)
    src_elems = full_elems if sub_elems else xp_elems     # what the builder reads
    fwd = (src_elems * FP32                               # patch builder reads x
           + 2 * patch_elems * FP32)                      # write+read patches
    bwd = (2 * patch_elems * FP32                         # re-read for code build
           + 2 * patch_elems * INT8                       # write msb+full codes
           + 3 * patch_elems * INT8)                      # kernel passes read codes
    return fwd + bwd


def fused_activation_bytes(shape) -> int:
    """x-side HBM traffic of one fwd+bwd on the implicit-GEMM path."""
    _, xp_elems, full_elems, sub_elems, n_j = _geom(shape)
    sub = (full_elems + sub_elems) * FP32 if sub_elems else 0  # build subsample
    fwd = sub + n_j * xp_elems * FP32                     # operand, per dout tile
    bwd = (2 * xp_elems * FP32                            # read for code build
           + 2 * xp_elems * INT8                          # write msb+full codes
           + 3 * n_j * xp_elems * INT8)                   # kernel passes read codes
    return fwd + bwd


def _shape_rows(fast: bool) -> List[Dict]:
    import jax
    import jax.numpy as jnp

    from benchmarks.common import one_per_kind, time_us as _time
    from repro.configs.paper_cnns import resnet_conv_shapes
    from repro.core import psg
    from repro.core.config import PSGConfig
    from repro.kernels.ref import conv_patches_ref

    cfg = PSGConfig(enabled=True)
    cfg_fused = PSGConfig(enabled=True, fused_conv=True)
    batch = 2 if fast else 8
    convs = resnet_conv_shapes(depth=74, width=16, batch=batch)
    if fast:                                  # one shape of each kind
        convs = one_per_kind(convs)

    rows = []
    for c in convs:
        k, s = c.k, c.stride
        key = jax.random.PRNGKey(c.hw + c.cin + c.cout + k + s)
        x = jax.random.normal(key, (c.batch, c.hw, c.hw, c.cin)) * 0.5
        w = jax.random.normal(jax.random.PRNGKey(1),
                              (k * k * c.cin, c.cout)) * 0.1
        gy = jax.random.normal(jax.random.PRNGKey(2),
                               (c.batch, c.hw_out, c.hw_out, c.cout)) * 0.01

        def im2col_loss(w_, x_):
            with psg.enable(cfg):
                pad = k // 2
                xp = jnp.pad(x_, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
                p2 = conv_patches_ref(xp, k, s)
                y = psg.psg_matmul(p2, w_, cfg)
            return jnp.sum(y.reshape(gy.shape) * gy)

        def fused_loss(w_, x_):
            with psg.enable(cfg_fused):
                y = psg.conv2d(x_, w_, k=k, stride=s)
            return jnp.sum(y * gy)

        us_im2col, _ = _time(jax.jit(jax.grad(im2col_loss)), w, x)
        us_fused, _ = _time(jax.jit(jax.grad(fused_loss)), w, x)
        b_im2col = im2col_activation_bytes(c)
        b_fused = fused_activation_bytes(c)
        rows.append({
            "batch": c.batch, "hw": c.hw, "cin": c.cin, "cout": c.cout,
            "k": k, "stride": s, "kind": c.kind,
            "us_im2col_cpu_interpret": us_im2col,
            "us_fused_cpu_interpret": us_fused,
            "im2col_activation_bytes": b_im2col,
            "fused_activation_bytes": b_fused,
            "bytes_ratio": b_im2col / b_fused,
        })
    return rows


def _paper_totals(depth: int = 74, width: int = 16, batch: int = 128) -> Dict:
    """Per-training-step activation-byte totals over EVERY conv site (with
    multiplicity) of the paper-shaped config — the acceptance quantity."""
    from repro.configs.paper_cnns import resnet_conv_shapes
    sites = resnet_conv_shapes(depth=depth, width=width, batch=batch,
                               unique=False)
    b_im2col = sum(im2col_activation_bytes(c) for c in sites)
    b_fused = sum(fused_activation_bytes(c) for c in sites)
    return {"depth": depth, "width": width, "batch": batch,
            "conv_sites": len(sites),
            "im2col_activation_bytes_per_step": b_im2col,
            "fused_activation_bytes_per_step": b_fused,
            "bytes_ratio": b_im2col / b_fused}


def _train_proxy(fast: bool) -> Dict:
    """Measured steps/s of a short CPU training A/B with fused_conv
    on/off.  The Pallas interpreter executes the fused kernels here, so
    this is a loop-plumbing check, NOT a hardware speed claim — the byte
    totals above are the quantity of record (module docstring)."""
    import jax

    from repro.configs.paper_cnns import cnn_model
    from repro.core.config import (E2TrainConfig, Experiment, PSGConfig,
                                   TrainConfig)
    from repro.data.synthetic import GaussianImageTask, make_image_batch
    from repro.training.train_step import init_train_state
    from repro.training.trainer import Trainer

    depth, width, batch, steps = (8, 8, 4, 2) if fast else (14, 16, 8, 4)
    task = GaussianImageTask(num_classes=10, snr=2.0)
    mk = lambda s, sh: make_image_batch(task, 0, s, sh, batch)
    out: Dict = {"depth": depth, "width": width, "batch": batch,
                 "steps": steps,
                 "note": "CPU Pallas-interpreter proxy; bytes_ratio is the "
                         "quantity of record"}
    for label, fused in (("im2col", False), ("fused", True)):
        exp = Experiment(
            model=cnn_model(f"resnet{depth}", depth, width=width),
            e2=E2TrainConfig(psg=PSGConfig(enabled=True, swa=False,
                                           fused_conv=fused)),
            train=TrainConfig(global_batch=batch, lr=0.03, optimizer="psg",
                              total_steps=1000, schedule="constant"),
            task="cifar_cnn")
        tr = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk)
        tr.run(1)                                     # compile
        t0 = time.perf_counter()
        tr.run(steps)
        out[f"{label}_steps_per_s"] = steps / (time.perf_counter() - t0)
    out["speedup_cpu_interpret"] = (out["fused_steps_per_s"] /
                                    out["im2col_steps_per_s"])
    return out


def conv_json(fast: bool = True) -> dict:
    """The BENCH_conv.json record (CI artifact)."""
    return {"paper_resnet74_batch128": _paper_totals(),
            "shapes": _shape_rows(fast),
            "train_proxy_cpu_interpret": _train_proxy(fast)}


def run(fast: bool = True):
    """CSV rows for benchmarks/run.py."""
    from benchmarks.common import csv_row
    totals = _paper_totals()
    yield csv_row("conv/paper_resnet74_batch128", 0.0,
                  f"bytes_ratio={totals['bytes_ratio']:.2f};"
                  f"im2col_GB={totals['im2col_activation_bytes_per_step']/1e9:.2f};"
                  f"fused_GB={totals['fused_activation_bytes_per_step']/1e9:.2f}")
    for r in _shape_rows(fast):
        yield csv_row(
            f"conv/{r['kind']}/{r['batch']}x{r['hw']}x{r['cin']}-"
            f"{r['cout']}k{r['k']}s{r['stride']}",
            r["us_fused_cpu_interpret"],
            f"im2col_us={r['us_im2col_cpu_interpret']:.1f};"
            f"bytes_ratio={r['bytes_ratio']:.2f}")
