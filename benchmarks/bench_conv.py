"""Fused implicit-GEMM conv kernels vs the materialized im2col path.

Two quantities per convolution site of the paper's CIFAR ResNet:

* **HBM activation bytes moved** — the quantity of record (the same
  precedent as bench_kernels' flash-attention ``hbm_ratio`` column): wall
  time on the CPU Pallas interpreter is not TPU-representative, but the
  operand lifecycle each path streams through HBM is a property of the
  dispatch/BlockSpec structure and is computed exactly below;
* **wall time** of a jitted forward+backward on both paths (recorded
  for the CPU trend only, clearly labeled as interpreter numbers).

The byte accounting covers the WHOLE conv step per path, split into
three named components (``assert_complete`` enforces that every path
reports all of them and that the totals reconcile — ``run.py
--json-conv`` exits nonzero otherwise):

``fwd_x``   forward x-side traffic.  im2col reads the input, then WRITES
            the (N, k*k*C) fp32 patch tensor and reads it back for the
            GEMM; fused reads the padded input once per dout tile
            (n_j = ceil(dout / BN)) — no patch tensor exists.
``bwd_x``   weight-gradient-side traffic.  Both paths re-read their
            kernel operand twice to build the MSB/full quantization code
            grids, write both int8 code copies, and the PSG kernel
            passes read the codes three times (predictor: msb; gated:
            msb + full) — per dout tile on the fused path.
``bwd_dx``  input-gradient-side traffic.  im2col writes the fp32
            dpatches cotangent from the GEMM vjp, re-reads it, and
            scatter-folds it into dx; the fused path's implicit
            transposed-conv kernel (``kernels/conv.conv_grad_x_pallas``)
            reads gy once across the dout-tile grid and writes each dx
            block exactly once — no dpatches tensor, no k² scatter
            passes.  The DEMOTED per-tap col2im loop the kernel replaced
            (k² read-modify-write sweeps over dx windows) is recorded as
            ``bwd_dx_col2im_demoted`` for the trajectory but excluded
            from the fused total.

Weights and the forward output move identically on both paths and are
excluded from both sides; gy is charged only where the paths differ (the
dx component).  For a 3x3 conv the patch tensor is a ~9x copy of the
input, so the per-direction ratios land around an order of magnitude;
``conv_json`` records the per-step totals over every conv site of the
paper-shaped ResNet-74 batch-128 config (``BENCH_conv.json``, uploaded
by CI next to the other BENCH artifacts).  The acceptance quantity is
``backward_bytes_ratio`` — the whole-backward (bwd_x + bwd_dx) im2col /
fused ratio.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.kernels.conv import DEFAULT_BN

FP32 = 4
INT8 = 1

#: every path's accounting must report exactly these traffic components
#: (plus optional informational extras prefixed with the component name).
REQUIRED_COMPONENTS = ("fwd_x", "bwd_x", "bwd_dx")


class IncompleteAccountingError(RuntimeError):
    """A conv path's byte accounting is missing a traffic direction."""


def assert_complete(acct: Dict[str, int], path: str) -> None:
    """Fail loudly if ``acct`` omits a traffic direction or its total
    does not reconcile with the components (run.py --json-conv gate)."""
    missing = [c for c in REQUIRED_COMPONENTS if not acct.get(c, 0) > 0]
    if missing:
        raise IncompleteAccountingError(
            f"{path}: byte accounting incomplete — missing/zero "
            f"components {missing} (have {sorted(acct)})")
    if acct.get("total") != sum(acct[c] for c in REQUIRED_COMPONENTS):
        raise IncompleteAccountingError(
            f"{path}: total {acct.get('total')} != sum of "
            f"{REQUIRED_COMPONENTS}")


def _geom(shape):
    """Per-path operand extents of a conv site: (patch elems, kernel-operand
    elems, full-input elems, pre-subsample elems or 0, dout tiles, gy
    elems, per-tap window elems).

    For ``k >= stride`` the kernel operand is the padded input.  For
    ``k < stride`` (the 1x1 stride-2 projection shortcut) both paths
    consume the ``[::s, ::s]`` subsample — ``core/psg.conv2d`` normalizes
    to a stride-1 conv over it, and the materialized path's patch tensor
    IS it — but BUILDING it still reads the full input once on either
    path, so that read is charged separately (``sub_elems`` marks the
    subsample-write the fused path additionally pays).
    """
    pad = shape.k // 2
    full_elems = shape.batch * shape.hw * shape.hw * shape.cin
    if shape.k < shape.stride:
        xp_elems = shape.batch * shape.hw_out * shape.hw_out * shape.cin
        sub_elems = xp_elems
    else:
        hw_in = shape.hw + 2 * pad
        xp_elems = shape.batch * hw_in * hw_in * shape.cin
        sub_elems = 0
    patch_elems = (shape.batch * shape.hw_out * shape.hw_out *
                   shape.k * shape.k * shape.cin)
    n_j = -(-shape.cout // DEFAULT_BN)        # the kernel's dout tile count
    g_elems = shape.batch * shape.hw_out * shape.hw_out * shape.cout
    win_elems = shape.batch * shape.hw_out * shape.hw_out * shape.cin
    return patch_elems, xp_elems, full_elems, sub_elems, n_j, g_elems, win_elems


def im2col_bytes(shape) -> Dict[str, int]:
    """Whole-step HBM traffic of the materialized path, per component."""
    patch, xp, full, sub, _, g, _ = _geom(shape)
    src = full if sub else xp                             # what the builder reads
    fwd_x = (src * FP32                                   # patch builder reads x
             + 2 * patch * FP32)                          # write+read patches
    bwd_x = (2 * patch * FP32                             # re-read for code build
             + 2 * patch * INT8                           # write msb+full codes
             + 3 * patch * INT8)                          # kernel passes read codes
    bwd_dx = (g * FP32                                    # GEMM vjp reads gy
              + 2 * patch * FP32                          # write+read dpatches
              + xp * FP32)                                # col2im fold writes dx
    return {"fwd_x": fwd_x, "bwd_x": bwd_x, "bwd_dx": bwd_dx,
            "total": fwd_x + bwd_x + bwd_dx}


def fused_bytes(shape) -> Dict[str, int]:
    """Whole-step HBM traffic of the implicit-GEMM path, per component.

    ``bwd_dx_col2im_demoted`` is what the per-tap scatter loop the
    implicit dx kernel replaced would have paid (k² sweeps, each reading
    gy and read-modify-writing a dx window) — informational only, not in
    ``total``.
    """
    _, xp, full, sub, n_j, g, win = _geom(shape)
    k2 = shape.k * shape.k
    build = (full + sub) * FP32 if sub else 0             # build subsample
    fwd_x = build + n_j * xp * FP32                       # operand, per dout tile
    bwd_x = (2 * xp * FP32                                # read for code build
             + 2 * xp * INT8                              # write msb+full codes
             + 3 * n_j * xp * INT8)                       # kernel passes read codes
    bwd_dx = (n_j * g * FP32                              # gy read once per tile grid
              + xp * FP32)                                # each dx block written once
    demoted = (k2 * (g + 3 * win) * FP32                  # per-tap: gy + rmw window
               + xp * FP32)                               # zero-init dx
    return {"fwd_x": fwd_x, "bwd_x": bwd_x, "bwd_dx": bwd_dx,
            "total": fwd_x + bwd_x + bwd_dx,
            "bwd_dx_col2im_demoted": demoted}


def _ratios(b_im2col: Dict[str, int], b_fused: Dict[str, int]) -> Dict:
    bwd_i = b_im2col["bwd_x"] + b_im2col["bwd_dx"]
    bwd_f = b_fused["bwd_x"] + b_fused["bwd_dx"]
    return {"bytes_ratio": b_im2col["total"] / b_fused["total"],
            "backward_bytes_ratio": bwd_i / bwd_f,
            "dx_bytes_ratio": b_im2col["bwd_dx"] / b_fused["bwd_dx"]}


def _shape_rows(fast: bool) -> List[Dict]:
    import jax
    import jax.numpy as jnp

    from benchmarks.common import one_per_kind, time_us as _time
    from repro.configs.paper_cnns import resnet_conv_shapes
    from repro.core import psg
    from repro.core.config import PSGConfig
    from repro.kernels.ref import conv_patches_ref

    cfg = PSGConfig(enabled=True, fused_conv=False)
    cfg_fused = PSGConfig(enabled=True, fused_conv=True)
    batch = 2 if fast else 8
    convs = resnet_conv_shapes(depth=74, width=16, batch=batch)
    if fast:                                  # one shape of each kind
        convs = one_per_kind(convs)

    rows = []
    for c in convs:
        k, s = c.k, c.stride
        key = jax.random.PRNGKey(c.hw + c.cin + c.cout + k + s)
        x = jax.random.normal(key, (c.batch, c.hw, c.hw, c.cin)) * 0.5
        w = jax.random.normal(jax.random.PRNGKey(1),
                              (k * k * c.cin, c.cout)) * 0.1
        gy = jax.random.normal(jax.random.PRNGKey(2),
                               (c.batch, c.hw_out, c.hw_out, c.cout)) * 0.01

        def im2col_loss(w_, x_):
            with psg.enable(cfg):
                pad = k // 2
                xp = jnp.pad(x_, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
                p2 = conv_patches_ref(xp, k, s)
                y = psg.psg_matmul(p2, w_, cfg)
            return jnp.sum(y.reshape(gy.shape) * gy)

        def fused_loss(w_, x_):
            with psg.enable(cfg_fused):
                y = psg.conv2d(x_, w_, k=k, stride=s)
            return jnp.sum(y * gy)

        # grad over BOTH operands: the timed program includes the dx side
        us_im2col, _ = _time(jax.jit(jax.grad(im2col_loss, argnums=(0, 1))),
                             w, x)
        us_fused, _ = _time(jax.jit(jax.grad(fused_loss, argnums=(0, 1))),
                            w, x)
        b_im2col = im2col_bytes(c)
        b_fused = fused_bytes(c)
        assert_complete(b_im2col, f"im2col/{c.kind}")
        assert_complete(b_fused, f"fused/{c.kind}")
        rows.append({
            "batch": c.batch, "hw": c.hw, "cin": c.cin, "cout": c.cout,
            "k": k, "stride": s, "kind": c.kind,
            "us_im2col_cpu_interpret": us_im2col,
            "us_fused_cpu_interpret": us_fused,
            "im2col_bytes": b_im2col,
            "fused_bytes": b_fused,
            **_ratios(b_im2col, b_fused),
        })
    return rows


def _paper_totals(depth: int = 74, width: int = 16, batch: int = 128) -> Dict:
    """Per-training-step activation-byte totals over EVERY conv site (with
    multiplicity) of the paper-shaped config — the acceptance quantity is
    ``backward_bytes_ratio`` (whole-backward: bwd_x + bwd_dx)."""
    from repro.configs.paper_cnns import resnet_conv_shapes
    sites = resnet_conv_shapes(depth=depth, width=width, batch=batch,
                               unique=False)
    b_im2col: Dict[str, int] = {c: 0 for c in (*REQUIRED_COMPONENTS, "total")}
    b_fused: Dict[str, int] = dict(b_im2col, bwd_dx_col2im_demoted=0)
    for c in sites:
        for acc, fn in ((b_im2col, im2col_bytes), (b_fused, fused_bytes)):
            for comp, v in fn(c).items():
                acc[comp] += v
    assert_complete(b_im2col, "im2col/paper_totals")
    assert_complete(b_fused, "fused/paper_totals")
    return {"depth": depth, "width": width, "batch": batch,
            "conv_sites": len(sites),
            "im2col_bytes_per_step": b_im2col,
            "fused_bytes_per_step": b_fused,
            **_ratios(b_im2col, b_fused)}


def _train_proxy(fast: bool) -> Dict:
    """Measured steps/s of a short CPU training A/B with fused_conv
    on/off.  The Pallas interpreter executes the fused kernels here, so
    this is a loop-plumbing check, NOT a hardware speed claim — the byte
    totals above are the quantity of record (module docstring)."""
    import jax

    from repro.configs.paper_cnns import cnn_model
    from repro.core.config import (E2TrainConfig, Experiment, PSGConfig,
                                   TrainConfig)
    from repro.data.synthetic import GaussianImageTask, make_image_batch
    from repro.training.train_step import init_train_state
    from repro.training.trainer import Trainer

    depth, width, batch, steps = (8, 8, 4, 2) if fast else (14, 16, 8, 4)
    task = GaussianImageTask(num_classes=10, snr=2.0)
    mk = lambda s, sh: make_image_batch(task, 0, s, sh, batch)
    out: Dict = {"depth": depth, "width": width, "batch": batch,
                 "steps": steps,
                 "note": "CPU Pallas-interpreter proxy; the byte ratios are "
                         "the quantity of record"}
    for label, fused in (("im2col", False), ("fused", True)):
        exp = Experiment(
            model=cnn_model(f"resnet{depth}", depth, width=width),
            e2=E2TrainConfig(psg=PSGConfig(enabled=True, swa=False,
                                           fused_conv=fused)),
            train=TrainConfig(global_batch=batch, lr=0.03, optimizer="psg",
                              total_steps=1000, schedule="constant"),
            task="cifar_cnn")
        tr = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk)
        tr.run(1)                                     # compile
        t0 = time.perf_counter()
        tr.run(steps)
        out[f"{label}_steps_per_s"] = steps / (time.perf_counter() - t0)
    out["speedup_cpu_interpret"] = (out["fused_steps_per_s"] /
                                    out["im2col_steps_per_s"])
    return out


def conv_json(fast: bool = True) -> dict:
    """The BENCH_conv.json record (CI artifact).  Raises
    :class:`IncompleteAccountingError` if any path omits a traffic
    direction — run.py --json-conv turns that into a nonzero exit."""
    return {"paper_resnet74_batch128": _paper_totals(),
            "shapes": _shape_rows(fast),
            "train_proxy_cpu_interpret": _train_proxy(fast)}


def run(fast: bool = True):
    """CSV rows for benchmarks/run.py."""
    from benchmarks.common import csv_row
    totals = _paper_totals()
    yield csv_row("conv/paper_resnet74_batch128", 0.0,
                  f"bytes_ratio={totals['bytes_ratio']:.2f};"
                  f"backward_bytes_ratio={totals['backward_bytes_ratio']:.2f};"
                  f"im2col_GB={totals['im2col_bytes_per_step']['total']/1e9:.2f};"
                  f"fused_GB={totals['fused_bytes_per_step']['total']/1e9:.2f}")
    for r in _shape_rows(fast):
        yield csv_row(
            f"conv/{r['kind']}/{r['batch']}x{r['hw']}x{r['cin']}-"
            f"{r['cout']}k{r['k']}s{r['stride']}",
            r["us_fused_cpu_interpret"],
            f"im2col_us={r['us_im2col_cpu_interpret']:.1f};"
            f"bytes_ratio={r['bytes_ratio']:.2f};"
            f"backward_bytes_ratio={r['backward_bytes_ratio']:.2f}")
