"""Static Pallas kernel linter (analysis/kernel_lint.py): the shipped
registry is clean and deliberately broken kernels are caught."""
import functools

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis import lint_shipped
from repro.analysis.kernel_lint import (VMEM_BUDGET_BYTES, LintFinding,
                                        lint_kernel)
from repro.kernels.dispatch import conv_lint_geometries, shipped_kernels


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def _trace_call(out_block, out_index_map, grid=(2, 2)):
    """A 256x256 f32 copy through pallas_call with a configurable output
    BlockSpec — traced only (make_jaxpr), never executed."""
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def fn(a):
        return pl.pallas_call(
            _copy_kernel,
            grid=grid,
            in_specs=[pl.BlockSpec(out_block, out_index_map)],
            out_specs=pl.BlockSpec(out_block, out_index_map),
            out_shape=jax.ShapeDtypeStruct((256, 256), jnp.float32),
            interpret=True,
        )(a)

    return fn, (x,)


# ---------------------------------------------------------------------------
# shipped kernels
# ---------------------------------------------------------------------------


def test_shipped_kernels_lint_clean():
    findings = lint_shipped()
    assert findings == [], "\n".join(str(f) for f in findings)


def test_registry_enumerates_every_shipped_kernel():
    base = {n.split("[")[0] for n in shipped_kernels()}
    assert base == {
        "psg_grad_w_pallas", "predictor_matmul_pallas", "conv_fwd_pallas",
        "conv_grad_w_predictor_pallas", "conv_grad_w_pallas",
        "conv_grad_x_pallas", "quantize_pallas", "flash_attention",
        "flash_bwd_dq_pallas", "flash_bwd_dkv_pallas"}


def test_conv_registry_covers_every_shipped_geometry_kind():
    """The conv entries are parameterized over the geometry kinds of
    ``configs/paper_cnns.resnet_conv_shapes`` (plus the MobileNetV2-style
    pointwise) — the old hardcoded ``partial(..., k=3)`` registry never
    linted the 1x1 conv geometries that actually ship."""
    geoms = conv_lint_geometries()
    assert set(geoms) == {"body", "strided", "down", "point"}
    ks = {kind: g[0] for kind, g in geoms.items()}
    assert ks["down"] == ks["point"] == 1 and ks["body"] == 3
    # the down kind arrives pre-subsample-normalized: never k < stride
    assert all(g[0] >= g[1] for g in geoms.values())
    names = set(shipped_kernels())
    for op in ("conv_fwd_pallas", "conv_grad_w_predictor_pallas",
               "conv_grad_w_pallas", "conv_grad_x_pallas"):
        for kind in geoms:
            assert f"{op}[{kind}]" in names, (op, kind)


def test_geometry_dependent_violation_is_caught():
    """A violation that exists only at a specific conv geometry must be
    caught when that geometry is linted: same kernel, same tile choice —
    clean where the block spans the full dout extent, a tile-alignment
    finding where it does not.  This is the failure mode the
    kind-parameterized registry exists to expose."""
    from repro.kernels import conv

    S = jax.ShapeDtypeStruct
    cx = S((4, 6, 6, 16), jnp.float32)
    fn = functools.partial(conv.conv_fwd_pallas, k=1, stride=1, bn=40,
                           interpret=True)
    # dout=40: the 40-wide block IS the full extent — clean
    assert lint_kernel(fn, cx, S((16, 40), jnp.float32), name="g40") == []
    # dout=120: identical call, different geometry — misaligned block
    rules = {f.rule for f in
             lint_kernel(fn, cx, S((16, 120), jnp.float32), name="g120")}
    assert "tile-alignment" in rules


def test_registry_grids_are_not_degenerate():
    """Every registered instantiation must tile (grid > 1 somewhere) —
    a coverage bug cannot hide behind a one-block grid."""
    for name, (fn, args) in shipped_kernels().items():
        closed = jax.make_jaxpr(fn)(*args)
        grids = [eqn.params["grid_mapping"].grid
                 for eqn in closed.jaxpr.eqns
                 if eqn.primitive.name == "pallas_call"]
        assert grids, name
        assert all(max(g) > 1 for g in grids), (name, grids)


# ---------------------------------------------------------------------------
# deliberately broken kernels
# ---------------------------------------------------------------------------


def test_uncovered_output_tile_is_caught():
    # constant index map: only block (0, 0) of the 2x2 lattice is written
    fn, args = _trace_call((128, 128), lambda i, j: (0, 0))
    rules = {f.rule for f in lint_kernel(fn, *args, name="bad")}
    assert "coverage" in rules


def test_oob_index_map_is_caught():
    fn, args = _trace_call((128, 128), lambda i, j: (i + 1, j))
    rules = {f.rule for f in lint_kernel(fn, *args, name="bad")}
    assert "oob-index" in rules


def test_mistiled_block_is_caught():
    # 100 is neither a multiple of 8 nor the full 256 extent
    fn, args = _trace_call((100, 256), lambda i, j: (i, 0), grid=(3, 1))
    findings = lint_kernel(fn, *args, name="bad")
    assert any(f.rule == "tile-alignment" for f in findings)


def test_well_tiled_copy_is_clean():
    fn, args = _trace_call((128, 128), lambda i, j: (i, j))
    assert lint_kernel(fn, *args, name="good") == []


def test_vmem_budget_overflow_is_caught():
    big = jax.ShapeDtypeStruct((4096, 4096), jnp.float32)

    def fn(a):
        return pl.pallas_call(
            _copy_kernel,
            grid=(1,),
            in_specs=[pl.BlockSpec((4096, 4096), lambda i: (0, 0))],
            out_specs=pl.BlockSpec((4096, 4096), lambda i: (0, 0)),
            out_shape=big,
            interpret=True,
        )(a)

    findings = lint_kernel(fn, big, name="huge")
    (f,) = [f for f in findings if f.rule == "vmem-budget"]
    assert str(VMEM_BUDGET_BYTES // 2**20) in f.message


def test_ungated_accumulator_is_caught():
    """A reduction-axis kernel with scratch but no pl.when init/finish
    gating must produce both accumulator-discipline findings."""
    def kernel(x_ref, o_ref, acc_ref):
        acc_ref[...] += jnp.pad(x_ref[...], ((0, 0), (0, 128)))
        o_ref[...] = acc_ref[...]

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def fn(a):
        return pl.pallas_call(
            kernel,
            grid=(2, 2),                 # axis 1 reduces: out map ignores k
            in_specs=[pl.BlockSpec((128, 128), lambda i, k: (i, k))],
            out_specs=pl.BlockSpec((128, 256), lambda i, k: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((256, 256), jnp.float32),
            scratch_shapes=[pltpu.VMEM((128, 256), jnp.float32)],
            interpret=True,
        )(a)

    msgs = [f.message for f in lint_kernel(fn, x, name="bad")
            if f.rule == "accumulator-discipline"]
    assert len(msgs) == 2
    assert any("== 0" in m for m in msgs)
    assert any("== 1" in m for m in msgs)


def test_gated_accumulator_passes():
    def kernel(x_ref, o_ref, acc_ref):
        k = pl.program_id(1)

        @pl.when(k == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jnp.pad(x_ref[...], ((0, 0), (0, 128)))

        @pl.when(k == 1)
        def _finish():
            o_ref[...] = acc_ref[...]

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def fn(a):
        return pl.pallas_call(
            kernel,
            grid=(2, 2),
            in_specs=[pl.BlockSpec((128, 128), lambda i, k: (i, k))],
            out_specs=pl.BlockSpec((128, 256), lambda i, k: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((256, 256), jnp.float32),
            scratch_shapes=[pltpu.VMEM((128, 256), jnp.float32)],
            interpret=True,
        )(a)

    assert [f for f in lint_kernel(fn, x, name="good")
            if f.rule == "accumulator-discipline"] == []


def test_finding_formats_with_rule_and_kernel():
    f = LintFinding("k", "coverage", "m")
    assert str(f) == "[coverage] k: m"
