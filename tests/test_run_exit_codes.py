"""benchmarks/run.py --json-audit exit-code contract: 0 clean, 1 when the
audit or a linter *fails*, 2 when a lint pass *errors* (crashed tooling
must never look like a green gate)."""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import benchmarks.bench_audit as bench_audit  # noqa: E402
import benchmarks.run as run  # noqa: E402


def _record(**overrides):
    base = {"schema_version": bench_audit.SCHEMA_VERSION,
            "audits": [{"passed": True}],
            "kernel_lint": {"findings": [], "passed": True, "error": None},
            "repo_lint": {"findings": [], "passed": True, "error": None},
            "precision": {"findings": [], "passed": True, "error": None},
            "hotloop": {"findings": [], "passed": True, "error": None},
            "lint_errors": [],
            "all_passed": True}
    base.update(overrides)
    return base


def _run_audit(tmp_path, monkeypatch, record):
    monkeypatch.setattr(bench_audit, "audit_json", lambda fast=True: record)
    path = str(tmp_path / "BENCH_audit.json")
    run.main(["--json-audit", path])
    return path


def test_clean_record_exits_zero_and_writes_json(tmp_path, monkeypatch):
    path = _run_audit(tmp_path, monkeypatch, _record())
    with open(path) as f:
        assert json.load(f)["schema_version"] == bench_audit.SCHEMA_VERSION


def test_lint_findings_exit_one(tmp_path, monkeypatch):
    rec = _record(all_passed=False)
    rec["precision"] = {"findings": ["kernel:x: narrow acc"],
                        "passed": False, "error": None}
    with pytest.raises(SystemExit) as e:
        _run_audit(tmp_path, monkeypatch, rec)
    assert e.value.code == 1


def test_crashed_lint_pass_exits_two_not_one(tmp_path, monkeypatch):
    rec = _record(all_passed=False, lint_errors=["hotloop"])
    rec["hotloop"] = {"findings": None, "passed": False,
                     "error": "KeyError: 'labels'"}
    with pytest.raises(SystemExit) as e:
        _run_audit(tmp_path, monkeypatch, rec)
    assert e.value.code == 2


def test_crash_beats_findings_when_both_present(tmp_path, monkeypatch):
    # a record with ordinary findings AND a crashed linter must surface the
    # crash: exit 2 tells CI the tooling is broken, not just the code
    rec = _record(all_passed=False, lint_errors=["precision"])
    rec["precision"] = {"findings": None, "passed": False,
                        "error": "RuntimeError: tracer leak"}
    rec["repo_lint"] = {"findings": ["repro/models/x.py:3: host-sync"],
                        "passed": False, "error": None}
    with pytest.raises(SystemExit) as e:
        _run_audit(tmp_path, monkeypatch, rec)
    assert e.value.code == 2


def test_json_still_written_before_nonzero_exit(tmp_path, monkeypatch):
    # CI uploads BENCH_audit.json with if: always() — the record must land
    # on disk even when the gate fails
    rec = _record(all_passed=False, lint_errors=["kernel_lint"])
    rec["kernel_lint"] = {"findings": None, "passed": False,
                          "error": "ValueError: boom"}
    path = str(tmp_path / "BENCH_audit.json")
    monkeypatch.setattr(bench_audit, "audit_json", lambda fast=True: rec)
    with pytest.raises(SystemExit):
        run.main(["--json-audit", path])
    with open(path) as f:
        assert json.load(f)["lint_errors"] == ["kernel_lint"]
