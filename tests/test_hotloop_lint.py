"""Hot-loop lint (analysis/hotloop_lint.py): CHUNK_CONTRACT verified on
the real chunk programs, and each rule pinned against a violating fixture."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hotloop_lint import (HotloopFinding, hotloop_report,
                                         lint_chunk, lint_program,
                                         lint_trainer_default)
from repro.training.loop import CHUNK_CONTRACT

S = jax.ShapeDtypeStruct
K = 3


def _chunk_args():
    state = S((4,), jnp.float32)
    batches = {"x": S((K, 8), jnp.float32)}
    incs = S((K,), jnp.int32)
    return state, batches, incs


def _good_chunk(state, batches, incs):
    def body(c, xs):
        b, inc = xs
        loss = jnp.sum(b["x"]) + inc.astype(jnp.float32)
        return c + loss, {"loss": loss}
    return jax.lax.scan(body, state, (batches, incs))


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# the real chunk programs honour the contract (acceptance criterion)
# ---------------------------------------------------------------------------


def test_contract_tuple_matches_the_lints_rules():
    assert set(CHUNK_CONTRACT) == {
        "no-host-callback", "static-trip-count", "shape-stable-body",
        "device-resident-metrics", "no-donation-default"}


def test_cnn_chunk_program_passes():
    from repro.configs.paper_cnns import cnn_model
    from repro.core.config import E2TrainConfig, Experiment, TrainConfig
    exp = Experiment(model=cnn_model("resnet14", 14), e2=E2TrainConfig(),
                     train=TrainConfig(global_batch=8, lr=0.1,
                                       total_steps=100, optimizer="sgdm"),
                     task="cifar_cnn")
    findings = lint_chunk(exp, K=K)
    assert findings == [], "\n".join(str(f) for f in findings)


def test_lm_chunk_program_passes():
    from repro.configs import smoke_experiment
    findings = lint_chunk(smoke_experiment("llama3_8b"), K=K)
    assert findings == [], "\n".join(str(f) for f in findings)


def test_trainer_donation_defaults_false():
    assert lint_trainer_default() == []


def test_report_shape_for_bench_audit():
    rep = hotloop_report(exps=[])
    assert rep == {"findings": [], "passed": True}


# ---------------------------------------------------------------------------
# each rule catches its violating fixture
# ---------------------------------------------------------------------------


def test_clean_synthetic_chunk_has_no_findings():
    assert lint_program(_good_chunk, _chunk_args(), K) == []


def test_host_callback_in_scanned_body_is_caught():
    def chunk(state, batches, incs):
        def body(c, xs):
            b, inc = xs
            loss = jnp.sum(b["x"])
            jax.debug.print("loss={l}", l=loss)   # one host sync per step
            return c + loss, {"loss": loss}
        return jax.lax.scan(body, state, (batches, incs))
    findings = lint_program(chunk, _chunk_args(), K, name="sync-fixture")
    assert "no-host-callback" in _rules(findings)
    f = next(f for f in findings if f.rule == "no-host-callback")
    assert f.site.startswith("sync-fixture")


def test_while_loop_chunk_fails_static_trip_count():
    def chunk(state, batches, incs):
        def cond(cv):
            return cv[0] < K
        def body(cv):
            i, c = cv
            return i + 1, c + jnp.sum(batches["x"][0])
        _, c = jax.lax.while_loop(cond, body, (0, state))
        return c, {"loss": jnp.broadcast_to(c[0], (K,))}
    findings = lint_program(chunk, _chunk_args(), K)
    assert "static-trip-count" in _rules(findings)


def test_python_value_dependent_body_fails_shape_stability():
    def chunk(state, batches, incs):
        def body(c, xs):
            b, inc = xs
            loss = jnp.sum(b["x"])
            if batches["x"].shape[0] > K:        # bakes K into the body
                loss = jnp.tanh(loss)
            return c + loss, {"loss": loss}
        return jax.lax.scan(body, state, (batches, incs))
    findings = lint_program(chunk, _chunk_args(), K)
    assert "shape-stable-body" in _rules(findings)


def test_prereduced_metrics_fail_device_residency():
    def chunk(state, batches, incs):
        c, m = _good_chunk(state, batches, incs)
        return c, {"loss": jnp.mean(m["loss"])}   # synced scalar, not (K,)
    findings = lint_program(chunk, _chunk_args(), K)
    assert "device-resident-metrics" in _rules(findings)


def test_donated_state_fails_no_donation_default():
    findings = lint_program(_good_chunk, _chunk_args(), K,
                            donate_argnums=(0,))
    assert "no-donation-default" in _rules(findings)


def test_findings_stringify_with_rule_and_site():
    f = HotloopFinding("no-host-callback", "chunk/scan/debug_callback",
                       "host round-trip")
    assert "[no-host-callback]" in str(f) and "chunk/scan" in str(f)
