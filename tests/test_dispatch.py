"""Kernel dispatch layer: backend selection + PSG backward-through-kernel.

These tests pin the PR-1 acceptance criteria: the training backward runs
the tile-level Pallas kernel (not the element-level oracle), its signs are
bit-identical to ``psg_grad_w_ref`` on the shape sweep, and the measured
fallback-tile ratio reaches the train-step metrics dict.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import psg
from repro.core.config import (E2TrainConfig, Experiment, ModelConfig,
                               PSGConfig, TrainConfig)
from repro.kernels import dispatch, ref

CFG = PSGConfig(enabled=True)


# ---------------------------------------------------------------------------
# backend resolution
# ---------------------------------------------------------------------------


def test_default_backend_platform_probe():
    want = "mosaic" if jax.default_backend() == "tpu" else "interpret"
    assert dispatch.default_backend() == want
    assert dispatch.resolve_backend(CFG) == want          # cfg "auto" defers


def test_config_pins_backend():
    pinned = PSGConfig(enabled=True, backend="reference")
    assert dispatch.resolve_backend(pinned) == "reference"


def test_override_wins_over_config():
    pinned = PSGConfig(enabled=True, backend="reference")
    with dispatch.override_backend("interpret"):
        assert dispatch.resolve_backend(pinned) == "interpret"
    assert dispatch.resolve_backend(pinned) == "reference"


def test_unknown_backend_rejected():
    with pytest.raises(ValueError):
        dispatch.resolve_backend(PSGConfig(enabled=True, backend="cuda"))
    with pytest.raises(ValueError):
        dispatch.set_default_backend("nope")


def test_no_env_reads_in_traced_code():
    """Trace the dispatched op and the PSG custom_vjp under a monkeypatched
    environ that explodes on access: selection must be trace-time pure."""
    import os
    real_get = os.environ.get

    def boom(*a, **k):
        raise AssertionError("os.environ read inside traced code")

    x = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    os.environ.get = boom
    try:
        jax.jit(lambda a, b: psg.psg_matmul(a, b, CFG)).lower(x, w)
        jax.jit(jax.grad(lambda b: jnp.sum(psg.psg_matmul(x, b, CFG)))
                ).lower(w)
    finally:
        os.environ.get = real_get


# ---------------------------------------------------------------------------
# backward pass runs the tile kernel, bit-identical to the oracle
# ---------------------------------------------------------------------------

SHAPES = [(64, 32, 48), (300, 130, 70), (512, 256, 128), (1024, 256, 256),
          (128, 7, 9)]


@pytest.mark.parametrize("N,din,dout", SHAPES)
def test_psg_bwd_signs_bit_identical_to_ref(N, din, dout):
    k1, k2 = jax.random.split(jax.random.PRNGKey(N + din))
    x = jax.random.normal(k1, (N, din)) * 0.5
    gy = jax.random.normal(k2, (N, dout)) * 0.01
    w = jax.random.normal(jax.random.PRNGKey(0), (din, dout)) * 0.1

    # sum(y * gy) makes gy the exact cotangent reaching _psg_bwd
    dw = jax.grad(lambda b: jnp.sum(psg.psg_matmul(x, b, CFG) * gy))(w)
    want = ref.psg_grad_w_ref(x, gy, CFG)
    np.testing.assert_array_equal(np.asarray(dw), np.asarray(want))
    assert set(np.unique(np.asarray(dw))).issubset({-1.0, 0.0, 1.0})


def test_bwd_executes_tile_kernel_not_oracle():
    """The traced backward must contain the Pallas kernel's tile-stats
    output — an artifact the element-level oracle does not produce."""
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 128))
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 64))
    jaxpr = jax.make_jaxpr(
        jax.grad(lambda b: jnp.sum(psg.psg_matmul(x, b, CFG))))(w)
    assert "pallas_call" in str(jaxpr)
    with dispatch.override_backend("reference"):
        jaxpr_ref = jax.make_jaxpr(
            jax.grad(lambda b: jnp.sum(psg.psg_matmul(x, b, CFG))))(w)
    assert "pallas_call" not in str(jaxpr_ref)


def test_reference_backend_matches_tile_backend():
    x = jax.random.normal(jax.random.PRNGKey(3), (512, 96)) * 0.5
    gy = jax.random.normal(jax.random.PRNGKey(4), (512, 40)) * 0.01
    with dispatch.override_backend("interpret"):
        s_tile, fb_tile = dispatch.psg_grad_w(x, gy, CFG)
    with dispatch.override_backend("reference"):
        s_ref, fb_ref = dispatch.psg_grad_w(x, gy, CFG)
    np.testing.assert_array_equal(np.asarray(s_tile), np.asarray(s_ref))
    assert 0.0 <= float(fb_tile) <= 1.0
    assert 0.0 <= float(fb_ref) <= 1.0


# ---------------------------------------------------------------------------
# fallback stats reach the training metrics
# ---------------------------------------------------------------------------


def test_probe_accumulates_across_matmuls():
    x = jax.random.normal(jax.random.PRNGKey(5), (64, 32))
    w1 = jax.random.normal(jax.random.PRNGKey(6), (32, 32)) * 0.1
    w2 = jax.random.normal(jax.random.PRNGKey(7), (32, 16)) * 0.1

    def loss(ws, probe):
        with psg.enable(CFG, probe=probe):
            h = psg.matmul(x, ws[0])
            return jnp.sum(psg.matmul(h, ws[1]))

    pg = jax.grad(loss, argnums=1)((w1, w2), psg.zero_probe())
    # MAC-weighted accumulation: both matmuls' MAC counts summed
    macs = 64 * 32 * 32 + 64 * 32 * 16
    assert float(pg[1]) == float(macs)
    assert 0.0 <= float(pg[0]) <= float(macs)
    ratio = psg.probe_fallback_ratio(pg)
    assert 0.0 <= float(ratio) <= 1.0


def test_train_step_reports_measured_fallback_ratio():
    from repro.training.train_step import init_train_state, make_train_step
    model = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                        num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=32,
                        dtype="float32")
    exp = Experiment(model=model,
                     e2=E2TrainConfig(psg=PSGConfig(enabled=True, swa=False)),
                     train=TrainConfig(global_batch=4, seq_len=8, lr=0.03,
                                       optimizer="psg", total_steps=4,
                                       schedule="constant"))
    state = init_train_state(jax.random.PRNGKey(0), exp)
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (4, 8), 0, 32),
             "labels": jax.random.randint(key, (4, 8), 0, 32)}
    _, metrics = jax.jit(make_train_step(exp))(state, batch)
    fb = float(metrics["psg_fallback_ratio"])
    assert 0.0 < fb <= 1.0, fb

    # PSG off: no measurement taken, so the metric must be absent (a
    # baseline step has no data, not a measurement of zero)
    exp_off = Experiment(model=model, train=exp.train)
    st2 = init_train_state(jax.random.PRNGKey(0), exp_off)
    _, m2 = jax.jit(make_train_step(exp_off))(st2, batch)
    assert "psg_fallback_ratio" not in m2


def test_energy_uses_measured_fallback():
    from repro.core import energy
    model = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=64)
    e2 = E2TrainConfig(psg=PSGConfig(enabled=True))
    lo = energy.training_energy_pj(model, 4, 32, e2, 10, psg_fallback_rate=0.1)
    hi = energy.training_energy_pj(model, 4, 32, e2, 10, psg_fallback_rate=0.9)
    assert lo < hi                        # more fallback -> more energy
    f_lo = energy.measured_psg_factor(e2, 0.1)
    f_hi = energy.measured_psg_factor(e2, 0.9)
    assert f_lo < f_hi < 1.0
