"""Sharding rules: spec shapes, divisibility fallbacks, candidate lists."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.launch.mesh import make_mesh


def abstract_mesh(shape, axes):
    """Mesh stand-in for spec-logic tests (no devices needed)."""
    return shd.make_abstract_mesh(shape, axes)


def _spec(shape, rule, mesh, fsdp=True):
    return shd._spec_for(shape, rule, mesh, fsdp)


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


def test_rules_match_paths():
    assert shd.logical_rules("units/b0_attn/attn/wq") == ("fsdp", "tp", None)
    assert shd.logical_rules("units/b0_attn/mlp/w_up") == ("fsdp", "tp")
    assert isinstance(shd.logical_rules("units/b0_moe/moe/w_up"), list)
    assert shd.logical_rules("units/b0_attn/ln1/scale") == ()


def test_divisibility_fallback():
    mesh = abstract_mesh((2, 4), ("data", "model"))
    # kv heads = 2 < model 4 -> heads axis dropped, fsdp kept
    spec = _spec((128, 2, 16), ("fsdp", "tp", None), mesh)
    assert spec == P("data", None, None)
    # divisible case
    spec2 = _spec((128, 8, 16), ("fsdp", "tp", None), mesh)
    assert spec2 == P("data", "model", None)


def test_candidate_list_expert_fallback():
    mesh = abstract_mesh((2, 4), ("data", "model"))
    rule = [("expert", "fsdp", None), (None, "fsdp", "tp")]
    # 8 experts % 4 == 0 -> EP
    assert _spec((8, 128, 64), rule, mesh) == P("model", "data", None)
    # 3 experts -> TP fallback on d_ff
    assert _spec((3, 128, 64), rule, mesh) == P(None, "data", "model")


def test_right_alignment_covers_stacked_units():
    mesh = abstract_mesh((2, 4), ("data", "model"))
    # (units, d, heads, hd) with a 3-axis rule -> units axis replicated
    spec = _spec((6, 128, 8, 32), ("fsdp", "tp", None), mesh)
    assert spec == P(None, "data", "model", None)


def test_param_shardings_tree(mesh):
    import jax.numpy as jnp
    from repro.configs import smoke_experiment
    from repro.models import transformer as T
    exp = smoke_experiment("llama3_8b")
    params = jax.eval_shape(
        lambda: T.init_lm(jax.random.PRNGKey(0), exp.model, exp.e2))
    sh = shd.param_shardings(params, mesh)
    assert jax.tree_util.tree_structure(sh) == \
        jax.tree_util.tree_structure(params)


def test_batch_sharding_drops_batch_one():
    mesh = abstract_mesh((4, 2), ("data", "model"))
    s = shd.batch_sharding(mesh, 2, shape=(1, 128))
    assert s.spec == P(None, None)
    s2 = shd.batch_sharding(mesh, 2, shape=(8, 128))
    assert s2.spec == P("data", None)


def test_batch_sharding_dict_batch_infers_rank_per_leaf():
    """A dict batch resolves per leaf: rank-1 labels, rank-2 tokens, and
    rank-4 NHWC CIFAR images all get the batch axis over data."""
    import numpy as np
    mesh = abstract_mesh((4, 2), ("data", "model"))
    batch = {"image": np.zeros((8, 32, 32, 3)), "label": np.zeros((8,)),
             "tokens": np.zeros((8, 128))}
    sh = shd.batch_sharding(mesh, batch)
    assert sh["image"].spec == P("data", None, None, None)
    assert sh["label"].spec == P("data")
    assert sh["tokens"].spec == P("data", None)


def test_batch_sharding_chunk_stacked_batch_axis():
    """Chunk-stacked batches (leading K scan axis): batch_axis=1 shards the
    true batch dim and leaves the scan axis unsharded; a non-divisible
    batch dim drops the sharding for that leaf only."""
    import numpy as np
    mesh = abstract_mesh((4, 2), ("data", "model"))
    batch = {"image": np.zeros((6, 8, 32, 32, 3)), "label": np.zeros((6, 8)),
             "odd": np.zeros((6, 3))}
    sh = shd.batch_sharding(mesh, batch, batch_axis=1)
    assert sh["image"].spec == P(None, "data", None, None, None)
    assert sh["label"].spec == P(None, "data")
    assert sh["odd"].spec == P(None, None)       # 3 % 4 != 0 -> replicated


def test_batch_sharding_pod_data_and_seq_shard():
    import numpy as np
    mesh = abstract_mesh((2, 2, 2), ("pod", "data", "model"))
    batch = {"tokens": np.zeros((8, 128)), "label": np.zeros((8,))}
    sh = shd.batch_sharding(mesh, batch, seq_shard=True)
    assert sh["tokens"].spec == P(("pod", "data"), "model")
    assert sh["label"].spec == P(("pod", "data"))
    # rank-0 / batch_axis beyond rank: fully replicated, never an error
    s0 = shd.batch_sharding(mesh, {"scalar": np.zeros(())})
    assert s0["scalar"].spec == P()


def test_hint_noop_outside_context():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    y = shd.hint(x, "batch", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_hint_dedupes_mesh_axes():
    import jax.numpy as jnp
    mesh = make_mesh((1, 1), ("data", "model"))
    with shd.activation_sharding(mesh):
        def f(x):
            return shd.hint(x, "batch", "seq", "vocab")  # seq+vocab -> model
        with mesh:
            jax.jit(f).lower(jnp.ones((4, 4, 4))).compile()
