import os
import sys

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device (assignment, dry-run step 0).  The sharding
# test that needs multiple devices spawns a subprocess.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)
