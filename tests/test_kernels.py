"""Per-kernel validation: shape/dtype sweep vs pure-jnp oracles (ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import PSGConfig
from repro.kernels import ops, ref

SHAPES = [(64, 32, 48), (300, 130, 70), (512, 256, 128), (1024, 256, 256),
          (128, 7, 9)]


@pytest.mark.parametrize("N,din,dout", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_psg_grad_w_matches_oracle(N, din, dout, dtype):
    cfg = PSGConfig(enabled=True)
    k1, k2 = jax.random.split(jax.random.PRNGKey(N + din))
    x = (jax.random.normal(k1, (N, din)) * 0.5).astype(dtype)
    gy = (jax.random.normal(k2, (N, dout)) * 0.01).astype(dtype)
    xf, gf = x.astype(jnp.float32), gy.astype(jnp.float32)
    want = np.asarray(ref.psg_grad_w_oracle(xf, gf, cfg))
    got, fb = ops.psg_grad_w(xf, gf, cfg)
    got = np.asarray(got)
    # Semantics are identical up to float determinism: the jitted kernel
    # wrapper and the eager oracle may round a handful of x/s values onto
    # adjacent quantization codes (1-ulp jit/eager divergence), shifting
    # borderline entries across the tau confidence threshold.  That is only
    # *observable* where the predictor and full-product signs disagree —
    # so every mismatch must be such a genuinely ambiguous entry, and the
    # overall rate must be tiny.
    from repro.core.psg import msb_of, quantize
    g_msb = np.asarray((msb_of(xf, cfg.bits_x, cfg.bits_x_msb).T
                        @ msb_of(gf, cfg.bits_g, cfg.bits_g_msb))
                       .astype(jnp.float32))
    g_full = np.asarray((quantize(xf, cfg.bits_x).T
                         @ quantize(gf, cfg.bits_g)).astype(jnp.float32))
    ambiguous = np.sign(g_msb) != np.sign(g_full)
    mism = want != got
    assert not (mism & ~ambiguous).any(), \
        f"{(mism & ~ambiguous).sum()} mismatches at unambiguous entries"
    assert mism.mean() < 5e-3
    assert 0.0 <= float(fb) <= 1.0


# CIFAR geometry is never MXU-aligned: widths 16/32/64 give k*k*C reduction
# dims of 144/288/576 and dout of 16/32/64 — none a multiple of 128.  The
# kernel clamps its (BM, BN, BK) tiles to the operand extents and pads to
# the clamped grid; these pin that the padding is masked out of the result
# (exact oracle match, unpadded output shape) and that the fallback stats
# grid matches the executed-tile count.
CIFAR_TILE_SHAPES = [(2 * 32 * 32, 9 * 16, 16),   # stage-0 body, width 16
                     (2 * 16 * 16, 9 * 32, 32),   # stage-1 body, width 32
                     (2 * 8 * 8, 9 * 64, 64),     # stage-2 body, width 64
                     (2 * 16 * 16, 16, 32),       # 1x1 projection shortcut
                     (100, 145, 33)]              # nothing aligned at all


@pytest.mark.parametrize("N,din,dout", CIFAR_TILE_SHAPES)
def test_psg_grad_w_non_mxu_aligned_tiles(N, din, dout):
    cfg = PSGConfig(enabled=True)
    k1, k2 = jax.random.split(jax.random.PRNGKey(N + din + dout))
    x = jax.random.normal(k1, (N, din)) * 0.5
    gy = jax.random.normal(k2, (N, dout)) * 0.01
    got, fb = ops.psg_grad_w(x, gy, cfg)
    assert got.shape == (din, dout)              # padding cropped
    want = np.asarray(ref.psg_grad_w_ref(x, gy, cfg))
    np.testing.assert_array_equal(np.asarray(got), want)
    assert 0.0 <= float(fb) <= 1.0


@pytest.mark.parametrize("N,din,dout", CIFAR_TILE_SHAPES[:3])
def test_psg_kernel_stats_grid_matches_executed_tiles(N, din, dout):
    """The raw kernel's per-tile stats grid covers exactly the padded tile
    grid — ceil(din/BM) x ceil(dout/BN) with clamped tiles — so the mean
    is the executed-tile fallback ratio (DESIGN.md §Dispatch caveat)."""
    from repro.core.quant import quantize_int
    from repro.kernels.psg_matmul import (DEFAULT_BM, DEFAULT_BN,
                                          psg_grad_w_pallas)
    cfg = PSGConfig(enabled=True)
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    x = jax.random.normal(k1, (N, din))
    gy = jax.random.normal(k2, (N, dout)) * 0.01
    xm, _ = quantize_int(x, cfg.bits_x_msb)
    gm, _ = quantize_int(gy, cfg.bits_g_msb)
    xq, _ = quantize_int(x, cfg.bits_x)
    gq, _ = quantize_int(gy, cfg.bits_g)
    tau = cfg.beta * jnp.max(jnp.abs(
        xm.astype(jnp.float32).T @ gm.astype(jnp.float32)))
    out, stats = psg_grad_w_pallas(xm, gm, xq, gq, tau)
    bm = min(DEFAULT_BM, din)
    bn = min(DEFAULT_BN, dout)
    assert stats.shape == (-(-din // bm), -(-dout // bn))
    assert out.shape == (din, dout)


@pytest.mark.parametrize("beta", [0.02, 0.05, 0.1, 0.3])
def test_psg_threshold_beta_sweep(beta):
    cfg = PSGConfig(enabled=True, beta=beta)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (256, 128))
    gy = jax.random.normal(k2, (256, 64)) * 0.1
    want = np.asarray(ref.psg_grad_w_oracle(x, gy, cfg))
    got, _ = ops.psg_grad_w(x, gy, cfg)
    assert (want == np.asarray(got)).mean() > 0.999


@pytest.mark.parametrize("shape", [(128, 256), (7, 300), (1000,), (4, 4, 64)])
@pytest.mark.parametrize("bits", [2, 4, 8, 10, 16])
def test_quantize_kernel_matches_oracle(shape, bits):
    x = jax.random.normal(jax.random.PRNGKey(bits), shape)
    got = ops.quantize(x, bits)
    want = ref.quantize_ref(x, bits)
    # same grid; 1-ulp differences allowed (jit vs eager fma ordering of q*s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-7)


def test_quantize_idempotent():
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 64))
    q1 = ops.quantize(x, 8)
    q2 = ops.quantize(q1, 8)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), atol=1e-7)


def test_predictor_matmul_pallas_matches_oracle():
    from repro.kernels.psg_matmul import predictor_matmul_pallas
    from repro.core.psg import quantize_int
    cfg = PSGConfig(enabled=True)
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    x = jax.random.normal(k1, (384, 192))
    gy = jax.random.normal(k2, (384, 96))
    xm, _ = quantize_int(x, cfg.bits_x_msb)
    gm, _ = quantize_int(gy, cfg.bits_g_msb)
    got = predictor_matmul_pallas(xm, gm)
    want = xm.astype(jnp.float32).T @ gm.astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_psg_kernel_block_shape_sweep():
    """BlockSpec tiling must not change results."""
    from repro.kernels.psg_matmul import psg_grad_w_pallas
    from repro.core.psg import quantize_int
    cfg = PSGConfig(enabled=True)
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    x = jax.random.normal(k1, (256, 128))
    gy = jax.random.normal(k2, (256, 64))
    xm, _ = quantize_int(x, cfg.bits_x_msb)
    gm, _ = quantize_int(gy, cfg.bits_g_msb)
    xq, _ = quantize_int(x, cfg.bits_x)
    gq, _ = quantize_int(gy, cfg.bits_g)
    g_msb = xm.astype(jnp.float32).T @ gm.astype(jnp.float32)
    tau = cfg.beta * jnp.max(jnp.abs(g_msb))
    outs = []
    for bm, bn, bk in [(32, 32, 64), (64, 64, 128), (128, 64, 256)]:
        out, _ = psg_grad_w_pallas(xm, gm, xq, gq, tau, bm=bm, bn=bn, bk=bk)
        outs.append(np.asarray(out))
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)


FLASH_SHAPES = [(2, 256, 4, 2, 64, True), (1, 300, 8, 8, 32, True),
                (2, 128, 4, 4, 64, False), (1, 384, 6, 2, 128, True),
                (1, 64, 2, 1, 64, True)]


@pytest.mark.parametrize("B,S,nh,nkv,hd,causal", FLASH_SHAPES)
def test_flash_attention_matches_oracle(B, S, nh, nkv, hd, causal):
    from repro.kernels.flash_attn import flash_attention
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(S + nh), 3)
    q = jax.random.normal(k1, (B, S, nh, hd))
    k = jax.random.normal(k2, (B, S, nkv, hd))
    v = jax.random.normal(k3, (B, S, nkv, hd))
    got = flash_attention(q, k, v, causal=causal, bq=64, bk=64)
    want = ref.flash_attention_oracle(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_block_sweep():
    from repro.kernels.flash_attn import flash_attention
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(k1, (1, 256, 4, 64))
    k = jax.random.normal(k2, (1, 256, 2, 64))
    v = jax.random.normal(k3, (1, 256, 2, 64))
    want = ref.flash_attention_oracle(q, k, v, True)
    for bq, bk in [(32, 64), (64, 32), (128, 128), (256, 64)]:
        got = flash_attention(q, k, v, bq=bq, bk=bk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5)


def test_flash_attention_bf16():
    from repro.kernels.flash_attn import flash_attention
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(k1, (1, 128, 4, 64)).astype(jnp.bfloat16)
    k = jax.random.normal(k2, (1, 128, 4, 64)).astype(jnp.bfloat16)
    v = jax.random.normal(k3, (1, 128, 4, 64)).astype(jnp.bfloat16)
    got = flash_attention(q, k, v, bq=64, bk=64)
    want = ref.flash_attention_oracle(q.astype(jnp.float32),
                                      k.astype(jnp.float32),
                                      v.astype(jnp.float32), True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), atol=2e-2)
