"""Precision lint (analysis/precision_lint.py): the PR 7 bug class caught
statically — including the re-broken PR 7 fixture itself."""
from functools import partial

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import precision_lint
from repro.analysis.dataflow import analyze
from repro.kernels.conv import to_tap_major
from repro.kernels.ref import conv_grad_x_ref

S = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# the PR 7 regression, re-broken on purpose
# ---------------------------------------------------------------------------


def _broken_conv_grad_x_ref(gq, wq, k, stride, hp, wp):
    """conv_grad_x_ref exactly as it was BEFORE PR 7's fix: tap sums
    accumulate in ``gq.dtype`` instead of forced float32."""
    B, ho, wo, dout = gq.shape
    C = wq.shape[0] // (k * k)
    wt = to_tap_major(wq.astype(gq.dtype), k, C)
    g2 = gq.reshape(-1, dout)
    dx = jnp.zeros((B, hp, wp, C), gq.dtype)        # <- the bug
    for t in range(k * k):
        ki, kj = t // k, t % k
        g_t = (g2 @ wt[t * C:(t + 1) * C, :].T).reshape(B, ho, wo, C)
        dx = dx.at[:, ki:ki + (ho - 1) * stride + 1:stride,
                   kj:kj + (wo - 1) * stride + 1:stride, :].add(g_t)
    return dx


_GQ = S((2, 8, 8, 8), jnp.bfloat16)
_WQ = S((9 * 4, 8), jnp.bfloat16)


def test_pr7_regression_fixture_fails_the_lint():
    fn = partial(_broken_conv_grad_x_ref, k=3, stride=1, hp=10, wp=10)
    hz = analyze(fn, _GQ, _WQ, name="pr7").hazards()
    # the per-tap col2im loop shows up as both the bf16 GEMM and the
    # bf16 scatter accumulation — site and dtype must be right
    kinds = {h.kind for h in hz}
    assert "scatter-add" in kinds
    scatter = next(h for h in hz if h.kind == "scatter-add")
    assert scatter.acc_dtype == "bfloat16"
    assert scatter.narrow_operands == ("bfloat16",)
    assert scatter.site.startswith("pr7")


def test_fixed_reference_is_clean_under_bf16_cotangents():
    fn = partial(conv_grad_x_ref, k=3, stride=1, hp=10, wp=10)
    assert analyze(fn, _GQ, _WQ).hazards() == []


def test_fixture_findings_carry_the_pr7_message():
    fn = partial(_broken_conv_grad_x_ref, k=3, stride=1, hp=10, wp=10)
    res = analyze(fn, _GQ, _WQ, name="pr7")
    findings = precision_lint._hazard_findings("fixture", res)
    assert findings
    assert all(f.rule == "narrow-accumulator" for f in findings)
    assert any("PR 7" in f.message for f in findings)


# ---------------------------------------------------------------------------
# shipped surfaces are clean on main (the acceptance criterion)
# ---------------------------------------------------------------------------


def test_every_shipped_kernel_is_clean_including_bf16_variants():
    assert precision_lint.lint_kernels() == []


def test_both_cnn_backbones_traced_fwd_bwd_are_clean():
    findings, allowlisted = precision_lint.lint_all()
    assert findings == [], "\n".join(str(f) for f in findings)
    assert allowlisted == []


def test_narrow_variant_swaps_only_f32_arrays():
    args = (S((4, 4), jnp.float32), S((4, 4), jnp.int8), S((), jnp.float32))
    out = precision_lint.narrow_variant(args)
    assert out[0].dtype == jnp.bfloat16
    assert out[1].dtype == jnp.int8          # never touch integer codes
    assert out[2].dtype == jnp.float32       # scalars keep their dtype


# ---------------------------------------------------------------------------
# accumulator-dtype intent registry
# ---------------------------------------------------------------------------


def test_intent_registry_covers_every_shipped_kernel():
    from repro.kernels.dispatch import kernel_acc_dtypes, shipped_kernels
    bases = {name.split("[")[0] for name in shipped_kernels()}
    assert bases <= set(kernel_acc_dtypes())
    assert all(v == "float32" for v in kernel_acc_dtypes().values())


def test_missing_intent_declaration_is_a_finding(monkeypatch):
    from repro.kernels import dispatch
    slimmed = {k: v for k, v in dispatch.kernel_acc_dtypes().items()
               if k != "flash_attention"}
    monkeypatch.setattr(dispatch, "kernel_acc_dtypes", lambda: slimmed)
    findings = precision_lint.lint_kernels()
    assert any(f.rule == "acc-intent-missing"
               and f.site.startswith("flash_attention") for f in findings)


# ---------------------------------------------------------------------------
# allowlist-with-justification convention
# ---------------------------------------------------------------------------


def test_allowlist_entry_without_justification_raises():
    with pytest.raises(ValueError, match="justification"):
        precision_lint.check_allowlist({"some-site": ""})
    with pytest.raises(ValueError, match="justification"):
        precision_lint.check_allowlist({"some-site": "   "})


def test_justified_allowlist_suppresses_matching_sites():
    fn = partial(_broken_conv_grad_x_ref, k=3, stride=1, hp=10, wp=10)
    res = analyze(fn, _GQ, _WQ, name="pr7-fixture")
    findings = precision_lint._hazard_findings("fixture", res)
    out, suppressed = precision_lint.split_findings(
        findings, {"pr7-fixture": "deliberately re-broken PR 7 regression "
                                  "for the lint's own test coverage"})
    assert out == [] and len(suppressed) == len(findings)
    # and without the allowlist everything surfaces
    out2, sup2 = precision_lint.split_findings(findings, {})
    assert len(out2) == len(findings) and sup2 == []
