"""Optimizer properties (hypothesis) + schedules + SWA."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")   # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core.config import TrainConfig
from repro.optim import (make_optimizer, sgd_apply, sgd_init, signsgd_apply,
                         signsgd_init, swa_init, swa_params, swa_update)
from repro.optim.schedules import make_schedule


def test_sgd_momentum_matches_reference():
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.5])}
    st0 = sgd_init(p)
    p1, st1 = sgd_apply(p, g, st0, lr=0.1, momentum=0.9, weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(p1["w"]), [0.95, -2.05], rtol=1e-6)
    p2, _ = sgd_apply(p1, g, st1, lr=0.1, momentum=0.9, weight_decay=0.0)
    # m2 = 0.9*0.5 + 0.5 = 0.95
    np.testing.assert_allclose(np.asarray(p2["w"]), [0.855, -2.145], rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(lr=st.floats(1e-3, 0.5), seed=st.integers(0, 100))
def test_signsgd_step_magnitude_property(lr, seed):
    """Every SignSGD update moves each weight by exactly lr (wd=0),
    up to fp32 rounding of p - lr*sign."""
    key = jax.random.PRNGKey(seed)
    p = {"w": jax.random.normal(key, (16,))}
    g = {"w": jax.random.normal(jax.random.fold_in(key, 1), (16,))}
    st0 = signsgd_init(p)
    p1, _ = signsgd_apply(p, g, st0, lr, weight_decay=0.0)
    delta = np.abs(np.asarray(p1["w"] - p["w"]))
    nz = np.abs(np.asarray(g["w"])) > 0
    np.testing.assert_allclose(delta[nz], lr, rtol=1e-2, atol=1e-6)


def test_swa_average_correct():
    p = {"w": jnp.array([0.0])}
    st0 = swa_init(p)
    vals = [1.0, 2.0, 3.0]
    st_ = st0
    for i, v in enumerate(vals):
        st_ = swa_update(st_, {"w": jnp.array([v])}, step=i, start_step=0)
    out = swa_params(st_, p)
    np.testing.assert_allclose(np.asarray(out["w"]), [2.0], rtol=1e-6)


def test_swa_respects_start_step():
    p = {"w": jnp.array([0.0])}
    st_ = swa_init(p)
    for i, v in enumerate([10.0, 1.0, 3.0]):
        st_ = swa_update(st_, {"w": jnp.array([v])}, step=i, start_step=1)
    out = swa_params(st_, p)
    np.testing.assert_allclose(np.asarray(out["w"]), [2.0], rtol=1e-6)


def test_step_schedule_paper_protocol():
    """lr 0.1, x0.1 at 32k/48k of 64k (He et al. / paper §4.1)."""
    cfg = TrainConfig(lr=0.1, total_steps=64000, schedule="step",
                      decay_points=(0.5, 0.75), decay_factor=0.1)
    f = make_schedule(cfg)
    assert abs(float(f(0)) - 0.1) < 1e-6
    assert abs(float(f(31999)) - 0.1) < 1e-6
    assert abs(float(f(32000)) - 0.01) < 1e-6
    assert abs(float(f(48000)) - 0.001) < 1e-6


def test_schedule_scales_with_budget():
    """§4.2: reduced-iteration baselines scale decay points proportionally."""
    cfg = TrainConfig(lr=0.1, total_steps=32000, schedule="step")
    f = make_schedule(cfg)
    assert abs(float(f(16000)) - 0.01) < 1e-6


def test_make_optimizer_psg_is_sign_update():
    cfg = TrainConfig(optimizer="psg", lr=0.03, schedule="constant",
                      weight_decay=0.0, momentum=0.0)
    opt = make_optimizer(cfg)
    p = {"w": jnp.array([1.0, 1.0])}
    g = {"w": jnp.array([0.001, -100.0])}   # magnitudes must not matter
    p1, _ = opt.apply(p, g, opt.init(p), jnp.int32(0))
    np.testing.assert_allclose(np.asarray(p1["w"]), [0.97, 1.03], rtol=1e-5)
