"""Fault-injection harness (ft/faults.py) + elastic supervisor (ft/supervisor).

Every recovery path gets a REAL injected fault:

* supervisor policy loop against deterministic worker exit codes;
* kill-and-restart through ``launch/train.py``: a worker hard-killed
  mid-run (``--ft-kill-at-step``) is detected, the world shrinks, and the
  resumed run's final checkpoint is BIT-IDENTICAL to an uninterrupted
  run's — the counter-based data/SMD schedule makes the restarted step
  stream consistent by construction;
* elastic mesh shrink: killed on a 2-device data-parallel mesh, resumed
  on a 1-device mesh from the last *intact* checkpoint (a save torn by
  the kill fails checksum verification and is skipped);
* a real ``jax.distributed`` 2-process world: rank/world discovery, per-
  process data shards and per-process checkpoint streams (CPU backend has
  no cross-process collectives, so each rank trains its own shard — the
  coordinator plumbing and counter-based sharding are what this smoke
  pins).

Subprocess tests are ``slow`` (excluded from tier-1); CI runs them in the
dedicated fault-injection job.
"""
import os
import subprocess
import sys
import tempfile
import time

import numpy as np
import pytest

from repro.ft import faults
from repro.ft.checkpoint import intact_steps, latest_intact_step
from repro.ft.supervisor import (RestartPolicy, Supervisor, SupervisorError,
                                 free_tcp_port)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _env(extra=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    env.update(extra or {})
    return env


def _launcher(*args):
    return [sys.executable, "-m", "repro.launch.train",
            "--arch", "llama3_8b", "--smoke", "--log-every", "0", *args]


# ---------------------------------------------------------------------------
# injector units
# ---------------------------------------------------------------------------


def test_raising_at_step_fires_deterministically():
    mk = faults.raising_at_step(lambda s, sh: {"s": s}, 5)
    assert mk(4, 0) == {"s": 4}
    with pytest.raises(RuntimeError, match="step 5"):
        mk(5, 0)
    with pytest.raises(RuntimeError):
        mk(9, 0)                       # >= step: a drop cannot skip the fault


def test_slow_at_step_delays_only_listed_steps():
    mk = faults.slow_at_step(lambda s, sh: {"s": s}, [2], 0.2)
    t0 = time.perf_counter()
    mk(1, 0)
    fast = time.perf_counter() - t0
    t0 = time.perf_counter()
    mk(2, 0)
    slow = time.perf_counter() - t0
    assert slow >= 0.2 > fast


def test_corrupt_checkpoint_rejects_unknown_mode():
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(ValueError, match="unknown corruption mode"):
            faults.corrupt_checkpoint(d, 0, "gamma-ray")


# ---------------------------------------------------------------------------
# supervisor policy loop (workers = trivial subprocesses, no JAX)
# ---------------------------------------------------------------------------


def _exit_cmd(code):
    return [sys.executable, "-c", f"import sys; sys.exit({code})"]


def test_supervisor_clean_world_single_attempt():
    with tempfile.TemporaryDirectory() as d:
        sup = Supervisor(lambda w, r, resume: _exit_cmd(0), world=2,
                         ckpt_dir=d)
        attempts = sup.run()
    assert len(attempts) == 1
    assert attempts[0].outcome == "ok"
    assert attempts[0].exit_codes == [0, 0]
    assert sup.summary()["restarts"] == 0


def test_supervisor_shrinks_world_and_recovers():
    """One worker dies (injected exit code) -> the attempt is torn down,
    the world shrinks by the death count, and the smaller world succeeds."""
    def make_cmd(world, rank, resume):
        # rank 1 of the 2-world dies with the injected-kill code; the
        # re-formed 1-world runs clean
        code = faults.KILL_EXIT_CODE if (world == 2 and rank == 1) else 0
        return _exit_cmd(code)

    with tempfile.TemporaryDirectory() as d:
        sup = Supervisor(make_cmd, world=2, ckpt_dir=d)
        attempts = sup.run()
    assert [a.world for a in attempts] == [2, 1]
    assert attempts[0].outcome == "worker-died"
    assert faults.KILL_EXIT_CODE in attempts[0].exit_codes
    assert attempts[1].outcome == "ok"
    assert attempts[1].resume_step is None       # no checkpoint ever landed


def test_supervisor_gives_up_after_max_restarts():
    # exactly one rank dies per attempt, so the world shrinks by one each
    # time and the RESTART budget (not the world floor) is what trips
    def make_cmd(world, rank, resume):
        return _exit_cmd(5 if rank == world - 1 else 0)

    with tempfile.TemporaryDirectory() as d:
        sup = Supervisor(make_cmd, world=3, ckpt_dir=d,
                         policy=RestartPolicy(max_restarts=1))
        with pytest.raises(SupervisorError, match="gave up"):
            sup.run()
    assert [a.world for a in sup.attempts] == [3, 2]
    assert sup.attempts[-1].outcome == "aborted"


def test_supervisor_respects_min_world():
    with tempfile.TemporaryDirectory() as d:
        sup = Supervisor(lambda w, r, resume: _exit_cmd(5), world=2,
                         ckpt_dir=d,
                         policy=RestartPolicy(max_restarts=5, min_world=2))
        with pytest.raises(SupervisorError, match="min_world"):
            sup.run()
    assert len(sup.attempts) == 1                # never relaunched below floor


# ---------------------------------------------------------------------------
# kill-and-restart through the real launcher (slow: subprocess training)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_kill_and_restart_resumes_bit_consistent():
    """THE tentpole acceptance test: a worker hard-killed mid-run is
    detected by the supervisor, the world shrinks 2 -> 1, the relaunched
    worker resumes from the last INTACT checkpoint — and the final
    checkpoint is bit-identical to an uninterrupted run, step counter and
    SMD drop stream included (counter-based schedule => the restart
    replays the exact decision stream)."""
    steps = 10
    with tempfile.TemporaryDirectory() as d:
        ckpt, scratch, ref = (os.path.join(d, n)
                              for n in ("ckpt", "scratch", "ref"))

        def make_cmd(world, rank, resume):
            args = ["--steps", str(steps), "--e2train", "smd",
                    "--ckpt-every", "1"]
            # rank 0 owns the supervised checkpoint stream; other ranks
            # write elsewhere (single-process workers are all shard 0)
            args += ["--ckpt", ckpt if rank == 0 else scratch]
            if resume is not None:
                args += ["--resume"]
            elif world > 1 and rank == world - 1:
                # first attempt only: the last rank is hard-killed mid-run
                args += ["--ft-kill-at-step", "6"]
            return _launcher(*args)

        sup = Supervisor(make_cmd, world=2, ckpt_dir=ckpt, env=_env())
        attempts = sup.run()

        assert [a.world for a in attempts] == [2, 1]
        assert attempts[0].outcome == "worker-died"
        assert faults.KILL_EXIT_CODE in attempts[0].exit_codes
        assert attempts[1].outcome == "ok"
        # the restart resumed from an intact checkpoint, not from scratch
        # and not from a torn save
        assert attempts[1].resume_step is not None
        assert attempts[1].resume_step < steps
        assert latest_intact_step(ckpt) == steps - 1

        # uninterrupted reference with the same counters
        out = subprocess.run(
            _launcher("--steps", str(steps), "--e2train", "smd",
                      "--ckpt-every", "1", "--ckpt", ref),
            cwd=REPO, env=_env(), capture_output=True, text=True,
            timeout=580)
        assert out.returncode == 0, out.stderr[-2000:]

        a = np.load(os.path.join(ckpt, f"step_{steps - 1:08d}.npz"))
        b = np.load(os.path.join(ref, f"step_{steps - 1:08d}.npz"))
        assert set(a.files) == set(b.files)
        for k in a.files:
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)


@pytest.mark.slow
def test_elastic_mesh_shrink_kill_and_restart():
    """Killed on a 2-device data-parallel mesh mid-chunked-run; resumed on
    a 1-device mesh (launch/train.py restores the last intact checkpoint
    and reshard_state places it onto the smaller mesh) and runs the step
    budget to completion."""
    steps = 16
    with tempfile.TemporaryDirectory() as d:
        killed = subprocess.run(
            _launcher("--steps", str(steps), "--e2train", "smd",
                      "--ckpt", d, "--ckpt-every", "1", "--chunk-steps", "2",
                      "--devices", "2", "--mesh-data", "2",
                      "--ft-kill-at-step", "12"),
            cwd=REPO, env=_env(), capture_output=True, text=True,
            timeout=580)
        assert killed.returncode == faults.KILL_EXIT_CODE
        survivors = intact_steps(d)

        resumed = subprocess.run(
            _launcher("--steps", str(steps), "--e2train", "smd",
                      "--ckpt", d, "--ckpt-every", "1", "--chunk-steps", "2",
                      "--devices", "1", "--mesh-data", "1", "--resume"),
            cwd=REPO, env=_env(), capture_output=True, text=True,
            timeout=580)
        assert resumed.returncode == 0, resumed.stderr[-2000:]
        if survivors:                   # the kill usually leaves intact saves
            assert f"resumed from intact step {survivors[-1]}" \
                in resumed.stdout
            assert "'data': 1" in resumed.stdout     # resharded onto 1-dev
        assert latest_intact_step(d) == steps - 1


@pytest.mark.slow
def test_jax_distributed_two_process_world():
    """A real jax.distributed world of 2 processes on one host: coordinator
    handshake, rank/world discovery (process_shard), per-process data
    shards and per-process checkpoint streams all work end to end."""
    steps = 4
    with tempfile.TemporaryDirectory() as d:
        port = free_tcp_port()
        procs = [subprocess.Popen(
            _launcher("--steps", str(steps), "--ckpt", d, "--ckpt-every", "1",
                      "--distributed", "--coordinator", f"localhost:{port}",
                      "--num-processes", "2", "--process-id", str(i)),
            cwd=REPO, env=_env(), stdout=subprocess.PIPE,
            stderr=subprocess.PIPE, text=True) for i in range(2)]
        outs = [p.communicate(timeout=580) for p in procs]
        for p, (so, se) in zip(procs, outs):
            assert p.returncode == 0, se[-2000:]
        # per-process checkpoint streams, both complete and intact
        d0, d1 = (os.path.join(d, f"proc{i:03d}") for i in range(2))
        assert latest_intact_step(d0) == steps - 1
        assert latest_intact_step(d1) == steps - 1
        # counter-based sharding: the two ranks trained DIFFERENT shards,
        # so their params diverge (identical params would mean shard 0 ran
        # twice — the multi-host bug this smoke exists to catch)
        a = np.load(os.path.join(d0, f"step_{steps - 1:08d}.npz"))
        b = np.load(os.path.join(d1, f"step_{steps - 1:08d}.npz"))
        assert any(not np.array_equal(a[k], b[k]) for k in a.files)
