"""Task registry: both registered tasks round-trip through the full shared
training stack — init_train_state -> make_train_step -> Trainer.run ->
checkpoint save/resume — and task-specific metrics surface through it."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnns import cnn_model
from repro.core.config import (E2TrainConfig, Experiment, ModelConfig,
                               PSGConfig, SLUConfig, TrainConfig)
from repro.data.synthetic import (GaussianImageTask, MarkovLMTask,
                                  make_image_batch, make_lm_batch)
from repro.ft.checkpoint import restore_checkpoint, save_checkpoint
from repro.tasks import get_task, task_names
from repro.training.train_step import init_train_state
from repro.training.trainer import Trainer


def _exp(task_name, e2=None):
    e2 = e2 or E2TrainConfig(slu=SLUConfig(enabled=True, alpha=1e-3))
    tr = TrainConfig(global_batch=8, seq_len=16, lr=0.05,
                     total_steps=10, schedule="constant")
    if task_name == "cifar_cnn":
        return Experiment(model=cnn_model("resnet14", 14), e2=e2, train=tr,
                          task="cifar_cnn")
    model = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                        num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=32,
                        dtype="float32")
    return Experiment(model=model, e2=e2, train=tr, task="lm")


def _mk(exp):
    if exp.task == "cifar_cnn":
        task = GaussianImageTask(num_classes=10, snr=2.0)
        return lambda s, sh: make_image_batch(task, 0, s, sh,
                                              exp.train.global_batch)
    task = MarkovLMTask(vocab=exp.model.vocab_size)
    return lambda s, sh: make_lm_batch(task, 0, s, sh, exp.train.global_batch,
                                       exp.train.seq_len)


def test_registry_contents():
    assert set(task_names()) >= {"lm", "cifar_cnn"}
    with pytest.raises(KeyError):
        get_task("no_such_task")


@pytest.mark.parametrize("task_name", ["lm", "cifar_cnn"])
def test_roundtrip_checkpoint_resume(task_name):
    """Train 6 straight == train 4, checkpoint, restore, train 2 — loss and
    state (params AND non-trainable model_state) continue identically."""
    exp = _exp(task_name)
    mk = _mk(exp)

    stA = init_train_state(jax.random.PRNGKey(0), exp)
    trA = Trainer(exp, stA, mk)
    histA = trA.run(6)

    stB = init_train_state(jax.random.PRNGKey(0), exp)
    trB = Trainer(exp, stB, mk)
    histB = trB.run(4)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, trB.state, 4)
        restored, step = restore_checkpoint(d, trB.state)
        assert step == 4
        trC = Trainer(exp, jax.tree.map(jnp.asarray, restored), mk)
        histC = trC.run(2)

    for a, b in zip(jax.tree.leaves(trA.state.params),
                    jax.tree.leaves(trC.state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    # non-trainable buffers (BN running stats for the CNN task) resume too
    for a, b in zip(jax.tree.leaves(trA.state.model_state),
                    jax.tree.leaves(trC.state.model_state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    # loss continuity: the resumed trainer's steps match the straight run's
    np.testing.assert_allclose(
        [h["loss"] for h in histB + histC],
        [h["loss"] for h in histA], rtol=1e-4)


@pytest.mark.parametrize("task_name", ["lm", "cifar_cnn"])
def test_psg_fallback_ratio_emitted(task_name):
    """PSG on -> the measured MAC-weighted fallback ratio appears in the
    step metrics for BOTH tasks (the CNN conv backward routes through the
    same tile kernel as the LM matmuls)."""
    e2 = E2TrainConfig(psg=PSGConfig(enabled=True, swa=False))
    exp = _exp(task_name, e2=e2)
    exp = exp.replace(train=TrainConfig(global_batch=4, seq_len=16, lr=0.03,
                                        optimizer="psg", total_steps=2,
                                        schedule="constant"))
    state = init_train_state(jax.random.PRNGKey(0), exp)
    tr = Trainer(exp, state, _mk(exp))
    hist = tr.run(2)
    for h in hist:
        assert "psg_fallback_ratio" in h
        assert 0.0 <= h["psg_fallback_ratio"] <= 1.0
    assert tr.measured_psg_fallback() is not None

    # one-call energy accounting rides the same registry path for both
    # tasks: the report prices the experiment through Task.cost and carries
    # this run's fallback measurement
    rep = tr.energy_report()
    assert rep.task == task_name
    assert rep.fwd_macs_per_example > 0 and rep.params > 0
    assert abs(rep.psg.measured - tr.measured_psg_fallback()) < 1e-6
    assert rep.computational_savings_measured is not None


def test_microbatch_accumulation_threads_model_state():
    """Grad accumulation carries the CNN's BN state through the microbatch
    scan: the EMA after a 2-microbatch step reflects both microbatches."""
    import dataclasses
    exp = _exp("cifar_cnn")
    exp2 = exp.replace(train=dataclasses.replace(exp.train, microbatches=2))
    mk = _mk(exp)
    s1 = init_train_state(jax.random.PRNGKey(0), exp2)
    from repro.training.train_step import make_train_step
    step = jax.jit(make_train_step(exp2))
    s2, metrics = step(s1, mk(0, 0))
    stem0 = np.asarray(s1.model_state["stem_bn"]["mean"])
    stem1 = np.asarray(s2.model_state["stem_bn"]["mean"])
    assert not np.allclose(stem0, stem1)
    assert np.isfinite(float(metrics["total_loss"]))


def test_recalibrate_model_state_for_swa_eval():
    """SWA-averaged weights need re-estimated BN stats (the running EMA
    tracked the raw trajectory): the helper moves them, and is a no-op for
    the stateless LM task."""
    from repro.training.train_step import (eval_params,
                                           recalibrate_model_state)
    e2 = E2TrainConfig(psg=PSGConfig(enabled=True, swa=True,
                                     swa_start_frac=0.0))
    exp = _exp("cifar_cnn", e2=e2)
    exp = exp.replace(train=TrainConfig(global_batch=4, lr=0.03,
                                        optimizer="psg", total_steps=4,
                                        schedule="constant"))
    mk = _mk(exp)
    tr = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk)
    tr.run(4)
    assert tr.state.swa is not None
    swa_p = eval_params(tr.state, exp)
    recal = recalibrate_model_state(exp, swa_p, tr.state.model_state,
                                    [mk(i, 0) for i in range(3)])
    a = np.asarray(tr.state.model_state["stem_bn"]["mean"])
    b = np.asarray(recal["stem_bn"]["mean"])
    assert not np.allclose(a, b)
    # stateless task: pass-through
    lm_exp = _exp("lm")
    assert recalibrate_model_state(lm_exp, None, None, []) is None


def test_mobilenetv2_task_trains():
    """The compact backbone rides the same registry path."""
    exp = Experiment(model=cnn_model("mobilenetv2", 0), e2=E2TrainConfig(),
                     train=TrainConfig(global_batch=4, lr=0.05,
                                       optimizer="sgdm", total_steps=2,
                                       schedule="constant"),
                     task="cifar_cnn")
    state = init_train_state(jax.random.PRNGKey(0), exp)
    tr = Trainer(exp, state, _mk(exp))
    hist = tr.run(2)
    assert len(hist) == 2
    assert all(np.isfinite(h["loss"]) for h in hist)
    # BN buffers updated, and eval-mode prediction consumes them
    assert float(np.abs(np.asarray(
        tr.state.model_state["stem_bn"]["mean"])).max()) > 0.0
    predict = get_task("cifar_cnn").make_predict(exp)
    logits = predict(tr.state.params, tr.state.model_state, _mk(exp)(99, 0))
    assert logits.shape == (4, 10)
    assert np.isfinite(np.asarray(logits)).all()
