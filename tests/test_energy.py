"""Energy model: paper-table reproduction (the quantitative core claims)."""
import numpy as np
import pytest

from repro.core.config import ModelConfig
from repro.core.energy import (ENERGY_45NM, FP32_MAC_PJ, PSG_FACTOR_PAPER,
                               computational_savings, mac_energy_pj,
                               model_flops_6nd, model_fwd_flops,
                               mult_energy_pj, psg_factor_from_energy_model,
                               roofline_terms, train_step_flops)


def test_horowitz_8bit_savings_claims():
    """Paper §3.3: 8-bit mult/add save ~95/97% vs 32-bit fp."""
    mult_saving = 1 - mult_energy_pj(8, 8) / ENERGY_45NM["mul_fp32"]
    assert mult_saving > 0.93
    from repro.core.energy import add_energy_pj
    add_saving = 1 - add_energy_pj(8) / ENERGY_45NM["add_fp32"]
    assert add_saving > 0.95


def test_paper_table3_computational_savings():
    """Table 3: savings 80.27 / 85.20 / 90.13 % at SLU skip 20/40/60%
    with SMD ratio 0.67 — reproduced by the composition law."""
    for skip, want in [(0.2, 0.8027), (0.4, 0.8520), (0.6, 0.9013)]:
        got = computational_savings(0.67, skip, PSG_FACTOR_PAPER)
        assert abs(got - want) < 0.002, (skip, got, want)


def test_psg_factor_first_principles_in_range():
    """Our 45nm-model PSG factor should be *at most* the paper's implied
    0.368 (the paper's figure includes overheads our MAC-only model omits)."""
    r = psg_factor_from_energy_model()
    assert 0.02 < r < PSG_FACTOR_PAPER


def test_model_flops_6nd_close_to_analytic_dense():
    cfg = ModelConfig(name="t", family="dense", num_layers=4, d_model=256,
                      num_heads=8, num_kv_heads=8, d_ff=1024, vocab_size=1000)
    ana = train_step_flops(cfg, 4, 128)
    nd = model_flops_6nd(cfg, 4, 128)
    assert 0.4 < nd / ana < 1.6   # 6ND vs full accounting, same ballpark


def test_moe_active_params_fewer_than_total():
    cfg = ModelConfig(name="t", family="moe", num_layers=4, d_model=256,
                      num_heads=8, num_kv_heads=8, d_ff=512, vocab_size=1000,
                      num_experts=8, num_shared_experts=1, top_k=2,
                      moe_d_ff=512)
    assert cfg.active_param_count() < cfg.param_count()


def test_roofline_terms_bottleneck():
    t = roofline_terms(hlo_flops=1e15, hlo_bytes=1e12, coll_bytes=1e10,
                       chips=256)
    assert t["bottleneck"] in ("compute", "memory", "collective")
    assert t["step_s"] == max(t["compute_s"], t["memory_s"],
                              t["collective_s"])
    # compute term: 1e15 / (256 * 197e12)
    assert abs(t["compute_s"] - 1e15 / (256 * 197e12)) < 1e-12


def test_sliding_window_reduces_attn_flops():
    full = ModelConfig(name="t", family="dense", num_layers=2, d_model=128,
                       num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=100)
    swa = ModelConfig(name="t", family="dense", num_layers=2, d_model=128,
                      num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=100,
                      sliding_window=512)
    assert model_fwd_flops(swa, 1, 8192) < model_fwd_flops(full, 1, 8192)
