"""Energy model: paper-table reproduction (the quantitative core claims)."""
import numpy as np
import pytest

from repro.configs.paper_cnns import resnet74
from repro.core.config import (E2TrainConfig, ModelConfig, PSGConfig,
                               SLUConfig, SMDConfig)
from repro.core.energy import (ENERGY_45NM, FP32_MAC_PJ, PSG_FACTOR_PAPER,
                               computational_savings, mac_energy_pj,
                               model_flops_6nd, model_fwd_flops,
                               mult_energy_pj, psg_factor_from_energy_model,
                               roofline_terms, train_step_flops,
                               training_energy_pj)
from repro.core.ledger import EnergyLedger


def _paper_e2(skip: float) -> E2TrainConfig:
    return E2TrainConfig(smd=SMDConfig(enabled=True, drop_prob=0.5),
                         slu=SLUConfig(enabled=True, target_skip=skip),
                         psg=PSGConfig(enabled=True))


def test_horowitz_8bit_savings_claims():
    """Paper §3.3: 8-bit mult/add save ~95/97% vs 32-bit fp."""
    mult_saving = 1 - mult_energy_pj(8, 8) / ENERGY_45NM["mul_fp32"]
    assert mult_saving > 0.93
    from repro.core.energy import add_energy_pj
    add_saving = 1 - add_energy_pj(8) / ENERGY_45NM["add_fp32"]
    assert add_saving > 0.95


def test_paper_table3_computational_savings():
    """Table 3: savings 80.27 / 85.20 / 90.13 % at SLU skip 20/40/60%
    with SMD ratio 0.67 — reproduced by the composition law."""
    for skip, want in [(0.2, 0.8027), (0.4, 0.8520), (0.6, 0.9013)]:
        got = computational_savings(0.67, skip, PSG_FACTOR_PAPER)
        assert abs(got - want) < 0.002, (skip, got, want)


def test_psg_factor_first_principles_in_range():
    """Our 45nm-model PSG factor should be *at most* the paper's implied
    0.368 (the paper's figure includes overheads our MAC-only model omits)."""
    r = psg_factor_from_energy_model()
    assert 0.02 < r < PSG_FACTOR_PAPER


def test_model_flops_6nd_close_to_analytic_dense():
    cfg = ModelConfig(name="t", family="dense", num_layers=4, d_model=256,
                      num_heads=8, num_kv_heads=8, d_ff=1024, vocab_size=1000)
    ana = train_step_flops(cfg, 4, 128)
    nd = model_flops_6nd(cfg, 4, 128)
    assert 0.4 < nd / ana < 1.6   # 6ND vs full accounting, same ballpark


def test_moe_active_params_fewer_than_total():
    cfg = ModelConfig(name="t", family="moe", num_layers=4, d_model=256,
                      num_heads=8, num_kv_heads=8, d_ff=512, vocab_size=1000,
                      num_experts=8, num_shared_experts=1, top_k=2,
                      moe_d_ff=512)
    assert cfg.active_param_count() < cfg.param_count()


def test_roofline_terms_bottleneck():
    t = roofline_terms(hlo_flops=1e15, hlo_bytes=1e12, coll_bytes=1e10,
                       chips=256)
    assert t["bottleneck"] in ("compute", "memory", "collective")
    assert t["step_s"] == max(t["compute_s"], t["memory_s"],
                              t["collective_s"])
    # compute term: 1e15 / (256 * 197e12)
    assert abs(t["compute_s"] - 1e15 / (256 * 197e12)) < 1e-12


def test_ledger_reproduces_table3_from_config():
    """Acceptance: a ResNet-74 experiment at the paper's three operating
    points — all inputs config-derived (drop_prob x epochs_multiplier,
    target_skip), none hand-fed — reproduces Table 3's composition rows."""
    for skip, want in [(0.2, 0.8027), (0.4, 0.8520), (0.6, 0.9013)]:
        rep = EnergyLedger(resnet74(e2=_paper_e2(skip))).report()
        assert abs(rep.paper_composition - want) < 2e-3, (skip, rep)
        # a ledger with no telemetry has no measured column — None, not 0
        assert rep.computational_savings_measured is None
        assert rep.energy_pj_measured is None
        assert rep.smd.measured is None and rep.psg.measured is None


def test_ledger_measured_column_from_telemetry():
    """Feeding step telemetry produces the measured column next to the
    assumed one, and the measured values drive the composition."""
    led = EnergyLedger(resnet74(e2=_paper_e2(0.2)))
    for _ in range(6):
        led.record_step({"slu_exec_ratio": 0.7, "psg_fallback_ratio": 0.5})
    for _ in range(6):
        led.record_dropped()
    rep = led.report(steps=12)
    # measured SMD is what actually executed vs the baseline budget — NOT
    # the measured keep rate rescaled by the assumed epochs multiplier
    assert abs(rep.smd.measured - 6 / 12) < 1e-9
    assert abs(rep.slu.measured - 0.3) < 1e-9
    assert abs(rep.psg.measured - 0.5) < 1e-9
    assert rep.computational_savings_measured is not None
    assert rep.energy_savings_measured is not None
    # higher measured skip than assumed -> more savings than assumed
    assert rep.computational_savings_measured > 0.0
    # the assumed column is untouched by telemetry
    assert abs(rep.paper_composition - 0.8037) < 2e-3


def test_ledger_disabled_techniques_are_neutral():
    """With everything off, the ledger reports zero savings and every
    technique entry disabled with no assumed/measured values."""
    rep = EnergyLedger(resnet74(e2=E2TrainConfig())).report()
    assert rep.computational_savings_assumed == 0.0
    assert abs(rep.energy_savings_assumed) < 1e-9
    for t in (rep.smd, rep.slu, rep.psg):
        assert not t.enabled and t.assumed is None and t.measured is None


def test_training_energy_smd_factor_from_config():
    """Satellite: the SMD epoch extension comes from the config, not a
    baked-in 1.3333 — changing the multiplier changes the energy."""
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=100)
    e_paper = training_energy_pj(
        cfg, 4, 32, E2TrainConfig(smd=SMDConfig(enabled=True, drop_prob=0.5)),
        steps=100)
    e_off = training_energy_pj(cfg, 4, 32, E2TrainConfig(), steps=100)
    # paper operating point: 4/3 x epochs at drop 0.5 -> 2/3 the energy
    assert abs(e_paper / e_off - 2.0 / 3.0) < 1e-6
    e_m1 = training_energy_pj(
        cfg, 4, 32,
        E2TrainConfig(smd=SMDConfig(enabled=True, drop_prob=0.5,
                                    epochs_multiplier=1.0)), steps=100)
    assert abs(e_m1 / e_off - 0.5) < 1e-6


def test_sliding_window_reduces_attn_flops():
    full = ModelConfig(name="t", family="dense", num_layers=2, d_model=128,
                       num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=100)
    swa = ModelConfig(name="t", family="dense", num_layers=2, d_model=128,
                      num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=100,
                      sliding_window=512)
    assert model_fwd_flops(swa, 1, 8192) < model_fwd_flops(full, 1, 8192)
