"""PSG semantics: Eq. (2) behavior, Eq. (3) bound, optimizer integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")   # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import psg
from repro.core.config import PSGConfig


def test_quantize_grid():
    x = jnp.linspace(-1, 1, 101)
    q = psg.quantize(x, 4)
    # 4-bit grid has 15 levels, step = max/7
    levels = np.unique(np.asarray(q))
    assert len(levels) <= 15
    assert np.abs(np.asarray(q) - np.asarray(x)).max() <= 1.0 / 7 / 2 + 1e-6


@settings(max_examples=25, deadline=None)
@given(bits=st.integers(3, 12), seed=st.integers(0, 100))
def test_quantize_error_bounded_property(bits, seed):
    """|x - q(x)| <= Delta/2 where Delta = max|x| / (2^(b-1)-1)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,))
    q = psg.quantize(x, bits)
    delta = float(jnp.max(jnp.abs(x))) / (2 ** (bits - 1) - 1)
    assert float(jnp.max(jnp.abs(q - x))) <= delta / 2 + 1e-6


def test_sign_values():
    cfg = PSGConfig(enabled=True)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (128, 32))
    gy = jax.random.normal(k2, (128, 16))
    s = psg.psg_grad_w_ref(x, gy, cfg)
    vals = np.unique(np.asarray(s))
    assert set(vals).issubset({-1.0, 0.0, 1.0})


def test_predictor_usage_paper_claim():
    """Paper §4.4: predictor decides >= 60% of entries at beta=0.05."""
    cfg = PSGConfig(enabled=True, beta=0.05)
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(k1, (1024, 256))
    gy = jax.random.normal(k2, (1024, 128)) * 0.01
    usage = float(psg.psg_predictor_usage(x, gy, cfg))
    assert usage >= 0.6, f"predictor usage {usage}"


@settings(max_examples=15, deadline=None)
@given(bx=st.integers(3, 6), bg=st.integers(8, 12), seed=st.integers(0, 50))
def test_prediction_error_bound_decays_with_precision(bx, bg, seed):
    """Eq. (3): empirical flip rate <= Chebyshev bound (when bound < 1)."""
    cfg = PSGConfig(enabled=True, bits_x_msb=bx, bits_g_msb=bg)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (256, 64))
    gy = jax.random.normal(k2, (256, 32))
    bound = float(psg.prediction_error_bound(x, gy, cfg))
    # empirical sign-flip rate of confident predictions
    s_pred = psg.psg_grad_w_ref(x, gy, cfg)
    g_true = x.T @ gy
    flips = float(jnp.mean((s_pred != jnp.sign(g_true)) &
                           (jnp.abs(g_true) > 1e-6)))
    if bound < 1.0:
        assert flips <= bound + 0.05
    # bound shrinks when predictor precision grows
    cfg_hi = PSGConfig(enabled=True, bits_x_msb=bx + 2, bits_g_msb=bg + 2)
    assert float(psg.prediction_error_bound(x, gy, cfg_hi)) <= bound


def test_psg_matmul_custom_vjp():
    cfg = PSGConfig(enabled=True)
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    x = jax.random.normal(k1, (64, 32))
    w = jax.random.normal(k2, (32, 16)) * 0.1

    def loss(w):
        y = psg.psg_matmul(x, w, cfg)
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(w)
    vals = np.unique(np.asarray(g))
    assert set(vals).issubset({-1.0, 0.0, 1.0}), "dW must be sign-valued"


def test_psg_einsum_dispatch_patterns():
    cfg = PSGConfig(enabled=True)
    key = jax.random.PRNGKey(3)
    with psg.enable(cfg):
        x = jax.random.normal(key, (2, 8, 16))
        w = jax.random.normal(key, (16, 4, 8))
        y = psg.einsum("bsd,dnh->bsnh", x, w)
        assert y.shape == (2, 8, 4, 8)
        x2 = jax.random.normal(key, (2, 8, 4, 8))
        w2 = jax.random.normal(key, (4, 8, 16))
        y2 = psg.einsum("bsnh,nhd->bsd", x2, w2)
        assert y2.shape == (2, 8, 16)
        xe = jax.random.normal(key, (3, 4, 5, 16))
        we = jax.random.normal(key, (4, 16, 8))
        ye = psg.einsum("gecd,edf->gecf", xe, we)
        assert ye.shape == (3, 4, 5, 8)
    # disabled -> plain einsum, exact
    y_plain = psg.einsum("bsd,dnh->bsnh", x, w)
    np.testing.assert_allclose(np.asarray(y_plain),
                               np.asarray(jnp.einsum("bsd,dnh->bsnh", x, w)),
                               rtol=1e-6)


def test_majority_vote_composition():
    """mean-of-signs then sign == majority vote; robust to missing voter."""
    from repro.optim.majority_vote import majority_vote_tree
    votes = jnp.array([[1., 1., -1.], [1., -1., -1.], [1., -1., 0.]])
    mean = jnp.mean(votes, axis=0)
    out = majority_vote_tree(mean)
    np.testing.assert_array_equal(np.asarray(out), [1., -1., -1.])
