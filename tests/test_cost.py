"""CostModel validation: the per-layer CNN cost model against independently
computed MAC totals and the actual jax parameter trees (DESIGN.md §Energy).
"""
import jax
import numpy as np
import pytest

from repro.configs.paper_cnns import cnn_model
from repro.core.config import E2TrainConfig, Experiment, ModelConfig
from repro.core.cost import cnn_cost, lm_cost, mobilenet_cost, resnet_cost
from repro.tasks import cost_model


# ---------------------------------------------------------------------------
# independent MAC computation (different code path from core/cost.py: a
# literal walk of the CIFAR ResNet stage schedule)
# ---------------------------------------------------------------------------


def _resnet_conv_fc_macs_independent(depth: int, width: int = 16,
                                     image: int = 32, classes: int = 10) -> int:
    n = (depth - 2) // 6
    macs = image * image * 9 * 3 * width                       # stem
    hw, cin = image, width
    for stage in range(3):
        cout = width * (2 ** stage)
        for b in range(n):
            if stage > 0 and b == 0:
                hw //= 2
            macs += hw * hw * 9 * cin * cout                   # conv1
            macs += hw * hw * 9 * cout * cout                  # conv2
            if cin != cout:
                macs += hw * hw * cin * cout                   # 1x1 down
            cin = cout
    return macs + 4 * width * classes                          # fc


@pytest.mark.parametrize("depth,want", [
    # the literature's well-known CIFAR ResNet figures: ResNet-110 is the
    # "253 MFLOPs" model (MACs), ResNet-74 scales to ~168M
    (74, 168_215_168),
    (110, 253_149_824),
])
def test_resnet_mac_totals_pinned(depth, want):
    cost = resnet_cost(cnn_model(f"resnet{depth}", depth))
    conv_fc = sum(l.macs for l in cost.layers if l.kind in ("conv", "fc"))
    assert conv_fc == want
    assert conv_fc == _resnet_conv_fc_macs_independent(depth)
    # BN adds ~1% on top; total stays in the same ballpark
    assert conv_fc < cost.fwd_macs() < conv_fc * 1.02


def test_resnet_param_count_matches_jax_tree():
    """Leaf-by-leaf ground truth: the cost table's parameter total equals
    the actual init tree (SLU gate excluded — it is an E2-Train add-on, not
    backbone cost)."""
    from repro.models import resnet as R
    for depth in (14, 26):
        p, _ = R.init_resnet(jax.random.PRNGKey(0), depth,
                             e2=E2TrainConfig())   # slu off -> no gate
        tree_n = sum(np.size(x) for x in jax.tree.leaves(p))
        assert cnn_cost(cnn_model(f"resnet{depth}", depth)).param_count() \
            == tree_n


def test_resnet110_param_count_well_known():
    """ResNet-110 on CIFAR-10 is the 1.7M-parameter model."""
    n = cnn_cost(cnn_model("resnet110", 110)).param_count()
    assert abs(n - 1.73e6) < 0.03e6


def test_mobilenet_param_count_matches_jax_tree():
    from repro.models import resnet as R
    p, _ = R.init_mobilenetv2(jax.random.PRNGKey(0))
    tree_n = sum(np.size(x) for x in jax.tree.leaves(p))
    assert mobilenet_cost(cnn_model("mobilenetv2", 0)).param_count() == tree_n


def test_mbv2_layout_matches_model_table():
    """core/cost.py restates MBV2_CFG (core must not import models); pin the
    two tables against each other so they cannot drift."""
    from repro.core import cost as C
    from repro.models import resnet as R
    assert C.MBV2_CFG == R.MBV2_CFG


def test_gated_fraction_excludes_projection_transitions():
    """SLU gates identity-shortcut blocks only: the projection transitions
    of stages 1/2, the stem, and the fc must not be gated (models/resnet.py
    semantics)."""
    cost = resnet_cost(cnn_model("resnet74", 74))
    by_name = {l.name: l for l in cost.layers}
    assert not by_name["stem"].gated
    assert not by_name["fc"].gated
    assert by_name["s0b0.conv1"].gated          # stage-0 transition: identity
    assert not by_name["s1b0.conv1"].gated      # projection transition
    assert not by_name["s2b0.conv1"].gated
    assert by_name["s1b1.conv1"].gated
    assert 0.9 < cost.gated_fraction() < 1.0


def test_slu_exec_scales_train_macs_and_movement():
    cost = resnet_cost(cnn_model("resnet74", 74))
    full = cost.train_macs(8, slu_exec=1.0)
    half = cost.train_macs(8, slu_exec=0.5)
    assert half < full
    assert abs(full - 3 * 8 * cost.fwd_macs()) < 1e-6
    assert cost.moved_words(8, slu_exec=0.5) < cost.moved_words(8)


# ---------------------------------------------------------------------------
# LM cost model + registry resolution + delegation (no silent CNN lies)
# ---------------------------------------------------------------------------


LM = ModelConfig(name="t", family="dense", num_layers=4, d_model=256,
                 num_heads=8, num_kv_heads=8, d_ff=1024, vocab_size=1000)


def test_lm_cost_matches_analytic_flops():
    from repro.core.energy import model_fwd_flops
    cost = lm_cost(LM, 128)
    assert abs(cost.fwd_macs() - model_fwd_flops(LM, 1, 128) / 2.0) < 1.0
    # blocks are SLU-gatable; embedding/head are not
    assert 0.0 < cost.gated_fraction() < 1.0


def test_cost_resolves_through_task_registry():
    cnn_exp = Experiment(model=cnn_model("resnet74", 74), task="cifar_cnn")
    assert cost_model(cnn_exp).fwd_macs() > 1e8
    lm_exp = Experiment(model=LM, task="lm")
    assert cost_model(lm_exp).param_count() > 0
    # the two tasks price through different models
    assert cost_model(cnn_exp).name == "resnet74"


def test_cnn_param_count_delegates_not_transformer_math():
    """Satellite: ModelConfig.param_count for family="cnn" must return the
    CNN count (≈1.15M for ResNet-74), not transformer-block arithmetic."""
    m = cnn_model("resnet74", 74)
    assert m.param_count() == cnn_cost(m).param_count()
    assert abs(m.param_count() - 1.147e6) < 0.01e6


def test_cnn_fwd_flops_delegates():
    from repro.core.energy import block_fwd_flops, model_fwd_flops
    m = cnn_model("resnet74", 74)
    assert model_fwd_flops(m, 2, 0) == 2 * 2.0 * cnn_cost(m).fwd_macs()
    with pytest.raises(ValueError):
        block_fwd_flops(m, "attn", 32)   # no transformer blocks in a CNN
    with pytest.raises(ValueError):
        lm_cost(m, 32)


def test_cnn_cost_rejects_non_cnn():
    with pytest.raises(ValueError):
        cnn_cost(LM)
