"""Repo convention linter (analysis/repo_lint.py): pallas_call containment,
REPRO_* env-read containment, host-sync containment and swallowed-exception
containment over src/repro."""
import pytest

from repro.analysis import lint_repo
from repro.analysis.repo_lint import (_HOST_SYNC_ALLOWED, _SWALLOW_ALLOWED,
                                      check_host_sync_allowlist,
                                      check_swallow_allowlist, lint_source)


def test_repo_is_clean():
    findings = lint_repo()
    assert findings == [], "\n".join(str(f) for f in findings)


def test_pallas_call_outside_kernels_is_flagged():
    src = "from jax.experimental import pallas as pl\n" \
          "y = pl.pallas_call(f, grid=(1,))(x)\n"
    (f,) = lint_source(src, "repro/models/sneaky.py")
    assert f.rule == "pallas-outside-kernels" and f.line == 2


def test_pallas_call_inside_kernels_is_allowed():
    src = "from jax.experimental import pallas as pl\n" \
          "y = pl.pallas_call(f, grid=(1,))(x)\n"
    assert lint_source(src, "repro/kernels/new_kernel.py") == []


def test_env_reads_are_flagged_everywhere():
    for src in ('import os\nv = os.environ.get("REPRO_FOO")\n',
                'import os\nv = os.getenv("REPRO_FOO", "x")\n',
                'import os\nv = os.environ["REPRO_FOO"]\n'):
        findings = lint_source(src, "repro/training/trainer.py")
        assert [f.rule for f in findings] == ["env-read"], src


def test_sanctioned_dispatch_read_is_allowed():
    src = 'import os\nv = os.environ.get("REPRO_KERNEL_BACKEND", "")\n'
    assert lint_source(src, "repro/kernels/dispatch.py") == []
    # ... but only in dispatch.py
    assert lint_source(src, "repro/kernels/ops.py") != []


def test_non_repro_env_and_mentions_are_not_flagged():
    src = ('import os\n'
           'v = os.environ.get("XLA_FLAGS")\n'
           's = "REPRO_KERNEL_BACKEND"  # naming it is fine\n')
    assert lint_source(src, "repro/launch/mesh.py") == []


def test_host_syncs_outside_training_are_flagged():
    for src in ("import jax\nv = jax.device_get(x)\n",
                "v = y.block_until_ready()\n",
                "import numpy as np\nv = np.asarray(tracer)\n",
                "import numpy as np\nv = np.array(tracer)\n"):
        findings = lint_source(src, "repro/models/sneaky.py")
        assert [f.rule for f in findings] == ["host-sync"], src


def test_host_syncs_are_allowed_at_the_loop_boundary():
    src = ("import jax\nimport numpy as np\n"
           "v = np.asarray(jax.device_get(x))\n"
           "w = y.block_until_ready()\n")
    assert lint_source(src, "repro/training/trainer.py") == []
    assert lint_source(src, "benchmarks/bench_smd.py") == []
    assert lint_source(src, "examples/train_cifar.py") == []


def test_host_sync_allowlist_entries_are_justified():
    check_host_sync_allowlist()          # the shipped allowlist must pass
    assert all(why.strip() for why in _HOST_SYNC_ALLOWED.values())
    with pytest.raises(ValueError, match="justification"):
        check_host_sync_allowlist({"repro/models/sneaky.py": ""})


def test_allowlisted_module_may_sync():
    src = "import jax\nv = jax.device_get(x)\n"
    path = next(iter(_HOST_SYNC_ALLOWED))
    assert lint_source(src, path) == []


def test_bare_except_is_flagged():
    src = "try:\n    f()\nexcept:\n    handle()\n"
    (f,) = lint_source(src, "repro/models/sneaky.py")
    assert f.rule == "swallowed-exception" and f.line == 3
    assert "bare except" in f.message


def test_broad_except_pass_is_flagged():
    for src in ("try:\n    f()\nexcept Exception:\n    pass\n",
                "try:\n    f()\nexcept BaseException as e:\n    ...\n",
                "try:\n    f()\nexcept (ValueError, Exception):\n    pass\n"):
        findings = lint_source(src, "repro/ft/supervisor.py")
        assert [f.rule for f in findings] == ["swallowed-exception"], src


def test_handled_broad_and_specific_swallows_are_allowed():
    for src in (
            # broad catch that HANDLES (captures/re-raises) is fine — the
            # pipeline's producer-thread capture is exactly this shape
            "try:\n    f()\nexcept BaseException as e:\n"
            "    err = e\n    raise\n",
            # swallowing a SPECIFIC exception is a normal idiom
            "try:\n    f()\nexcept queue.Empty:\n    pass\n",
            "try:\n    f()\nexcept (OSError, ValueError):\n    pass\n"):
        assert lint_source(src, "repro/data/pipeline.py") == [], src


def test_swallow_allowlist_requires_justification(monkeypatch):
    check_swallow_allowlist()            # the shipped allowlist must pass
    with pytest.raises(ValueError, match="justification"):
        check_swallow_allowlist({"repro/models/sneaky.py": "  "})
    # a justified entry exempts the module
    from repro.analysis import repo_lint as rl
    monkeypatch.setitem(rl._SWALLOW_ALLOWED, "repro/legacy/vendored.py",
                        "vendored code retained verbatim")
    src = "try:\n    f()\nexcept Exception:\n    pass\n"
    assert lint_source(src, "repro/legacy/vendored.py") == []
    assert lint_source(src, "repro/legacy/other.py") != []
