"""Repo convention linter (analysis/repo_lint.py): pallas_call containment
and REPRO_* env-read containment over src/repro."""
from repro.analysis import lint_repo
from repro.analysis.repo_lint import lint_source


def test_repo_is_clean():
    findings = lint_repo()
    assert findings == [], "\n".join(str(f) for f in findings)


def test_pallas_call_outside_kernels_is_flagged():
    src = "from jax.experimental import pallas as pl\n" \
          "y = pl.pallas_call(f, grid=(1,))(x)\n"
    (f,) = lint_source(src, "repro/models/sneaky.py")
    assert f.rule == "pallas-outside-kernels" and f.line == 2


def test_pallas_call_inside_kernels_is_allowed():
    src = "from jax.experimental import pallas as pl\n" \
          "y = pl.pallas_call(f, grid=(1,))(x)\n"
    assert lint_source(src, "repro/kernels/new_kernel.py") == []


def test_env_reads_are_flagged_everywhere():
    for src in ('import os\nv = os.environ.get("REPRO_FOO")\n',
                'import os\nv = os.getenv("REPRO_FOO", "x")\n',
                'import os\nv = os.environ["REPRO_FOO"]\n'):
        findings = lint_source(src, "repro/training/trainer.py")
        assert [f.rule for f in findings] == ["env-read"], src


def test_sanctioned_dispatch_read_is_allowed():
    src = 'import os\nv = os.environ.get("REPRO_KERNEL_BACKEND", "")\n'
    assert lint_source(src, "repro/kernels/dispatch.py") == []
    # ... but only in dispatch.py
    assert lint_source(src, "repro/kernels/ops.py") != []


def test_non_repro_env_and_mentions_are_not_flagged():
    src = ('import os\n'
           'v = os.environ.get("XLA_FLAGS")\n'
           's = "REPRO_KERNEL_BACKEND"  # naming it is fine\n')
    assert lint_source(src, "repro/launch/mesh.py") == []
