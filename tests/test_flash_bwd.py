"""Flash-attention backward: recomputed-tile dq vs the fp32 vjp oracle,
BIT-IDENTICAL PSG dk/dv code products vs the tile-replay oracle, the
attention_fwd path-parity matrix (chunked scan vs flash kernel vs fp32
oracle), and the probe -> psg_fallback_ratio -> energy_report() channel
from a transformer train step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import psg
from repro.core.config import (E2TrainConfig, Experiment, ModelConfig,
                               PSGConfig, TrainConfig)
from repro.kernels import dispatch, ops, ref
from repro.kernels import flash_attn as fa


def _rand(B, S, nh, nkv, hd, seed, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, S, nh, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, nkv, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, nkv, hd)).astype(dtype)
    do = (jax.random.normal(ks[3], (B, S, nh, hd)) * 0.1).astype(dtype)
    return q, k, v, do


# shipped LM geometries (hd=128 GQA like llama3-class configs) plus the
# awkward cases: S not a multiple of the 128 query block, tiny heads,
# non-causal
BWD_SHAPES = [(1, 256, 4, 2, 128, True),     # LM geometry, 2x2 blocks
              (1, 192, 4, 2, 128, True),     # S % 128 != 0 (padded rows)
              (2, 300, 8, 8, 32, True),      # MHA, double padding
              (1, 128, 4, 4, 64, False)]     # non-causal


@pytest.mark.parametrize("B,S,nh,nkv,hd,causal", BWD_SHAPES)
def test_forward_lse_matches_oracle(B, S, nh, nkv, hd, causal):
    q, k, v, _ = _rand(B, S, nh, nkv, hd, seed=S + nh)
    o, lse = fa.flash_attention(q, k, v, causal=causal, interpret=True,
                                return_lse=True)
    o_plain = fa.flash_attention(q, k, v, causal=causal, interpret=True)
    np.testing.assert_array_equal(np.asarray(o), np.asarray(o_plain))
    np.testing.assert_allclose(np.asarray(lse),
                               np.asarray(ref.attention_lse_ref(q, k, causal)),
                               atol=2e-5)


@pytest.mark.parametrize("B,S,nh,nkv,hd,causal", BWD_SHAPES)
def test_bwd_dq_matches_vjp_oracle(B, S, nh, nkv, hd, causal):
    q, k, v, do = _rand(B, S, nh, nkv, hd, seed=2 * S + hd)
    o, lse = fa.flash_attention(q, k, v, causal=causal, interpret=True,
                                return_lse=True)
    delta = jnp.einsum("bsnh,bsnh->bns", do, o.astype(jnp.float32))
    dq = fa.flash_bwd_dq_pallas(q, k, v, do, lse, delta, causal=causal,
                                interpret=True)
    dq_o, _, _ = ref.flash_attention_vjp_oracle(q, k, v, do, causal)
    scale = float(jnp.max(jnp.abs(dq_o))) + 1e-12
    np.testing.assert_allclose(np.asarray(dq), np.asarray(dq_o),
                               atol=1e-5 * max(scale, 1.0))


@pytest.mark.parametrize("B,S,nh,nkv,hd,causal", BWD_SHAPES)
def test_bwd_dkv_code_products_bit_identical(B, S, nh, nkv, hd, causal):
    """The acceptance pin: the kernel's four code-product accumulators are
    bit-for-bit the tile-replay oracle's — same tile schedule, same dot
    shapes, same accumulation order — so the Eq. (2) select (a shared,
    deterministic function of these products) yields identical dk/dv signs
    by construction."""
    cfg = PSGConfig(enabled=True)
    q, k, v, do = _rand(B, S, nh, nkv, hd, seed=3 * S + nh)
    o, lse = fa.flash_attention(q, k, v, causal=causal, interpret=True,
                                return_lse=True)
    delta = jnp.einsum("bsnh,bsnh->bns", do, o.astype(jnp.float32))
    scales = fa.attention_psg_scales(
        q, v, do, delta, bits_x=cfg.bits_x, bits_x_msb=cfg.bits_x_msb,
        bits_g=cfg.bits_g, bits_g_msb=cfg.bits_g_msb)
    lims = (fa.qlim(cfg.bits_x), fa.qlim(cfg.bits_x_msb),
            fa.qlim(cfg.bits_g), fa.qlim(cfg.bits_g_msb))
    got = fa.flash_bwd_dkv_pallas(q, k, v, do, lse, delta, scales,
                                  lims=lims, causal=causal, interpret=True)
    want = ref.attention_dkv_products_oracle(q, k, v, do, lse, delta,
                                             scales, lims=lims,
                                             causal=causal)
    for g, w, name in zip(got, want, ("dv_msb", "dv_full", "dk_msb",
                                      "dk_full")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)


def test_full_bwd_signs_bit_identical_to_element_oracle():
    """End-to-end on the shipped LM geometry: ops.flash_attention_bwd's
    dk/dv are exactly the select applied to the (group-summed) oracle
    products — signs included, bit for bit."""
    cfg = PSGConfig(enabled=True)
    B, S, nh, nkv, hd = 1, 256, 4, 2, 128
    q, k, v, do = _rand(B, S, nh, nkv, hd, seed=11)
    o, lse = fa.flash_attention(q, k, v, causal=True, interpret=True,
                                return_lse=True)
    dq, dk, dv, fb = ops.flash_attention_bwd(q, k, v, o, lse, do, cfg,
                                             causal=True, interpret=True)
    delta = jnp.einsum("bsnh,bsnh->bns", do, o.astype(jnp.float32))
    scales = fa.attention_psg_scales(
        q, v, do, delta, bits_x=cfg.bits_x, bits_x_msb=cfg.bits_x_msb,
        bits_g=cfg.bits_g, bits_g_msb=cfg.bits_g_msb)
    lims = (fa.qlim(cfg.bits_x), fa.qlim(cfg.bits_x_msb),
            fa.qlim(cfg.bits_g), fa.qlim(cfg.bits_g_msb))
    parts = ref.attention_dkv_products_oracle(q, k, v, do, lse, delta,
                                              scales, lims=lims, causal=True)
    g = nh // nkv
    dv_m, dv_f, dk_m, dk_f = (
        p.reshape(B, S, nkv, g, hd).sum(axis=3) for p in parts)
    s_q, s_qm, s_do, s_dom, s_ds, s_dsm = scales
    dv_o, r_dv = fa.psg_attention_select(dv_m, dv_f,
                                         (1.0 / lims[1]) * s_dom,
                                         (1.0 / lims[0]) * s_do, cfg.beta)
    dk_o, r_dk = fa.psg_attention_select(dk_m, dk_f, s_dsm * s_qm,
                                         s_ds * s_q, cfg.beta)
    # signs: BIT-IDENTICAL (the select picks a code product — exact by the
    # products test above — and dequantization scales are positive, so no
    # rounding can flip a sign).  Values: identical up to 1-ulp in the
    # dequantization multiply (jit may fuse codes*s1*s2 in either order).
    np.testing.assert_array_equal(np.sign(np.asarray(dv)),
                                  np.sign(np.asarray(dv_o)))
    np.testing.assert_array_equal(np.sign(np.asarray(dk)),
                                  np.sign(np.asarray(dk_o)))
    np.testing.assert_allclose(np.asarray(dv), np.asarray(dv_o),
                               rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dk_o),
                               rtol=1e-6, atol=1e-8)
    assert 0.0 <= float(fb) <= 1.0
    assert abs(float(fb) - 0.5 * (float(r_dv) + float(r_dk))) < 1e-6


def test_bwd_bf16_operands_fp32_outputs():
    """bf16 activations (the model's real dtype): kernels accept narrow
    operands, gradients come back finite in fp32 accumulators."""
    cfg = PSGConfig(enabled=True)
    q, k, v, do = _rand(1, 192, 4, 2, 64, seed=5, dtype=jnp.bfloat16)
    o, lse = fa.flash_attention(q, k, v, causal=True, interpret=True,
                                return_lse=True)
    assert lse.dtype == jnp.float32
    dq, dk, dv, fb = ops.flash_attention_bwd(q, k, v, o, lse, do, cfg,
                                             causal=True, interpret=True)
    for t in (dq, dk, dv):
        assert t.dtype == jnp.float32
        assert bool(jnp.all(jnp.isfinite(t)))
    assert 0.0 <= float(fb) <= 1.0


def test_reference_backend_bwd_contract():
    """The reference backend's element-level path honors the same contract:
    fp32 dq close to autodiff, dk/dv shaped to kv heads, ratio in [0,1]."""
    cfg = PSGConfig(enabled=True, backend="reference")
    q, k, v, do = _rand(1, 64, 4, 2, 32, seed=13)
    o, lse = dispatch.attention_fwd(q, k, v, cfg, causal=True)
    np.testing.assert_allclose(
        np.asarray(o), np.asarray(ref.flash_attention_oracle(q, k, v, True)),
        atol=1e-6)
    dq, dk, dv, fb = dispatch.attention_bwd(q, k, v, o, lse, do, cfg,
                                            causal=True)
    dq_o, dk_o, dv_o = ref.flash_attention_vjp_oracle(q, k, v, do, True)
    np.testing.assert_allclose(np.asarray(dq), np.asarray(dq_o), atol=1e-5)
    assert dk.shape == k.shape and dv.shape == v.shape
    assert 0.0 <= float(fb) <= 1.0


# ---------------------------------------------------------------------------
# attention_fwd parity matrix: chunked scan vs flash kernel vs fp32 oracle
# ---------------------------------------------------------------------------


PARITY_SHAPES = [(2, 192, 4, 2, 16),    # GQA, S not a multiple of 128
                 (1, 256, 4, 4, 16)]    # MHA, block-aligned


@pytest.mark.parametrize("B,S,nh,nkv,hd", PARITY_SHAPES)
@pytest.mark.parametrize("return_kv", [False, True])
def test_attention_fwd_path_parity(B, S, nh, nkv, hd, return_kv,
                                   monkeypatch):
    """All three causal paths — fused flash kernel, query-chunked scan,
    materialized softmax — agree on the same PSG-quantized QKV, and the
    fused path tracks the fp32 oracle of its own (quantized) inputs."""
    from repro.models import layers
    monkeypatch.setattr(layers, "ATTN_Q_CHUNK", 64)  # chunk at small S
    d = nh * hd
    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=d,
                      num_heads=nh, num_kv_heads=nkv, d_ff=2 * d,
                      vocab_size=64)
    key = jax.random.PRNGKey(S + nh)
    p = layers.init_attention(key, cfg)
    x = jax.random.normal(key, (B, S, d), jnp.float32)

    def run(pcfg, prefer_chunked):
        with psg.enable(pcfg, psg.zero_probe()):
            return layers.attention_fwd(p, x, cfg,
                                        prefer_chunked=prefer_chunked,
                                        return_kv=return_kv)

    fused = run(PSGConfig(enabled=True, fused_attention=True), False)
    chunked = run(PSGConfig(enabled=True, fused_attention=False), True)
    mat = run(PSGConfig(enabled=True, fused_attention=False), False)
    if return_kv:
        (fused, (fk, fv)), (chunked, (ck, _)), (mat, _) = fused, chunked, mat
        np.testing.assert_array_equal(np.asarray(fk), np.asarray(ck))
        assert fk.shape == (B, S, nkv, hd) and fv.shape == (B, S, nkv, hd)
    # the unfused paths round the probability tensor to bf16
    # (_softmax_lowp's residual trick); the flash kernel keeps probability
    # tiles in fp32 VMEM — so parity holds at bf16-probability resolution,
    # not fp32
    tol = 2e-2 * float(jnp.max(jnp.abs(fused))) + 1e-6
    np.testing.assert_allclose(np.asarray(fused), np.asarray(chunked),
                               atol=tol)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(mat),
                               atol=tol)


def test_fused_attention_auto_resolution():
    """fused_attention=None mirrors fused_conv: on for reference/interpret,
    off for Mosaic; explicit pin always wins; disabled PSG -> inactive."""
    assert psg.fused_attention_active(None) is False
    auto = PSGConfig(enabled=True)
    with dispatch.override_backend(dispatch.BACKEND_INTERPRET):
        assert psg.fused_attention_active(auto) is True
    with dispatch.override_backend(dispatch.BACKEND_REFERENCE):
        assert psg.fused_attention_active(auto) is True
    with dispatch.override_backend(dispatch.BACKEND_MOSAIC):
        assert psg.fused_attention_active(auto) is False
        assert psg.fused_attention_active(
            PSGConfig(enabled=True, fused_attention=True)) is True
    assert psg.fused_attention_active(
        PSGConfig(enabled=True, fused_attention=False)) is False


# ---------------------------------------------------------------------------
# probe -> psg_fallback_ratio -> energy_report() from a transformer step
# ---------------------------------------------------------------------------


def test_lm_train_step_emits_attention_fallback_into_energy_report():
    """A PSG-enabled transformer train step routes attention through the
    fused kernels (auto default under the interpret backend), the probe's
    MAC-weighted fallback ratio lands in the step metrics, and
    Trainer.energy_report() consumes it as the measured PSG column."""
    from repro.data.synthetic import MarkovLMTask, make_lm_batch
    from repro.training.train_step import init_train_state
    from repro.training.trainer import Trainer

    model = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                        num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=32,
                        dtype="float32")
    e2 = E2TrainConfig(psg=PSGConfig(enabled=True, swa=False))
    exp = Experiment(model=model, e2=e2,
                     train=TrainConfig(global_batch=4, seq_len=16, lr=0.05,
                                       optimizer="psg", total_steps=3,
                                       schedule="constant"),
                     task="lm")
    task = MarkovLMTask(vocab=model.vocab_size)
    mk = lambda s, sh: make_lm_batch(task, 0, s, sh,              # noqa: E731
                                     exp.train.global_batch,
                                     exp.train.seq_len)
    tr = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk)
    hist = tr.run(3)
    assert all(np.isfinite(h["loss"]) for h in hist)
    ratios = [h["psg_fallback_ratio"] for h in hist]
    assert all(0.0 <= r <= 1.0 for r in ratios)
    fb = tr.measured_psg_fallback()
    assert fb is not None and 0.0 <= fb <= 1.0
    rep = tr.energy_report()
    assert rep.psg.measured is not None
    assert abs(rep.psg.measured - fb) < 1e-6
