"""Fault tolerance: checkpoint roundtrip, async, elastic reshard, straggler,
integrity verification against injected corruption, write-failure surfacing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import (E2TrainConfig, Experiment, ModelConfig,
                               SMDConfig, TrainConfig)
from repro.ft import faults
from repro.ft.checkpoint import (CheckpointWriteError, intact_steps,
                                 latest_intact_step, latest_step,
                                 restore_checkpoint, resume_chunk_start,
                                 save_checkpoint, verify_checkpoint,
                                 wait_for_saves)


def _state():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.ones((3,))},
            "step": jnp.int32(7)}


def test_checkpoint_roundtrip_sync():
    st = _state()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, st, 7)
        out, step = restore_checkpoint(d, st)
        assert step == 7
        np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                      np.asarray(st["params"]["w"]))


def test_checkpoint_async_and_latest():
    st = _state()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, st, 10, async_save=True)
        save_checkpoint(d, st, 20, async_save=True)
        wait_for_saves()
        assert latest_step(d) == 20
        out, step = restore_checkpoint(d, st)
        assert step == 20


def test_resume_chunk_start():
    """Chunk boundary derived from the saved step; empty dir reads as None
    (fresh run), never step 0."""
    st = _state()
    with tempfile.TemporaryDirectory() as d:
        assert resume_chunk_start(d) is None
        save_checkpoint(d, st, 23)
        assert resume_chunk_start(d) == 24
        assert resume_chunk_start(d, step=7) == 8


def test_checkpoint_shape_validation():
    st = _state()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, st, 1)
        bad = {"params": {"w": jnp.zeros((3, 3)), "b": jnp.ones((3,))},
               "step": jnp.int32(0)}
        with pytest.raises(ValueError):
            restore_checkpoint(d, bad)


def test_trainer_resume_equivalence():
    """Train 6 steps straight == train 3, checkpoint, restore, train 3."""
    from repro.data.synthetic import MarkovLMTask, make_lm_batch
    from repro.training.train_step import init_train_state
    from repro.training.trainer import Trainer

    model = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                        num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=32,
                        dtype="float32")
    exp = Experiment(model=model,
                     train=TrainConfig(global_batch=8, seq_len=16, lr=0.1,
                                       total_steps=10, schedule="constant"))
    task = MarkovLMTask(vocab=32)
    mk = lambda s, sh: make_lm_batch(task, 0, s, sh, 8, 16)

    st0 = init_train_state(jax.random.PRNGKey(0), exp)
    trA = Trainer(exp, st0, mk)
    trA.run(6)

    st1 = init_train_state(jax.random.PRNGKey(0), exp)
    trB = Trainer(exp, st1, mk)
    trB.run(3)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, trB.state, 3)
        restored, _ = restore_checkpoint(d, trB.state)
        trC = Trainer(exp, jax.tree.map(jnp.asarray, restored), mk)
        trC.run(3)
    for a, b in zip(jax.tree.leaves(trA.state.params),
                    jax.tree.leaves(trC.state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_straggler_becomes_smd_drop():
    from repro.data.synthetic import MarkovLMTask, make_lm_batch
    from repro.training.train_step import init_train_state
    from repro.training.trainer import Trainer
    model = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                        num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=32,
                        dtype="float32")
    exp = Experiment(model=model,
                     train=TrainConfig(global_batch=8, seq_len=16,
                                       total_steps=10, schedule="constant"))
    task = MarkovLMTask(vocab=32)
    mk = lambda s, sh: make_lm_batch(task, 0, s, sh, 8, 16)
    st = init_train_state(jax.random.PRNGKey(0), exp)
    tr = Trainer(exp, st, mk, deadline_s=1e-9)   # every step "straggles"
    tr.run(6)
    # every executed step arms a drop for the next -> alternating pattern
    assert tr.dropped_steps >= 2
    assert tr.executed_steps + tr.dropped_steps == 6


def test_elastic_reshard_roundtrip():
    """Reshard to a different (single-device) mesh preserves values."""
    from repro.ft.elastic import reshard_state
    from repro.launch.mesh import make_mesh
    st = _state()
    mesh = make_mesh((1, 1), ("data", "model"))
    out = reshard_state(st, mesh)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(st["params"]["w"]))


# ---------------------------------------------------------------------------
# checkpoint integrity: manifest commit, checksums, corruption fallback
# ---------------------------------------------------------------------------


def test_manifest_commits_checkpoint():
    """A committed save carries a manifest with per-leaf CRC32s and
    verifies intact."""
    import json
    st = _state()
    with tempfile.TemporaryDirectory() as d:
        path = save_checkpoint(d, st, 5)
        mpath = path + ".manifest.json"
        assert os.path.exists(mpath)
        with open(mpath) as f:
            manifest = json.load(f)
        assert manifest["step"] == 5
        assert all("crc32" in m and "shape" in m and "dtype" in m
                   for m in manifest["leaves"].values())
        ok, reason = verify_checkpoint(d, 5)
        assert ok, reason
        assert intact_steps(d) == [5]
        assert latest_intact_step(d) == 5


@pytest.mark.parametrize("mode", faults.CORRUPT_MODES)
def test_corruption_detected_and_fallback(mode):
    """Every injected corruption mode is detected by integrity verification
    and restore falls back to the previous intact step — never loads the
    damaged save, never crashes on it."""
    stA = _state()
    stB = {"params": {"w": jnp.arange(6.0).reshape(2, 3) + 100.0,
                      "b": jnp.zeros((3,))},
           "step": jnp.int32(8)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, stA, 3)
        save_checkpoint(d, stB, 7)
        faults.corrupt_checkpoint(d, 7, mode)
        ok, reason = verify_checkpoint(d, 7)
        assert not ok, f"{mode} not detected"
        assert reason
        assert verify_checkpoint(d, 3) == (True, "ok")
        assert latest_intact_step(d) == 3
        # latest_step (no verification) still sees the damaged 7 except
        # when the npz itself was removed — the gap integrity closes
        if mode != "partial":
            assert latest_step(d) == 7
        out, step = restore_checkpoint(d, stA)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                      np.asarray(stA["params"]["w"]))


def test_tamper_caught_only_by_manifest_crc():
    """The tamper mode rewrites the npz LEGITIMATELY (self-consistent zip
    container, np.load succeeds) — only the manifest's per-leaf checksum
    catches it.  This is the failure mode that justifies checkpoint-level
    CRCs over trusting the container format."""
    st = _state()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, st, 2)
        path = faults.corrupt_checkpoint(d, 2, "tamper")
        with np.load(path) as data:          # container reads fine
            assert set(data.files) == {"params::w", "params::b", "step"}
        ok, reason = verify_checkpoint(d, 2)
        assert not ok and "checksum" in reason


def test_restore_verify_false_is_legacy_path():
    """verify=False restores the raw latest step even when its manifest is
    gone (pre-integrity behavior, kept for tooling)."""
    st = _state()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, st, 4)
        faults.corrupt_checkpoint(d, 4, "partial")     # manifest deleted
        with pytest.raises(FileNotFoundError):
            restore_checkpoint(d, st)                  # verified: nothing intact
        out, step = restore_checkpoint(d, st, verify=False)
        assert step == 4


def test_restore_requested_step_falls_back_at_or_before():
    """restore_checkpoint(step=s) with a damaged s picks the newest intact
    step <= s, not a later one."""
    st = _state()
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 4, 9):
            save_checkpoint(d, st, s)
        faults.corrupt_checkpoint(d, 4, "truncate")
        _, step = restore_checkpoint(d, st, step=4)
        assert step == 1


# ---------------------------------------------------------------------------
# write-failure surfacing: retry-with-backoff, errors never die in the
# daemon thread
# ---------------------------------------------------------------------------


def test_failing_writer_retry_then_success():
    """A transient write failure (fewer failures than the retry budget) is
    absorbed by retry-with-backoff; the save lands intact."""
    from repro.ft.checkpoint import WRITE_RETRIES
    st = _state()
    with tempfile.TemporaryDirectory() as d:
        with faults.failing_writer(fails=WRITE_RETRIES - 1) as count:
            save_checkpoint(d, st, 6)
        assert count["n"] == WRITE_RETRIES - 1
        assert verify_checkpoint(d, 6) == (True, "ok")
        assert wait_for_saves() == {}


def test_failing_writer_terminal_sync_raises():
    st = _state()
    with tempfile.TemporaryDirectory() as d:
        with faults.failing_writer():                  # never recovers
            with pytest.raises(CheckpointWriteError):
                save_checkpoint(d, st, 6)
        assert intact_steps(d) == []


def test_failing_writer_terminal_async_surfaces():
    """An async write that fails post-retry surfaces through
    wait_for_saves() as CheckpointWriteError — not a silently dead daemon
    thread — and the failure record is consumed exactly once."""
    st = _state()
    with tempfile.TemporaryDirectory() as d:
        with faults.failing_writer():
            save_checkpoint(d, st, 6, async_save=True)
            with pytest.raises(CheckpointWriteError) as ei:
                wait_for_saves()
        assert len(ei.value.failures) == 1
        assert isinstance(next(iter(ei.value.failures.values())), OSError)
        assert wait_for_saves() == {}                  # consumed
        assert latest_intact_step(d) is None


def test_trainer_reports_failed_final_save():
    """Trainer._final_save under persistent write failure: the run keeps
    its history/telemetry, reports the failure in save_errors, and never
    claims the checkpoint landed."""
    from repro.data.synthetic import MarkovLMTask, make_lm_batch
    from repro.training.train_step import init_train_state
    from repro.training.trainer import Trainer
    model = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                        num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=32,
                        dtype="float32")
    exp = Experiment(model=model,
                     train=TrainConfig(global_batch=8, seq_len=16,
                                       total_steps=4, schedule="constant"))
    task = MarkovLMTask(vocab=32)
    mk = lambda s, sh: make_lm_batch(task, 0, s, sh, 8, 16)
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk,
                     checkpoint_dir=d)
        with faults.failing_writer():
            hist = tr.run(3)
        assert len(hist) == 3                          # training survived
        assert tr.save_errors                          # failure surfaced
        assert all(isinstance(e, OSError)
                   for e in tr.save_errors.values())
        assert latest_intact_step(d) is None


# ---------------------------------------------------------------------------
# elastic reshard of a REAL TrainState across mesh shapes
# ---------------------------------------------------------------------------


def test_reshard_trainstate_save_restore_roundtrip():
    """A real TrainState round-trips save -> restore -> reshard onto a
    (1,1) CPU mesh with the param tree bit-identical, placed under the new
    mesh's shardings; the same sharding specs resolve on a differently-
    shaped device-free AbstractMesh (the shape-planning path a shrunk
    restart uses before devices exist)."""
    from jax.sharding import NamedSharding
    from repro.distributed.sharding import (make_abstract_mesh,
                                            state_shardings)
    from repro.ft.elastic import reshard_state
    from repro.launch.mesh import make_mesh
    from repro.training.train_step import init_train_state
    model = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                        num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=32,
                        dtype="float32")
    exp = Experiment(model=model,
                     train=TrainConfig(global_batch=8, seq_len=16,
                                       total_steps=4, schedule="constant"))
    st = init_train_state(jax.random.PRNGKey(0), exp)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, st, 0)
        restored, _ = restore_checkpoint(d, st)
    mesh = make_mesh((1, 1), ("data", "model"))
    out = reshard_state(restored, mesh)
    for a, b in zip(jax.tree.leaves(st.params), jax.tree.leaves(out.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for leaf in jax.tree.leaves(out.params):
        assert isinstance(leaf.sharding, NamedSharding)
        assert leaf.sharding.mesh.shape == {"data": 1, "model": 1}
    # the rule engine resolves shardings for a 4x2 world it has no devices
    # for — the divisibility fallback guarantees a valid placement exists
    amesh = make_abstract_mesh((4, 2), ("data", "model"))
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          restored)
    sh = state_shardings(shapes, amesh)
    assert len(jax.tree.leaves(sh)) == len(jax.tree.leaves(shapes))


# ---------------------------------------------------------------------------
# straggler accounting in telemetry
# ---------------------------------------------------------------------------


def test_straggler_drops_counted_and_reported():
    """Forced straggler drops are counted separately (a subset of the SMD
    drop count) and surface through energy_report telemetry."""
    from repro.data.synthetic import MarkovLMTask, make_lm_batch
    from repro.training.train_step import init_train_state
    from repro.training.trainer import Trainer
    model = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                        num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=32,
                        dtype="float32")
    exp = Experiment(model=model,
                     train=TrainConfig(global_batch=8, seq_len=16,
                                       total_steps=10, schedule="constant"))
    task = MarkovLMTask(vocab=32)
    mk = lambda s, sh: make_lm_batch(task, 0, s, sh, 8, 16)
    tr = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk,
                 deadline_s=1e-9)                      # everything straggles
    tr.run(6)
    assert tr.straggler_dropped_steps >= 2
    assert tr.straggler_dropped_steps <= tr.dropped_steps
    rep = tr.energy_report(steps=6)
    assert rep.straggler_dropped == tr.straggler_dropped_steps
    # no deadline -> no straggler drops reported
    tr2 = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk)
    tr2.run(4)
    assert tr2.energy_report(steps=4).straggler_dropped == 0
