"""Fault tolerance: checkpoint roundtrip, async, elastic reshard, straggler."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import (E2TrainConfig, Experiment, ModelConfig,
                               SMDConfig, TrainConfig)
from repro.ft.checkpoint import (latest_step, restore_checkpoint,
                                 resume_chunk_start, save_checkpoint,
                                 wait_for_saves)


def _state():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.ones((3,))},
            "step": jnp.int32(7)}


def test_checkpoint_roundtrip_sync():
    st = _state()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, st, 7)
        out, step = restore_checkpoint(d, st)
        assert step == 7
        np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                      np.asarray(st["params"]["w"]))


def test_checkpoint_async_and_latest():
    st = _state()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, st, 10, async_save=True)
        save_checkpoint(d, st, 20, async_save=True)
        wait_for_saves()
        assert latest_step(d) == 20
        out, step = restore_checkpoint(d, st)
        assert step == 20


def test_resume_chunk_start():
    """Chunk boundary derived from the saved step; empty dir reads as None
    (fresh run), never step 0."""
    st = _state()
    with tempfile.TemporaryDirectory() as d:
        assert resume_chunk_start(d) is None
        save_checkpoint(d, st, 23)
        assert resume_chunk_start(d) == 24
        assert resume_chunk_start(d, step=7) == 8


def test_checkpoint_shape_validation():
    st = _state()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, st, 1)
        bad = {"params": {"w": jnp.zeros((3, 3)), "b": jnp.ones((3,))},
               "step": jnp.int32(0)}
        with pytest.raises(ValueError):
            restore_checkpoint(d, bad)


def test_trainer_resume_equivalence():
    """Train 6 steps straight == train 3, checkpoint, restore, train 3."""
    from repro.data.synthetic import MarkovLMTask, make_lm_batch
    from repro.training.train_step import init_train_state
    from repro.training.trainer import Trainer

    model = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                        num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=32,
                        dtype="float32")
    exp = Experiment(model=model,
                     train=TrainConfig(global_batch=8, seq_len=16, lr=0.1,
                                       total_steps=10, schedule="constant"))
    task = MarkovLMTask(vocab=32)
    mk = lambda s, sh: make_lm_batch(task, 0, s, sh, 8, 16)

    st0 = init_train_state(jax.random.PRNGKey(0), exp)
    trA = Trainer(exp, st0, mk)
    trA.run(6)

    st1 = init_train_state(jax.random.PRNGKey(0), exp)
    trB = Trainer(exp, st1, mk)
    trB.run(3)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, trB.state, 3)
        restored, _ = restore_checkpoint(d, trB.state)
        trC = Trainer(exp, jax.tree.map(jnp.asarray, restored), mk)
        trC.run(3)
    for a, b in zip(jax.tree.leaves(trA.state.params),
                    jax.tree.leaves(trC.state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_straggler_becomes_smd_drop():
    from repro.data.synthetic import MarkovLMTask, make_lm_batch
    from repro.training.train_step import init_train_state
    from repro.training.trainer import Trainer
    model = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                        num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=32,
                        dtype="float32")
    exp = Experiment(model=model,
                     train=TrainConfig(global_batch=8, seq_len=16,
                                       total_steps=10, schedule="constant"))
    task = MarkovLMTask(vocab=32)
    mk = lambda s, sh: make_lm_batch(task, 0, s, sh, 8, 16)
    st = init_train_state(jax.random.PRNGKey(0), exp)
    tr = Trainer(exp, st, mk, deadline_s=1e-9)   # every step "straggles"
    tr.run(6)
    # every executed step arms a drop for the next -> alternating pattern
    assert tr.dropped_steps >= 2
    assert tr.executed_steps + tr.dropped_steps == 6


def test_elastic_reshard_roundtrip():
    """Reshard to a different (single-device) mesh preserves values."""
    from repro.ft.elastic import reshard_state
    from repro.launch.mesh import make_mesh
    st = _state()
    mesh = make_mesh((1, 1), ("data", "model"))
    out = reshard_state(st, mesh)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(st["params"]["w"]))
