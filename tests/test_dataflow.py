"""Jaxpr dataflow engine (analysis/dataflow.py): precision provenance
through elementwise ops, reductions, control flow and Pallas kernels."""
import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis.dataflow import (ADD_CHAIN_SITE, acc_is_narrow, analyze)

S = jax.ShapeDtypeStruct


def hazards(fn, *args):
    return analyze(fn, *args).hazards()


# ---------------------------------------------------------------------------
# the narrowness predicate
# ---------------------------------------------------------------------------


def test_acc_narrowness_is_itemsize_under_32_bits():
    assert acc_is_narrow("bfloat16")
    assert acc_is_narrow("float16")
    assert acc_is_narrow("int8")
    assert acc_is_narrow("int16")
    assert not acc_is_narrow("float32")
    assert not acc_is_narrow("int32")
    assert not acc_is_narrow("float64")


# ---------------------------------------------------------------------------
# reductions
# ---------------------------------------------------------------------------


def test_f32_dot_records_a_site_but_no_hazard():
    r = analyze(lambda a, b: a @ b,
                S((8, 8), jnp.float32), S((8, 8), jnp.float32))
    assert any(s.kind == "dot_general" for s in r.sites)
    assert r.hazards() == []


def test_bf16_dot_accumulating_in_bf16_is_a_hazard():
    (h,) = hazards(lambda a, b: a @ b,
                   S((8, 8), jnp.bfloat16), S((8, 8), jnp.bfloat16))
    assert h.kind == "dot_general"
    assert h.acc_dtype == "bfloat16"
    assert "bfloat16" in h.narrow_operands


def test_bf16_dot_with_f32_preferred_accumulator_is_clean():
    def f(a, b):
        return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)
    assert hazards(f, S((8, 8), jnp.bfloat16), S((8, 8), jnp.bfloat16)) == []


def test_jnp_sum_upcast_accumulation_is_correctly_clean():
    # jnp.sum of bf16 converts to f32, reduces, converts back: the
    # accumulator really is f32, so the engine must NOT flag it
    assert hazards(lambda a: jnp.sum(a), S((64,), jnp.bfloat16)) == []


def test_lax_reduce_in_bf16_is_a_hazard():
    def f(a):
        return jax.lax.reduce(a, jnp.bfloat16(0), jax.lax.add, (0,))
    (h,) = hazards(f, S((64,), jnp.bfloat16))
    assert h.kind == "reduce_sum" and h.acc_dtype == "bfloat16"


def test_narrow_provenance_survives_upcast():
    # bf16 -> f32 -> f16 reduce: operand lineage still carries bfloat16
    def f(a):
        v = a.astype(jnp.float32).astype(jnp.float16)
        return jax.lax.reduce(v, jnp.float16(0), jax.lax.add, (0,))
    (h,) = hazards(f, S((64,), jnp.bfloat16))
    assert set(h.narrow_operands) >= {"bfloat16", "float16"}


def test_scatter_add_in_narrow_dtype_is_a_hazard():
    def f(acc, upd):
        return acc.at[2:6].add(upd)
    (h,) = hazards(f, S((16,), jnp.bfloat16), S((4,), jnp.bfloat16))
    assert h.kind == "scatter-add" and h.acc_dtype == "bfloat16"


# ---------------------------------------------------------------------------
# additive chains (unrolled accumulation loops)
# ---------------------------------------------------------------------------


def test_add_chain_crossing_threshold_is_flagged():
    assert ADD_CHAIN_SITE == 3
    hz = hazards(lambda a: a + a + a + a + a, S((4,), jnp.bfloat16))
    assert [h.kind for h in hz] == ["add-chain"]


def test_short_add_runs_are_not_flagged():
    # a residual add or a bias add must never be a finding
    assert hazards(lambda a: a + a + a, S((4,), jnp.bfloat16)) == []


# ---------------------------------------------------------------------------
# control flow
# ---------------------------------------------------------------------------


def test_scan_carry_running_sum_in_bf16_is_a_hazard():
    def f(xs):
        def body(c, x):
            return c + x, x
        return jax.lax.scan(body, jnp.zeros((4,), jnp.bfloat16), xs)[0]
    hz = hazards(f, S((10, 4), jnp.bfloat16))
    assert any(h.kind == "scan-carry" and h.acc_dtype == "bfloat16"
               for h in hz)


def test_scan_carry_running_sum_in_f32_is_clean():
    def f(xs):
        def body(c, x):
            return c + x.astype(jnp.float32), x
        return jax.lax.scan(body, jnp.zeros((4,), jnp.float32), xs)[0]
    assert hazards(f, S((10, 4), jnp.bfloat16)) == []


def test_pass_through_scan_carry_is_not_an_accumulation():
    def f(xs):
        def body(c, x):
            return c * 0.5, x          # no additive feedback
        return jax.lax.scan(body, jnp.zeros((4,), jnp.bfloat16), xs)[0]
    r = analyze(f, S((10, 4), jnp.bfloat16))
    assert not any(s.kind == "scan-carry" for s in r.sites)


def test_while_carry_sum_in_narrow_dtype_is_a_hazard():
    def f(x):
        def cond(cv):
            return cv[0] < 10
        def body(cv):
            i, acc = cv
            return i + 1, acc + acc * jnp.bfloat16(0.5)
        return jax.lax.while_loop(cond, body, (0, x))
    hz = hazards(f, S((4,), jnp.bfloat16))
    assert any(h.kind == "scan-carry" and h.prim == "while" for h in hz)


def test_cond_branches_join_narrow_lineage():
    # one branch is pure f32, the other descends from bf16 — the joined
    # value must carry bfloat16 lineage into the downstream reduction
    def f(p, a32, b16):
        v = jax.lax.cond(p, lambda: a32, lambda: b16.astype(jnp.float32))
        return jax.lax.reduce(v.astype(jnp.float16), jnp.float16(0),
                              jax.lax.add, (0,))
    (h,) = hazards(f, S((), jnp.bool_), S((8,), jnp.float32),
                   S((8,), jnp.bfloat16))
    assert "bfloat16" in h.narrow_operands


# ---------------------------------------------------------------------------
# pallas kernels: the lattice flows through refs
# ---------------------------------------------------------------------------


def _accum_kernel_fn(acc_dtype):
    def kernel(x_ref, o_ref, acc_ref):
        @pl.when(pl.program_id(0) == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
        acc_ref[...] += x_ref[...].astype(acc_dtype)
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)

    def f(x):
        return pl.pallas_call(
            kernel, grid=(2,),
            in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((8, 128), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((8, 128), jnp.bfloat16),
            scratch_shapes=[pltpu.VMEM((8, 128), acc_dtype)],
            interpret=True)(x)
    return f


def test_pallas_scratch_accumulator_following_operand_dtype_is_caught():
    hz = hazards(_accum_kernel_fn(jnp.bfloat16), S((16, 128), jnp.bfloat16))
    assert any(h.kind == "ref-accum" and h.acc_dtype == "bfloat16"
               for h in hz)


def test_pallas_f32_scratch_accumulator_is_clean():
    assert hazards(_accum_kernel_fn(jnp.float32),
                   S((16, 128), jnp.bfloat16)) == []


def test_pallas_plain_overwrite_is_not_an_accumulation():
    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2
    def f(x):
        return pl.pallas_call(
            kernel, grid=(2,),
            in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((16, 128), jnp.bfloat16),
            interpret=True)(x)
    r = analyze(f, S((16, 128), jnp.bfloat16))
    assert not any(s.kind == "ref-accum" for s in r.sites)
    assert r.hazards() == []


# ---------------------------------------------------------------------------
# plumbing
# ---------------------------------------------------------------------------


def test_analyze_accepts_shape_dtype_structs_and_never_executes():
    # a shape that would be prohibitively large if materialized
    r = analyze(lambda a, b: a @ b,
                S((1 << 16, 1 << 12), jnp.bfloat16),
                S((1 << 12, 1 << 14), jnp.bfloat16))
    assert len(r.hazards()) == 1


def test_sites_record_origin_of_narrowness():
    (h,) = hazards(lambda a, b: a @ b,
                   S((8, 8), jnp.bfloat16), S((8, 8), jnp.bfloat16))
    assert "bfloat16" in h.origin


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_wide_programs_produce_no_hazards(dtype):
    def f(a):
        return jnp.cumsum(a) + a
    assert hazards(f, S((16,), dtype)) == []
