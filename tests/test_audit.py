"""Three-way cost audit: CostModel vs traced jaxpr vs compiled HLO
(analysis/audit.py, DESIGN.md §Analysis)."""
import dataclasses

import pytest

from repro.analysis import audit_experiment
from repro.analysis.audit import TOL_BY_TASK, _group_of
from repro.configs import smoke_experiment
from repro.configs.paper_cnns import mobilenetv2, resnet74, resnet110

# the figure the literature reports as "253 MFLOPs" for CIFAR ResNet-110;
# also pinned against the table in tests/test_cost.py
RESNET110_MACS = 253_149_824


def test_resnet110_jaxpr_macs_match_pinned_exactly():
    """The traced predict program's contraction MACs reproduce the pinned
    literature count exactly — not within tolerance, exactly: the table and
    the trace count the same convolutions."""
    rep = audit_experiment(resnet110(), batch=2, with_hlo=False)
    assert int(round(rep.jaxpr_total_macs)) == RESNET110_MACS
    assert int(round(rep.cost_total_macs)) == RESNET110_MACS


@pytest.mark.parametrize("factory", [resnet74, resnet110, mobilenetv2])
def test_cnn_table_matches_trace_within_tolerance(factory):
    """Property: conv+fc MAC totals of the CostModel match the jaxpr-derived
    FLOPs/2 within the declared cifar_cnn tolerance, per layer group."""
    rep = audit_experiment(factory(), batch=2, with_hlo=False)
    assert rep.tolerance == TOL_BY_TASK["cifar_cnn"]
    bad = [r for r in rep.rows if not r.ok]
    assert not bad, rep.summary()
    assert rep.jaxpr_unknown_trips == 0
    rel = (abs(rep.cost_total_macs - rep.jaxpr_total_macs)
           / max(rep.cost_total_macs, rep.jaxpr_total_macs))
    assert rel <= rep.tolerance


def test_lm_analytic_table_matches_trace():
    rep = audit_experiment(smoke_experiment("llama3_8b"), batch=2,
                           with_hlo=False)
    assert rep.passed, rep.failures()
    groups = {r.group for r in rep.rows}
    assert {"embed", "unit", "head"} <= groups


def test_hlo_totals_reconcile_on_smoke_lm():
    """The compiled-HLO column: totals agree with the walked jaxpr within
    the HLO tolerance and no while loop has an unknown trip count."""
    rep = audit_experiment(smoke_experiment("llama3_8b"), batch=2)
    assert rep.hlo_total_flops is not None
    assert rep.hlo_unknown_trips == 0
    assert rep.hlo_rel_diff <= rep.hlo_tolerance
    assert rep.passed, rep.failures()


def test_forgotten_table_layer_fails_none_is_not_zero(monkeypatch):
    """A layer the table prices but the trace never runs must FAIL the
    audit (None ≠ 0), not silently reconcile."""
    import repro.tasks as tasks
    from repro.core.cost import LayerCost, TableCostModel

    real = tasks.cost_model

    def with_ghost(exp):
        cost = real(exp)
        ghost = LayerCost("ghost", "fc", 1e6, 0, 0.0)
        return TableCostModel(cost.name, cost.layers + (ghost,))

    monkeypatch.setattr(tasks, "cost_model", with_ghost)
    rep = audit_experiment(resnet74(), batch=2, with_hlo=False)
    assert not rep.passed
    (row,) = [r for r in rep.rows if r.group == "ghost"]
    assert row.cost_macs == 1e6 and row.jaxpr_macs is None and not row.ok


def test_group_mapping_mirrors_model_scopes():
    assert _group_of("s1b0.conv1", "cifar_cnn") == "s1.trans"
    assert _group_of("s1b3.conv2", "cifar_cnn") == "s1.rest"
    assert _group_of("stem_bn", "cifar_cnn") == "stem"
    assert _group_of("b4.dw", "cifar_cnn") == "b4.dw"
    assert _group_of("b4.expand", "cifar_cnn") == "b4"
    assert _group_of("block7.attn", "lm") == "unit"
    assert _group_of("head", "lm") == "head"


def test_report_round_trips_to_dict():
    rep = audit_experiment(resnet74(), batch=2, with_hlo=False)
    d = rep.to_dict()
    assert d["passed"] is True
    assert d["rows"] and all("group" in r for r in d["rows"])
    assert dataclasses.asdict(rep)  # frozen dataclass stays serializable
