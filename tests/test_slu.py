"""SLU: gates, regularizer (Eq. 1), actual skipping, vs stochastic depth."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import slu
from repro.core.config import (E2TrainConfig, Experiment, ModelConfig,
                               SLUConfig, TrainConfig)


def test_gate_outputs_probability():
    scfg = SLUConfig(enabled=True)
    gp = slu.init_gate(jax.random.PRNGKey(0), 32, scfg)
    st = slu.init_gate_state(scfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    p, st2 = slu.gate_apply(gp, x, st, scfg)
    assert scfg.min_keep_prob <= float(p) <= 1.0
    assert st2[0].shape == (scfg.gate_hidden,)


def test_gate_pads_narrow_inputs():
    """One weight-shared gate serves narrower (early-stage CNN) inputs by
    zero-padding the pooled features up to the gate's projection width."""
    scfg = SLUConfig(enabled=True)
    gp = slu.init_gate(jax.random.PRNGKey(0), 64, scfg)
    st = slu.init_gate_state(scfg)
    narrow = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 4, 16))
    p, _ = slu.gate_apply(gp, narrow, st, scfg)
    assert scfg.min_keep_prob <= float(p) <= 1.0
    # padding is exactly zero-extension: a pre-padded input agrees
    pooled = jnp.mean(narrow, axis=(0, 1, 2))
    wide = jnp.pad(pooled, (0, 48))[None, None, None, :]
    p2, _ = slu.gate_apply(gp, wide, st, scfg)
    np.testing.assert_allclose(float(p), float(p2), rtol=1e-6)


def test_gated_residual_skip_and_keep():
    x = jnp.ones((2, 4))
    block = lambda h: 2 * h
    # p=1 & forced keep -> executes
    out, ex = slu.gated_residual(block, x, jnp.float32(1.0),
                                 jax.random.PRNGKey(0), jnp.bool_(True))
    np.testing.assert_allclose(np.asarray(out), 3.0, rtol=1e-6)
    assert float(ex) == 1.0
    # p=min & not forced: with key sweep, some skip (identity)
    skipped = 0
    for i in range(20):
        out, ex = slu.gated_residual(block, x, jnp.float32(0.05),
                                     jax.random.PRNGKey(i), jnp.bool_(False))
        if float(ex) == 0.0:
            np.testing.assert_allclose(np.asarray(out), 1.0)
            skipped += 1
    assert skipped >= 15


def test_gate_gradient_flows_through_st():
    """Straight-through: task loss produces d(loss)/d(gate params) != 0."""
    scfg = SLUConfig(enabled=True)
    gp = slu.init_gate(jax.random.PRNGKey(0), 32, scfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))

    def loss(gp):
        p, _ = slu.gate_apply(gp, x, slu.init_gate_state(scfg), scfg)
        out, _ = slu.gated_residual(lambda h: h * 2, x, p,
                                    jax.random.PRNGKey(3), jnp.bool_(True))
        return jnp.sum(out ** 2) + 0.1 * p

    g = jax.grad(loss)(gp)
    total = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(g))
    assert total > 0.0


def test_flops_regularizer_normalized():
    kp = jnp.array([1.0, 0.5, 0.0, 1.0])
    fl = jnp.array([10.0, 10.0, 10.0, 10.0])
    c = slu.flops_regularizer(kp, fl, SLUConfig(enabled=True))
    assert abs(float(c) - 2.5 / 4.0) < 1e-6


@pytest.mark.slow
def test_slu_alpha_drives_skipping():
    """Eq. 1: larger alpha -> lower average keep prob after training."""
    model = ModelConfig(name="t", family="dense", num_layers=4, d_model=64,
                        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                        dtype="float32")

    def run(alpha):
        exp = Experiment(
            model=model,
            e2=E2TrainConfig(slu=SLUConfig(enabled=True, alpha=alpha,
                                           never_skip_first_last=False)),
            train=TrainConfig(global_batch=16, seq_len=32, lr=0.1,
                              total_steps=60, schedule="constant"))
        from repro.data.synthetic import MarkovLMTask, make_lm_batch
        from repro.training.train_step import init_train_state
        from repro.training.trainer import Trainer
        task = MarkovLMTask(vocab=64)
        mk = lambda s, sh: make_lm_batch(task, 0, s, sh, 16, 32)
        st = init_train_state(jax.random.PRNGKey(0), exp)
        tr = Trainer(exp, st, mk)
        hist = tr.run(60)
        return np.mean([h["slu_cost"] for h in hist[-10:]])

    low, high = run(0.001), run(5.0)
    assert high < low, (low, high)
