"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")   # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core.config import ModelConfig, PSGConfig, SMDConfig


# ---------------------------------------------------------------------------
# MoE invariants
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50), E=st.sampled_from([2, 4, 8]),
       k=st.integers(1, 2), cf=st.floats(0.25, 2.0))
def test_moe_combine_weights_bounded(seed, E, k, cf):
    """Per-token combine mass <= 1 (== 1 when nothing dropped)."""
    from repro.models import moe
    cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=2, d_ff=16, vocab_size=8,
                      num_experts=E, top_k=min(k, E), moe_d_ff=16,
                      capacity_factor=cf, dtype="float32")
    p = moe.init_moe(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(seed), 1),
                          (2, 8, 16))
    y, aux = moe.moe_fwd(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) >= 0.0
    # identity-ish check: output magnitude bounded by expert lipschitz-ish
    assert float(jnp.max(jnp.abs(y))) < 1e3


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50))
def test_moe_capacity_enforced(seed):
    """No expert receives more than C tokens per group (dispatch mass)."""
    from repro.models import moe
    cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=2, d_ff=16, vocab_size=8,
                      num_experts=4, top_k=2, moe_d_ff=16,
                      capacity_factor=0.5, dtype="float32")
    # reproduce dispatch internals at small scale
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 16, 16))
    p = moe.init_moe(jax.random.PRNGKey(seed + 1), cfg)
    y, _ = moe.moe_fwd(p, x, cfg)     # no assertion error => shapes consistent
    assert y.shape == x.shape


# ---------------------------------------------------------------------------
# RoPE / attention invariants
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50), shift=st.integers(1, 16))
def test_rope_relative_property(seed, shift):
    """<rope(q,i), rope(k,j)> depends only on i - j."""
    from repro.models.layers import apply_rope
    hd = 32
    q = jax.random.normal(jax.random.PRNGKey(seed), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(seed), 1),
                          (1, 1, 1, hd))
    def dot_at(i, j):
        qi = apply_rope(q, jnp.array([[i]]), 1e4)
        kj = apply_rope(k, jnp.array([[j]]), 1e4)
        return float(jnp.sum(qi * kj))
    a = dot_at(3, 1)
    b = dot_at(3 + shift, 1 + shift)
    assert abs(a - b) < 1e-3


def test_attention_permutation_equivariance_over_batch():
    from repro.models import layers
    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=32, vocab_size=8,
                      dtype="float32")
    p = layers.init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))
    y = layers.attention_fwd(p, x, cfg)
    perm = jnp.array([2, 0, 3, 1])
    y2 = layers.attention_fwd(p, x[perm], cfg)
    np.testing.assert_allclose(np.asarray(y[perm]), np.asarray(y2),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# SMD statistics
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), p=st.floats(0.2, 0.8))
def test_smd_drop_rate_binomial_bound(seed, p):
    from repro.core.smd import smd_schedule
    n = 600
    sched = smd_schedule(SMDConfig(enabled=True, drop_prob=p), seed, n)
    rate = 1.0 - sched.mean()
    # 4-sigma binomial bound
    sigma = (p * (1 - p) / n) ** 0.5
    assert abs(rate - p) < 4 * sigma + 0.02


# ---------------------------------------------------------------------------
# error feedback
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50))
def test_error_feedback_residual_bounded(seed):
    """EF residual stays bounded (contraction property)."""
    from repro.optim.error_feedback import ef_compress, ef_init
    g = {"w": jax.random.normal(jax.random.PRNGKey(seed), (32,))}
    st_ = ef_init(g)
    for i in range(50):
        gi = {"w": jax.random.normal(
            jax.random.fold_in(jax.random.PRNGKey(seed), i), (32,))}
        payload, st_ = ef_compress(gi, st_)
        assert set(np.unique(np.asarray(payload["w"]))) <= {-1.0, 0.0, 1.0}
    res = float(jnp.max(jnp.abs(st_["residual"]["w"])))
    assert res < 50.0    # bounded, not exploding


def test_error_feedback_preserves_signal():
    """Constant gradient: EF-sign average direction converges to sign(g)."""
    from repro.optim.error_feedback import ef_compress, ef_init
    g = {"w": jnp.array([0.3, -2.0, 0.01])}
    st_ = ef_init(g)
    acc = jnp.zeros(3)
    for _ in range(100):
        payload, st_ = ef_compress(g, st_)
        acc = acc + payload["w"]
    a = np.asarray(acc)
    # dominant coordinates: direction preserved; tiny coordinate oscillates
    # around zero by design (residual bounces across the sign boundary)
    assert a[0] > 0 and a[1] < 0
    assert abs(a[2]) <= 100


# ---------------------------------------------------------------------------
# energy model monotonicity
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(b1=st.integers(2, 16), b2=st.integers(2, 16))
def test_mac_energy_monotone_in_bits(b1, b2):
    from repro.core.energy import mult_energy_pj
    lo, hi = sorted((b1, b2))
    assert mult_energy_pj(lo, 8) <= mult_energy_pj(hi, 8)


@settings(max_examples=20, deadline=None)
@given(smd=st.floats(0.3, 1.0), skip=st.floats(0.0, 0.9))
def test_computational_savings_monotone(smd, skip):
    from repro.core.energy import computational_savings
    s = computational_savings(smd, skip)
    assert 0.0 <= s <= 1.0
    assert computational_savings(smd, min(skip + 0.05, 0.95)) >= s


# ---------------------------------------------------------------------------
# EnergyReport (ledger) monotonicity in measured telemetry
# ---------------------------------------------------------------------------


def _measured_report(slu_exec: float, psg_fb: float):
    from repro.configs.paper_cnns import resnet74
    from repro.core.config import (E2TrainConfig, PSGConfig, SLUConfig,
                                   SMDConfig)
    from repro.core.ledger import EnergyLedger
    e2 = E2TrainConfig(smd=SMDConfig(enabled=True, drop_prob=0.5),
                       slu=SLUConfig(enabled=True, target_skip=0.2),
                       psg=PSGConfig(enabled=True))
    led = EnergyLedger(resnet74(e2=e2))
    for _ in range(4):
        led.record_step({"slu_exec_ratio": slu_exec,
                         "psg_fallback_ratio": psg_fb})
    for _ in range(4):
        led.record_dropped()
    return led.report(steps=8)


@settings(max_examples=15, deadline=None)
@given(ex=st.floats(0.1, 0.9), fb=st.floats(0.0, 0.9))
def test_energy_report_monotone_in_slu_skip_and_psg_fallback(ex, fb):
    """More SLU skipping (lower execution) -> more savings; more PSG
    fallback (full-precision products) -> less savings.  Holds for both the
    composition (MAC) and the 45nm (pJ) columns."""
    base = _measured_report(ex, fb)
    more_skip = _measured_report(max(ex - 0.05, 0.0), fb)
    more_fb = _measured_report(ex, min(fb + 0.05, 1.0))
    for a in (base, more_skip, more_fb):
        assert 0.0 <= a.computational_savings_measured <= 1.0
        assert a.energy_savings_measured is not None
    assert more_skip.computational_savings_measured >= \
        base.computational_savings_measured
    assert more_skip.energy_savings_measured >= base.energy_savings_measured
    assert more_fb.computational_savings_measured <= \
        base.computational_savings_measured
    assert more_fb.energy_savings_measured <= base.energy_savings_measured
