"""Loop-aware HLO cost analyzer: trip counts, dot FLOPs, collectives."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_cost as H

SYNTH = """
HloModule m

%cond (arg: (s32[], f32[8,16])) -> pred[] {
  %arg = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %n = s32[] constant(10)
  ROOT %cmp = pred[] compare(%i, %n), direction=LT
}

%body (arg2: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %arg2 = (s32[], f32[8,16]) parameter(0)
  %j = s32[] get-tuple-element(%arg2), index=0
  %x = f32[8,16] get-tuple-element(%arg2), index=1
  %w = f32[16,16] constant(0)
  %y = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16] all-reduce(%y), replica_groups={}
  %one = s32[] constant(1)
  %j2 = s32[] add(%j, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%j2, %ar)
}

ENTRY %main (p0: f32[8,16]) -> f32[8,16] {
  %p0 = f32[8,16] parameter(0)
  %c0 = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%c0, %p0)
  %w = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,16] get-tuple-element(%w), index=1
}
"""


def test_synthetic_while_trip_and_dot_flops():
    t = H.analyze(SYNTH)
    # dot: 2 * 8*16 * 16 = 4096 flops per trip, 10 trips
    assert t["flops"] >= 10 * 4096
    assert t["flops"] < 10 * 4096 + 200      # small elementwise slack
    # all-reduce payload: 8*16*4 bytes * 10 trips
    assert t["collective_bytes"] == 10 * 8 * 16 * 4


def test_trip_count_uses_compare_constant():
    comps = H.split_computations(SYNTH)
    assert H._trip_count(comps["cond"]) == (10, True)
    t = H.analyze(SYNTH)
    assert t["unknown_trip_count"] == 0


def test_unknown_trip_count_flagged_not_silent():
    """A while whose condition exposes no compare constant must be counted
    once AND surfaced in the totals, never silently trusted."""
    hlo = """
%cond (arg: (pred[], f32[8,16])) -> pred[] {
  %arg = (pred[], f32[8,16]) parameter(0)
  ROOT %p = pred[] get-tuple-element(%arg), index=0
}

%body (arg2: (pred[], f32[8,16])) -> (pred[], f32[8,16]) {
  %arg2 = (pred[], f32[8,16]) parameter(0)
  %p = pred[] get-tuple-element(%arg2), index=0
  %x = f32[8,16] get-tuple-element(%arg2), index=1
  %w = f32[16,16] constant(0)
  %y = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (pred[], f32[8,16]) tuple(%p, %y)
}

ENTRY %main (p0: f32[8,16], c0: pred[]) -> f32[8,16] {
  %p0 = f32[8,16] parameter(0)
  %c0 = pred[] parameter(1)
  %init = (pred[], f32[8,16]) tuple(%c0, %p0)
  %w = (pred[], f32[8,16]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,16] get-tuple-element(%w), index=1
}
"""
    comps = H.split_computations(hlo)
    trips, known = H._trip_count(comps["cond"])
    assert trips == 1 and not known
    t = H.analyze(hlo)
    assert t["unknown_trip_count"] == 1


def test_real_scan_flops_close_to_analytic():
    """jit a scanned matmul chain and check the analyzer's FLOPs."""
    w = jnp.zeros((8, 64, 64), jnp.float32)

    def f(x, w):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    hlo = jax.jit(f).lower(jnp.zeros((32, 64)), w).compile().as_text()
    t = H.analyze(hlo)
    want = 8 * 2 * 32 * 64 * 64        # 8 trips x matmul flops
    assert 0.8 * want <= t["flops"] <= 1.6 * want, (t["flops"], want)


def test_computation_splitting_handles_tuple_params():
    comps = H.split_computations(SYNTH)
    assert set(comps) == {"cond", "body", "main"}
    assert "dot" in " ".join(comps["body"].lines)


def test_fusion_slice_io_not_charged_full_stack():
    hlo = """
%fused_slice (param_0: f32[100,64], param_1: s32[]) -> f32[1,64] {
  %param_0 = f32[100,64] parameter(0)
  %param_1 = s32[] parameter(1)
  %z = s32[] constant(0)
  ROOT %ds = f32[1,64] dynamic-slice(%param_0, %param_1, %z), dynamic_slice_sizes={1,64}
}

ENTRY %main (a: f32[100,64], i: s32[]) -> f32[1,64] {
  %a = f32[100,64] parameter(0)
  %i = s32[] parameter(1)
  ROOT %f = f32[1,64] fusion(%a, %i), kind=kLoop, calls=%fused_slice
}
"""
    t = H.analyze(hlo)
    # charged: result (1*64*4) + slice read (1*64*4), NOT the 100x64 stack
    assert t["bytes"] <= 3 * 64 * 4
