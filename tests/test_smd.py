"""SMD: determinism, energy accounting, and the paper's SMD>=SMB claim."""
import dataclasses

import jax
import numpy as np
import pytest
pytest.importorskip("hypothesis")   # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core.config import (E2TrainConfig, Experiment, ModelConfig,
                               SMDConfig, TrainConfig)
from repro.core.smd import (SMDIterator, expected_energy_ratio, smd_keep_host,
                            smd_schedule)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), step=st.integers(0, 10000))
def test_smd_decision_deterministic(seed, step):
    """Counter-based: every host computes the same decision (straggler/FT)."""
    a = smd_keep_host(seed, step, 0.5)
    b = smd_keep_host(seed, step, 0.5)
    assert a == b


def test_smd_drop_rate():
    sched = smd_schedule(SMDConfig(enabled=True, drop_prob=0.5), 0, 2000)
    rate = 1.0 - sched.mean()
    assert 0.45 < rate < 0.55


def test_smd_energy_ratio_paper_operating_point():
    """Paper Fig. 3a: SMD at 1.33x epochs = 0.67 energy ratio."""
    cfg = SMDConfig(enabled=True, drop_prob=0.5)
    assert abs(expected_energy_ratio(cfg, 4.0 / 3.0) - 2.0 / 3.0) < 1e-9


def test_smd_iterator_skips_without_fetch():
    fetched = []

    def gen():
        i = 0
        while True:
            fetched.append(i)
            yield i
            i += 1

    it = SMDIterator(gen(), SMDConfig(enabled=True, drop_prob=0.5), seed=0)
    out = [next(it) for _ in range(100)]
    dropped = sum(1 for _, b in out if b is None)
    assert dropped > 20
    assert len(fetched) == 100 - dropped  # dropped steps never fetched


def _train(exp, steps, seed=0):
    from repro.data.synthetic import MarkovLMTask, make_lm_batch
    from repro.training.train_step import init_train_state
    from repro.training.trainer import Trainer
    task = MarkovLMTask(vocab=exp.model.vocab_size)
    mk = lambda s, sh: make_lm_batch(task, 0, s, sh, exp.train.global_batch,
                                     exp.train.seq_len)
    state = init_train_state(jax.random.PRNGKey(seed), exp)
    tr = Trainer(exp, state, mk)
    hist = tr.run(steps)
    return hist, tr


@pytest.mark.slow
def test_smd_vs_smb_matched_budget():
    """Paper §4.2: at the same executed-step budget, SMD (spread over more
    nominal steps, sampling-with-replacement) matches or beats SMB."""
    model = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                        dtype="float32")
    base = Experiment(model=model,
                      train=TrainConfig(global_batch=16, seq_len=32, lr=0.1,
                                        total_steps=120, schedule="constant"))
    smb_exp = base
    h_smb, _ = _train(smb_exp, 60)
    smd_exp = base.replace(e2=E2TrainConfig(smd=SMDConfig(True, 0.5)))
    h_smd, tr = _train(smd_exp, 120)
    # matched executed budget (~60 steps each)
    assert 40 <= tr.executed_steps <= 80
    smb_final = np.mean([h["loss"] for h in h_smb[-10:]])
    smd_final = np.mean([h["loss"] for h in h_smd[-10:]])
    assert smd_final < smb_final * 1.15, (smb_final, smd_final)
