"""End-to-end behaviour tests for the E²-Train system."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import (E2TrainConfig, Experiment, ModelConfig,
                               PSGConfig, SLUConfig, SMDConfig, TrainConfig)
from repro.data.synthetic import MarkovLMTask, make_lm_batch
from repro.training.train_step import (eval_params, init_train_state,
                                       make_train_step)
from repro.training.trainer import Trainer

TINY = ModelConfig(name="t", family="dense", num_layers=4, d_model=64,
                   num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                   dtype="float32")


def _mk(exp):
    task = MarkovLMTask(vocab=exp.model.vocab_size)
    return lambda s, sh: make_lm_batch(task, 0, s, sh, exp.train.global_batch,
                                       exp.train.seq_len)


def test_full_e2train_converges():
    """SMD + SLU + PSG together: loss decreases toward the Bayes floor."""
    exp = Experiment(
        model=TINY,
        e2=E2TrainConfig(smd=SMDConfig(True, 0.5),
                         slu=SLUConfig(True, alpha=1e-3),
                         psg=PSGConfig(True)),
        train=TrainConfig(global_batch=16, seq_len=32, lr=0.03,
                          optimizer="psg", total_steps=80,
                          schedule="constant"))
    state = init_train_state(jax.random.PRNGKey(0), exp)
    tr = Trainer(exp, state, _mk(exp))
    hist = tr.run(80)
    first = np.mean([h["loss"] for h in hist[:3]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first * 0.6, (first, last)
    assert tr.dropped_steps > 10          # SMD active
    # SWA is active for PSG
    assert tr.state.swa is not None
    ev = eval_params(tr.state, exp)
    assert jax.tree_util.tree_structure(ev) == \
        jax.tree_util.tree_structure(tr.state.params)


def test_psg_matches_signsgd_quality():
    """Paper Tab. 2: PSG ~ SignSGD accuracy (here: final loss within 15%)."""
    def run(optimizer, psg_on):
        e2 = E2TrainConfig(psg=PSGConfig(enabled=psg_on, swa=False))
        exp = Experiment(model=TINY, e2=e2,
                         train=TrainConfig(global_batch=16, seq_len=32,
                                           lr=0.03, optimizer=optimizer,
                                           total_steps=60,
                                           schedule="constant"))
        st = init_train_state(jax.random.PRNGKey(0), exp)
        tr = Trainer(exp, st, _mk(exp))
        hist = tr.run(60)
        return np.mean([h["loss"] for h in hist[-5:]])

    l_sign = run("signsgd", False)
    l_psg = run("psg", True)
    assert l_psg < l_sign * 1.15, (l_sign, l_psg)


def test_microbatch_equivalence_sgdm():
    """grad accumulation == big batch for plain SGD (same data)."""
    base = Experiment(model=TINY,
                      train=TrainConfig(global_batch=16, seq_len=32, lr=0.1,
                                        total_steps=10, schedule="constant",
                                        microbatches=1))
    exp2 = base.replace(train=dataclasses.replace(base.train, microbatches=4))
    mk = _mk(base)
    s1 = init_train_state(jax.random.PRNGKey(0), base)
    s2 = init_train_state(jax.random.PRNGKey(0), exp2)
    step1 = jax.jit(make_train_step(base))
    step2 = jax.jit(make_train_step(exp2))
    b = mk(0, 0)
    s1b, m1 = step1(s1, b)
    s2b, m2 = step2(s2, b)
    for a, c in zip(jax.tree.leaves(s1b.params), jax.tree.leaves(s2b.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   atol=5e-3, rtol=1e-2)


def test_serving_engine_waves():
    from repro.serving.engine import Request, ServeEngine
    exp = Experiment(model=TINY, train=TrainConfig())
    from repro.models import transformer as T
    params = T.init_lm(jax.random.PRNGKey(0), TINY, exp.e2)
    eng = ServeEngine(exp, params, batch_slots=2, max_len=32)
    rng = np.random.RandomState(0)
    for i in range(5):
        eng.submit(Request(rid=i, prompt=rng.randint(0, 64, size=4),
                           max_new=6))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.out) == 6 for r in done)
    assert all(0 <= t < 64 for r in done for t in r.out)


def _cnn_exp(depth, e2, **train_kw):
    from repro.configs.paper_cnns import cnn_model
    kw = dict(global_batch=8, lr=0.03, optimizer="psg", total_steps=30,
              schedule="constant", weight_decay=5e-4)
    kw.update(train_kw)
    return Experiment(model=cnn_model(f"resnet{depth}", depth), e2=e2,
                      train=TrainConfig(**kw), task="cifar_cnn")


def _mk_img(exp):
    from repro.data.synthetic import GaussianImageTask, make_image_batch
    task = GaussianImageTask(num_classes=10, snr=2.0)
    return lambda s, sh: make_image_batch(task, 0, s, sh,
                                          exp.train.global_batch)


def test_resnet14_converges_through_trainer():
    """Paper-faithful path: CIFAR ResNet (reduced depth 14) + SLU + PSG,
    through the SAME Trainer/train_step stack as the LM experiments."""
    e2 = E2TrainConfig(slu=SLUConfig(True, alpha=0.01),
                       psg=PSGConfig(True, swa=False))
    exp = _cnn_exp(14, e2, global_batch=16)
    state = init_train_state(jax.random.PRNGKey(0), exp)
    tr = Trainer(exp, state, _mk_img(exp))
    hist = tr.run(30)
    losses = [h["loss"] for h in hist]
    assert losses[-1] < losses[0], losses[:3] + losses[-3:]
    assert np.isfinite(losses).all()


def test_resnet74_full_e2train_through_trainer():
    """Acceptance: ResNet-74 (CIFAR shapes) end-to-end with SMD+SLU+PSG via
    the Trainer — measured psg_fallback_ratio and a non-trivial
    slu_exec_ratio come out of the shared metrics path, and the run's
    EnergyLedger reproduces the paper's Table 3 composition from
    config-derived inputs with a measured column next to it."""
    e2 = E2TrainConfig(smd=SMDConfig(True, 0.5),
                       slu=SLUConfig(True, alpha=0.01, target_skip=0.2),
                       psg=PSGConfig(True, swa=False))
    exp = _cnn_exp(74, e2, global_batch=4, total_steps=6)
    state = init_train_state(jax.random.PRNGKey(0), exp)
    tr = Trainer(exp, state, _mk_img(exp))
    hist = tr.run(6)
    assert tr.executed_steps >= 1 and tr.dropped_steps >= 1   # SMD active
    assert all(np.isfinite(h["loss"]) for h in hist)
    fb = tr.measured_psg_fallback()
    assert fb is not None and 0.0 < fb <= 1.0
    ex = np.mean([h["slu_exec_ratio"] for h in hist])
    assert 0.0 < ex < 1.0, ex      # gates actually skip some of 36 blocks
    # BN running stats moved off their init under the shared stack
    stem = tr.state.model_state["stem_bn"]
    assert float(np.abs(np.asarray(stem["mean"])).max()) > 0.0

    # --- EnergyLedger acceptance: the run reproduces Table 3's 20%-skip
    # row from the config's operating point (drop 0.5 x m=4/3, skip 0.2)
    # and reports what this run actually measured next to it ---
    rep = tr.energy_report()
    assert abs(rep.paper_composition - 0.8027) < 2e-3
    assert rep.smd.measured is not None          # executed/dropped counts
    assert abs(rep.slu.measured - (1.0 - ex)) < 1e-6
    assert abs(rep.psg.measured - fb) < 1e-6
    assert rep.computational_savings_measured is not None
    assert 0.0 < rep.computational_savings_measured < 1.0
    assert rep.energy_savings_measured is not None
    # the CNN is priced by the per-layer cost model, not transformer math
    assert abs(rep.fwd_macs_per_example - 168.9e6) < 2e6
    assert abs(rep.params - 1.147e6) < 0.01e6


def test_resnet110_trace_time_budget():
    """The scanned stack keeps the FULL ResNet-110 train-step trace cheap
    (54 blocks would otherwise unroll into the jaxpr)."""
    import time
    e2 = E2TrainConfig(slu=SLUConfig(True, alpha=0.01))
    exp = _cnn_exp(110, e2, global_batch=2)
    state = init_train_state(jax.random.PRNGKey(0), exp)
    batch = _mk_img(exp)(0, 0)
    t0 = time.perf_counter()
    jax.jit(make_train_step(exp)).lower(state, batch)
    dt = time.perf_counter() - t0
    assert dt < 60.0, f"ResNet-110 train-step trace took {dt:.1f}s"
