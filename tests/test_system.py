"""End-to-end behaviour tests for the E²-Train system."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import (E2TrainConfig, Experiment, ModelConfig,
                               PSGConfig, SLUConfig, SMDConfig, TrainConfig)
from repro.data.synthetic import MarkovLMTask, make_lm_batch
from repro.training.train_step import (eval_params, init_train_state,
                                       make_train_step)
from repro.training.trainer import Trainer

TINY = ModelConfig(name="t", family="dense", num_layers=4, d_model=64,
                   num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                   dtype="float32")


def _mk(exp):
    task = MarkovLMTask(vocab=exp.model.vocab_size)
    return lambda s, sh: make_lm_batch(task, 0, s, sh, exp.train.global_batch,
                                       exp.train.seq_len)


def test_full_e2train_converges():
    """SMD + SLU + PSG together: loss decreases toward the Bayes floor."""
    exp = Experiment(
        model=TINY,
        e2=E2TrainConfig(smd=SMDConfig(True, 0.5),
                         slu=SLUConfig(True, alpha=1e-3),
                         psg=PSGConfig(True)),
        train=TrainConfig(global_batch=16, seq_len=32, lr=0.03,
                          optimizer="psg", total_steps=80,
                          schedule="constant"))
    state = init_train_state(jax.random.PRNGKey(0), exp)
    tr = Trainer(exp, state, _mk(exp))
    hist = tr.run(80)
    first = np.mean([h["loss"] for h in hist[:3]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first * 0.6, (first, last)
    assert tr.dropped_steps > 10          # SMD active
    # SWA is active for PSG
    assert tr.state.swa is not None
    ev = eval_params(tr.state, exp)
    assert jax.tree_util.tree_structure(ev) == \
        jax.tree_util.tree_structure(tr.state.params)


def test_psg_matches_signsgd_quality():
    """Paper Tab. 2: PSG ~ SignSGD accuracy (here: final loss within 15%)."""
    def run(optimizer, psg_on):
        e2 = E2TrainConfig(psg=PSGConfig(enabled=psg_on, swa=False))
        exp = Experiment(model=TINY, e2=e2,
                         train=TrainConfig(global_batch=16, seq_len=32,
                                           lr=0.03, optimizer=optimizer,
                                           total_steps=60,
                                           schedule="constant"))
        st = init_train_state(jax.random.PRNGKey(0), exp)
        tr = Trainer(exp, st, _mk(exp))
        hist = tr.run(60)
        return np.mean([h["loss"] for h in hist[-5:]])

    l_sign = run("signsgd", False)
    l_psg = run("psg", True)
    assert l_psg < l_sign * 1.15, (l_sign, l_psg)


def test_microbatch_equivalence_sgdm():
    """grad accumulation == big batch for plain SGD (same data)."""
    base = Experiment(model=TINY,
                      train=TrainConfig(global_batch=16, seq_len=32, lr=0.1,
                                        total_steps=10, schedule="constant",
                                        microbatches=1))
    exp2 = base.replace(train=dataclasses.replace(base.train, microbatches=4))
    mk = _mk(base)
    s1 = init_train_state(jax.random.PRNGKey(0), base)
    s2 = init_train_state(jax.random.PRNGKey(0), exp2)
    step1 = jax.jit(make_train_step(base))
    step2 = jax.jit(make_train_step(exp2))
    b = mk(0, 0)
    s1b, m1 = step1(s1, b)
    s2b, m2 = step2(s2, b)
    for a, c in zip(jax.tree.leaves(s1b.params), jax.tree.leaves(s2b.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   atol=5e-3, rtol=1e-2)


def test_serving_engine_waves():
    from repro.serving.engine import Request, ServeEngine
    exp = Experiment(model=TINY, train=TrainConfig())
    from repro.models import transformer as T
    params = T.init_lm(jax.random.PRNGKey(0), TINY, exp.e2)
    eng = ServeEngine(exp, params, batch_slots=2, max_len=32)
    rng = np.random.RandomState(0)
    for i in range(5):
        eng.submit(Request(rid=i, prompt=rng.randint(0, 64, size=4),
                           max_new=6))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.out) == 6 for r in done)
    assert all(0 <= t < 64 for r in done for t in r.out)


def test_resnet74_family_e2train_smoke():
    """Paper-faithful path: CIFAR ResNet (reduced depth 14) + full E²-Train."""
    from repro.data.synthetic import GaussianImageTask, make_image_batch
    from repro.models import resnet as R
    from repro.optim.api import make_optimizer

    e2 = E2TrainConfig(smd=SMDConfig(True), slu=SLUConfig(True, alpha=0.01),
                       psg=PSGConfig(True, swa=False))
    tcfg = TrainConfig(lr=0.03, optimizer="psg", total_steps=30,
                       schedule="constant", weight_decay=5e-4)
    task = GaussianImageTask(num_classes=10, snr=2.0)
    params = R.init_resnet(jax.random.PRNGKey(0), 14, 10, e2)
    opt = make_optimizer(tcfg)
    opt_state = opt.init(params)

    from repro.core import psg as psgmod

    @jax.jit
    def step(params, opt_state, batch, i):
        def loss_fn(p):
            with psgmod.enable(e2.psg):
                return R.resnet_loss(p, batch, 14, e2,
                                     jax.random.fold_in(jax.random.PRNGKey(1), i))
        (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params2, opt2 = opt.apply(params, g, opt_state, i)
        return params2, opt2, l

    losses = []
    for i in range(30):
        batch = make_image_batch(task, 0, i, 0, 16)
        params, opt_state, l = step(params, opt_state, batch, jnp.int32(i))
        losses.append(float(l))
    assert losses[-1] < losses[0], losses[:3] + losses[-3:]
    assert np.isfinite(losses).all()
