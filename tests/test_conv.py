"""Fused implicit-GEMM conv kernels (kernels/conv.py, DESIGN.md §Kernels).

The acceptance property this module pins: the fused conv forward +
PSG weight-gradient path (``PSGConfig.fused_conv``) is **bit-identical in
output signs** to the materialized im2col + ``psg.matmul`` path on the
paper's ResNet conv geometries — including the stride-2 transitions and
the 1x1 downsample/pointwise convs — emits tile-fallback stats into the
``psg_fallback_ratio`` telemetry, and dispatches through the
reference/interpret/mosaic backend layer like every other kernel.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnns import cnn_model, resnet_conv_shapes
from repro.core import psg
from repro.core.config import (E2TrainConfig, Experiment, PSGConfig,
                               SLUConfig, SMDConfig, TrainConfig)
from repro.kernels import dispatch, ops, ref
from repro.models.resnet import conv2d as model_conv2d

# fused_conv=None now AUTO-resolves fused-on for non-Mosaic backends
# (core/psg.fused_conv_active), so the im2col comparator must opt out
# explicitly.
CFG = PSGConfig(enabled=True, fused_conv=False)
CFG_FUSED = PSGConfig(enabled=True, fused_conv=True)

# every distinct conv KIND of the paper's ResNets at test batch, plus the
# MobileNetV2-style pointwise shapes (non-128-multiple dout exercising the
# kernel's dout padding); (batch, hw, cin, cout, k, stride)
CONV_CASES = [pytest.param(*c, id=f"{c.kind}_{c.hw}x{c.cin}-{c.cout}"
                           f"k{c.k}s{c.stride}")
              for c in resnet_conv_shapes(depth=14, width=16, batch=2)]
CONV_CASES += [
    pytest.param(2, 8, 24, 40, 1, 1, id="point_8x24-40k1s1"),
    pytest.param(1, 4, 40, 200, 1, 1, id="point_pad_4x40-200k1s1"),
]


def _data(B, H, C, Cout, k, s):
    key = jax.random.PRNGKey(B + H + C + Cout + k + s)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (B, H, H, C)) * 0.5
    w = jax.random.normal(k2, (k * k * C, Cout)) * 0.1
    ho = -(-H // s)
    gy = jax.random.normal(k3, (B, ho, ho, Cout)) * 0.01
    return x, w, gy


def _grads(loss, w, x):
    return jax.grad(loss, argnums=(0, 1))(w, x)


def _paths(x, w, gy, k, s):
    """(y, dw, dx) through the im2col+psg.matmul path and the fused path."""
    def im2col_loss(w_, x_):
        with psg.enable(CFG):
            y = model_conv2d({"w": w_}, x_, k=k, stride=s)
        return jnp.sum(y * gy)

    def fused_loss(w_, x_):
        with psg.enable(CFG_FUSED):
            y = model_conv2d({"w": w_}, x_, k=k, stride=s)
        return jnp.sum(y * gy)

    with psg.enable(CFG):
        yA = model_conv2d({"w": w}, x, k=k, stride=s)
    with psg.enable(CFG_FUSED):
        yB = model_conv2d({"w": w}, x, k=k, stride=s)
    dwA, dxA = _grads(im2col_loss, w, x)
    dwB, dxB = _grads(fused_loss, w, x)
    return (yA, dwA, dxA), (yB, dwB, dxB)


# ---------------------------------------------------------------------------
# parity with the materialized path (the tentpole acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,C,Cout,k,s", CONV_CASES)
def test_fused_conv_parity_with_im2col_path(B, H, C, Cout, k, s):
    """Forward values match to fp32 tap-summation tolerance; the PSG
    weight-gradient SIGNS are bit-identical; dx matches numerically."""
    x, w, gy = _data(B, H, C, Cout, k, s)
    (yA, dwA, dxA), (yB, dwB, dxB) = _paths(x, w, gy, k, s)
    np.testing.assert_allclose(np.asarray(yA), np.asarray(yB),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(dwA), np.asarray(dwB))
    assert set(np.unique(np.asarray(dwB))).issubset({-1.0, 0.0, 1.0})
    np.testing.assert_allclose(np.asarray(dxA), np.asarray(dxB),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("B,H,C,Cout,k,s", CONV_CASES)
def test_fused_conv_grad_w_matches_element_oracle(B, H, C, Cout, k, s):
    """The kernel's signs also match the element-level Eq. (2) oracle on
    the (never materialized) im2col operand."""
    x, w, gy = _data(B, H, C, Cout, k, s)
    del w
    if k < s:                      # psg.conv2d's 1x1-downsample normalization
        x, s = x[:, ::s, ::s, :], 1
    pad = k // 2
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0))) if pad else x
    sign, fb = ops.conv_grad_w(xp, gy, CFG, k, s)
    want = ref.conv_grad_w_ref(xp, gy, CFG, k, s)
    np.testing.assert_array_equal(np.asarray(sign), np.asarray(want))
    assert 0.0 <= float(fb) <= 1.0


def test_fused_conv_fwd_matches_ref():
    x, w, _ = _data(2, 16, 16, 32, 3, 1)
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    got = ops.conv_fwd(xp, w, 3, 1)
    want = ref.conv_fwd_ref(xp, w, 3, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# input-gradient kernel (implicit transposed conv)
# ---------------------------------------------------------------------------


def _dx_operands(B, H, C, Cout, k, s):
    """(gy, w, xp, stride) for the dx kernel after psg.conv2d's
    1x1-downsample normalization and SAME padding."""
    x, w, gy = _data(B, H, C, Cout, k, s)
    if k < s:                      # psg.conv2d's 1x1-downsample normalization
        x, s = x[:, ::s, ::s, :], 1
    pad = k // 2
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0))) if pad else x
    return gy, w, xp, s


@pytest.mark.parametrize("B,H,C,Cout,k,s", CONV_CASES)
def test_conv_grad_x_kernel_matches_ref_and_oracle(B, H, C, Cout, k, s):
    """The implicit transposed-conv kernel matches the demoted col2im
    reference AND the float32 ``jax.vjp`` oracle of the materialized
    forward on every shipped geometry (stride-2 included)."""
    gy, w, xp, s = _dx_operands(B, H, C, Cout, k, s)
    Hp = xp.shape[1]
    got = ops.conv_grad_x(gy, w, k, s, Hp, Hp)
    assert got.shape == xp.shape and got.dtype == jnp.float32
    want = ref.conv_grad_x_ref(gy, w, k, s, Hp, Hp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    _, vjp = jax.vjp(lambda xp_: ref.conv_fwd_ref(xp_, w, k, s), xp)
    (oracle,) = vjp(gy)
    np.testing.assert_allclose(np.asarray(got), np.asarray(oracle),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("B,H,C,Cout,k,s", CONV_CASES)
def test_conv_grad_x_dispatch_backends_agree(B, H, C, Cout, k, s):
    gy, w, xp, s = _dx_operands(B, H, C, Cout, k, s)
    Hp = xp.shape[1]
    with dispatch.override_backend("interpret"):
        dx_i = dispatch.conv_grad_x(gy, w, CFG, k=k, stride=s, hp=Hp, wp=Hp)
    with dispatch.override_backend("reference"):
        dx_r = dispatch.conv_grad_x(gy, w, CFG, k=k, stride=s, hp=Hp, wp=Hp)
    np.testing.assert_allclose(np.asarray(dx_i), np.asarray(dx_r),
                               rtol=1e-5, atol=1e-6)


def test_conv_grad_x_accumulates_in_f32_regression():
    """Regression: ``_psg_conv2d_bwd`` used to accumulate dx in
    ``gq.dtype`` — with low-precision cotangents the k*k tap sums
    collapsed at bf16 precision.  Both the kernel path and the demoted
    reference must hit the f32 oracle (computed on the same
    bf16-rounded operands) at f32 tolerance, and return f32."""
    gy, w, xp, s = _dx_operands(2, 8, 16, 32, 3, 1)
    Hp = xp.shape[1]
    gyb, wb = gy.astype(jnp.bfloat16), w.astype(jnp.bfloat16)
    _, vjp = jax.vjp(
        lambda xp_: ref.conv_fwd_ref(xp_, wb.astype(jnp.float32), 3, s), xp)
    (oracle,) = vjp(gyb.astype(jnp.float32))
    for name, fn in (("kernel", ops.conv_grad_x),
                     ("reference", ref.conv_grad_x_ref)):
        dx = fn(gyb, wb, 3, s, Hp, Hp)
        assert dx.dtype == jnp.float32, name
        np.testing.assert_allclose(np.asarray(dx), np.asarray(oracle),
                                   rtol=1e-5, atol=1e-7, err_msg=name)


def test_fused_conv_is_default_on_non_mosaic_backends():
    """fused_conv=None auto-resolves: ON under reference/interpret, OFF
    under Mosaic (pending a real-TPU profile) and when PSG is off."""
    auto = PSGConfig(enabled=True)
    assert psg.fused_conv_active(auto)                  # interpret default
    with dispatch.override_backend("reference"):
        assert psg.fused_conv_active(auto)
    with dispatch.override_backend("mosaic"):
        assert not psg.fused_conv_active(auto)
        assert psg.fused_conv_active(PSGConfig(enabled=True,
                                               fused_conv=True))
    assert not psg.fused_conv_active(CFG)               # explicit opt-out
    assert not psg.fused_conv_active(None)


def test_fused_fwd_bwd_moves_no_patch_tensor():
    """Acceptance: with fused conv on, neither direction materializes a
    patch tensor — jaxpr_cost classes ZERO gather movement and no
    scatter passes remain (the demoted col2im loop was scatter-add);
    the im2col path shows the patch-extraction gather traffic."""
    from repro.analysis.jaxpr_cost import jaxpr_costs
    x, w, gy = _data(2, 8, 16, 32, 3, 2)

    def make_grad(cfg):
        def loss(w_, x_):
            with psg.enable(cfg):
                y = model_conv2d({"w": w_}, x_, k=3, stride=2)
            return jnp.sum(y * gy)
        return jax.grad(loss, argnums=(0, 1))

    fused, im2col = make_grad(CFG_FUSED), make_grad(CFG)
    assert jaxpr_costs(fused, w, x).total().gather_flops == 0.0
    assert jaxpr_costs(im2col, w, x).total().gather_flops > 0.0
    assert "scatter" not in str(jax.make_jaxpr(fused)(w, x))


# ---------------------------------------------------------------------------
# dispatch routing
# ---------------------------------------------------------------------------


def test_conv_dispatch_reference_vs_interpret():
    x, w, gy = _data(2, 8, 16, 32, 3, 2)
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    with dispatch.override_backend("interpret"):
        s_tile, fb_tile = dispatch.conv_grad_w(xp, gy, CFG, k=3, stride=2)
        y_tile = dispatch.conv_fwd(xp, w, CFG, k=3, stride=2)
    with dispatch.override_backend("reference"):
        s_ref, fb_ref = dispatch.conv_grad_w(xp, gy, CFG, k=3, stride=2)
        y_ref = dispatch.conv_fwd(xp, w, CFG, k=3, stride=2)
    np.testing.assert_array_equal(np.asarray(s_tile), np.asarray(s_ref))
    np.testing.assert_allclose(np.asarray(y_tile), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    assert 0.0 <= float(fb_tile) <= 1.0
    assert 0.0 <= float(fb_ref) <= 1.0


def test_fused_bwd_executes_conv_kernel_not_oracle():
    """The traced fused backward must contain a pallas_call (and none when
    pinned to the reference backend)."""
    x, w, gy = _data(1, 8, 8, 16, 3, 1)

    def loss(w_):
        with psg.enable(CFG_FUSED):
            return jnp.sum(model_conv2d({"w": w_}, x) * gy)

    assert "pallas_call" in str(jax.make_jaxpr(jax.grad(loss))(w))
    with dispatch.override_backend("reference"):
        jaxpr_ref = str(jax.make_jaxpr(jax.grad(loss))(w))
    assert "pallas_call" not in jaxpr_ref


# ---------------------------------------------------------------------------
# fallback stats reach the probe / training telemetry
# ---------------------------------------------------------------------------


def test_fused_conv_probe_macs_accounting():
    x, w, gy = _data(2, 8, 16, 16, 3, 1)

    def loss(w_, probe):
        with psg.enable(CFG_FUSED, probe=probe):
            return jnp.sum(model_conv2d({"w": w_}, x) * gy)

    pg = jax.grad(loss, argnums=1)(w, psg.zero_probe())
    macs = 2 * 8 * 8 * (9 * 16) * 16        # B*Ho*Wo * k*k*C * Cout
    assert float(pg[1]) == float(macs)
    assert 0.0 <= float(pg[0]) <= float(macs)
    assert 0.0 <= float(psg.probe_fallback_ratio(pg)) <= 1.0


def test_fused_train_step_reports_fallback_and_energy():
    """A full resnet train step with fused_conv emits the measured
    psg_fallback_ratio and Trainer.energy_report() consumes it."""
    from repro.data.synthetic import GaussianImageTask, make_image_batch
    from repro.training.train_step import init_train_state
    from repro.training.trainer import Trainer

    e2 = E2TrainConfig(smd=SMDConfig(enabled=False),
                       slu=SLUConfig(enabled=True, alpha=1e-3),
                       psg=PSGConfig(enabled=True, swa=False,
                                     fused_conv=True))
    exp = Experiment(model=cnn_model("resnet8", 8, width=8), e2=e2,
                     train=TrainConfig(global_batch=2, lr=0.05,
                                       optimizer="psg", total_steps=8,
                                       schedule="constant"),
                     task="cifar_cnn")
    task = GaussianImageTask(num_classes=10, snr=2.0)
    tr = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp),
                 lambda s, sh: make_image_batch(task, 0, s, sh, 2))
    hist = tr.run(2)
    assert all(0.0 < h["psg_fallback_ratio"] <= 1.0 for h in hist)
    measured = tr.measured_psg_fallback()
    assert measured is not None and 0.0 < measured <= 1.0
    rep = tr.energy_report(steps=2).to_dict()
    assert rep["psg"]["measured"] is not None
    assert rep["psg"]["measured"] == pytest.approx(measured)


def test_fused_train_matches_im2col_train_losses():
    """Short resnet runs through both conv paths track each other.

    Signs are bit-identical for identical inputs (pinned above), but the
    forward is only fp-close (tap-summation order), so BatchNorm batch
    statistics — and from step 2 on the whole trajectory — drift at fp
    magnitude: the first step must agree tightly, the short curve within
    a small band."""
    from repro.data.synthetic import GaussianImageTask, make_image_batch
    from repro.training.train_step import init_train_state
    from repro.training.trainer import Trainer

    task = GaussianImageTask(num_classes=10, snr=2.0)
    mk = lambda s, sh: make_image_batch(task, 0, s, sh, 2)
    curves = {}
    for fused in (False, True):
        e2 = E2TrainConfig(psg=PSGConfig(enabled=True, swa=False,
                                         fused_conv=fused))
        exp = Experiment(model=cnn_model("resnet8", 8, width=8), e2=e2,
                         train=TrainConfig(global_batch=2, lr=0.05,
                                           optimizer="psg", total_steps=8,
                                           schedule="constant"),
                         task="cifar_cnn")
        tr = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk)
        curves[fused] = [h["total_loss"] for h in tr.run(3)]
    np.testing.assert_allclose(curves[False][0], curves[True][0], rtol=1e-4)
    np.testing.assert_allclose(curves[False], curves[True], rtol=5e-2)


@pytest.mark.parametrize("name,depth", [("resnet8", 8), ("mobilenetv2", 0)])
def test_fused_default_train_step_both_backbones(name, depth):
    """End-to-end train steps on BOTH CNN backbones with the fused conv
    path active by DEFAULT (fused_conv=None on the interpret backend):
    losses stay finite and continuous step to step, and the measured
    psg_fallback_ratio telemetry is emitted."""
    from repro.data.synthetic import GaussianImageTask, make_image_batch
    from repro.training.train_step import init_train_state
    from repro.training.trainer import Trainer

    cfg = PSGConfig(enabled=True, swa=False)      # fused_conv left at None
    assert psg.fused_conv_active(cfg)
    exp = Experiment(model=cnn_model(name, depth, width=8),
                     e2=E2TrainConfig(psg=cfg),
                     train=TrainConfig(global_batch=2, lr=0.03,
                                       optimizer="psg", total_steps=8,
                                       schedule="constant"),
                     task="cifar_cnn")
    task = GaussianImageTask(num_classes=10, snr=2.0)
    tr = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp),
                 lambda s, sh: make_image_batch(task, 0, s, sh, 2))
    hist = tr.run(3)
    losses = [h["total_loss"] for h in hist]
    assert all(np.isfinite(l) for l in losses)
    # continuity: no step-to-step blowup from the dx path
    assert max(losses) < 10 * min(losses) + 10
    assert all(0.0 < h["psg_fallback_ratio"] <= 1.0 for h in hist)


# ---------------------------------------------------------------------------
# layout + padding/masking (non-MXU-aligned dout)
# ---------------------------------------------------------------------------


def test_tap_major_round_trip():
    from repro.kernels.conv import to_patch_major, to_tap_major
    w = jnp.arange(9 * 5 * 7, dtype=jnp.float32).reshape(9 * 5, 7)
    np.testing.assert_array_equal(
        np.asarray(to_patch_major(to_tap_major(w, 3, 5), 3, 5)),
        np.asarray(w))


def test_conv_kernel_dout_padding_cropped():
    """dout=200 pads to the clamped 128 tile (n_j=2, padded columns) and
    the result is cropped back — shape and values must be unpadded."""
    x, w, gy = _data(1, 4, 40, 200, 1, 1)
    sign, fb = ops.conv_grad_w(x, gy, CFG, 1, 1)
    assert sign.shape == (40, 200)
    want = ref.conv_grad_w_ref(x, gy, CFG, 1, 1)
    np.testing.assert_array_equal(np.asarray(sign), np.asarray(want))
    y = ops.conv_fwd(x, w, 1, 1)
    assert y.shape == (1, 4, 4, 200)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.conv_fwd_ref(x, w, 1, 1)),
                               rtol=1e-5, atol=1e-5)
