"""Per-architecture smoke tests (assignment deliverable f) + numerics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, smoke_experiment
from repro.core.config import E2TrainConfig, ModelConfig
from repro.models import ssm, transformer as T
from repro.training.train_step import init_train_state, make_train_step


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    """Reduced same-family config: one train step, output shapes, no NaNs."""
    exp = smoke_experiment(arch)
    m = exp.model
    key = jax.random.PRNGKey(0)
    state = init_train_state(key, exp)
    B, S = exp.train.global_batch, exp.train.seq_len
    batch = {"tokens": jax.random.randint(key, (B, S), 0, m.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, m.vocab_size)}
    if m.frontend:
        batch["frontend"] = jax.random.normal(
            key, (B, m.frontend_tokens, m.d_model), m.act_dtype)
    out = T.lm_fwd(state.params, batch["tokens"], m, exp.e2,
                   frontend_embeds=batch.get("frontend"), train=False,
                   remat="none")
    exp_S = S + (m.frontend_tokens if m.frontend and not m.encoder_layers else 0)
    assert out.logits.shape == (B, exp_S, m.vocab_size)
    assert np.isfinite(np.asarray(out.logits)).all()
    st2, metrics = jax.jit(make_train_step(exp))(state, batch)
    assert np.isfinite(float(metrics["total_loss"]))
    assert int(st2.step) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode_step(arch):
    exp = smoke_experiment(arch)
    m = exp.model
    key = jax.random.PRNGKey(0)
    params = T.init_lm(key, m, exp.e2)
    B = 2
    st = T.init_decode_state(m, B, 32, dtype=jnp.float32)
    mem = None
    if m.encoder_layers:
        emb = jax.random.normal(key, (B, m.frontend_tokens, m.d_model),
                                m.act_dtype)
        mem = T.encoder_fwd(params, emb, m)
    tok = jax.random.randint(key, (B, 1), 0, m.vocab_size)
    logits, st2 = T.decode_step(params, tok, st, m, mem)
    assert logits.shape == (B, 1, m.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert int(st2["pos"][0]) == 1


def _tiny(family="dense", **kw):
    base = dict(name="t", family=family, num_layers=2, d_model=32,
                num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=32,
                dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def test_decode_matches_fwd_dense():
    cfg = _tiny(num_layers=4)
    p = T.init_lm(jax.random.PRNGKey(0), cfg, E2TrainConfig())
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 32)
    out = T.lm_fwd(p, toks, cfg, train=False, remat="none")
    st = T.init_decode_state(cfg, 2, 16, dtype=jnp.float32)
    logs = []
    for t in range(12):
        lg, st = T.decode_step(p, toks[:, t:t + 1], st, cfg)
        logs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(logs, 1)),
                               np.asarray(out.logits), atol=2e-4)


def test_sliding_window_attention_masks():
    """SWA: token attends only within window."""
    cfg = _tiny(sliding_window=4)
    from repro.models.layers import causal_mask
    m = np.asarray(causal_mask(8, 8, 0, 4))
    assert m[7, 7] and m[7, 4]
    assert not m[7, 3] and not m[7, 0] and not m[0, 1]


@pytest.mark.parametrize("kind", ["mamba", "mlstm", "slstm"])
def test_ssm_fwd_step_parity(kind):
    cfg = _tiny(family="ssm", num_kv_heads=4, ssm_state=8)
    init_fn = getattr(ssm, f"init_{kind}")
    fwd = getattr(ssm, f"{kind}_fwd")
    step = getattr(ssm, f"{kind}_step")
    init_st = getattr(ssm, f"init_{kind}_state")
    p = init_fn(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 32))
    y_full = fwd(p, x, cfg)
    s = init_st(cfg, 2)
    ys = []
    for t in range(8):
        y, s = step(p, x[:, t:t + 1], s, cfg)
        ys.append(y[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_full), atol=1e-4)


def test_mamba_chunk_boundary_exactness():
    """Chunked SSD == recurrence across chunk boundaries (S > chunk)."""
    import repro.models.ssm as S
    old = S.SSD_CHUNK
    S.SSD_CHUNK = 4
    try:
        cfg = _tiny(family="ssm", num_kv_heads=4, ssm_state=4)
        p = ssm.init_mamba(jax.random.PRNGKey(1), cfg)
        x = jax.random.normal(jax.random.PRNGKey(2), (1, 16, 32))
        y_full = ssm.mamba_fwd(p, x, cfg)
        st = ssm.init_mamba_state(cfg, 1)
        ys = []
        for t in range(16):
            y, st = ssm.mamba_step(p, x[:, t:t + 1], st, cfg)
            ys.append(y[:, 0])
        np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                                   np.asarray(y_full), atol=1e-4)
    finally:
        S.SSD_CHUNK = old


def test_moe_capacity_drops_and_aux():
    from repro.models import moe
    cfg = _tiny(family="moe", num_experts=4, top_k=2, moe_d_ff=32,
                capacity_factor=0.5)   # force drops
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y, aux = moe.moe_fwd(p, x, cfg)
    assert y.shape == x.shape
    assert float(aux) > 0.0
    assert np.isfinite(np.asarray(y)).all()


def test_resnet_paper_depths():
    from repro.models.resnet import resnet_depth_to_n
    assert resnet_depth_to_n(74) == 12   # paper's ResNet-74
    assert resnet_depth_to_n(110) == 18  # paper's ResNet-110


def test_vlm_prepends_patches():
    cfg = _tiny(family="vlm", frontend="vision", frontend_tokens=4)
    p = T.init_lm(jax.random.PRNGKey(0), cfg, E2TrainConfig())
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 32)
    fe = jax.random.normal(jax.random.PRNGKey(2), (2, 4, 32))
    out = T.lm_fwd(p, toks, cfg, frontend_embeds=fe, train=False, remat="none")
    assert out.logits.shape == (2, 12, 32)
    # loss aligns labels with the text tail
    loss, _ = T.lm_loss(p, {"tokens": toks, "labels": toks, "frontend": fe},
                        cfg, remat="none")
    assert np.isfinite(float(loss))


def test_vocab_padding_masks_pad_ids():
    """Indivisible vocab (whisper-style) pads tables; pad logits = -inf."""
    cfg = _tiny(vocab_size=1100)     # pads to 1152
    assert cfg.padded_vocab == 1152
    assert _tiny(vocab_size=100).padded_vocab == 100   # tiny: unpadded
    p = T.init_lm(jax.random.PRNGKey(0), cfg, E2TrainConfig())
    assert p["embed"].shape == (1152, 32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 1100)
    out = T.lm_fwd(p, toks, cfg, train=False, remat="none")
    lg = np.asarray(out.logits)
    assert lg.shape[-1] == 1152
    assert (lg[..., 1100:] <= -1e29).all()
    loss, _ = T.lm_loss(p, {"tokens": toks, "labels": toks}, cfg, remat="none")
    assert np.isfinite(float(loss))



@pytest.mark.parametrize("variant", ["dense", "swa", "xlstm", "zamba"])
def test_prefill_to_state_matches_decode(variant):
    """Bulk prefill -> decode-state handoff == token-by-token decode."""
    from repro.core.config import (BLOCK_MAMBA, BLOCK_MLSTM,
                                   BLOCK_SHARED_ATTN, BLOCK_SLSTM)
    base = dict(name="t", num_layers=4, d_model=32, num_heads=4,
                num_kv_heads=2, d_ff=64, vocab_size=64, dtype="float32")
    cfg = {
        "dense": ModelConfig(family="dense", **base),
        "swa": ModelConfig(family="dense", **{**base, "sliding_window": 6}),
        "xlstm": ModelConfig(family="ssm", **{**base, "num_kv_heads": 4,
                  "ssm_state": 8, "block_unit": (BLOCK_MLSTM, BLOCK_MLSTM,
                                                 BLOCK_MLSTM, BLOCK_SLSTM)}),
        "zamba": ModelConfig(family="hybrid", **{**base, "num_kv_heads": 4,
                  "ssm_state": 8,
                  "block_unit": (BLOCK_MAMBA, BLOCK_SHARED_ATTN)}),
    }[variant]
    S = 8
    p = T.init_lm(jax.random.PRNGKey(0), cfg, E2TrainConfig())
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0, 64)
    stA = T.init_decode_state(cfg, 2, 32, dtype=jnp.float32)
    for t_ in range(S):
        lgA, stA = T.decode_step(p, toks[:, t_:t_ + 1], stA, cfg)
    lgB, stB = T.prefill_to_state(p, toks, cfg, 32, cache_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lgA), np.asarray(lgB), atol=2e-4)
    nxt = jnp.argmax(lgB[:, 0], -1)[:, None].astype(jnp.int32)
    lgA2, _ = T.decode_step(p, nxt, stA, cfg)
    lgB2, _ = T.decode_step(p, nxt, stB, cfg)
    np.testing.assert_allclose(np.asarray(lgA2), np.asarray(lgB2), atol=2e-4)
