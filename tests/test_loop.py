"""Chunked compiled loop (DESIGN.md §Loop): parity with the per-step loop.

The acceptance property this module pins: the chunked loop (K>1) matches
the per-step reference loop **bit-for-bit** on the loss curve, the step
counter, and the executed/dropped SMD counts — for both registered tasks,
including a checkpoint/resume across a chunk boundary — and the energy
report built from identical telemetry is unchanged.
"""
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnns import cnn_model
from repro.core.config import (E2TrainConfig, Experiment, ModelConfig,
                               PSGConfig, SLUConfig, SMDConfig, TrainConfig)
from repro.data.synthetic import (GaussianImageTask, MarkovLMTask,
                                  make_image_batch, make_lm_batch)
from repro.training.loop import ChunkPlanner, make_chunk_step, stack_batches
from repro.training.train_step import init_train_state
from repro.training.trainer import Trainer


def _exp(task_name, smd=True):
    e2 = E2TrainConfig(smd=SMDConfig(enabled=smd, drop_prob=0.5),
                       slu=SLUConfig(enabled=True, alpha=1e-3),
                       psg=PSGConfig(enabled=True, swa=False))
    tr = TrainConfig(global_batch=8, seq_len=16, lr=0.05, optimizer="psg",
                     total_steps=64, schedule="constant")
    if task_name == "cifar_cnn":
        return Experiment(model=cnn_model("resnet14", 14, width=8), e2=e2,
                          train=tr, task="cifar_cnn")
    model = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                        num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=32,
                        dtype="float32")
    return Experiment(model=model, e2=e2, train=tr, task="lm")


def _mk(exp):
    if exp.task == "cifar_cnn":
        task = GaussianImageTask(num_classes=10, snr=2.0)
        return lambda s, sh: make_image_batch(task, 0, s, sh,
                                              exp.train.global_batch)
    task = MarkovLMTask(vocab=exp.model.vocab_size)
    return lambda s, sh: make_lm_batch(task, 0, s, sh, exp.train.global_batch,
                                       exp.train.seq_len)


def _curve(hist):
    return [(h["step"], h["total_loss"]) for h in hist]


@pytest.mark.parametrize("task_name", ["lm", "cifar_cnn"])
def test_chunked_matches_per_step_bitwise(task_name):
    """K=4 chunks: loss curve, step counter, SMD counts and final params are
    IDENTICAL to the per-step loop — drops ride as step_increments, so the
    per-step RNG fold-in sees the same counters."""
    steps = 20 if task_name == "cifar_cnn" else 24
    exp = _exp(task_name)
    mk = _mk(exp)
    trA = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk)
    hA = trA.run(steps)
    trB = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk,
                  chunk_steps=4)
    hB = trB.run(steps)

    assert _curve(hA) == _curve(hB)              # bit-for-bit, not allclose
    assert int(trA.state.step) == int(trB.state.step) == steps
    assert (trA.executed_steps, trA.dropped_steps) == \
        (trB.executed_steps, trB.dropped_steps)
    assert trA.dropped_steps > 0                 # SMD actually dropped
    for a, b in zip(jax.tree.leaves(trA.state.params),
                    jax.tree.leaves(trB.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # telemetry-derived accounting is unchanged for identical telemetry
    repA = trA.energy_report(steps=steps).to_dict()
    repB = trB.energy_report(steps=steps).to_dict()
    assert repA == repB


def test_chunked_resume_across_chunk_boundary():
    """Straight chunked run == chunked run interrupted at a chunk-cadence
    checkpoint and resumed (the save lands on a chunk boundary; resume
    derives the restart from the saved step)."""
    from repro.ft.checkpoint import (latest_step, restore_checkpoint,
                                     resume_chunk_start)
    exp = _exp("lm")
    mk = _mk(exp)
    steps, K = 24, 4

    trA = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk,
                  chunk_steps=K)
    hA = trA.run(steps)

    with tempfile.TemporaryDirectory() as d:
        trB = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk,
                      chunk_steps=K, checkpoint_dir=d, checkpoint_every=1)
        trB.run(12)
        saved = latest_step(d)
        assert saved == 11                       # final save at window end
        start = resume_chunk_start(d)
        assert start == 12
        restored, _ = restore_checkpoint(d, trB.state)
        trC = Trainer(exp, jax.tree.map(jnp.asarray, restored), mk,
                      chunk_steps=K)
        assert int(trC.state.step) == start      # lands on the boundary
        hC = trC.run(steps - start)

    assert _curve(trB.history) + _curve(hC) == _curve(hA)
    for a, b in zip(jax.tree.leaves(trA.state.params),
                    jax.tree.leaves(trC.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert trB.dropped_steps + trC.dropped_steps == trA.dropped_steps


def test_chunk_cadence_checkpoint_state_is_boundary_state():
    """A cadence save inside a chunked run captures the state AT that
    chunk's boundary, not a later in-flight state (regression: the save
    must block on its own chunk, not trail the next dispatch)."""
    from repro.ft.checkpoint import restore_checkpoint
    exp = _exp("lm", smd=False)
    mk = _mk(exp)
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk,
                     chunk_steps=4, checkpoint_dir=d, checkpoint_every=4)
        tr.run(12)
        # step 3 closes the first chunk (steps 0..3): cadence 4 saved there
        restored, step = restore_checkpoint(d, tr.state, step=3)
        assert int(np.asarray(restored.step)) == 4
        # resumed continuation reproduces the straight run
        trC = Trainer(exp, jax.tree.map(jnp.asarray, restored), mk,
                      chunk_steps=4)
        hC = trC.run(8)
        assert _curve(hC) == _curve(tr.history)[4:]


def test_make_chunk_step_validates_shapes():
    exp = _exp("lm", smd=False)
    mk = _mk(exp)
    state = init_train_state(jax.random.PRNGKey(0), exp)
    batches = stack_batches([mk(t, 0) for t in range(3)])
    fn = make_chunk_step(exp, K=4)
    with pytest.raises(ValueError, match="K=4"):
        fn(state, batches, jnp.ones((3,), jnp.int32))
    fn3 = make_chunk_step(exp)
    with pytest.raises(ValueError, match="leading axes"):
        fn3(state, batches, jnp.ones((4,), jnp.int32))


def test_chunk_planner_increments_and_trailing():
    """Drops before an executed step fold into its increment; trailing
    drops stay pending until flushed; straggler drop() accounts like SMD."""
    p = ChunkPlanner(2)
    assert p.add(0, None) is None                # drop
    assert p.add(1, {"x": np.ones(2)}) is None   # exec, inc=2
    p.drop(2, {"x": np.ones(2)})                 # straggler-dropped kept step
    chunk = p.add(3, {"x": np.ones(2)})          # exec, inc=2 -> chunk full
    steps, batches, incs = chunk
    assert steps == (1, 3)
    assert incs.tolist() == [2, 2]
    assert batches["x"].shape == (2, 2)
    assert p.add(4, None) is None
    assert p.flush() is None                     # no buffered executed step
    assert p.flush_trailing() == 1
    assert (p.executed, p.dropped) == (2, 3)


def test_chunked_straggler_drops_at_chunk_granularity():
    """deadline_s below any chunk's per-step wall time: each finalized
    chunk arms one drop; executed+dropped still covers the window and the
    counter stays correct."""
    exp = _exp("lm", smd=False)
    mk = _mk(exp)
    tr = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk,
                 chunk_steps=4, deadline_s=1e-9)
    tr.run(16)
    assert tr.dropped_steps >= 1                 # straggler policy fired
    assert tr.executed_steps + tr.dropped_steps == 16
    assert int(tr.state.step) == 16
    # dropped steps leave no history entries, like the per-step loop
    assert len(tr.history) == tr.executed_steps


def test_chunked_straggler_deadline_is_per_step():
    """PR 10: the deadline applies PER SCANNED STEP (timed chunk program:
    one ordered callback per step), not per chunk mean.  With a deadline
    below every step's device time, ONE finalized chunk arms K drops —
    under the old chunk-granularity check a whole run of N/K chunks could
    arm at most N/K.  24 steps at K=4 finalize at most 4 executed chunks,
    so > 4 straggler drops proves per-step arming."""
    exp = _exp("lm", smd=False)
    mk = _mk(exp)
    tr = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk,
                 chunk_steps=4, deadline_s=1e-9)
    tr.run(24)
    assert tr.dropped_steps + tr.executed_steps == 24
    assert int(tr.state.step) == 24
    assert tr.straggler_dropped_steps == tr.dropped_steps   # smd off
    assert tr.straggler_dropped_steps > 4
    assert len(tr.history) == tr.executed_steps


def test_timed_chunk_instrumentation_is_invisible():
    """The timed chunk program (deadline_s > 0) only observes: with a
    deadline nothing exceeds, the loss curve, counters and params are
    bit-identical to the untimed chunked run."""
    exp = _exp("lm")
    mk = _mk(exp)
    trA = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk,
                  chunk_steps=4)
    hA = trA.run(16)
    trB = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk,
                  chunk_steps=4, deadline_s=1e9)
    hB = trB.run(16)
    assert _curve(hA) == _curve(hB)
    assert trB.straggler_dropped_steps == 0
    assert (trA.executed_steps, trA.dropped_steps) == \
        (trB.executed_steps, trB.dropped_steps)
    for a, b in zip(jax.tree.leaves(trA.state.params),
                    jax.tree.leaves(trB.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_chunked_partial_tail_chunk():
    """Window not divisible by K: the tail chunk is shorter, the counter
    and history still line up with the per-step loop."""
    exp = _exp("lm")
    mk = _mk(exp)
    trA = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk)
    hA = trA.run(10)
    trB = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk,
                  chunk_steps=4)
    hB = trB.run(10)
    assert _curve(hA) == _curve(hB)
    assert int(trB.state.step) == 10


def test_donate_chunk_state_parity():
    """Opt-in chunk-state donation (ROADMAP "chunk-jit donation").

    Donating the scanned TrainState lets XLA CPU rewrite the chunk body in
    place, which changes fusion — so the curve is NOT bit-for-bit against
    the per-step loop (the measured 4th-decimal drift documented in
    DESIGN.md §Loop is exactly why the default stays off).  What the
    opt-in DOES guarantee: same step counter, same SMD executed/dropped
    bookkeeping, and a loss curve equal to fp tolerance.

    Like the 2-device mesh test, this parity claim is for the smooth
    optimizer path (sgdm, PSG off): sign-based PSG updates turn the
    fp-level fusion drift into discrete sign flips and diverge by design."""
    import dataclasses
    exp = _exp("lm")
    exp = exp.replace(
        e2=dataclasses.replace(exp.e2, psg=PSGConfig(enabled=False)),
        train=dataclasses.replace(exp.train, optimizer="sgdm"))
    mk = _mk(exp)
    steps = 16
    trA = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk)
    hA = trA.run(steps)
    trB = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk,
                  chunk_steps=4, donate_chunk_state=True)
    hB = trB.run(steps)
    assert [s for s, _ in _curve(hA)] == [s for s, _ in _curve(hB)]
    np.testing.assert_allclose([l for _, l in _curve(hA)],
                               [l for _, l in _curve(hB)], rtol=1e-3)
    assert int(trA.state.step) == int(trB.state.step) == steps
    assert (trA.executed_steps, trA.dropped_steps) == \
        (trB.executed_steps, trB.dropped_steps)
    for a, b in zip(jax.tree.leaves(trA.state.params),
                    jax.tree.leaves(trB.state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)


def test_mesh_single_device_chunked_parity():
    """mesh=(1,1) routes through state/batch sharding + the chunked loop
    and still reproduces the per-step curve bitwise."""
    from repro.launch.mesh import make_mesh
    exp = _exp("lm")
    mk = _mk(exp)
    trA = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk)
    hA = trA.run(16)
    mesh = make_mesh((1, 1), ("data", "model"))
    trB = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk,
                  chunk_steps=4, mesh=mesh)
    hB = trB.run(16)
    assert _curve(hA) == _curve(hB)


@pytest.mark.slow
def test_mesh_two_device_data_parallel():
    """2-way data-parallel chunked training (subprocess: the suite must
    keep the default single-device runtime).  Loss curves match the
    single-device per-step loop to reduction-order tolerance, and SMD
    counts match exactly (host-side counter-based decisions)."""
    script = r"""
import dataclasses
import sys
sys.path.insert(0, "src")
import jax, numpy as np
assert jax.device_count() == 2, jax.devices()
from tests.test_loop import _exp, _mk, _curve
from repro.core.config import PSGConfig
from repro.launch.mesh import make_mesh
from repro.training.train_step import init_train_state
from repro.training.trainer import Trainer

# sgdm, PSG off: sign-based PSG updates can flip on cross-device
# reduction-order differences, which is trajectory divergence by design —
# the data-parallel parity claim is for the smooth optimizer path
exp = _exp("lm")
exp = exp.replace(
    e2=dataclasses.replace(exp.e2, psg=PSGConfig(enabled=False)),
    train=dataclasses.replace(exp.train, optimizer="sgdm"))
mk = _mk(exp)
trA = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk)
hA = trA.run(16)
mesh = make_mesh((2, 1), ("data", "model"))
trB = Trainer(exp, init_train_state(jax.random.PRNGKey(0), exp), mk,
              chunk_steps=4, mesh=mesh)
hB = trB.run(16)
assert [s for s, _ in _curve(hA)] == [s for s, _ in _curve(hB)]
np.testing.assert_allclose([l for _, l in _curve(hA)],
                           [l for _, l in _curve(hB)], rtol=2e-3)
assert (trA.executed_steps, trA.dropped_steps) == \
    (trB.executed_steps, trB.dropped_steps)
for leaf in jax.tree.leaves(trB.state.params):
    assert leaf.sharding.mesh.shape["data"] == 2
print("MESH2_OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=2")
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", script],
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))),
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MESH2_OK" in out.stdout
