"""Scanned CIFAR ResNet: parity with the kept per-block reference, and
BatchNorm running-statistic behaviour (the regression the state tree fixes).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import (E2TrainConfig, PSGConfig, SLUConfig,
                               TrainConfig)
from repro.data.synthetic import GaussianImageTask, make_image_batch
from repro.models import resnet as R

TASK = GaussianImageTask(num_classes=10, snr=2.0)


def _setup(slu_on, depth=14):
    e2 = E2TrainConfig(slu=SLUConfig(enabled=slu_on, alpha=1e-3))
    p, s = R.init_resnet(jax.random.PRNGKey(0), depth, 10, e2)
    batch = make_image_batch(TASK, 0, 0, 0, 4)
    rng = jax.random.PRNGKey(3)
    return e2, p, s, batch, rng


@pytest.mark.parametrize("slu_on", [False, True])
def test_scanned_forward_matches_reference(slu_on):
    """lax.scan over stacked block params == per-block unrolled execution:
    logits, SLU aux, and the returned BN state tree (depth 14, ~1e-5)."""
    e2, p, s, batch, rng = _setup(slu_on)
    la, aa, nsa = R.resnet_fwd(p, s, batch["image"], 14, e2, rng, train=True)
    lb, ab, nsb = R.resnet_fwd_ref(p, s, batch["image"], 14, e2, rng,
                                   train=True)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-5)
    np.testing.assert_allclose(np.asarray(aa["slu_keep_probs"]),
                               np.asarray(ab["slu_keep_probs"]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(aa["slu_executed"]),
                               np.asarray(ab["slu_executed"]), atol=0)
    assert (jax.tree_util.tree_structure(nsa) ==
            jax.tree_util.tree_structure(nsb))
    for x, y in zip(jax.tree.leaves(nsa), jax.tree.leaves(nsb)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6)


@pytest.mark.parametrize("slu_on", [False, True])
def test_scanned_grad_matches_reference(slu_on):
    """jax.grad through the scan == through the unrolled reference, for the
    full task loss (xent + SLU regularizer), SLU forced on and off."""
    e2, p, s, batch, rng = _setup(slu_on)
    ga = jax.grad(lambda p: R.resnet_loss(p, s, batch, 14, e2, rng)[0])(p)
    gb = jax.grad(lambda p: R.resnet_loss(p, s, batch, 14, e2, rng,
                                          fwd=R.resnet_fwd_ref)[0])(p)
    assert (jax.tree_util.tree_structure(ga) ==
            jax.tree_util.tree_structure(gb))
    for x, y in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=2e-5)


def test_eval_mode_matches_reference_and_uses_stored_stats():
    e2, p, s, batch, rng = _setup(False)
    la, _, nsa = R.resnet_fwd(p, s, batch["image"], 14, e2, rng, train=False)
    lb, _, nsb = R.resnet_fwd_ref(p, s, batch["image"], 14, e2, rng,
                                  train=False)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-5)
    # eval does not move the stats
    for x, y in zip(jax.tree.leaves(nsa), jax.tree.leaves(s)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# BatchNorm running statistics
# ---------------------------------------------------------------------------


def test_bn_train_steps_move_the_ema():
    """Repeated train-mode forwards converge the EMA to the batch moments."""
    e2, p, s, batch, rng = _setup(False, depth=8)
    x = batch["image"]
    stem_out = R.conv2d(p["stem"], x)          # what stem_bn normalizes
    mu_batch = np.asarray(jnp.mean(stem_out, axis=(0, 1, 2)))
    # one step moves the stem EMA off init by (1 - momentum) * mu
    _, _, s1 = R.resnet_fwd(p, s, x, 8, e2, rng, train=True)
    step1 = np.asarray(s1["stem_bn"]["mean"])
    np.testing.assert_allclose(step1, (1 - R.BN_MOMENTUM) * mu_batch,
                               atol=1e-6)
    # many steps converge it to the batch moments
    for _ in range(80):
        _, _, s = R.resnet_fwd(p, s, x, 8, e2, rng, train=True)
    np.testing.assert_allclose(np.asarray(s["stem_bn"]["mean"]), mu_batch,
                               atol=1e-4)
    var_batch = np.asarray(jnp.var(stem_out, axis=(0, 1, 2)))
    np.testing.assert_allclose(np.asarray(s["stem_bn"]["var"]), var_batch,
                               rtol=1e-2)


def test_bn_eval_uses_learned_stats_not_init():
    """Regression pin: eval normalization reads the trained EMA, not the
    init zeros/ones the old params-resident buffers were stuck at."""
    e2, p, s0, batch, rng = _setup(False, depth=8)
    x = batch["image"]
    s = s0
    for _ in range(80):
        _, _, s = R.resnet_fwd(p, s, x, 8, e2, rng, train=True)
    logits_init, _, _ = R.resnet_fwd(p, s0, x, 8, e2, rng, train=False)
    logits_ema, _, _ = R.resnet_fwd(p, s, x, 8, e2, rng, train=False)
    assert not np.allclose(np.asarray(logits_init), np.asarray(logits_ema))
    # with the EMA converged to this batch's moments, eval == train-mode
    logits_train, _, _ = R.resnet_fwd(p, s, x, 8, e2, rng, train=True)
    np.testing.assert_allclose(np.asarray(logits_ema),
                               np.asarray(logits_train), atol=1e-2)


def test_bn_stats_are_not_trainable_params():
    """Regression pin: running stats live in the state tree, NOT in params —
    the optimizer (weight decay, sign updates) can never corrupt them."""
    e2, p, s, batch, rng = _setup(True)
    flat_p = jax.tree_util.tree_flatten_with_path(p)[0]
    keys_p = {str(k) for path, _ in flat_p for k in path}
    assert "'mean'" not in str(keys_p) and "'var'" not in str(keys_p)
    flat_s = jax.tree_util.tree_flatten_with_path(s)[0]
    keys_s = {str(path) for path, _ in flat_s}
    assert any("mean" in k for k in keys_s) and any("var" in k for k in keys_s)

    # end-to-end: an aggressive-weight-decay sign-optimizer train step moves
    # every param leaf, yet the stats follow the data EMA exactly
    from repro.configs.paper_cnns import cnn_model
    from repro.core.config import Experiment
    from repro.training.train_step import init_train_state, make_train_step
    exp = Experiment(model=cnn_model("resnet14", 14),
                     e2=E2TrainConfig(psg=PSGConfig(True, swa=False)),
                     train=TrainConfig(global_batch=4, lr=0.1,
                                       optimizer="psg", weight_decay=0.5,
                                       total_steps=2, schedule="constant"),
                     task="cifar_cnn")
    st = init_train_state(jax.random.PRNGKey(0), exp)
    st2, _ = jax.jit(make_train_step(exp))(st, batch)
    var_leaves = [np.asarray(l) for l in jax.tree.leaves(
        jax.tree.map(lambda s: s["var"],
                     st2.model_state, is_leaf=lambda n: isinstance(n, dict)
                     and "var" in n))]
    assert all((v > 0).all() for v in var_leaves)
