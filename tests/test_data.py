"""Data pipeline: determinism, shard disjointness, learnable structure."""
import numpy as np
import pytest

from repro.core.config import SMDConfig
from repro.data.pipeline import DataPipeline
from repro.data.synthetic import (GaussianImageTask, MarkovLMTask,
                                  make_image_batch, make_lm_batch)


def test_lm_batch_deterministic():
    task = MarkovLMTask(vocab=64)
    a = make_lm_batch(task, 0, 3, 0, 4, 16)
    b = make_lm_batch(task, 0, 3, 0, 4, 16)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))


def test_lm_batch_shards_distinct():
    task = MarkovLMTask(vocab=64)
    a = make_lm_batch(task, 0, 3, 0, 4, 16)
    b = make_lm_batch(task, 0, 3, 1, 4, 16)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_markov_structure_learnable():
    """Labels follow the permutation with prob ~peak."""
    task = MarkovLMTask(vocab=64, peak=0.9)
    batch = make_lm_batch(task, 0, 0, 0, 32, 64)
    toks = np.asarray(batch["tokens"])
    labs = np.asarray(batch["labels"])
    perm = task.transition()
    valid = labs >= 0
    agree = (perm[toks[valid]] == labs[valid]).mean()
    assert 0.85 < agree <= 1.0


def test_image_batch_class_separation():
    task = GaussianImageTask(num_classes=4, snr=3.0)
    b = make_image_batch(task, 0, 0, 0, 64)
    imgs, labs = np.asarray(b["image"]), np.asarray(b["label"])
    means = task.means()
    # nearest-mean classification should beat chance easily at snr 3
    d = ((imgs[:, None] - 3.0 * means[None]) ** 2).sum((2, 3, 4))
    acc = (d.argmin(1) == labs).mean()
    assert acc > 0.9


def test_pipeline_prefetch_and_smd():
    task = MarkovLMTask(vocab=32)
    made = []

    def mk(step, shard):
        made.append(step)
        return make_lm_batch(task, 0, step, shard, 2, 8)

    pipe = DataPipeline(mk, SMDConfig(enabled=True, drop_prob=0.5), seed=0)
    out = [next(pipe) for _ in range(40)]
    pipe.close()
    dropped = [s for s, b in out if b is None]
    kept = [s for s, b in out if b is not None]
    assert len(dropped) + len(kept) == 40
    assert len(dropped) > 5
    assert set(made).isdisjoint(set(dropped))  # dropped never generated
