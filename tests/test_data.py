"""Data pipeline: determinism, shard disjointness, learnable structure."""
import time

import numpy as np
import pytest

from repro.core.config import SMDConfig
from repro.core.smd import SMDIterator, smd_schedule
from repro.data.pipeline import DataPipeline
from repro.data.synthetic import (GaussianImageTask, MarkovLMTask,
                                  make_image_batch, make_lm_batch)


def test_lm_batch_deterministic():
    task = MarkovLMTask(vocab=64)
    a = make_lm_batch(task, 0, 3, 0, 4, 16)
    b = make_lm_batch(task, 0, 3, 0, 4, 16)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))


def test_lm_batch_shards_distinct():
    task = MarkovLMTask(vocab=64)
    a = make_lm_batch(task, 0, 3, 0, 4, 16)
    b = make_lm_batch(task, 0, 3, 1, 4, 16)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_markov_structure_learnable():
    """Labels follow the permutation with prob ~peak."""
    task = MarkovLMTask(vocab=64, peak=0.9)
    batch = make_lm_batch(task, 0, 0, 0, 32, 64)
    toks = np.asarray(batch["tokens"])
    labs = np.asarray(batch["labels"])
    perm = task.transition()
    valid = labs >= 0
    agree = (perm[toks[valid]] == labs[valid]).mean()
    assert 0.85 < agree <= 1.0


def test_image_batch_class_separation():
    task = GaussianImageTask(num_classes=4, snr=3.0)
    b = make_image_batch(task, 0, 0, 0, 64)
    imgs, labs = np.asarray(b["image"]), np.asarray(b["label"])
    means = task.means()
    # nearest-mean classification should beat chance easily at snr 3
    d = ((imgs[:, None] - 3.0 * means[None]) ** 2).sum((2, 3, 4))
    acc = (d.argmin(1) == labs).mean()
    assert acc > 0.9


def test_pipeline_prefetch_and_smd():
    task = MarkovLMTask(vocab=32)
    made = []

    def mk(step, shard):
        made.append(step)
        return make_lm_batch(task, 0, step, shard, 2, 8)

    pipe = DataPipeline(mk, SMDConfig(enabled=True, drop_prob=0.5), seed=0)
    out = [next(pipe) for _ in range(40)]
    pipe.close()
    dropped = [s for s, b in out if b is None]
    kept = [s for s, b in out if b is not None]
    assert len(dropped) + len(kept) == 40
    assert len(dropped) > 5
    assert set(made).isdisjoint(set(dropped))  # dropped never generated


def test_pipeline_close_joins_producer():
    """Shutdown race (pinned): the producer can complete a ``put`` right
    after close() drains the queue and go on generating; close() must
    actually JOIN the thread, not just drain once."""
    mk = lambda step, shard: {"x": np.full((2,), step)}
    pipe = DataPipeline(mk, None, prefetch=1)
    time.sleep(0.3)               # producer fills the queue and parks in put
    assert pipe._thread.is_alive()
    assert pipe.close() is True   # terminated within the timeout
    assert not pipe._thread.is_alive()
    with pytest.raises(StopIteration):
        next(pipe)                # closed pipeline never blocks forever


def test_pipeline_close_mid_consumption():
    """close() while the consumer raced items off the queue still joins."""
    mk = lambda step, shard: {"x": np.full((4,), step)}
    pipe = DataPipeline(mk, None, prefetch=2)
    for _ in range(5):
        next(pipe)
    assert pipe.close() is True
    assert not pipe._thread.is_alive()


def test_pipeline_producer_exception_propagates():
    """Regression (PR 10): a make_batch exception must not die silently
    with the producer thread.  Already-generated batches are consumed
    first, then the ORIGINAL exception re-raises at the consumer call site
    within one get-timeout — instead of the consumer spinning forever on
    an empty queue."""
    def mk(step, shard):
        if step >= 3:
            raise RuntimeError("boom at step 3")
        return {"x": np.full((2,), step)}

    pipe = DataPipeline(mk, None, prefetch=2)
    got = []
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="boom at step 3"):
        for _ in range(10):
            got.append(next(pipe))
    assert time.monotonic() - t0 < 5.0          # surfaced, not a hang
    assert [s for s, _ in got] == [0, 1, 2]     # good batches drained first
    assert pipe.close() is True


def test_pipeline_injected_fault_via_raising_at_step():
    """The ft/faults injector composes with the pipeline: deterministic
    producer death at a chosen nominal step."""
    from repro.ft.faults import raising_at_step
    mk = raising_at_step(lambda s, sh: {"x": np.full((2,), s)}, 2)
    pipe = DataPipeline(mk, None, prefetch=1)
    assert next(pipe)[0] == 0
    assert next(pipe)[0] == 1
    with pytest.raises(RuntimeError, match="injected data fault"):
        next(pipe)
    pipe.close()


def test_pipeline_resume_matches_schedule_tail():
    """start_step > 0 reproduces the TAIL of smd_schedule exactly — same
    drop positions and counts — which is what makes chunked resume land on
    the same chunk layout as an uninterrupted run."""
    cfg = SMDConfig(enabled=True, drop_prob=0.5)
    seed, total, start = 7, 40, 17
    sched = smd_schedule(cfg, seed, total)
    mk = lambda step, shard: {"x": np.full((2,), step)}
    pipe = DataPipeline(mk, cfg, seed=seed, start_step=start)
    out = [next(pipe) for _ in range(total - start)]
    pipe.close()
    assert [s for s, _ in out] == list(range(start, total))
    got_kept = [b is not None for _, b in out]
    assert got_kept == [bool(k) for k in sched[start:]]
    assert sum(1 for k in got_kept if not k) == int((~sched[start:]).sum())


def test_smd_iterator_resume_matches_schedule_tail():
    """SMDIterator at start_step > 0: same tail reproduction, and the
    underlying iterator advances only on kept steps (zero-overhead drops).
    The drop count over the window equals what Trainer.dropped_steps would
    accumulate (both are counts of False entries in the same schedule)."""
    cfg = SMDConfig(enabled=True, drop_prob=0.5)
    seed, total, start = 3, 32, 9
    sched = smd_schedule(cfg, seed, total)
    consumed = []
    def src():
        i = 0
        while True:
            consumed.append(i)
            yield {"i": i}
            i += 1
    it = SMDIterator(src(), cfg, seed, start_step=start)
    out = [next(it) for _ in range(total - start)]
    assert [s for s, _ in out] == list(range(start, total))
    assert [b is not None for _, b in out] == [bool(k) for k in sched[start:]]
    kept = int(sched[start:].sum())
    assert len(consumed) == kept               # drops never touch the source
    assert (total - start) - kept == int((~sched[start:]).sum())
